"""Layer-2 correctness: the scan_stats epilogue vs a brute-force OLS
oracle, and the full compress→project→scan pipeline in pure Python.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import party_compress, scan_stats
from compile.kernels import ref


def brute_force_ols(y, x_col, c):
    """OLS of y on [x | C]; returns (beta_x, se_x)."""
    design = np.column_stack([x_col, c])
    n, k1 = design.shape
    coef, *_ = np.linalg.lstsq(design, y, rcond=None)
    resid = y - design @ coef
    df = n - k1
    sigma2 = resid @ resid / df
    cov = sigma2 * np.linalg.inv(design.T @ design)
    return coef[0], np.sqrt(cov[0, 0])


class TestScanStats:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([40, 80, 200]),
        k=st.integers(min_value=1, max_value=6),
        m=st.sampled_from([3, 11]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_brute_force(self, n, k, m, seed):
        rng = np.random.default_rng(seed)
        c = rng.normal(size=(n, k))
        c[:, 0] = 1.0
        x = rng.normal(size=(n, m))
        y = 0.5 * x[:, 0] + rng.normal(size=n)
        q, _ = np.linalg.qr(c)
        beta, se, t = scan_stats(
            float(n),
            float(k),
            float(y @ y),
            jnp.asarray(x.T @ y),
            jnp.asarray(np.sum(x * x, axis=0)),
            jnp.asarray(q.T @ y),
            jnp.asarray(q.T @ x),
        )
        for j in range(m):
            b_ref, se_ref = brute_force_ols(y, x[:, j], c)
            np.testing.assert_allclose(float(beta[j]), b_ref, rtol=1e-9)
            np.testing.assert_allclose(float(se[j]), se_ref, rtol=1e-9)

    def test_matches_ref_oracle(self):
        rng = np.random.default_rng(3)
        n, k, m = 64, 4, 32
        c = rng.normal(size=(n, k))
        x = rng.normal(size=(n, m))
        y = rng.normal(size=n)
        q, _ = np.linalg.qr(c)
        args = (
            float(n),
            float(k),
            float(y @ y),
            jnp.asarray(x.T @ y),
            jnp.asarray(np.sum(x * x, axis=0)),
            jnp.asarray(q.T @ y),
            jnp.asarray(q.T @ x),
        )
        got = scan_stats(*args)
        want = ref.scan_stats_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)

    def test_collinear_variant_is_nan(self):
        rng = np.random.default_rng(4)
        n, k = 50, 3
        c = rng.normal(size=(n, k))
        x = c[:, [1]]  # in the covariate span
        y = rng.normal(size=n)
        q, _ = np.linalg.qr(c)
        beta, se, t = scan_stats(
            float(n),
            float(k),
            float(y @ y),
            jnp.asarray(x.T @ y),
            jnp.asarray(np.sum(x * x, axis=0)),
            jnp.asarray(q.T @ y),
            jnp.asarray(q.T @ x),
        )
        assert np.isnan(float(beta[0]))
        assert np.isnan(float(se[0]))

    def test_padded_lanes_are_nan(self):
        # zero-padded variant lanes (xtx == 0) must produce NaN, which the
        # Rust runtime slices away
        n, k, m_real, m_pad = 40, 2, 5, 8
        rng = np.random.default_rng(5)
        c = rng.normal(size=(n, k))
        x = np.zeros((n, m_pad))
        x[:, :m_real] = rng.normal(size=(n, m_real))
        y = rng.normal(size=n)
        q, _ = np.linalg.qr(c)
        beta, se, t = scan_stats(
            float(n),
            float(k),
            float(y @ y),
            jnp.asarray(x.T @ y),
            jnp.asarray(np.sum(x * x, axis=0)),
            jnp.asarray(q.T @ y),
            jnp.asarray(q.T @ x),
        )
        assert np.all(np.isfinite(np.asarray(beta[:m_real])))
        assert np.all(np.isnan(np.asarray(beta[m_real:])))


class TestFullPipeline:
    def test_compress_project_scan_equals_ols(self):
        """party_compress → R-projection → scan_stats == brute force."""
        rng = np.random.default_rng(6)
        n, k, m = 120, 5, 17
        c = rng.normal(size=(n, k))
        c[:, 0] = 1.0
        x = rng.normal(size=(n, m))
        y = 0.4 * x[:, 2] + rng.normal(size=n)

        yty, cty, ctc, xty, xtx, ctx = party_compress(
            jnp.asarray(y), jnp.asarray(c), jnp.asarray(x)
        )
        # combine-stage projection from compressed stats only
        r = np.linalg.cholesky(np.asarray(ctc)).T
        qty = np.linalg.solve(r.T, np.asarray(cty))
        qtx = np.linalg.solve(r.T, np.asarray(ctx))
        beta, se, t = scan_stats(
            float(n), float(k), float(yty[0]),
            xty, xtx, jnp.asarray(qty), jnp.asarray(qtx),
        )
        for j in [0, 2, m - 1]:
            b_ref, se_ref = brute_force_ols(y, x[:, j], c)
            np.testing.assert_allclose(float(beta[j]), b_ref, rtol=1e-9)
            np.testing.assert_allclose(float(se[j]), se_ref, rtol=1e-9)

    def test_multi_party_additivity_end_to_end(self):
        """Sum of per-party compresses + Cholesky projection == pooled."""
        rng = np.random.default_rng(7)
        k, m = 4, 9
        parts = []
        for n_p in [50, 70, 30]:
            c = rng.normal(size=(n_p, k))
            c[:, 0] = 1.0
            x = rng.normal(size=(n_p, m))
            y = 0.3 * x[:, 1] + rng.normal(size=n_p)
            parts.append((y, c, x))
        comps = [
            party_compress(jnp.asarray(y), jnp.asarray(c), jnp.asarray(x))
            for (y, c, x) in parts
        ]
        agg = [sum(np.asarray(t[i]) for t in comps) for i in range(6)]
        yty, cty, ctc, xty, xtx, ctx = agg
        n = sum(len(p[0]) for p in parts)
        r = np.linalg.cholesky(ctc).T
        qty = np.linalg.solve(r.T, cty)
        qtx = np.linalg.solve(r.T, ctx)
        beta, se, t = scan_stats(
            float(n), float(k), float(yty[0]),
            jnp.asarray(xty), jnp.asarray(xtx), jnp.asarray(qty), jnp.asarray(qtx),
        )
        y_all = np.concatenate([p[0] for p in parts])
        c_all = np.vstack([p[1] for p in parts])
        x_all = np.vstack([p[2] for p in parts])
        for j in range(m):
            b_ref, se_ref = brute_force_ols(y_all, x_all[:, j], c_all)
            np.testing.assert_allclose(float(beta[j]), b_ref, rtol=1e-8)
            np.testing.assert_allclose(float(se[j]), se_ref, rtol=1e-8)
