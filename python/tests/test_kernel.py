"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
ref.py. This is the build-time gate — `make test` runs it before the Rust
suite so a kernel regression can never reach the artifacts.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.compress import compress_x_block, compress_yc_block
from compile.kernels import ref


def _data(rng, n, k, m, dtype):
    y = rng.normal(size=n).astype(dtype)
    c = rng.normal(size=(n, k)).astype(dtype)
    x = rng.normal(size=(n, m)).astype(dtype)
    return jnp.asarray(y), jnp.asarray(c), jnp.asarray(x)


TOL = {np.float32: dict(rtol=2e-5, atol=2e-5), np.float64: dict(rtol=1e-12, atol=1e-12)}


class TestCompressX:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([8, 32, 64, 128]),
        k=st.integers(min_value=1, max_value=16),
        m=st.sampled_from([1, 2, 16, 64, 128, 256]),
        dtype=st.sampled_from([np.float32, np.float64]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref(self, n, k, m, dtype, seed):
        rng = np.random.default_rng(seed)
        y, c, x = _data(rng, n, k, m, dtype)
        got = compress_x_block(y, c, x)
        want = ref.compress_x_ref(y, c, x)
        for g, w, name in zip(got, want, ["xty", "xtx", "ctx"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), err_msg=name, **TOL[dtype]
            )

    def test_multi_tile_grid(self):
        # m > tile_m exercises the grid index_map
        rng = np.random.default_rng(7)
        y, c, x = _data(rng, 64, 4, 512, np.float64)
        got = compress_x_block(y, c, x, tile_m=128)
        want = ref.compress_x_ref(y, c, x)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)

    def test_zero_padding_rows_is_exact(self):
        # zero sample rows contribute nothing — the property the Rust
        # runtime relies on when padding the tail sample block
        rng = np.random.default_rng(8)
        y, c, x = _data(rng, 48, 3, 16, np.float64)
        pad = 16
        yp = jnp.concatenate([y, jnp.zeros(pad)])
        cp = jnp.concatenate([c, jnp.zeros((pad, 3))])
        xp = jnp.concatenate([x, jnp.zeros((pad, 16))])
        got = compress_x_block(yp, cp, xp)
        want = ref.compress_x_ref(y, c, x)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)

    def test_zero_padding_covariate_columns(self):
        # zero C columns produce zero CᵀX rows (slice-away property)
        rng = np.random.default_rng(9)
        y, c, x = _data(rng, 32, 3, 8, np.float64)
        cp = jnp.concatenate([c, jnp.zeros((32, 5))], axis=1)
        _, _, ctx = compress_x_block(y, cp, x)
        np.testing.assert_allclose(np.asarray(ctx[3:]), 0.0)
        want = ref.compress_x_ref(y, c, x)[2]
        np.testing.assert_allclose(np.asarray(ctx[:3]), np.asarray(want), rtol=1e-12)

    def test_genotype_dosages(self):
        # integer dosages 0/1/2 are exactly representable — results exact
        rng = np.random.default_rng(10)
        x = rng.integers(0, 3, size=(128, 64)).astype(np.float64)
        y = rng.normal(size=128)
        c = rng.normal(size=(128, 4))
        got = compress_x_block(jnp.asarray(y), jnp.asarray(c), jnp.asarray(x))
        want = ref.compress_x_ref(jnp.asarray(y), jnp.asarray(c), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=0, atol=0)


class TestCompressYC:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([4, 16, 64, 512]),
        k=st.integers(min_value=1, max_value=16),
        dtype=st.sampled_from([np.float32, np.float64]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_matches_ref(self, n, k, dtype, seed):
        rng = np.random.default_rng(seed)
        y, c, _ = _data(rng, n, k, 1, dtype)
        got = compress_yc_block(y, c)
        want = ref.compress_yc_ref(y, c)
        for g, w, name in zip(got, want, ["yty", "cty", "ctc"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), err_msg=name, **TOL[dtype]
            )

    def test_ctc_symmetric(self):
        rng = np.random.default_rng(11)
        y, c, _ = _data(rng, 64, 8, 1, np.float64)
        _, _, ctc = compress_yc_block(y, c)
        np.testing.assert_allclose(np.asarray(ctc), np.asarray(ctc).T, rtol=1e-12)


class TestAdditivity:
    """The property the whole distributed design rests on: compress of a
    concatenation equals the sum of per-block compresses."""

    @settings(max_examples=15, deadline=None)
    @given(
        n1=st.sampled_from([8, 32, 64]),
        n2=st.sampled_from([8, 16, 128]),
        k=st.integers(min_value=1, max_value=8),
        m=st.sampled_from([4, 32]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_sample_block_additivity(self, n1, n2, k, m, seed):
        rng = np.random.default_rng(seed)
        y1, c1, x1 = _data(rng, n1, k, m, np.float64)
        y2, c2, x2 = _data(rng, n2, k, m, np.float64)
        y = jnp.concatenate([y1, y2])
        c = jnp.concatenate([c1, c2])
        x = jnp.concatenate([x1, x2])
        whole = compress_x_block(y, c, x)
        p1 = compress_x_block(y1, c1, x1)
        p2 = compress_x_block(y2, c2, x2)
        for w, a, b in zip(whole, p1, p2):
            np.testing.assert_allclose(
                np.asarray(w), np.asarray(a) + np.asarray(b), rtol=1e-11, atol=1e-11
            )
