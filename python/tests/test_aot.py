"""AOT pipeline: lowering round-trip and manifest integrity.

Executes the lowered HLO back through the XLA client (the same
compile-and-run path the Rust runtime uses) and checks numerics against
the live-JAX outputs — catching any divergence between the artifact and
the model before Rust ever sees it.
"""

import json

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import lower_all, to_hlo_text
from compile.model import ENTRY_FNS, make_specs

N_B, K_PAD, M_B = 64, 8, 32  # small variant for fast tests


def test_lower_all_produces_all_entries():
    texts = lower_all(N_B, K_PAD, M_B, widths=(16,), trait_batches=(1, 2))
    # legacy trio plus the parameterized suite for the given ladders
    want = set(ENTRY_FNS) | {
        "compress_xy.t1",
        "compress_xy.t2",
        "compress_x.w16.t1",
        "compress_x.w16.t2",
        "select_gather.h16",
    }
    assert set(texts) == want
    for name, text in texts.items():
        assert text.startswith("HloModule"), name
        assert "f64" in text, f"{name} must be lowered in f64"


def test_hlo_text_reparses():
    """The text must round-trip through the HLO parser — the same parser
    family the Rust side's HloModuleProto::from_text_file uses (which
    reassigns instruction ids; execution numerics are verified by the
    Rust integration tests against this module's live-JAX outputs)."""
    texts = lower_all(N_B, K_PAD, M_B, widths=(16,), trait_batches=(2,))
    for name, text in texts.items():
        module = xc._xla.hlo_module_from_text(text)
        reparsed = module.to_string()
        assert "ENTRY" in reparsed, name
        # proto serialization must succeed (what the Rust loader consumes)
        assert len(module.as_serialized_hlo_module_proto()) > 0, name


def test_suite_entries_match_reference_numerics():
    """The trait-batched / gathered entries compute the same statistics
    as the single-trait reference oracles, trait by trait."""
    from compile.model import compress_x_batched, compress_xy_batched, select_gather

    rng = np.random.default_rng(7)
    n, k, w, t = 48, 5, 12, 3
    ys = jnp.asarray(rng.normal(size=(n, t)))
    c = jnp.asarray(rng.normal(size=(n, k)))
    x = jnp.asarray(rng.normal(size=(n, w)))
    yty, cty, ctc = compress_xy_batched(ys, c)
    xty, xtx, ctx = compress_x_batched(ys, c, x)
    for tt in range(t):
        y = ys[:, tt]
        ryty, rcty, rctc = [np.asarray(v) for v in (jnp.sum(y * y), c.T @ y, c.T @ c)]
        rxty, rxtx, rctx = [np.asarray(v) for v in (x.T @ y, jnp.sum(x * x, axis=0), c.T @ x)]
        np.testing.assert_allclose(np.asarray(yty)[tt], ryty, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(cty)[:, tt], rcty, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ctc), rctc, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(xty)[:, tt], rxty, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(xtx), rxtx, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ctx), rctx, rtol=1e-12)
    (v,) = select_gather(x[:, 2], x)
    np.testing.assert_allclose(np.asarray(v), np.asarray(x.T @ x[:, 2]), rtol=1e-12)


def test_compress_x_entry_layout():
    """Entry computation signature matches the manifest contract the Rust
    runtime is written against."""
    texts = lower_all(N_B, K_PAD, M_B, widths=(), trait_batches=())
    head = texts["compress_x"].splitlines()[0]
    assert f"f64[{N_B}]" in head  # y
    assert f"f64[{N_B},{K_PAD}]" in head  # c
    assert f"f64[{N_B},{M_B}]" in head  # x
    assert f"f64[{M_B}]" in head  # xty/xtx out
    assert f"f64[{K_PAD},{M_B}]" in head  # ctx out


def test_scan_stats_entry_layout():
    texts = lower_all(N_B, K_PAD, M_B, widths=(), trait_batches=())
    head = texts["scan_stats"].splitlines()[0]
    # three scalars + (M,) + (M,) + (K,) + (K,M) inputs
    assert head.count("f64[]") >= 3
    assert f"f64[{K_PAD},{M_B}]" in head
    # outputs: three (M,) vectors
    assert f"(f64[{M_B}]{{0}}, f64[{M_B}]{{0}}, f64[{M_B}]{{0}})" in head


def test_specs_match_entry_signatures():
    specs = make_specs(N_B, K_PAD, M_B)
    assert set(specs) == set(ENTRY_FNS)
    # lowering with the specs must succeed for each entry
    for name, fn in ENTRY_FNS.items():
        jax.jit(fn).lower(*specs[name])


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "arts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(out),
            "--n-block",
            "32",
            "--m-block",
            "16",
            "--k-pad",
            "4",
            "--widths",
            "16",
            "--trait-batches",
            "1,2",
        ],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["n_block"] == 32
    assert manifest["m_block"] == 16
    assert manifest["k_pad"] == 4
    assert manifest["widths"] == [16]
    assert manifest["trait_batches"] == [1, 2]
    assert "compress_x.w16.t2" in manifest["entries"]
    assert "select_gather.h16" in manifest["entries"]
    for fname in manifest["entries"].values():
        text = (out / fname).read_text()
        assert text.startswith("HloModule")
