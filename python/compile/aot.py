"""AOT lowering: JAX entry points → HLO text artifacts + manifest.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which
the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py there.

Run once at build time (``make artifacts``); the Rust binary is
self-contained afterwards. The manifest records block shapes so the Rust
runtime can pad/slice without re-deriving conventions.

Usage: python -m compile.aot --out ../artifacts [--n-block 512]
       [--m-block 256] [--k-pad 16]
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # statistics in f64, matching L3

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .model import entry_fn_for, make_specs  # noqa: E402

# Default ShapePolicy ladders, mirrored from rust/src/runtime/kernels.rs.
DEFAULT_WIDTHS = (64, 256, 1024, 4096)
DEFAULT_TRAIT_BATCHES = (1, 4, 16, 64)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-renumbering path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(n_block: int, k_pad: int, m_block: int,
              widths=DEFAULT_WIDTHS, trait_batches=DEFAULT_TRAIT_BATCHES):
    """Lower every entry point (legacy trio + parameterized suite);
    returns {name: hlo_text}."""
    specs = make_specs(n_block, k_pad, m_block, dtype=jnp.float64,
                       widths=widths, trait_batches=trait_batches)
    out = {}
    for name, spec in specs.items():
        lowered = jax.jit(entry_fn_for(name)).lower(*spec)
        out[name] = to_hlo_text(lowered)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--n-block", type=int, default=512)
    ap.add_argument("--m-block", type=int, default=256)
    ap.add_argument("--k-pad", type=int, default=16)
    ap.add_argument("--widths", default=",".join(map(str, DEFAULT_WIDTHS)),
                    help="canonical shard-width ladder (CSV) for the suite")
    ap.add_argument("--trait-batches",
                    default=",".join(map(str, DEFAULT_TRAIT_BATCHES)),
                    help="canonical trait-batch ladder (CSV) for the suite")
    args = ap.parse_args()

    widths = tuple(int(w) for w in args.widths.split(","))
    trait_batches = tuple(int(t) for t in args.trait_batches.split(","))
    os.makedirs(args.out, exist_ok=True)
    texts = lower_all(args.n_block, args.k_pad, args.m_block,
                      widths=widths, trait_batches=trait_batches)

    entries = {}
    for name, text in texts.items():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        entries[name] = fname
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 2,
        "dtype": "f64",
        "n_block": args.n_block,
        "m_block": args.m_block,
        "k_pad": args.k_pad,
        "widths": list(widths),
        "trait_batches": list(trait_batches),
        "entries": entries,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
