"""Layer-2 JAX model: the compress-within statistics and the Lemma 3.1
epilogue, composed from the Layer-1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text; the Rust runtime
executes them per (sample-block × variant-block) tile and accumulates.
Everything is shape-static; the Rust side zero-pads tails (exact, since
all outputs are sums of per-sample products — zero rows contribute zero)
and slices away covariate padding (zero columns of C produce zero rows of
CᵀX / zero rows+cols of CᵀC, which the combine stage drops before
factorization).
"""

import jax
import jax.numpy as jnp

from .kernels.compress import compress_x_block, compress_yc_block


def party_compress(y, c, x):
    """Full compress of one (sample-block, variant-block) tile.

    Args:
      y: (N_b,) response block.
      c: (N_b, K) permanent covariates.
      x: (N_b, M_b) transient covariates (variants).

    Returns a 6-tuple of additive partial statistics:
      yty (1,), cty (K,), ctc (K, K), xty (M_b,), xtx (M_b,), ctx (K, M_b).
    """
    yty, cty, ctc = compress_yc_block(y, c)
    xty, xtx, ctx = compress_x_block(y, c, x)
    return yty, cty, ctc, xty, xtx, ctx


def compress_x_only(y, c, x):
    """X-side compress only (used when streaming variant blocks: the
    covariate-side statistics are accumulated once per sample block)."""
    return compress_x_block(y, c, x)


def compress_yc_only(y, c):
    """Covariate-side compress only."""
    return compress_yc_block(y, c)


def scan_stats(n, k, yty, xty, xtx, qty, qtx):
    """Lemma 3.1 epilogue on aggregates (vectorized over M).

    β̂  = (X·y − QᵀX·Qᵀy) / (X·X − QᵀX·QᵀX)
    σ̂² = ((y·y − Qᵀy·Qᵀy)/(X·X − QᵀX·QᵀX) − β̂²) / (N−K−1)

    Args:
      n, k: scalars (float) — sample count and covariate count.
      yty: scalar aggregate yᵀy.
      xty, xtx: (M_b,) aggregates.
      qty: (K,) = R⁻ᵀ(Cᵀy).
      qtx: (K, M_b) = R⁻ᵀ(CᵀX).

    Returns (beta, se, tstat), each (M_b,); NaN where the variant is in
    the covariate span (denominator ≈ 0, incl. padded lanes).
    """
    df = n - k - 1.0
    qx_qy = qtx.T @ qty
    qx_qx = jnp.sum(qtx * qtx, axis=0)
    denom = xtx - qx_qx
    yy_resid = yty - jnp.sum(qty * qty)
    eps = 1e-12 * jnp.maximum(jnp.abs(xtx), 1.0)
    ok = denom > eps
    safe = jnp.where(ok, denom, 1.0)
    beta = jnp.where(ok, (xty - qx_qy) / safe, jnp.nan)
    sigma2 = jnp.where(ok, (yy_resid / safe - beta * beta) / df, jnp.nan)
    se = jnp.sqrt(jnp.maximum(sigma2, 0.0))
    tstat = jnp.where(se > 0.0, beta / se, jnp.inf)
    return beta, se, tstat


def compress_xy_batched(ys, c):
    """Trait-batched covariate-side entry (`compress_xy.t{T}`).

    Args:
      ys: (N_b, T) trait-column block.
      c: (N_b, K) permanent covariates.

    Returns additive partials: yty (T,), cty (K, T), ctc (K, K).
    One Y-side pass covers every trait; the Rust runtime accumulates
    across sample blocks (zero-padded trait lanes contribute zero and
    are sliced away).
    """
    yty = jnp.sum(ys * ys, axis=0)
    cty = c.T @ ys
    ctc = c.T @ c
    return yty, cty, ctc


def compress_x_batched(ys, c, x):
    """Shard-width / trait-batched variant-side entry
    (`compress_x.w{W}.t{T}`).

    Args:
      ys: (N_b, T) trait-column block.
      c: (N_b, K) permanent covariates.
      x: (N_b, W) one variant shard (canonical width, zero-padded tail).

    Returns additive partials: xty (W, T), xtx (W,), ctx (K, W) — one
    X-side pass amortized across all T traits.
    """
    xty = x.T @ ys
    xtx = jnp.sum(x * x, axis=0)
    ctx = c.T @ x
    return xty, xtx, ctx


def select_gather(xj, xs):
    """Gathered-columns SELECT entry (`select_gather.h{H}`): one promoted
    column's cross-products against the H shortlisted columns.

    Args:
      xj: (N_b,) the promoted variant column.
      xs: (N_b, H) gathered shortlist block (canonical width).

    Returns (v,): v (H,) = xjᵀ X_S.
    """
    return (xs.T @ xj,)


def make_specs(n_block, k_pad, m_block, dtype=jnp.float64,
               widths=(), trait_batches=()):
    """ShapeDtypeStructs for each AOT entry point.

    The legacy fixed trio is always present; pass ``widths`` and
    ``trait_batches`` (the ShapePolicy ladders) to add the parameterized
    suite entries keyed ``compress_xy.t{T}`` / ``compress_x.w{W}.t{T}`` /
    ``select_gather.h{H}``.
    """
    f = lambda *shape: jax.ShapeDtypeStruct(shape, dtype)
    specs = {
        "compress_x": (f(n_block), f(n_block, k_pad), f(n_block, m_block)),
        "compress_yc": (f(n_block), f(n_block, k_pad)),
        "scan_stats": (
            f(), f(), f(),                   # n, k, yty scalars
            f(m_block), f(m_block),          # xty, xtx
            f(k_pad), f(k_pad, m_block),     # qty, qtx
        ),
    }
    for t in trait_batches:
        specs[f"compress_xy.t{t}"] = (f(n_block, t), f(n_block, k_pad))
        for w in widths:
            specs[f"compress_x.w{w}.t{t}"] = (
                f(n_block, t), f(n_block, k_pad), f(n_block, w),
            )
    for w in widths:
        specs[f"select_gather.h{w}"] = (f(n_block), f(n_block, w))
    return specs


ENTRY_FNS = {
    "compress_x": compress_x_only,
    "compress_yc": compress_yc_only,
    "scan_stats": scan_stats,
}


def entry_fn_for(name):
    """Entry function for a (possibly parameterized) entry name."""
    if name in ENTRY_FNS:
        return ENTRY_FNS[name]
    if name.startswith("compress_xy.t"):
        return compress_xy_batched
    if name.startswith("compress_x.w"):
        return compress_x_batched
    if name.startswith("select_gather.h"):
        return select_gather
    raise KeyError(f"unknown entry {name!r}")
