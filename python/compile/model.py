"""Layer-2 JAX model: the compress-within statistics and the Lemma 3.1
epilogue, composed from the Layer-1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text; the Rust runtime
executes them per (sample-block × variant-block) tile and accumulates.
Everything is shape-static; the Rust side zero-pads tails (exact, since
all outputs are sums of per-sample products — zero rows contribute zero)
and slices away covariate padding (zero columns of C produce zero rows of
CᵀX / zero rows+cols of CᵀC, which the combine stage drops before
factorization).
"""

import jax
import jax.numpy as jnp

from .kernels.compress import compress_x_block, compress_yc_block


def party_compress(y, c, x):
    """Full compress of one (sample-block, variant-block) tile.

    Args:
      y: (N_b,) response block.
      c: (N_b, K) permanent covariates.
      x: (N_b, M_b) transient covariates (variants).

    Returns a 6-tuple of additive partial statistics:
      yty (1,), cty (K,), ctc (K, K), xty (M_b,), xtx (M_b,), ctx (K, M_b).
    """
    yty, cty, ctc = compress_yc_block(y, c)
    xty, xtx, ctx = compress_x_block(y, c, x)
    return yty, cty, ctc, xty, xtx, ctx


def compress_x_only(y, c, x):
    """X-side compress only (used when streaming variant blocks: the
    covariate-side statistics are accumulated once per sample block)."""
    return compress_x_block(y, c, x)


def compress_yc_only(y, c):
    """Covariate-side compress only."""
    return compress_yc_block(y, c)


def scan_stats(n, k, yty, xty, xtx, qty, qtx):
    """Lemma 3.1 epilogue on aggregates (vectorized over M).

    β̂  = (X·y − QᵀX·Qᵀy) / (X·X − QᵀX·QᵀX)
    σ̂² = ((y·y − Qᵀy·Qᵀy)/(X·X − QᵀX·QᵀX) − β̂²) / (N−K−1)

    Args:
      n, k: scalars (float) — sample count and covariate count.
      yty: scalar aggregate yᵀy.
      xty, xtx: (M_b,) aggregates.
      qty: (K,) = R⁻ᵀ(Cᵀy).
      qtx: (K, M_b) = R⁻ᵀ(CᵀX).

    Returns (beta, se, tstat), each (M_b,); NaN where the variant is in
    the covariate span (denominator ≈ 0, incl. padded lanes).
    """
    df = n - k - 1.0
    qx_qy = qtx.T @ qty
    qx_qx = jnp.sum(qtx * qtx, axis=0)
    denom = xtx - qx_qx
    yy_resid = yty - jnp.sum(qty * qty)
    eps = 1e-12 * jnp.maximum(jnp.abs(xtx), 1.0)
    ok = denom > eps
    safe = jnp.where(ok, denom, 1.0)
    beta = jnp.where(ok, (xty - qx_qy) / safe, jnp.nan)
    sigma2 = jnp.where(ok, (yy_resid / safe - beta * beta) / df, jnp.nan)
    se = jnp.sqrt(jnp.maximum(sigma2, 0.0))
    tstat = jnp.where(se > 0.0, beta / se, jnp.inf)
    return beta, se, tstat


def make_specs(n_block, k_pad, m_block, dtype=jnp.float64):
    """ShapeDtypeStructs for each AOT entry point."""
    f = lambda *shape: jax.ShapeDtypeStruct(shape, dtype)
    return {
        "compress_x": (f(n_block), f(n_block, k_pad), f(n_block, m_block)),
        "compress_yc": (f(n_block), f(n_block, k_pad)),
        "scan_stats": (
            f(), f(), f(),                   # n, k, yty scalars
            f(m_block), f(m_block),          # xty, xtx
            f(k_pad), f(k_pad, m_block),     # qty, qtx
        ),
    }


ENTRY_FNS = {
    "compress_x": compress_x_only,
    "compress_yc": compress_yc_only,
    "scan_stats": scan_stats,
}
