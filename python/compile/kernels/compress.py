"""Layer-1 Pallas kernel: blocked compress of the transient-covariate block.

The compute hot spot of the paper is the compress-within stage's
cross-products against the variant block (`O(N K M)` of the total
`O(N K (K + M))`):

    xty = Xᵀy        (M_b,)
    xtx = Σ_i X²     (M_b,)   — per-variant dot products X_m · X_m
    ctx = CᵀX        (K, M_b)

This kernel tiles the variant dimension: grid step ``j`` loads an
``(N_b, T_M)`` tile of X plus the full ``(N_b,)`` response and ``(N_b, K)``
covariate block into VMEM and emits the three partial products. On TPU
the ``c_ref.T @ x_ref`` contraction maps onto the MXU with bf16/f32
accumulation; the sample dimension is streamed by the caller (Rust runtime
accumulates across sample blocks, so zero-padding the tail block is
exact — every output is a sum of per-sample products).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
commodity CPU clusters via Hail/Spark; the TPU mapping expresses the same
schedule a GPU version would express with threadblocks — HBM→VMEM tiles
via BlockSpec, MXU for the rank-K update, VMEM budget
``T_M·(N_b + K + 3) · 8B ≈ 1.1 MiB`` at the default
``N_b=512, T_M=128, K=16`` (fits the ~16 MiB VMEM with double-buffering
headroom).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO ops with identical
numerics (validated against :mod:`ref` by pytest).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile width along the variant dimension. 128 lanes matches the
# TPU vector-register lane count and divides the default M_b=256.
DEFAULT_TILE_M = 128


def _compress_x_kernel(y_ref, c_ref, x_ref, xty_ref, xtx_ref, ctx_ref):
    """One grid step: cross-products of an (N_b, T_M) X-tile.

    y_ref: (N_b, 1)     — response column
    c_ref: (N_b, K)     — permanent covariates
    x_ref: (N_b, T_M)   — variant tile
    xty_ref: (T_M,)     — out: Xᵀy
    xtx_ref: (T_M,)     — out: per-column squared norms
    ctx_ref: (K, T_M)   — out: CᵀX
    """
    x = x_ref[...]
    y = y_ref[...]  # (N_b, 1)
    c = c_ref[...]
    # Xᵀy — contraction over samples; (T_M,)
    xty_ref[...] = jnp.sum(x * y, axis=0)
    # per-variant squared norm; (T_M,)
    xtx_ref[...] = jnp.sum(x * x, axis=0)
    # CᵀX — the MXU matmul: (K, N_b) @ (N_b, T_M)
    ctx_ref[...] = jnp.dot(c.T, x, preferred_element_type=x.dtype)


@partial(jax.jit, static_argnames=("tile_m",))
def compress_x_block(y, c, x, *, tile_m=DEFAULT_TILE_M):
    """Compress one (sample-block × variant-block) tile of X.

    Args:
      y: (N_b,) response block.
      c: (N_b, K) covariate block.
      x: (N_b, M_b) variant block; M_b must be a multiple of ``tile_m``.

    Returns:
      (xty, xtx, ctx) with shapes ((M_b,), (M_b,), (K, M_b)).
    """
    n_b, m_b = x.shape
    k = c.shape[1]
    tile_m = min(tile_m, m_b)
    if m_b % tile_m != 0:
        raise ValueError(f"M_b={m_b} not a multiple of tile_m={tile_m}")
    grid = (m_b // tile_m,)
    return pl.pallas_call(
        _compress_x_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_b, 1), lambda j: (0, 0)),        # y: reused each step
            pl.BlockSpec((n_b, k), lambda j: (0, 0)),        # C: reused each step
            pl.BlockSpec((n_b, tile_m), lambda j: (0, j)),   # X: streamed by tile
        ],
        out_specs=[
            pl.BlockSpec((tile_m,), lambda j: (j,)),
            pl.BlockSpec((tile_m,), lambda j: (j,)),
            pl.BlockSpec((k, tile_m), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_b,), x.dtype),
            jax.ShapeDtypeStruct((m_b,), x.dtype),
            jax.ShapeDtypeStruct((k, m_b), x.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(y.reshape(n_b, 1), c, x)


def _compress_yc_kernel(y_ref, c_ref, yty_ref, cty_ref, ctc_ref):
    """Covariate-side compress: yᵀy, Cᵀy, CᵀC for one sample block."""
    y = y_ref[...]  # (N_b, 1)
    c = c_ref[...]
    yty_ref[...] = jnp.sum(y * y).reshape(1)
    cty_ref[...] = jnp.dot(c.T, y, preferred_element_type=c.dtype)[:, 0]
    ctc_ref[...] = jnp.dot(c.T, c, preferred_element_type=c.dtype)

@jax.jit
def compress_yc_block(y, c):
    """Compress the covariate side of one sample block.

    Returns (yty(1,), cty(K,), ctc(K,K)); additive over sample blocks.
    """
    n_b = y.shape[0]
    k = c.shape[1]
    return pl.pallas_call(
        _compress_yc_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((1,), y.dtype),
            jax.ShapeDtypeStruct((k,), y.dtype),
            jax.ShapeDtypeStruct((k, k), y.dtype),
        ],
        interpret=True,
    )(y.reshape(n_b, 1), c)
