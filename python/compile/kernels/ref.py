# Pure-jnp correctness oracles for the Pallas kernels and the L2 model.
# pytest asserts kernel == ref to tight tolerances — the CORE correctness
# signal for Layer 1 (see python/tests/test_kernel.py).

import jax.numpy as jnp


def compress_x_ref(y, c, x):
    """Reference for kernels.compress.compress_x_block."""
    xty = x.T @ y
    xtx = jnp.sum(x * x, axis=0)
    ctx = c.T @ x
    return xty, xtx, ctx


def compress_yc_ref(y, c):
    """Reference for kernels.compress.compress_yc_block."""
    yty = jnp.sum(y * y).reshape(1)
    cty = c.T @ y
    ctc = c.T @ c
    return yty, cty, ctc


def scan_stats_ref(n, k, yty, xty, xtx, qty, qtx):
    """Reference for the Lemma 3.1 epilogue (model.scan_stats).

    All inputs are aggregates; padded variants (denominator ≈ 0) yield NaN.
    n, k are scalars (float); qtx is (K, M); returns (beta, se, tstat).
    """
    df = n - k - 1.0
    qx_qy = qtx.T @ qty                      # (M,)
    qx_qx = jnp.sum(qtx * qtx, axis=0)       # (M,)
    denom = xtx - qx_qx
    yy_resid = yty - jnp.sum(qty * qty)
    eps = 1e-12 * jnp.maximum(jnp.abs(xtx), 1.0)
    ok = denom > eps
    safe = jnp.where(ok, denom, 1.0)
    beta = jnp.where(ok, (xty - qx_qy) / safe, jnp.nan)
    sigma2 = jnp.where(ok, (yy_resid / safe - beta * beta) / df, jnp.nan)
    se = jnp.sqrt(jnp.maximum(sigma2, 0.0))
    tstat = jnp.where(se > 0.0, beta / se, jnp.inf)
    return beta, se, tstat
