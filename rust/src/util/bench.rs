//! Micro/meso benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets are plain binaries (`harness = false`) built on
//! this module. For each case we warm up, choose an iteration count that
//! fills a target measurement window, collect per-iteration wall times,
//! and report median, MAD, and throughput. Output is both a human table
//! and machine-readable JSON lines (consumed by EXPERIMENTS.md tooling).

use std::time::Instant;

/// One measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// median seconds per iteration
    pub median_s: f64,
    /// median absolute deviation, seconds
    pub mad_s: f64,
    pub iters: usize,
    /// optional user-supplied work units per iteration (elements, bytes…)
    pub units: Option<f64>,
    pub unit_name: &'static str,
}

impl Measurement {
    /// Work units per second (if `units` set).
    pub fn throughput(&self) -> Option<f64> {
        self.units.map(|u| u / self.median_s)
    }
}

/// Benchmark runner with a shared report.
pub struct Bench {
    pub group: String,
    pub warmup_s: f64,
    pub target_s: f64,
    pub max_iters: usize,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // Quick mode for CI: DASH_BENCH_QUICK=1 shrinks windows ~10x.
        let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
        Bench {
            group: group.to_string(),
            warmup_s: if quick { 0.05 } else { 0.3 },
            target_s: if quick { 0.2 } else { 1.5 },
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs one iteration of the case.
    pub fn case<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.case_units(name, None, "", f)
    }

    /// Time `f` and report throughput in `units` per second.
    pub fn case_units<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<f64>,
        unit_name: &'static str,
        mut f: F,
    ) -> &Measurement {
        // Warmup and single-shot estimate.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let mut spent = once;
        while spent < self.warmup_s {
            f();
            spent += once;
        }
        // Choose iteration count to fill the target window.
        let iters = ((self.target_s / once).ceil() as usize).clamp(3, self.max_iters);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        let m = Measurement {
            name: name.to_string(),
            median_s: median,
            mad_s: mad,
            iters,
            units,
            unit_name,
        };
        self.print_row(&m);
        self.results.push(m);
        self.results.last().unwrap()
    }

    fn print_row(&self, m: &Measurement) {
        let tp = match m.throughput() {
            Some(t) if t >= 1e9 => format!("  {:>8.2} G{}/s", t / 1e9, m.unit_name),
            Some(t) if t >= 1e6 => format!("  {:>8.2} M{}/s", t / 1e6, m.unit_name),
            Some(t) if t >= 1e3 => format!("  {:>8.2} K{}/s", t / 1e3, m.unit_name),
            Some(t) => format!("  {:>8.2} {}/s", t, m.unit_name),
            None => String::new(),
        };
        println!(
            "{:<52} {:>12} ± {:>10}  ({} iters){}",
            format!("{}/{}", self.group, m.name),
            crate::util::human_secs(m.median_s),
            crate::util::human_secs(m.mad_s),
            m.iters,
            tp
        );
    }

    /// Emit JSON-lines records for all cases (one per line).
    pub fn json_lines(&self) -> String {
        use crate::util::json::Json;
        let mut out = String::new();
        for m in &self.results {
            let mut o = Json::obj();
            o.set("group", self.group.as_str())
                .set("name", m.name.as_str())
                .set("median_s", m.median_s)
                .set("mad_s", m.mad_s)
                .set("iters", m.iters);
            if let Some(u) = m.units {
                o.set("units", u).set("unit_name", m.unit_name);
            }
            out.push_str(&o.to_string());
            out.push('\n');
        }
        out
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write the JSON-lines report under `target/bench-reports/`.
    pub fn save_report(&self) {
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.jsonl", self.group.replace('/', "_")));
        self.save_report_to(path.to_str().unwrap_or("bench-report.jsonl"));
    }

    /// Write the JSON-lines report to an explicit path (e.g. the
    /// `BENCH_*.json` files consumed by EXPERIMENTS.md tooling).
    pub fn save_report_to(&self, path: &str) {
        if let Err(e) = std::fs::write(path, self.json_lines()) {
            eprintln!("warn: could not write {path}: {e}");
        } else {
            println!("report: {path}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("DASH_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let m = b
            .case("spin", || {
                let mut x = 0u64;
                for i in 0..1000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            })
            .clone();
        assert!(m.median_s > 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn throughput_computed() {
        std::env::set_var("DASH_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let m = b
            .case_units("units", Some(1000.0), "elem", || {
                std::hint::black_box((0..1000u64).sum::<u64>());
            })
            .clone();
        assert!(m.throughput().unwrap() > 0.0);
        let jl = b.json_lines();
        assert!(jl.contains("\"units\""));
    }
}
