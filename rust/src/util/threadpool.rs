//! Scoped data-parallel helpers (no `rayon`/`tokio` offline).
//!
//! The compress stage parallelizes over column blocks within a party
//! (the paper's `O(NKM/C)` term). [`parallel_for_chunks`] slices an index
//! range into contiguous chunks and runs them on `std::thread::scope`
//! threads; [`parallel_map`] is the collect-results variant. Thread count
//! defaults to available parallelism and is overridable for the E2 core
//! sweep (`DASH_THREADS` or explicit argument).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: explicit `n`, else `DASH_THREADS`,
/// else `std::thread::available_parallelism()`.
pub fn effective_threads(n: Option<usize>) -> usize {
    if let Some(n) = n {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("DASH_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(start, end)` over disjoint chunks of `0..len` on up to
/// `threads` workers. Work is distributed dynamically (atomic cursor over
/// fixed-size chunks) so uneven block costs balance out.
///
/// A panic in `f` on any worker short-circuits the remaining chunks and
/// is re-raised on the calling thread with its original payload — never
/// a silent partial result, never the anonymous "a scoped thread
/// panicked" abort from `std::thread::scope`.
pub fn parallel_for_chunks<F>(len: usize, chunk: usize, threads: Option<usize>, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    assert!(chunk > 0);
    let nthreads = effective_threads(threads).min(len.div_ceil(chunk).max(1));
    if len == 0 {
        return;
    }
    if nthreads <= 1 {
        // serial path: panics unwind to the caller naturally
        let mut s = 0;
        while s < len {
            f(s, (s + chunk).min(len));
            s += chunk;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            scope.spawn(|| loop {
                let s = cursor.fetch_add(chunk, Ordering::Relaxed);
                if s >= len {
                    break;
                }
                if let Err(payload) =
                    catch_unwind(AssertUnwindSafe(|| f(s, (s + chunk).min(len))))
                {
                    // park the cursor past the end so every worker stops
                    // handing out chunks, keep the first payload
                    cursor.store(len, Ordering::Relaxed);
                    let mut slot = panic_payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    break;
                }
            });
        }
    });
    if let Some(payload) = panic_payload.into_inner().unwrap() {
        resume_unwind(payload);
    }
}

/// Parallel map over `0..n` producing a `Vec<T>` in index order.
pub fn parallel_map<T, F>(n: usize, threads: Option<usize>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = as_send_cells(&mut out);
        parallel_for_chunks(n, 1, threads, |s, e| {
            for i in s..e {
                // SAFETY: each index is written by exactly one chunk.
                unsafe { *slots.get(i) = Some(f(i)) };
            }
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Helper granting disjoint-index mutable access across threads.
struct SendCells<T>(*mut T, usize);
unsafe impl<T: Send> Sync for SendCells<T> {}
impl<T> SendCells<T> {
    /// SAFETY: caller must ensure no two threads use the same index.
    unsafe fn get(&self, i: usize) -> &mut T {
        debug_assert!(i < self.1);
        &mut *self.0.add(i)
    }
}

fn as_send_cells<T>(v: &mut [T]) -> SendCells<T> {
    SendCells(v.as_mut_ptr(), v.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 7, Some(4), |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_path() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(100, 13, Some(1), |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 8, Some(4), |_, _| panic!("should not run"));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(257, Some(8), |i| i * i);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn effective_threads_floor_one() {
        assert_eq!(effective_threads(Some(0)), 1);
        assert!(effective_threads(None) >= 1);
    }

    #[test]
    fn worker_panic_propagates_with_payload() {
        let err = std::panic::catch_unwind(|| {
            parallel_for_chunks(1000, 7, Some(4), |s, _| {
                if s >= 35 {
                    panic!("boom at {s}");
                }
            });
        })
        .expect_err("worker panic must reach the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("payload must be the original panic message");
        assert!(msg.starts_with("boom at "), "unexpected payload: {msg}");
    }

    #[test]
    fn serial_path_panic_propagates() {
        let err = std::panic::catch_unwind(|| {
            parallel_for_chunks(10, 3, Some(1), |_, _| panic!("serial boom"));
        })
        .expect_err("serial-path panic must reach the caller");
        assert_eq!(err.downcast_ref::<&str>(), Some(&"serial boom"));
    }

    #[test]
    fn map_worker_panic_propagates() {
        let err = std::panic::catch_unwind(|| {
            parallel_map(100, Some(4), |i| {
                if i == 63 {
                    panic!("map boom");
                }
                i
            })
        })
        .expect_err("parallel_map must re-raise worker panics");
        assert_eq!(err.downcast_ref::<&str>(), Some(&"map boom"));
    }

    #[test]
    fn sums_match_serial() {
        let n = 10_000usize;
        let total = AtomicU64::new(0);
        parallel_for_chunks(n, 64, Some(6), |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }
}
