//! Declarative command-line parser (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults and typed accessors, and auto-generated `--help` text.

use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed argument set.
#[derive(Clone, Debug, Default)]
pub struct Args {
    vals: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.vals.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing option --{name}"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing option --{name}"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing option --{name}"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A subcommand with its option specs.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse raw args (after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                args.vals.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.help_text()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    args.flags.insert(key.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .ok_or_else(|| anyhow::anyhow!("option --{key} needs a value"))?
                                .clone()
                        }
                    };
                    args.vals.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        for spec in &self.opts {
            if !spec.is_flag && spec.default.is_none() && !args.vals.contains_key(spec.name) {
                anyhow::bail!("missing required option --{}\n{}", spec.name, self.help_text());
            }
        }
        Ok(args)
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = o.default {
                format!(" <val> (default: {d})")
            } else {
                " <val> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, kind, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("scan", "run a scan")
            .opt("parties", "4", "number of parties")
            .opt("seed", "7", "rng seed")
            .req("out", "output path")
            .flag("secure", "enable SMC")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&sv(&["--out", "x.json"])).unwrap();
        assert_eq!(a.get_usize("parties").unwrap(), 4);
        assert!(!a.flag("secure"));
        let a = cmd()
            .parse(&sv(&["--parties=9", "--secure", "--out", "y"]))
            .unwrap();
        assert_eq!(a.get_usize("parties").unwrap(), 9);
        assert!(a.flag("secure"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&sv(&["--parties", "2"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&sv(&["--nope", "1", "--out", "x"])).is_err());
    }

    #[test]
    fn value_styles() {
        let a = cmd().parse(&sv(&["--seed=123", "--out=o"])).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), 123);
        let a = cmd().parse(&sv(&["--seed", "99", "--out", "o"])).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), 99);
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&sv(&["--out", "o", "fileA", "fileB"])).unwrap();
        assert_eq!(a.positional, vec!["fileA", "fileB"]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&sv(&["--secure=1", "--out", "o"])).is_err());
    }
}
