//! Tiny property-testing driver (no `proptest` crate offline).
//!
//! [`run_prop`] executes a property over `cases` randomly generated
//! inputs; on failure it retries with progressively "smaller" inputs from
//! the generator's shrink hint and reports the seed so the case is
//! reproducible. Generators are plain closures over [`Rng`], composed in
//! each test — no macro DSL, but the same methodology: random inputs,
//! explicit invariants, reproducible failures.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // DASH_PROP_CASES overrides for heavier local runs.
        let cases = std::env::var("DASH_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed: 0xDA5B00F5 }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`. Panics with the
/// failing case index + seed on first violation.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).derive(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {:#x}):\n  {msg}\n  input: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Generator: an `f64` exactly representable in `frac_bits` fixed point,
/// uniform over `±2^mag_bits` on the fixed-point grid. Secure-sum
/// round-trips of such values are *lossless* (encode/decode is exact and
/// ring/field sums are exact integers), so properties over them can
/// assert bit-identity rather than tolerance.
pub fn fixed_repr(rng: &mut Rng, frac_bits: u32, mag_bits: u32) -> f64 {
    assert!(frac_bits + mag_bits < 52, "grid must stay exactly representable");
    let span = 1u64 << (frac_bits + mag_bits);
    let raw = rng.below(2 * span + 1) as i64 - span as i64;
    raw as f64 / (1u64 << frac_bits) as f64
}

/// Generator: a vector of fixed-point-representable values.
pub fn fixed_repr_vec(rng: &mut Rng, len: usize, frac_bits: u32, mag_bits: u32) -> Vec<f64> {
    (0..len).map(|_| fixed_repr(rng, frac_bits, mag_bits)).collect()
}

/// Helper: assert two floats are close (absolute + relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol} (rel)", (a - b).abs()))
    }
}

/// Helper: assert all pairs in two slices are close.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        close(*x, *y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop(
            "sum-commutes",
            PropConfig { cases: 32, ..Default::default() },
            |r| (r.uniform(), r.uniform()),
            |(a, b)| close(a + b, b + a, 1e-15),
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn reports_failures() {
        run_prop(
            "always-fails",
            PropConfig { cases: 4, ..Default::default() },
            |r| r.uniform(),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn fixed_repr_is_lossless_under_codec() {
        use crate::mpc::fixed::FixedCodec;
        let codec = FixedCodec::new(24);
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let v = fixed_repr(&mut rng, 24, 6);
            assert!(v.abs() <= 64.0 + 1e-9);
            let back = codec.decode(codec.encode(v).unwrap());
            assert_eq!(back.to_bits(), v.to_bits(), "{v} not on the codec grid");
        }
        assert_eq!(fixed_repr_vec(&mut rng, 7, 24, 6).len(), 7);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 1.1, 1e-9).is_err());
        // relative scaling for large magnitudes
        assert!(close(1e12, 1e12 + 1.0, 1e-9).is_ok());
    }

    #[test]
    fn all_close_checks_lengths() {
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-9).is_ok());
    }
}
