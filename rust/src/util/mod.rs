//! Self-contained utility substrate.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no `rand`, `serde`, `clap`, `rayon`, `criterion`, `proptest`), so the
//! pieces a production crate would normally pull in are implemented here:
//!
//! - [`rng`] — SplitMix64 / xoshiro256++ PRNG with normal & binomial draws
//! - [`json`] — minimal JSON value model, parser and writer
//! - [`cli`] — declarative flag/option parser for the launcher
//! - [`threadpool`] — scoped parallel-for over index ranges
//! - [`bench`] — timing harness (warmup, adaptive iteration, median/MAD)
//! - [`proptest`] — tiny property-testing driver with shrinking-lite

pub mod rng;
pub mod json;
pub mod cli;
pub mod threadpool;
pub mod bench;
pub mod proptest;

/// Lock a mutex, recovering from poisoning. A panic while a guard was
/// held marks the mutex poisoned forever; for scheduler/registry state
/// that must stay queryable after a crashed worker (a daemon answering
/// `GET /jobs/{id}` after one job panicked), the stored data is still a
/// consistent snapshot — every writer updates it atomically under the
/// guard — so the right move is to take the data back, not to cascade
/// the panic into every later reader.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Format a byte count as a human-readable string (e.g. `"1.25 MiB"`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicked_holder() {
        use std::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        // poison the mutex: panic while holding the guard
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // the data is still the consistent pre-panic snapshot
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert!(human_secs(3.2e-9).ends_with("ns"));
        assert!(human_secs(4.5e-5).ends_with("µs"));
        assert!(human_secs(0.012).ends_with("ms"));
        assert!(human_secs(2.0).ends_with(" s"));
    }
}
