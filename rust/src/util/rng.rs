//! Deterministic, seedable PRNG substrate (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256++ seeded via SplitMix64 — fast, high quality, and
//! reproducible across platforms, which matters because every experiment in
//! EXPERIMENTS.md is keyed by an explicit seed. On top of the raw generator
//! we provide the distributions the workload generator and the MPC masking
//! layer need: uniform ranges, standard normal (Box–Muller with caching),
//! binomial (inverse-CDF for small n, normal approximation for large n),
//! and fills for mask vectors.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal draw from Box–Muller
    normal_cache: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, normal_cache: None }
    }

    /// Derive an independent stream for a sub-task (party p, block b, ...).
    /// Streams derived with distinct tags are statistically independent.
    pub fn derive(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, normal_cache: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection, unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal draw (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.normal_cache.take() {
            return z;
        }
        // Avoid u == 0 (log would blow up).
        let mut u = self.uniform();
        while u <= f64::MIN_POSITIVE {
            u = self.uniform();
        }
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.normal_cache = Some(r * s);
        r * c
    }

    /// Normal with mean/sd.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Binomial(n, p) draw. Exact inversion for small n·p, normal
    /// approximation with continuity correction for large n (adequate for
    /// genotype simulation where n = 2).
    pub fn binomial(&mut self, n: u32, p: f64) -> u32 {
        debug_assert!((0.0..=1.0).contains(&p));
        if p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 64 {
            // Direct Bernoulli sum — n is tiny in our workloads (n = 2).
            let mut k = 0;
            for _ in 0..n {
                if self.uniform() < p {
                    k += 1;
                }
            }
            k
        } else {
            let mean = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            let z = self.normal_ms(mean, sd).round();
            z.clamp(0.0, n as f64) as u32
        }
    }

    /// Beta(a, b) via Jöhnk/gamma-ratio (Marsaglia–Tsang gamma).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boost for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: G(a) = G(a+1) * U^(1/a)
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Fill a slice with raw u64s (mask generation hot path).
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.next_u64();
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_streams_independent_of_parent_state() {
        let parent = Rng::new(7);
        let mut c1 = parent.derive(1);
        let mut c2 = parent.derive(1);
        assert_eq!(c1.next_u64(), c2.next_u64());
        let mut c3 = parent.derive(2);
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn binomial_small_n_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let sum: u64 = (0..n).map(|_| r.binomial(2, 0.3) as u64).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 0.6).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn binomial_edges() {
        let mut r = Rng::new(1);
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(21);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.gamma(3.5)).sum::<f64>() / n as f64;
        assert!((m - 3.5).abs() < 0.08, "m={m}");
    }

    #[test]
    fn beta_in_unit_interval_and_mean() {
        let mut r = Rng::new(22);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let b = r.beta(2.0, 6.0);
            assert!((0.0..=1.0).contains(&b));
            sum += b;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(33);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
