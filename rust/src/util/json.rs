//! Minimal JSON codec (no `serde`/`serde_json` offline).
//!
//! Covers the full JSON grammar; used for the artifact manifest
//! (`artifacts/manifest.json`), run configs, and machine-readable
//! experiment reports. Numbers are kept as `f64` (adequate: all our
//! payloads are shapes, counts and statistics well inside 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing or non-array field `{key}`"))
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => anyhow::bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs: keep it simple, accept BMP only.
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => anyhow::bail!("expected `,` or `]` at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => anyhow::bail!("expected `,` or `}}` at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let s = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -1e-3}"#;
        let v = Json::parse(s).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2], "b": true}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parse() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(1234567.0).to_string(), "1234567");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", 1usize).set("y", "z").set("v", vec![1.0, 2.0]);
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.req_usize("x").unwrap(), 1);
    }
}
