//! Forward stepwise feature selection over cached compressed statistics
//! — the iterative half of the paper's contribution ("…linear regression
//! **and feature selection** at plaintext speed").
//!
//! After a scan, SELECT runs multi-round forward stepwise: each round
//! promotes the best-scoring variant into the covariate basis and
//! re-scores the remaining candidates against the grown basis. The
//! geometric insight is that promotion is a **rank-1 extension of the
//! shared QR** ([`crate::linalg::qr_append`],
//! [`CombineContext::append_column`]): the promoted column's
//! cross-products against the permanent covariates and the traits
//! (`Cᵀx`, `xᵀY`, `x·x`) already sit inside the compressed sums, so no
//! party re-runs compress and no `O(N·M·K)` pass recurs.
//!
//! The one statistic genuinely *outside* the compressed sums is the
//! promoted column's cross-product against other variants (`xᵀx'` —
//! compression keeps only the `X·X` diagonal). Exact stepwise therefore
//! scores a bounded **candidate shortlist** chosen from the scan's
//! p-values (`ScanConfig::select_candidates`, the COJO-style conditional
//! analysis shape): per round, the parties secure-sum one `O(H)` vector
//! of the promoted column's cross-products against the `H` shortlisted
//! columns — independent of `M` — and every other projection update is
//! `O(K+T+H)` leader-side arithmetic ([`crate::linalg::project_append`]).
//! With `H = M` this is textbook forward stepwise; the shortlist is what
//! keeps per-round traffic `O(K+T+H+round)` instead of `O(M)`.
//!
//! Selection is **policy-driven** over lanes: [`SelectPolicy::Union`]
//! runs one lane whose basis is shared by all `T` traits (each round
//! promotes the best variant across traits); [`SelectPolicy::PerTrait`]
//! runs `T` independent lanes, each bit-identical to a `T = 1` session
//! of its trait. The scoring inside a lane is the unchanged Lemma 3.1
//! epilogue against the augmented basis — `combine_shard`'s math with
//! `K` grown by the promoted columns.

use super::combine::{CombineContext, ScanOutput};
use super::compressed::ShardSums;
use crate::linalg::{project_append, solve_rt_b, Matrix};
use crate::stats::scan_stats_from_projected_parts;
use std::collections::BTreeSet;

/// How SELECT lanes map onto traits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectPolicy {
    /// One lane, one shared basis: each round promotes the variant with
    /// the best score across all traits.
    Union,
    /// `T` independent lanes, one per trait — lane `t` is bit-identical
    /// to a `T = 1` selection on that trait.
    PerTrait,
}

impl SelectPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectPolicy::Union => "union",
            SelectPolicy::PerTrait => "per-trait",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<SelectPolicy> {
        match s {
            "union" => Ok(SelectPolicy::Union),
            "per-trait" => Ok(SelectPolicy::PerTrait),
            other => anyhow::bail!("unknown select policy `{other}` (union|per-trait)"),
        }
    }

    /// Wire encoding (SETUP/SELECT_SETUP frames).
    pub fn code(&self) -> u64 {
        match self {
            SelectPolicy::Union => 0,
            SelectPolicy::PerTrait => 1,
        }
    }

    pub fn from_code(c: u64) -> anyhow::Result<SelectPolicy> {
        match c {
            0 => Ok(SelectPolicy::Union),
            1 => Ok(SelectPolicy::PerTrait),
            other => anyhow::bail!("unknown select policy code {other}"),
        }
    }
}

/// One promoted variant: which column entered which lane's basis, with
/// its association statistics *at entry* (scored against the basis of
/// the round it was promoted in).
#[derive(Clone, Debug)]
pub struct SelectPick {
    /// absolute variant index
    pub variant: usize,
    /// candidate-shortlist slot of the variant
    pub slot: usize,
    /// trait whose score won the round (for per-trait lanes, the lane's
    /// own trait)
    pub trait_idx: usize,
    pub beta: f64,
    pub se: f64,
    pub t: f64,
    pub p: f64,
}

/// One SELECT round: at most one pick per lane (`None` = lane already
/// stopped).
#[derive(Clone, Debug)]
pub struct SelectRound {
    /// 1-based round index
    pub round: usize,
    pub picks: Vec<Option<SelectPick>>,
}

/// Result of a SELECT phase.
#[derive(Clone, Debug)]
pub struct SelectOutput {
    pub policy: SelectPolicy,
    /// candidate shortlist (absolute variant indices, strictly
    /// increasing)
    pub candidates: Vec<usize>,
    /// number of selection lanes (1 for union, T for per-trait)
    pub lanes: usize,
    pub rounds: Vec<SelectRound>,
}

impl SelectOutput {
    /// Number of selection lanes (1 for union, T for per-trait).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Variants promoted into lane `lane`, in promotion order.
    pub fn selected(&self, lane: usize) -> Vec<usize> {
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        self.rounds
            .iter()
            .filter_map(|r| r.picks[lane].as_ref().map(|p| p.variant))
            .collect()
    }
}

/// Rank the scan's variants and return the candidate shortlist: the
/// union over traits of the `cap` smallest finite p-values, as a
/// strictly-increasing index list. The shortlist bounds every SELECT
/// round's traffic at `O(H)` independent of `M`; `cap ≥ M` recovers
/// unrestricted forward stepwise.
pub fn choose_candidates(out: &ScanOutput, cap: usize) -> Vec<usize> {
    let mut set = BTreeSet::new();
    for assoc in &out.assoc {
        // total_cmp with an explicit NaN-last key: a zero-variance
        // variant yields p = NaN, which partial_cmp().unwrap() would
        // turn into a leader panic mid-session
        let mut ranked: Vec<usize> = (0..out.m).collect();
        ranked.sort_by(|&a, &b| {
            let (pa, pb) = (assoc.p[a], assoc.p[b]);
            pa.is_nan()
                .cmp(&pb.is_nan())
                .then_with(|| pa.total_cmp(&pb))
                .then(a.cmp(&b))
        });
        for &j in ranked.iter().take(cap) {
            if assoc.p[j].is_finite() {
                set.insert(j);
            }
        }
    }
    set.into_iter().collect()
}

/// Party-side kernel of a Promote round: cross-products of column `j`
/// of `x` against every column of the gathered shortlist `xs`, summed
/// over rows in row order (bit-identical to the compress kernel's
/// accumulation, so `v[slot_of_j] == x_j·x_j` exactly).
pub fn cross_products(x: &Matrix, j: usize, xs: &Matrix) -> Vec<f64> {
    assert!(j < x.cols, "variant {j} out of range ({} cols)", x.cols);
    assert_eq!(x.rows, xs.rows, "row mismatch");
    let mut v = vec![0.0; xs.cols];
    for i in 0..x.rows {
        let xj = x[(i, j)];
        if xj == 0.0 {
            continue;
        }
        for (o, &b) in v.iter_mut().zip(xs.row(i)) {
            *o += xj * b;
        }
    }
    v
}

/// One selection lane: a basis (grown per promotion) plus the projected
/// candidate columns against it.
struct Lane {
    /// trait columns this lane scores
    traits: Vec<usize>,
    /// factorized (and grown) basis + per-trait `QᵀY`
    ctx: CombineContext,
    /// `QᵀX_S` against the lane's current basis, `basis_k × H`
    qt_c: Matrix,
    /// promoted shortlist slots, in promotion order
    promoted: Vec<usize>,
    done: bool,
}

/// Leader-side SELECT engine, protocol-agnostic: fed the aggregate
/// shortlist statistics once and one aggregate cross-product vector per
/// promotion, it reproduces forward stepwise exactly. The wire layers
/// (any backend) only move those two kinds of sums.
pub struct SelectState {
    policy: SelectPolicy,
    /// p-value entry threshold (stop rule)
    p_enter: f64,
    n: usize,
    cand: Vec<usize>,
    /// aggregate `X_SᵀY`, `H × T`
    xty_s: Matrix,
    /// aggregate `X_S·X_S`, length `H`
    xtx_s: Vec<f64>,
    lanes: Vec<Lane>,
    rounds: Vec<SelectRound>,
}

impl SelectState {
    /// Build from the session's combine context and the aggregate
    /// shortlist sums (`ShardSums` over the gathered candidate columns —
    /// the same wire shape as a variant shard).
    pub fn new(
        cx: &CombineContext,
        cand: Vec<usize>,
        sums: &ShardSums,
        policy: SelectPolicy,
        p_enter: f64,
    ) -> anyhow::Result<SelectState> {
        anyhow::ensure!(sums.width() == cand.len(), "candidate stats width mismatch");
        anyhow::ensure!(sums.t() == cx.t(), "candidate stats trait-count mismatch");
        anyhow::ensure!(p_enter > 0.0, "entry threshold must be positive");
        for w in cand.windows(2) {
            anyhow::ensure!(w[0] < w[1], "candidates must be strictly increasing");
        }
        let qt_c = solve_rt_b(&cx.r, &sums.ctx);
        let lane_traits: Vec<Vec<usize>> = match policy {
            SelectPolicy::Union => vec![(0..cx.t()).collect()],
            SelectPolicy::PerTrait => (0..cx.t()).map(|tt| vec![tt]).collect(),
        };
        let lanes = lane_traits
            .into_iter()
            .map(|traits| Lane {
                traits,
                ctx: cx.clone(),
                qt_c: qt_c.clone(),
                promoted: Vec::new(),
                done: false,
            })
            .collect();
        Ok(SelectState {
            policy,
            p_enter,
            n: cx.n,
            cand,
            xty_s: sums.xty.clone(),
            xtx_s: sums.xtx.clone(),
            lanes,
            rounds: Vec::new(),
        })
    }

    /// Shortlist size `H`.
    pub fn h(&self) -> usize {
        self.cand.len()
    }

    /// Number of selection lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Rounds folded so far.
    pub fn rounds(&self) -> &[SelectRound] {
        &self.rounds
    }

    /// Score one lane's candidates against its current basis — the
    /// Lemma 3.1 epilogue with `K` grown by the promoted columns — and
    /// return the best pick passing the stop rule, ties to the earlier
    /// trait then the lower variant index.
    fn score_lane(&self, li: usize) -> Option<SelectPick> {
        let lane = &self.lanes[li];
        let kb = lane.ctx.basis_k();
        // residual df after one more covariate must stay positive
        if (self.n as f64) - (kb as f64) - 1.0 < 1.0 {
            return None;
        }
        let mut best: Option<SelectPick> = None;
        for &tt in &lane.traits {
            let assoc = scan_stats_from_projected_parts(
                self.n,
                kb,
                lane.ctx.yty[tt],
                &self.xty_s.col(tt),
                &self.xtx_s,
                &lane.ctx.qt_y.col(tt),
                &lane.qt_c,
            );
            for slot in 0..self.cand.len() {
                if lane.promoted.contains(&slot) {
                    continue;
                }
                let p = assoc.p[slot];
                if !p.is_finite() || p > self.p_enter {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(b) => p < b.p,
                };
                if better {
                    best = Some(SelectPick {
                        variant: self.cand[slot],
                        slot,
                        trait_idx: tt,
                        beta: assoc.beta[slot],
                        se: assoc.se[slot],
                        t: assoc.t[slot],
                        p,
                    });
                }
            }
        }
        best
    }

    /// Score every lane and return this round's proposed picks (`None`
    /// marks a lane as stopped). The leader broadcasts the picks as a
    /// `PROMOTE` frame; [`fold`](Self::fold) applies them once the
    /// cross-product sums return.
    pub fn propose(&mut self) -> Vec<Option<SelectPick>> {
        let mut picks = Vec::with_capacity(self.lanes.len());
        for li in 0..self.lanes.len() {
            if self.lanes[li].done {
                picks.push(None);
                continue;
            }
            let pick = self.score_lane(li);
            if pick.is_none() {
                self.lanes[li].done = true;
            }
            picks.push(pick);
        }
        picks
    }

    /// Apply one round: `flat` is the securely-summed concatenation, in
    /// lane order, of each *active* lane's promoted-column cross-products
    /// against the shortlist (`H` values per active lane). Grows each
    /// active lane's basis by its promoted column and extends every
    /// cached projection by one entry.
    pub fn fold(&mut self, picks: &[Option<SelectPick>], flat: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(picks.len() == self.lanes.len(), "lane count mismatch");
        let h = self.cand.len();
        let active = picks.iter().filter(|p| p.is_some()).count();
        anyhow::ensure!(flat.len() == active * h, "cross-product round length mismatch");
        let mut off = 0usize;
        for (li, pick) in picks.iter().enumerate() {
            let Some(pick) = pick else { continue };
            let v = &flat[off..off + h];
            off += h;
            let slot = pick.slot;
            anyhow::ensure!(slot < h, "promoted slot out of range");
            anyhow::ensure!(
                !self.lanes[li].promoted.contains(&slot),
                "slot {slot} already promoted in lane {li}"
            );
            // the promoted column's self cross-product must reproduce the
            // cached X·X entry (same sums, same order) — a cheap
            // integrity check on the round
            anyhow::ensure!(
                (v[slot] - self.xtx_s[slot]).abs() <= 1e-6 * self.xtx_s[slot].abs().max(1.0),
                "promote round inconsistent: self cross-product {} vs cached X·X {}",
                v[slot],
                self.xtx_s[slot]
            );
            let lane = &mut self.lanes[li];
            let u = lane.qt_c.col(slot);
            let rho = lane.ctx.append_column(&u, self.xtx_s[slot], self.xty_s.row(slot))?;
            let kb = lane.qt_c.rows;
            let mut qt_c = Matrix::zeros(kb + 1, h);
            qt_c.data[..kb * h].copy_from_slice(&lane.qt_c.data);
            for c in 0..h {
                qt_c[(kb, c)] = project_append(&u, rho, &lane.qt_c.col(c), v[c]);
            }
            lane.qt_c = qt_c;
            lane.promoted.push(slot);
        }
        let round = self.rounds.len() + 1;
        self.rounds.push(SelectRound { round, picks: picks.to_vec() });
        Ok(())
    }

    /// Finish, consuming the state.
    pub fn into_output(self) -> SelectOutput {
        SelectOutput {
            policy: self.policy,
            candidates: self.cand,
            lanes: self.lanes.len(),
            rounds: self.rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder_qr;
    use crate::scan::compressed::{compress_party, flatten_for_sum, unflatten_sum};
    use crate::scan::{combine_base, CombineOptions, RFactorMethod};
    use crate::util::rng::Rng;

    /// Test data with two planted effects on trait 0 and a different one
    /// on trait 1 (when T > 1) so stepwise has a deterministic story.
    fn data(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let mut ys = Matrix::randn(n, t, &mut rng);
        for i in 0..n {
            ys[(i, 0)] += 0.5 * x[(i, 0)] + 0.3 * x[(i, 2)];
            if t > 1 {
                ys[(i, 1)] += 0.6 * x[(i, 1)];
            }
        }
        (ys, c, x)
    }

    fn hstack_col(a: &Matrix, col: Vec<f64>) -> Matrix {
        Matrix::vstack(&[&a.transpose(), &Matrix::from_col(col).transpose()]).transpose()
    }

    /// Brute-force forward stepwise on the raw data, same scoring rule:
    /// per round, min-p over (traits, candidates) with ties to the
    /// earlier trait then lower variant index; stop at `p > alpha`.
    fn oracle_stepwise(
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        traits: &[usize],
        cand: &[usize],
        k_max: usize,
        alpha: f64,
    ) -> Vec<(usize, usize, f64, f64, f64)> {
        let n = ys.rows;
        let xs = x.gather_cols(cand);
        let xtx: Vec<f64> = (0..xs.cols)
            .map(|j| xs.col(j).iter().map(|v| v * v).sum())
            .collect();
        let mut basis = c.clone();
        let mut chosen_slots: Vec<usize> = Vec::new();
        let mut out = Vec::new();
        for _ in 0..k_max {
            let f = householder_qr(&basis);
            let qt_x = f.q.t_matmul(&xs);
            let mut best: Option<(usize, usize, f64, f64, f64)> = None;
            for &tt in traits {
                let y = ys.col(tt);
                let yty: f64 = y.iter().map(|v| v * v).sum();
                let assoc = crate::stats::scan_stats_from_projected_parts(
                    n,
                    basis.cols,
                    yty,
                    &xs.t_matvec(&y),
                    &xtx,
                    &f.q.t_matvec(&y),
                    &qt_x,
                );
                for slot in 0..xs.cols {
                    if chosen_slots.contains(&slot) {
                        continue;
                    }
                    let p = assoc.p[slot];
                    if !p.is_finite() || p > alpha {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => p < b.4,
                    };
                    if better {
                        best = Some((cand[slot], slot, assoc.beta[slot], assoc.se[slot], p));
                    }
                }
            }
            let Some(b) = best else { break };
            chosen_slots.push(b.1);
            basis = hstack_col(&basis, x.col(b.0));
            out.push(b);
        }
        out
    }

    fn aggregate_of(ys: &Matrix, c: &Matrix, x: &Matrix) -> crate::scan::AggregateSums {
        let cp = compress_party(ys, c, x, x.cols.max(1), Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        unflatten_sum(layout, &flat).unwrap()
    }

    /// Drive a SelectState exactly as the leader does, feeding it exact
    /// plaintext sums and cross-products.
    fn run_select(
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        cand: Vec<usize>,
        policy: SelectPolicy,
        alpha: f64,
        k_max: usize,
    ) -> SelectOutput {
        let agg = aggregate_of(ys, c, x);
        let cx = combine_base(
            &agg.base(),
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
        .unwrap();
        let xs = x.gather_cols(&cand);
        let sub = compress_party(ys, c, &xs, xs.cols.max(1), Some(1));
        let sums = crate::scan::ShardSums {
            xty: sub.xty.clone(),
            xtx: sub.xtx.clone(),
            ctx: sub.ctx.clone(),
        };
        let mut st = SelectState::new(&cx, cand, &sums, policy, alpha).unwrap();
        for _ in 0..k_max {
            let picks = st.propose();
            if picks.iter().all(|p| p.is_none()) {
                break;
            }
            let mut flat = Vec::new();
            for p in picks.iter().flatten() {
                flat.extend(cross_products(x, p.variant, &xs));
            }
            st.fold(&picks, &flat).unwrap();
        }
        st.into_output()
    }

    #[test]
    fn select_matches_bruteforce_oracle() {
        let (ys, c, x) = data(220, 3, 12, 1, 400);
        let cand: Vec<usize> = (0..12).collect();
        let got = run_select(&ys, &c, &x, cand.clone(), SelectPolicy::Union, 0.05, 3);
        let want = oracle_stepwise(&ys, &c, &x, &[0], &cand, 3, 0.05);
        assert!(!want.is_empty(), "oracle selected nothing");
        assert_eq!(got.rounds.len(), want.len());
        for (r, w) in got.rounds.iter().zip(&want) {
            let p = r.picks[0].as_ref().unwrap();
            assert_eq!(p.variant, w.0, "round {}", r.round);
            assert!((p.beta - w.2).abs() < 1e-8 * w.2.abs().max(1.0), "beta");
            assert!((p.se - w.3).abs() < 1e-8 * w.3.abs().max(1.0), "se");
            assert!((p.p - w.4).abs() < 1e-6 * w.4.max(1e-30), "p");
        }
        assert_eq!(got.selected(0), want.iter().map(|w| w.0).collect::<Vec<_>>());
    }

    #[test]
    fn per_trait_lanes_match_independent_single_trait_runs() {
        let (ys, c, x) = data(200, 3, 10, 2, 401);
        let cand: Vec<usize> = (0..10).collect();
        let joint = run_select(&ys, &c, &x, cand.clone(), SelectPolicy::PerTrait, 0.1, 2);
        assert_eq!(joint.lanes(), 2);
        for tt in 0..2 {
            let solo_ys = Matrix::from_col(ys.col(tt));
            let solo =
                run_select(&solo_ys, &c, &x, cand.clone(), SelectPolicy::Union, 0.1, 2);
            assert_eq!(joint.selected(tt), solo.selected(0), "trait {tt}");
            for (jr, sr) in joint.rounds.iter().zip(&solo.rounds) {
                match (&jr.picks[tt], &sr.picks[0]) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.variant, b.variant);
                        assert_eq!(a.beta.to_bits(), b.beta.to_bits(), "beta bits");
                        assert_eq!(a.p.to_bits(), b.p.to_bits(), "p bits");
                    }
                    (None, None) => {}
                    other => panic!("lane/solo divergence: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn union_policy_promotes_across_traits() {
        let (ys, c, x) = data(260, 3, 8, 2, 402);
        let cand: Vec<usize> = (0..8).collect();
        let got = run_select(&ys, &c, &x, cand, SelectPolicy::Union, 0.05, 3);
        assert_eq!(got.lanes(), 1);
        let sel = got.selected(0);
        assert!(!sel.is_empty());
        // the planted effects live on variants 0/2 (trait 0) and 1
        // (trait 1); the union lane should surface a mix
        for v in &sel {
            assert!([0usize, 1, 2].contains(v), "unexpected selection {v}");
        }
        let traits: BTreeSet<usize> = got
            .rounds
            .iter()
            .filter_map(|r| r.picks[0].as_ref().map(|p| p.trait_idx))
            .collect();
        assert!(traits.len() > 1, "expected picks from more than one trait: {traits:?}");
    }

    #[test]
    fn stop_rule_and_exhaustion() {
        let (ys, c, x) = data(150, 3, 5, 1, 403);
        // impossible threshold → nothing selected, lane marked done
        let got = run_select(&ys, &c, &x, (0..5).collect(), SelectPolicy::Union, 1e-300, 4);
        assert!(got.rounds.is_empty());
        // permissive threshold → selection exhausts the shortlist
        let got = run_select(&ys, &c, &x, (0..3).collect(), SelectPolicy::Union, 0.9999, 10);
        assert!(got.rounds.len() <= 3);
        let sel = got.selected(0);
        let uniq: BTreeSet<usize> = sel.iter().copied().collect();
        assert_eq!(uniq.len(), sel.len(), "no variant promoted twice");
    }

    #[test]
    fn fold_rejects_inconsistent_cross_products() {
        let (ys, c, x) = data(120, 3, 6, 1, 404);
        let cand: Vec<usize> = (0..6).collect();
        let agg = aggregate_of(&ys, &c, &x);
        let cx = combine_base(&agg.base(), None, CombineOptions::default()).unwrap();
        let sums = agg.shard_sums(0, 6);
        let mut st =
            SelectState::new(&cx, cand, &sums, SelectPolicy::Union, 0.5).unwrap();
        let picks = st.propose();
        assert!(picks[0].is_some());
        // wrong length
        assert!(st.fold(&picks, &[0.0; 3]).is_err());
        // self cross-product that contradicts the cached X·X
        let mut flat = cross_products(&x, picks[0].as_ref().unwrap().variant, &x);
        flat[picks[0].as_ref().unwrap().slot] += 1.0;
        assert!(st.fold(&picks, &flat).is_err());
    }

    #[test]
    fn choose_candidates_survives_zero_variance_variant() {
        // a constant (zero-variance) genotype column produces NaN
        // association statistics; ranking must neither panic nor admit
        // the degenerate variant into the shortlist
        let (ys, c, mut x) = data(160, 3, 7, 1, 406);
        for i in 0..x.rows {
            x[(i, 4)] = 0.0;
        }
        let agg = aggregate_of(&ys, &c, &x);
        let out = crate::scan::combine_compressed(&agg, None, CombineOptions::default())
            .unwrap();
        assert!(
            !out.assoc[0].p[4].is_finite(),
            "expected a non-finite p for the constant column, got {}",
            out.assoc[0].p[4]
        );
        let cand = choose_candidates(&out, 7);
        assert!(!cand.contains(&4), "zero-variance variant shortlisted: {cand:?}");
        assert_eq!(cand.len(), 6, "all finite-p variants kept: {cand:?}");
        for w in cand.windows(2) {
            assert!(w[0] < w[1]);
        }
        // and the scan-level hit ranking stays panic-free too
        let _ = out.hits(1.0);
    }

    #[test]
    fn choose_candidates_ranks_and_unions() {
        let (ys, c, x) = data(180, 3, 9, 2, 405);
        let agg = aggregate_of(&ys, &c, &x);
        let out = crate::scan::combine_compressed(&agg, None, CombineOptions::default())
            .unwrap();
        let cand = choose_candidates(&out, 2);
        // strictly increasing, bounded by 2 per trait
        for w in cand.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(!cand.is_empty() && cand.len() <= 4);
        // the planted top hits are shortlisted
        assert!(cand.contains(&0), "trait-0 top hit missing from {cand:?}");
        assert!(cand.contains(&1), "trait-1 top hit missing from {cand:?}");
        // cap ≥ M keeps every finite-p variant
        assert_eq!(choose_candidates(&out, 9).len(), 9);
    }
}
