//! The paper's algorithms, end to end: compress-within, combine-across,
//! and the association-scan epilogue — plus the meta-analysis baseline.
//!
//! Two compute paths produce identical `CompressedParty` values:
//! a pure-Rust reference path (always available; used by tests and as the
//! plaintext baseline) and the AOT-compiled XLA path driven by
//! [`crate::runtime`] (the production hot path, loaded from
//! `artifacts/*.hlo.txt`).

pub mod compressed;
mod combine;
mod meta;
mod multitrait;

pub use multitrait::{
    aggregate_multi, combine_multi, compress_party_multi, MultiTraitCompressed,
};

pub use compressed::{
    compress_party, flatten_for_sum, unflatten_sum, AggregateSums, CompressedParty, FlatLayout,
};
pub use combine::{
    combine_compressed, combine_regression, CombineOptions, RFactorMethod, ScanOutput,
};
pub use meta::{meta_analyze, MetaResult};

pub use crate::mpc::Backend as SmcBackend;

/// Top-level scan configuration.
#[derive(Clone, Debug)]
pub struct ScanConfig {
    pub backend: SmcBackend,
    /// fixed-point fractional bits for secure encoding
    pub frac_bits: u32,
    /// worker threads per party for the compress stage (None = auto)
    pub threads: Option<usize>,
    /// variant-block width for the compress stage
    pub block_m: usize,
    /// R-factor method for the combine stage (TSQR vs Gram+Cholesky)
    pub r_method: RFactorMethod,
    /// use the AOT artifacts runtime for compression when available
    pub use_artifacts: bool,
    /// directory holding artifacts/manifest.json
    pub artifacts_dir: String,
}

impl Default for ScanConfig {
    fn default() -> Self {
        ScanConfig {
            backend: SmcBackend::Masked,
            frac_bits: 24,
            threads: None,
            block_m: 256,
            r_method: RFactorMethod::Auto,
            use_artifacts: false,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}
