//! The paper's algorithms, end to end: compress-within, combine-across,
//! and the association-scan epilogue — plus the meta-analysis baseline.
//!
//! ## The trait-major sharded streaming pipeline
//!
//! Every stage is **trait-major**: statistics carry a trait dimension
//! `T` (§3's "promote y to a matrix Y"), and the classic single-trait
//! scan is exactly the degenerate `T = 1` case — same structs, same
//! wire layout, bit-identical values. The genotype-sized statistics
//! (`X·X`, `CᵀX`, `CᵀC`) are shared across traits, so the `O(NKM)`
//! compression and the `O(K²M)` projection are paid once and each extra
//! trait costs only `O(N(M+K))` — the amortization that makes biobank
//! PheWAS (~4K traits) and eQTL (~20K) economical.
//!
//! Scans run as a **variant-shard pipeline**: a [`ShardPlan`] splits the
//! `M` transient covariates into fixed-width column shards
//! ([`ScanConfig::shard_m`]), and each stage is factored to match:
//!
//! - compress = [`compress_base`] (once) + [`compress_variant_block`]
//!   (per shard, `O((K+T)·width)` memory);
//! - secure aggregation sums one base round plus one round per shard;
//! - combine = [`combine_base`] (factorize once, `O(K³)` + `O(K²)` per
//!   trait) + [`combine_shard`] (Lemma 3.1 epilogue per shard, `QᵀX`
//!   projection shared across traits).
//!
//! Parties compress shard `s+1` while the leader is still combining
//! shard `s`, so peak payload per round and leader working memory are
//! bounded by `O((K+T)·width)` instead of `O((K+T)·M)`. Because every
//! per-variant statistic is independent of how columns are chunked, the
//! sharded scan is **bit-identical** to the single-shot scan — and the
//! single-shot path *is* the degenerate one-shard plan (`shard_m == 0`).
//!
//! Two compute paths produce identical `CompressedParty` values: the
//! pure-Rust streaming kernels in this module, and the parameterized
//! artifact kernel suite driven by [`crate::runtime`] — per-shard
//! `compress_x` entries, a trait-batched `compress_xy` entry, and a
//! gathered-columns SELECT entry, served by the PJRT executor (the
//! production hot path, `artifacts/*.hlo.txt`) or by the bit-identical
//! pure-Rust reference executor (always available; the conformance
//! matrix in `tests/conformance.rs` pins artifact-mode sessions to the
//! Rust path bit-for-bit).
//!
//! ## The SELECT phase (iterative forward stepwise)
//!
//! `ScanConfig::select_k > 0` appends multi-round forward stepwise
//! selection to the session ([`SelectState`], `--select-k`): each round
//! promotes the best-scoring variant into the covariate basis via a
//! rank-1 QR append and re-scores a bounded candidate shortlist against
//! the grown basis — `O(lanes·H)` traffic per round, independent of M,
//! with no re-compression at the parties.

pub mod compressed;
mod combine;
pub mod logistic;
mod meta;
mod select;
mod shard;

pub use compressed::{
    base_flat_len, canonical_tile_rows, compress_base, compress_base_opts, compress_party,
    compress_variant_block, compress_variant_block_opts, compress_yside, flatten_for_sum,
    shard_flat_len, unflatten_base, unflatten_shard, unflatten_sum, AggregateSums, BaseStats,
    BaseSums, CompressedParty, FlatLayout, ShardSums, VariantBlockStats,
};
pub use combine::{
    combine_base, combine_compressed, combine_regression, combine_shard, CombineContext,
    CombineOptions, RFactorMethod, ScanOutput,
};
pub use logistic::{
    compress_irls_base, compress_irls_shard, irls_base_flat_len, irls_shard_flat_len,
    unflatten_irls_base, unflatten_irls_shard, IrlsBaseSums, IrlsShardSums, IrlsState,
    IrlsStep,
};
pub use meta::{meta_analyze, MetaResult};
pub use select::{
    choose_candidates, cross_products, SelectOutput, SelectPick, SelectPolicy, SelectRound,
    SelectState,
};
pub use shard::{ShardPlan, ShardRange};

pub use crate::mpc::Backend as SmcBackend;

/// Which generalized linear model the scan fits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Glm {
    /// classic linear association scan (the paper's workload)
    Linear,
    /// logistic regression: secure IRLS null model + one weighted
    /// score-test pass over the variant shards
    Logistic,
}

impl Glm {
    pub fn name(self) -> &'static str {
        match self {
            Glm::Linear => "linear",
            Glm::Logistic => "logistic",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Glm> {
        match s {
            "linear" => Ok(Glm::Linear),
            "logistic" => Ok(Glm::Logistic),
            other => anyhow::bail!("unknown glm {other:?} (expected linear|logistic)"),
        }
    }

    /// Wire encoding (Setup.glm field).
    pub fn code(self) -> u64 {
        match self {
            Glm::Linear => 0,
            Glm::Logistic => 1,
        }
    }

    pub fn from_code(code: u64) -> anyhow::Result<Glm> {
        match code {
            0 => Ok(Glm::Linear),
            1 => Ok(Glm::Logistic),
            other => anyhow::bail!("unknown glm code {other}"),
        }
    }
}

/// Top-level scan configuration.
#[derive(Clone, Debug)]
pub struct ScanConfig {
    pub backend: SmcBackend,
    /// fixed-point fractional bits for secure encoding
    pub frac_bits: u32,
    /// worker threads per party for the compress stage (None = auto)
    pub threads: Option<usize>,
    /// dedicated worker-thread budget for the tiled compress kernels
    /// (`--compress-threads`). `None` falls back to [`Self::threads`];
    /// the thread count never changes results — the canonical tiled
    /// accumulation is bit-identical at any worker count.
    pub compress_threads: Option<usize>,
    /// variant-block width for the compress stage (intra-shard
    /// parallelism granularity)
    pub block_m: usize,
    /// variant-shard width for the streaming protocol: each shard is one
    /// contribution round, bounding peak payload and leader memory at
    /// `O((K+T)·shard_m)`. `0` = single-shot (one shard spanning all of
    /// `M`).
    pub shard_m: usize,
    /// R-factor method for the combine stage (TSQR vs Gram+Cholesky)
    pub r_method: RFactorMethod,
    /// use the artifact kernel suite for compression
    pub use_artifacts: bool,
    /// directory holding artifacts/manifest.json
    pub artifacts_dir: String,
    /// which executor serves the artifact suite (auto|pjrt|reference)
    pub artifact_exec: crate::runtime::ArtifactExec,
    /// canonical shard widths of the artifact entry-shape policy
    pub entry_widths: Vec<usize>,
    /// canonical trait batches of the artifact entry-shape policy
    pub entry_traits: Vec<usize>,
    /// covariate padding of the artifact entries
    pub entry_k_pad: usize,
    /// maximum SELECT rounds after the scan (0 = scan only)
    pub select_k: usize,
    /// SELECT stop rule: a round only promotes a variant whose entry
    /// p-value is below this threshold
    pub select_alpha: f64,
    /// how SELECT lanes map onto traits
    pub select_policy: SelectPolicy,
    /// candidate-shortlist cap per trait (bounds per-round SELECT
    /// traffic at `O(H)` independent of M; ≥ M = unrestricted stepwise)
    pub select_candidates: usize,
    /// directory for leader-side per-session scan checkpoints
    /// (`--checkpoint-dir`): a snapshot after every combined shard, so
    /// an interrupted session resumes at the last combined shard instead
    /// of recomputing from zero. Empty = checkpointing off.
    pub checkpoint_dir: String,
    /// resume from an existing checkpoint in `checkpoint_dir`
    /// (`--resume`); a missing snapshot falls back to a fresh session
    pub resume: bool,
    /// which GLM the scan fits (`--glm`). Logistic runs secure IRLS
    /// rounds for the null model before a single weighted shard pass;
    /// it requires 0/1 traits and is incompatible with SELECT and
    /// checkpoint/resume.
    pub glm: Glm,
    /// IRLS iteration cap for logistic scans (`--irls-max-iter`)
    pub irls_max_iter: usize,
    /// IRLS deviance stop tolerance for logistic scans (`--irls-tol`):
    /// stop when `|dev_i − dev_{i−1}| < tol·(|dev_i| + 0.1)`
    pub irls_tol: f64,
}

impl Default for ScanConfig {
    fn default() -> Self {
        ScanConfig {
            backend: SmcBackend::Masked,
            frac_bits: 24,
            threads: None,
            compress_threads: None,
            block_m: 256,
            shard_m: 0,
            r_method: RFactorMethod::Auto,
            use_artifacts: false,
            artifacts_dir: "artifacts".to_string(),
            artifact_exec: crate::runtime::ArtifactExec::Auto,
            entry_widths: crate::runtime::ShapePolicy::default().widths,
            entry_traits: crate::runtime::ShapePolicy::default().trait_batches,
            entry_k_pad: crate::runtime::ShapePolicy::default().k_pad,
            select_k: 0,
            select_alpha: 1e-4,
            select_policy: SelectPolicy::Union,
            select_candidates: 32,
            checkpoint_dir: String::new(),
            resume: false,
            glm: Glm::Linear,
            irls_max_iter: crate::stats::IRLS_DEFAULT_MAX_ITER,
            irls_tol: crate::stats::IRLS_DEFAULT_TOL,
        }
    }
}

impl ScanConfig {
    /// The compress-stage worker budget: the dedicated
    /// `compress_threads` knob when set, else the legacy `threads` knob
    /// (None = auto-detect).
    pub fn effective_compress_threads(&self) -> Option<usize> {
        self.compress_threads.or(self.threads)
    }

    /// Entry-shape policy of the artifact kernel suite for this config.
    pub fn entry_policy(&self) -> crate::runtime::ShapePolicy {
        crate::runtime::ShapePolicy {
            widths: self.entry_widths.clone(),
            trait_batches: self.entry_traits.clone(),
            k_pad: self.entry_k_pad,
        }
    }
}
