//! Multi-trait scans: the paper's §3 extension — "All algorithms herein
//! generalize efficiently on vectorized hardware by promoting the vector
//! y to a matrix Y" (biobank studies test ~4K traits; eQTL ~20K).
//!
//! For T traits the compressed statistics gain a trait dimension:
//! `YᵀY` diag (T), `CᵀY` (K×T), `XᵀY` (M×T); `X·X`, `CᵀX`, `CᵀC` are
//! shared across traits — which is exactly the economy the paper points
//! at: the expensive `O(NKM)` genotype-side compression is paid once,
//! each extra trait costs only `O(N(M+K))`.

use super::combine::{CombineOptions, RFactorMethod};
use super::compressed::CompressedParty;
use crate::linalg::{cholesky_upper, solve_rt_b, tsqr_stack_r, Matrix};
use crate::stats::{scan_stats_from_projected, AssocResult, ScanStats};

/// Per-party compressed statistics for T traits.
#[derive(Clone, Debug)]
pub struct MultiTraitCompressed {
    pub n: usize,
    /// Y_tᵀY_t per trait, length T
    pub yty: Vec<f64>,
    /// CᵀY, K × T
    pub cty: Matrix,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// per-party R factor (TSQR path)
    pub r: Matrix,
    /// XᵀY, M × T
    pub xty: Matrix,
    /// X·X diag, length M
    pub xtx: Vec<f64>,
    /// CᵀX, K × M
    pub ctx: Matrix,
}

impl MultiTraitCompressed {
    pub fn t(&self) -> usize {
        self.yty.len()
    }

    pub fn k(&self) -> usize {
        self.ctc.rows
    }

    pub fn m(&self) -> usize {
        self.xtx.len()
    }
}

/// Compress one party's data for T traits. `ys` is `N × T` (row-major
/// samples × traits).
pub fn compress_party_multi(ys: &Matrix, c: &Matrix, x: &Matrix) -> MultiTraitCompressed {
    let n = ys.rows;
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(x.rows, n, "X rows != N");
    let t = ys.cols;
    let yty: Vec<f64> = (0..t)
        .map(|tt| (0..n).map(|i| ys[(i, tt)] * ys[(i, tt)]).sum())
        .collect();
    let cty = c.t_matmul(ys);
    let ctc = c.gram();
    let r = crate::linalg::householder_qr(c).r;
    let xty = x.t_matmul(ys);
    let xtx: Vec<f64> = {
        let mut v = vec![0.0; x.cols];
        for i in 0..n {
            for (j, &xv) in x.row(i).iter().enumerate() {
                v[j] += xv * xv;
            }
        }
        v
    };
    let ctx = c.t_matmul(x);
    MultiTraitCompressed { n, yty, cty, ctc, r, xty, xtx, ctx }
}

/// Aggregate across parties (all additive).
pub fn aggregate_multi(parts: &[MultiTraitCompressed]) -> MultiTraitCompressed {
    assert!(!parts.is_empty());
    let mut acc = parts[0].clone();
    for p in &parts[1..] {
        assert_eq!(p.t(), acc.t(), "trait count mismatch");
        assert_eq!(p.k(), acc.k(), "covariate count mismatch");
        assert_eq!(p.m(), acc.m(), "variant count mismatch");
        acc.n += p.n;
        for (a, b) in acc.yty.iter_mut().zip(&p.yty) {
            *a += b;
        }
        acc.cty = acc.cty.add(&p.cty);
        acc.ctc = acc.ctc.add(&p.ctc);
        acc.xty = acc.xty.add(&p.xty);
        for (a, b) in acc.xtx.iter_mut().zip(&p.xtx) {
            *a += b;
        }
        acc.ctx = acc.ctx.add(&p.ctx);
    }
    acc
}

/// Combine aggregated multi-trait statistics into one [`AssocResult`]
/// per trait. The projection `QᵀX = R⁻ᵀ(CᵀX)` is computed ONCE and
/// shared across traits.
pub fn combine_multi(
    agg: &MultiTraitCompressed,
    party_rs: Option<&[Matrix]>,
    opts: CombineOptions,
) -> anyhow::Result<Vec<AssocResult>> {
    let k = agg.k();
    let t = agg.t();
    let method = match opts.r_method {
        RFactorMethod::Auto => {
            if party_rs.is_some() {
                RFactorMethod::Tsqr
            } else {
                RFactorMethod::Cholesky
            }
        }
        m => m,
    };
    let r = match method {
        RFactorMethod::Tsqr => tsqr_stack_r(
            party_rs.ok_or_else(|| anyhow::anyhow!("TSQR requires per-party R factors"))?,
        ),
        RFactorMethod::Cholesky => cholesky_upper(&agg.ctc)?,
        RFactorMethod::Auto => unreachable!(),
    };
    // shared across traits: QᵀX (K × M)
    let qt_x = solve_rt_b(&r, &agg.ctx);
    // per trait: QᵀY column
    let qt_y_all = solve_rt_b(&r, &agg.cty); // K × T
    let mut out = Vec::with_capacity(t);
    for tt in 0..t {
        let qt_y: Vec<f64> = (0..k).map(|i| qt_y_all[(i, tt)]).collect();
        let xty_t: Vec<f64> = (0..agg.m()).map(|j| agg.xty[(j, tt)]).collect();
        out.push(scan_stats_from_projected(&ScanStats {
            n: agg.n,
            k,
            yty: agg.yty[tt],
            xty: xty_t,
            xtx: agg.xtx.clone(),
            qt_y,
            qt_x: qt_x.clone(),
        }));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::scan::{combine_compressed, compress_party, flatten_for_sum, unflatten_sum};
    use crate::util::rng::Rng;

    fn data(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let mut ys = Matrix::randn(n, t, &mut rng);
        // trait 0 carries signal from variant 0
        for i in 0..n {
            ys[(i, 0)] += 0.5 * x[(i, 0)];
        }
        (ys, c, x)
    }

    /// Each trait of the multi-trait scan equals an independent
    /// single-trait scan of that trait.
    #[test]
    fn each_trait_matches_single_trait_scan() {
        let (ys, c, x) = data(150, 4, 12, 3, 210);
        let mtc = compress_party_multi(&ys, &c, &x);
        let res = combine_multi(
            &mtc,
            Some(std::slice::from_ref(&mtc.r)),
            CombineOptions::default(),
        )
        .unwrap();
        assert_eq!(res.len(), 3);
        for tt in 0..3 {
            let y = ys.col(tt);
            let cp = compress_party(&y, &c, &x, 12, Some(1));
            let (layout, flat) = flatten_for_sum(&cp);
            let agg = unflatten_sum(layout, &flat).unwrap();
            let single = combine_compressed(
                &agg,
                Some(std::slice::from_ref(&cp.r)),
                CombineOptions::default(),
            )
            .unwrap();
            assert!(
                rel_err(&res[tt].beta, &single.assoc.beta) < 1e-11,
                "trait {tt} beta"
            );
            assert!(rel_err(&res[tt].se, &single.assoc.se) < 1e-11, "trait {tt} se");
        }
    }

    /// Multi-party aggregation equals pooled computation, per trait.
    #[test]
    fn multi_party_multi_trait_equals_pooled() {
        let (ys1, c1, x1) = data(80, 3, 8, 2, 211);
        let (ys2, c2, x2) = data(120, 3, 8, 2, 212);
        let p1 = compress_party_multi(&ys1, &c1, &x1);
        let p2 = compress_party_multi(&ys2, &c2, &x2);
        let rs = vec![p1.r.clone(), p2.r.clone()];
        let agg = aggregate_multi(&[p1, p2]);
        let res = combine_multi(&agg, Some(&rs), CombineOptions::default()).unwrap();

        let ys = Matrix::vstack(&[&ys1, &ys2]);
        let c = Matrix::vstack(&[&c1, &c2]);
        let x = Matrix::vstack(&[&x1, &x2]);
        let pooled_cp = compress_party_multi(&ys, &c, &x);
        let pooled = combine_multi(
            &pooled_cp,
            Some(std::slice::from_ref(&pooled_cp.r)),
            CombineOptions::default(),
        )
        .unwrap();
        for tt in 0..2 {
            assert!(rel_err(&res[tt].beta, &pooled[tt].beta) < 1e-10, "trait {tt}");
            assert!(rel_err(&res[tt].p, &pooled[tt].p) < 1e-8, "trait {tt} p");
        }
    }

    /// The signal trait detects its causal variant; null traits don't.
    #[test]
    fn signal_isolated_to_correct_trait() {
        let (ys, c, x) = data(400, 3, 20, 3, 213);
        let mtc = compress_party_multi(&ys, &c, &x);
        let res = combine_multi(
            &mtc,
            Some(std::slice::from_ref(&mtc.r)),
            CombineOptions::default(),
        )
        .unwrap();
        assert!(res[0].p[0] < 1e-8, "signal trait p={}", res[0].p[0]);
        assert!(res[1].p[0] > 1e-4, "null trait 1 p={}", res[1].p[0]);
        assert!(res[2].p[0] > 1e-4, "null trait 2 p={}", res[2].p[0]);
    }

    #[test]
    fn shared_projection_consistency() {
        // xtx/ctx identical across traits by construction — aggregate
        // and single-trait compress agree on the shared pieces.
        let (ys, c, x) = data(60, 3, 5, 2, 214);
        let mtc = compress_party_multi(&ys, &c, &x);
        let cp0 = compress_party(&ys.col(0), &c, &x, 5, Some(1));
        assert!(rel_err(&mtc.xtx, &cp0.xtx) < 1e-13);
        assert!(rel_err(&mtc.ctx.data, &cp0.ctx.data) < 1e-13);
        assert!(rel_err(&[mtc.yty[0]], &[cp0.yty]) < 1e-13);
    }

    #[test]
    #[should_panic(expected = "trait count mismatch")]
    fn aggregate_rejects_mismatched_traits() {
        let (ys1, c1, x1) = data(40, 3, 5, 2, 215);
        let (ys2, c2, x2) = data(40, 3, 5, 3, 216);
        let p1 = compress_party_multi(&ys1, &c1, &x1);
        let p2 = compress_party_multi(&ys2, &c2, &x2);
        let _ = aggregate_multi(&[p1, p2]);
    }
}
