//! Combine-across stage (§2/§4): from aggregate sums to exact statistics.
//!
//! Work here is `O(PK² + K³ + K²M)` and **independent of N** — the paper's
//! central complexity claim (E3). Two ways to obtain the `R` factor of
//! the stacked covariate matrix:
//!
//! - [`RFactorMethod::Tsqr`]: stack per-party `R_p` and re-QR (Lemma 4.1).
//!   Numerically ideal, but requires the `R_p` in the clear.
//! - [`RFactorMethod::Cholesky`]: `R = chol(Σ C_pᵀC_p)`. Works from the
//!   securely-summed Gram matrix only; condition number is squared.
//!
//! `Auto` picks TSQR when per-party factors are available (plaintext
//! mode) and Cholesky otherwise.
//!
//! The stage is split for the sharded streaming pipeline: [`combine_base`]
//! factorizes the covariate block once into a [`CombineContext`]
//! (`O(K³)`), and [`combine_shard`] runs the Lemma 3.1 epilogue on one
//! shard's `O(K·width)` sums. Because the epilogue is per-variant, a
//! shard-by-shard combine is bit-identical to the single-shot
//! [`combine_compressed`] — which is itself now implemented as the
//! one-shard degenerate case.

use super::compressed::{AggregateSums, BaseSums, CompressedParty, ShardSums};
use crate::linalg::{cholesky_upper, solve_rt_b, tsqr_stack_r, Matrix};
use crate::stats::{
    fit_from_sufficient, scan_stats_from_projected, AssocResult, RegressionFit, ScanStats,
};

/// How the combine stage obtains the stacked-R factor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RFactorMethod {
    Auto,
    Tsqr,
    Cholesky,
}

/// Options for the combine stage.
#[derive(Clone, Copy, Debug)]
pub struct CombineOptions {
    pub r_method: RFactorMethod,
}

impl Default for CombineOptions {
    fn default() -> Self {
        CombineOptions { r_method: RFactorMethod::Auto }
    }
}

/// Output of a full association scan.
#[derive(Clone, Debug)]
pub struct ScanOutput {
    pub assoc: AssocResult,
    /// the covariate-only fit (γ̂ etc.) that comes for free from the sums
    pub covariate_fit: RegressionFit,
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

impl ScanOutput {
    pub fn min_p_value(&self) -> Option<f64> {
        self.assoc.min_p()
    }

    /// Indices of variants passing a significance threshold, sorted by p.
    pub fn hits(&self, alpha: f64) -> Vec<usize> {
        let mut hs: Vec<usize> = (0..self.m)
            .filter(|&j| self.assoc.p[j].is_finite() && self.assoc.p[j] < alpha)
            .collect();
        hs.sort_by(|&a, &b| self.assoc.p[a].partial_cmp(&self.assoc.p[b]).unwrap());
        hs
    }
}

/// The factorized covariate block, reused across every shard of a scan:
/// everything the Lemma 3.1 epilogue needs besides a shard's own sums.
#[derive(Clone, Debug)]
pub struct CombineContext {
    pub n: usize,
    pub k: usize,
    pub yty: f64,
    /// R factor of the stacked covariate matrix
    pub r: Matrix,
    /// Qᵀy = R⁻ᵀ(Cᵀy), length K
    pub qt_y: Vec<f64>,
    /// covariate-only fit (γ̂ etc.), computed once per session
    pub covariate_fit: RegressionFit,
}

/// Factorize the aggregate covariate block — `O(K³)`, once per scan.
pub fn combine_base(
    base: &BaseSums,
    party_rs: Option<&[Matrix]>,
    opts: CombineOptions,
) -> anyhow::Result<CombineContext> {
    let k = base.cty.len();
    let method = match opts.r_method {
        RFactorMethod::Auto => {
            if party_rs.is_some() {
                RFactorMethod::Tsqr
            } else {
                RFactorMethod::Cholesky
            }
        }
        m => m,
    };
    let r = match method {
        RFactorMethod::Tsqr => {
            let rs = party_rs
                .ok_or_else(|| anyhow::anyhow!("TSQR requires per-party R factors"))?;
            tsqr_stack_r(rs)
        }
        RFactorMethod::Cholesky => cholesky_upper(&base.ctc)?,
        RFactorMethod::Auto => unreachable!(),
    };

    // Projection through Qᵀ without Q: Qᵀy = R⁻ᵀ(Cᵀy).
    let qt_y = solve_rt_b(&r, &Matrix::from_vec(k, 1, base.cty.clone())).data;
    let covariate_fit = fit_from_sufficient(base.n, base.yty, &base.cty, &base.ctc)?;

    Ok(CombineContext { n: base.n, k, yty: base.yty, r, qt_y, covariate_fit })
}

/// Lemma 3.1 epilogue on one shard's aggregate sums — `O(K²·width)`,
/// per-variant independent, so shard results concatenate into exactly
/// the single-shot answer.
pub fn combine_shard(ctx: &CombineContext, shard: &ShardSums) -> AssocResult {
    combine_shard_parts(ctx, &shard.xty, &shard.xtx, &shard.ctx)
}

/// Borrowed-parts form of [`combine_shard`], so the degenerate full-M
/// path can feed the aggregate's own slices without cloning them into a
/// `ShardSums` first.
fn combine_shard_parts(
    cx: &CombineContext,
    xty: &[f64],
    xtx: &[f64],
    ctx_cols: &Matrix,
) -> AssocResult {
    // QᵀX = R⁻ᵀ(CᵀX), columns of this shard only.
    let qt_x = solve_rt_b(&cx.r, ctx_cols);
    scan_stats_from_projected(&ScanStats {
        n: cx.n,
        k: cx.k,
        yty: cx.yty,
        xty: xty.to_vec(),
        xtx: xtx.to_vec(),
        qt_y: cx.qt_y.clone(),
        qt_x,
    })
}

/// Combine aggregate sums (and optionally per-party `R_p` factors for the
/// TSQR path) into exact scan statistics — the one-shard degenerate case
/// of the streaming pipeline.
pub fn combine_compressed(
    agg: &AggregateSums,
    party_rs: Option<&[Matrix]>,
    opts: CombineOptions,
) -> anyhow::Result<ScanOutput> {
    let k = agg.cty.len();
    let m = agg.xty.len();
    let cx = combine_base(&agg.base(), party_rs, opts)?;
    let assoc = combine_shard_parts(&cx, &agg.xty, &agg.xtx, &agg.ctx);
    Ok(ScanOutput { assoc, covariate_fit: cx.covariate_fit, n: agg.n, k, m })
}

/// §2 only (no transient covariates): multi-party plain linear regression
/// from per-party compressed statistics.
pub fn combine_regression(parties: &[CompressedParty]) -> anyhow::Result<RegressionFit> {
    anyhow::ensure!(!parties.is_empty());
    let k = parties[0].k();
    let n: usize = parties.iter().map(|p| p.n).sum();
    let yty: f64 = parties.iter().map(|p| p.yty).sum();
    let mut cty = vec![0.0; k];
    let mut ctc = Matrix::zeros(k, k);
    for p in parties {
        anyhow::ensure!(p.k() == k, "covariate dimension mismatch across parties");
        for i in 0..k {
            cty[i] += p.cty[i];
        }
        ctc = ctc.add(&p.ctc);
    }
    fit_from_sufficient(n, yty, &cty, &ctc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::scan::compressed::{compress_party, flatten_for_sum, unflatten_sum};
    use crate::scan::ShardPlan;
    use crate::util::rng::Rng;

    fn party(n: usize, k: usize, m: usize, seed: u64) -> (Vec<f64>, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|i| 0.4 * x[(i, 0)] + rng.normal()).collect();
        (y, c, x)
    }

    fn aggregate(cps: &[CompressedParty]) -> AggregateSums {
        let (layout, mut acc) = flatten_for_sum(&cps[0]);
        for cp in &cps[1..] {
            let (_, f) = flatten_for_sum(cp);
            for (a, b) in acc.iter_mut().zip(&f) {
                *a += b;
            }
        }
        unflatten_sum(layout, &acc).unwrap()
    }

    #[test]
    fn multiparty_equals_pooled_tsqr_and_cholesky() {
        let (y1, c1, x1) = party(40, 3, 8, 140);
        let (y2, c2, x2) = party(55, 3, 8, 141);
        let (y3, c3, x3) = party(33, 3, 8, 142);
        let cps: Vec<CompressedParty> = [(&y1, &c1, &x1), (&y2, &c2, &x2), (&y3, &c3, &x3)]
            .iter()
            .map(|(y, c, x)| compress_party(y, c, x, 8, Some(1)))
            .collect();
        let agg = aggregate(&cps);
        let rs: Vec<Matrix> = cps.iter().map(|p| p.r.clone()).collect();

        // pooled oracle
        let y: Vec<f64> = y1.iter().chain(&y2).chain(&y3).copied().collect();
        let c = Matrix::vstack(&[&c1, &c2, &c3]);
        let x = Matrix::vstack(&[&x1, &x2, &x3]);
        let pooled_cp = compress_party(&y, &c, &x, 8, Some(1));
        let pooled_agg = aggregate(std::slice::from_ref(&pooled_cp));
        let oracle = combine_compressed(
            &pooled_agg,
            Some(std::slice::from_ref(&pooled_cp.r)),
            CombineOptions { r_method: RFactorMethod::Tsqr },
        )
        .unwrap();

        for method in [RFactorMethod::Tsqr, RFactorMethod::Cholesky] {
            let got = combine_compressed(
                &agg,
                Some(&rs),
                CombineOptions { r_method: method },
            )
            .unwrap();
            assert!(
                rel_err(&got.assoc.beta, &oracle.assoc.beta) < 1e-9,
                "{method:?} beta"
            );
            assert!(rel_err(&got.assoc.se, &oracle.assoc.se) < 1e-9, "{method:?} se");
        }
    }

    #[test]
    fn shard_by_shard_combine_is_bit_identical() {
        let (y, c, x) = party(90, 4, 21, 148);
        let cp = compress_party(&y, &c, &x, 21, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let single = combine_compressed(&agg, None, CombineOptions::default()).unwrap();

        let ctx = combine_base(&agg.base(), None, CombineOptions::default()).unwrap();
        let plan = ShardPlan::new(21, 6); // 4 shards, ragged tail
        let mut beta = Vec::new();
        let mut se = Vec::new();
        for r in plan.ranges() {
            let sums = ShardSums {
                xty: agg.xty[r.j0..r.j1].to_vec(),
                xtx: agg.xtx[r.j0..r.j1].to_vec(),
                ctx: agg.ctx.col_slice(r.j0, r.j1),
            };
            let part = combine_shard(&ctx, &sums);
            beta.extend_from_slice(&part.beta);
            se.extend_from_slice(&part.se);
        }
        // per-variant epilogue + column-wise triangular solve → bit-equal
        for j in 0..21 {
            assert_eq!(beta[j].to_bits(), single.assoc.beta[j].to_bits(), "beta[{j}]");
            assert_eq!(se[j].to_bits(), single.assoc.se[j].to_bits(), "se[{j}]");
        }
    }

    #[test]
    fn auto_uses_cholesky_without_rs() {
        let (y, c, x) = party(60, 4, 5, 143);
        let cp = compress_party(&y, &c, &x, 5, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let out = combine_compressed(&agg, None, CombineOptions::default()).unwrap();
        assert_eq!(out.m, 5);
        assert!(out.min_p_value().is_some());
    }

    #[test]
    fn tsqr_without_rs_errors() {
        let (y, c, x) = party(30, 3, 4, 144);
        let cp = compress_party(&y, &c, &x, 4, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        assert!(combine_compressed(
            &agg,
            None,
            CombineOptions { r_method: RFactorMethod::Tsqr }
        )
        .is_err());
    }

    #[test]
    fn combine_regression_matches_pooled_fit() {
        let (y1, c1, x1) = party(50, 4, 1, 145);
        let (y2, c2, x2) = party(70, 4, 1, 146);
        let cp1 = compress_party(&y1, &c1, &x1, 1, Some(1));
        let cp2 = compress_party(&y2, &c2, &x2, 1, Some(1));
        let fit = combine_regression(&[cp1, cp2]).unwrap();

        let y: Vec<f64> = y1.iter().chain(&y2).copied().collect();
        let c = Matrix::vstack(&[&c1, &c2]);
        let oracle = fit_from_sufficient(
            y.len(),
            y.iter().map(|v| v * v).sum(),
            &c.t_matvec(&y),
            &c.gram(),
        )
        .unwrap();
        assert!(rel_err(&fit.gamma, &oracle.gamma) < 1e-11);
        assert!(rel_err(&fit.se, &oracle.se) < 1e-11);
    }

    #[test]
    fn hits_sorted_by_p() {
        let (y, c, x) = party(200, 3, 12, 147);
        let cp = compress_party(&y, &c, &x, 12, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let out = combine_compressed(&agg, None, CombineOptions::default()).unwrap();
        let hits = out.hits(0.5);
        for w in hits.windows(2) {
            assert!(out.assoc.p[w[0]] <= out.assoc.p[w[1]]);
        }
        // variant 0 carries real signal → should be the top hit
        assert_eq!(hits.first(), Some(&0));
    }
}
