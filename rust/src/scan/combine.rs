//! Combine-across stage (§2/§4): from aggregate sums to exact statistics.
//!
//! Work here is `O(PK² + K³ + K²M + KMT)` and **independent of N** — the
//! paper's central complexity claim (E3). Two ways to obtain the `R`
//! factor of the stacked covariate matrix:
//!
//! - [`RFactorMethod::Tsqr`]: stack per-party `R_p` and re-QR (Lemma 4.1).
//!   Numerically ideal, but requires the `R_p` in the clear.
//! - [`RFactorMethod::Cholesky`]: `R = chol(Σ C_pᵀC_p)`. Works from the
//!   securely-summed Gram matrix only; condition number is squared.
//!
//! `Auto` picks TSQR when per-party factors are available (plaintext
//! mode) and Cholesky otherwise.
//!
//! The stage is split for the sharded streaming pipeline: [`combine_base`]
//! factorizes the covariate block once into a [`CombineContext`]
//! (`O(K³)`, plus one `O(K²)` projection and covariate fit per trait),
//! and [`combine_shard`] runs the Lemma 3.1 epilogue on one shard's
//! `O((K+T)·width)` sums — the `QᵀX = R⁻ᵀ(CᵀX)` projection is computed
//! **once per shard and shared by all T traits**, which is the paper's
//! §3 amortization. Because the epilogue is per-variant and per-trait,
//! a shard-by-shard combine is bit-identical to the single-shot
//! [`combine_compressed`] — which is itself implemented as the one-shard
//! degenerate case — and trait `t` of a T-trait combine is bit-identical
//! to a `T = 1` combine of that trait.

use super::compressed::{AggregateSums, BaseSums, CompressedParty, ShardSums};
use crate::linalg::{
    cholesky_upper, project_append, qr_append, solve_rt_b, tsqr_stack_r, Matrix,
};
use crate::stats::{
    fit_from_sufficient, scan_stats_from_projected_parts, AssocResult, RegressionFit,
};

/// How the combine stage obtains the stacked-R factor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RFactorMethod {
    Auto,
    Tsqr,
    Cholesky,
}

/// Options for the combine stage.
#[derive(Clone, Copy, Debug)]
pub struct CombineOptions {
    pub r_method: RFactorMethod,
}

impl Default for CombineOptions {
    fn default() -> Self {
        CombineOptions { r_method: RFactorMethod::Auto }
    }
}

/// Output of a full association scan: one [`AssocResult`] per trait
/// (`assoc.len() == T`; a classic single-trait scan is `T = 1` and its
/// result lives at `assoc[0]`).
#[derive(Clone, Debug)]
pub struct ScanOutput {
    /// per-trait association statistics, length T
    pub assoc: Vec<AssocResult>,
    /// per-trait covariate-only fits (γ̂ etc.) that come for free from
    /// the sums, length T
    pub covariate_fit: Vec<RegressionFit>,
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

impl ScanOutput {
    /// Number of traits scanned.
    pub fn t(&self) -> usize {
        self.assoc.len()
    }

    /// Minimum finite p-value of trait 0 (the primary trait).
    pub fn min_p_value(&self) -> Option<f64> {
        self.assoc[0].min_p()
    }

    /// Indices of trait-0 variants passing a significance threshold,
    /// sorted by p. See [`hits_for`](Self::hits_for) for other traits.
    pub fn hits(&self, alpha: f64) -> Vec<usize> {
        self.hits_for(0, alpha)
    }

    /// Indices of trait `tt`'s variants passing a significance
    /// threshold, sorted by p.
    pub fn hits_for(&self, tt: usize, alpha: f64) -> Vec<usize> {
        let assoc = &self.assoc[tt];
        let mut hs: Vec<usize> = (0..self.m)
            .filter(|&j| assoc.p[j].is_finite() && assoc.p[j] < alpha)
            .collect();
        hs.sort_by(|&a, &b| assoc.p[a].total_cmp(&assoc.p[b]));
        hs
    }
}

/// The factorized covariate block, reused across every shard of a scan:
/// everything the Lemma 3.1 epilogue needs besides a shard's own sums.
#[derive(Clone, Debug)]
pub struct CombineContext {
    pub n: usize,
    pub k: usize,
    /// YᵀY diag, length T
    pub yty: Vec<f64>,
    /// R factor of the stacked covariate matrix
    pub r: Matrix,
    /// QᵀY = R⁻ᵀ(CᵀY), K × T
    pub qt_y: Matrix,
    /// per-trait covariate-only fits (γ̂ etc.), computed once per session
    pub covariate_fit: Vec<RegressionFit>,
}

impl CombineContext {
    pub fn t(&self) -> usize {
        self.yty.len()
    }

    /// Current basis width: the `K` permanent covariates plus every
    /// column promoted by [`append_column`](Self::append_column).
    pub fn basis_k(&self) -> usize {
        self.r.rows
    }

    /// Promote a variant into the covariate basis (the SELECT-phase
    /// rank-1 extension): grow the cached `R` factor by one column via
    /// [`qr_append`] and extend every trait's `QᵀY` projection by its one
    /// new entry — no pass over party data and no re-factorization.
    ///
    /// `u` is the promoted column's projection against the *current*
    /// basis (`Qᵀx`, length [`basis_k`](Self::basis_k)), `xtx` its `x·x`,
    /// and `xty` its `xᵀY` cross-products (length `T`) — all of which sit
    /// in the cached compressed sums. Returns the residual norm `ρ` so
    /// callers can extend their own cached projections with
    /// [`project_append`]. Errors if the column is numerically in the
    /// span of the basis. `covariate_fit` deliberately keeps the
    /// session's original covariate-only fits.
    pub fn append_column(&mut self, u: &[f64], xtx: f64, xty: &[f64]) -> anyhow::Result<f64> {
        let kb = self.basis_k();
        anyhow::ensure!(u.len() == kb, "projection length {} != basis {kb}", u.len());
        anyhow::ensure!(xty.len() == self.t(), "xᵀY trait-count mismatch");
        let r = qr_append(&self.r, u, xtx)?;
        let rho = r[(kb, kb)];
        let mut qt_y = Matrix::zeros(kb + 1, self.t());
        for i in 0..kb {
            for tt in 0..self.t() {
                qt_y[(i, tt)] = self.qt_y[(i, tt)];
            }
        }
        for tt in 0..self.t() {
            qt_y[(kb, tt)] = project_append(u, rho, &self.qt_y.col(tt), xty[tt]);
        }
        self.r = r;
        self.qt_y = qt_y;
        self.k += 1;
        Ok(rho)
    }
}

/// Factorize the aggregate covariate block — `O(K³)` plus `O(K²)` per
/// trait, once per scan.
pub fn combine_base(
    base: &BaseSums,
    party_rs: Option<&[Matrix]>,
    opts: CombineOptions,
) -> anyhow::Result<CombineContext> {
    let k = base.cty.rows;
    let t = base.t();
    anyhow::ensure!(base.cty.cols == t, "CᵀY trait dimension mismatch");
    let method = match opts.r_method {
        RFactorMethod::Auto => {
            if party_rs.is_some() {
                RFactorMethod::Tsqr
            } else {
                RFactorMethod::Cholesky
            }
        }
        m => m,
    };
    let r = match method {
        RFactorMethod::Tsqr => {
            let rs = party_rs
                .ok_or_else(|| anyhow::anyhow!("TSQR requires per-party R factors"))?;
            tsqr_stack_r(rs)
        }
        RFactorMethod::Cholesky => cholesky_upper(&base.ctc)?,
        RFactorMethod::Auto => unreachable!(),
    };

    // Projection through Qᵀ without Q: QᵀY = R⁻ᵀ(CᵀY) — one triangular
    // solve over all T trait columns (column-independent, so trait t is
    // bit-identical to a solo K×1 solve of that trait).
    let qt_y = solve_rt_b(&r, &base.cty);
    let covariate_fit = (0..t)
        .map(|tt| fit_from_sufficient(base.n, base.yty[tt], &base.cty.col(tt), &base.ctc))
        .collect::<anyhow::Result<Vec<_>>>()?;

    Ok(CombineContext { n: base.n, k, yty: base.yty.clone(), r, qt_y, covariate_fit })
}

/// Lemma 3.1 epilogue on one shard's aggregate sums — `O((K² + KT)·width)`,
/// per-variant and per-trait independent, so shard results concatenate
/// into exactly the single-shot answer. Returns one [`AssocResult`] per
/// trait; the `QᵀX` projection is computed once and shared across traits.
pub fn combine_shard(ctx: &CombineContext, shard: &ShardSums) -> Vec<AssocResult> {
    combine_shard_parts(ctx, &shard.xty, &shard.xtx, &shard.ctx)
}

/// Borrowed-parts form of [`combine_shard`], so the degenerate full-M
/// path can feed the aggregate's own pieces without cloning them into a
/// `ShardSums` first.
fn combine_shard_parts(
    cx: &CombineContext,
    xty: &Matrix,
    xtx: &[f64],
    ctx_cols: &Matrix,
) -> Vec<AssocResult> {
    // QᵀX = R⁻ᵀ(CᵀX), columns of this shard only — computed ONCE and
    // borrowed by every trait's epilogue (no per-trait clone of the
    // K×width projection or the shared X·X).
    let qt_x = solve_rt_b(&cx.r, ctx_cols);
    (0..cx.t())
        .map(|tt| {
            scan_stats_from_projected_parts(
                cx.n,
                cx.k,
                cx.yty[tt],
                &xty.col(tt),
                xtx,
                &cx.qt_y.col(tt),
                &qt_x,
            )
        })
        .collect()
}

/// Combine aggregate sums (and optionally per-party `R_p` factors for the
/// TSQR path) into exact scan statistics — the one-shard degenerate case
/// of the streaming pipeline.
pub fn combine_compressed(
    agg: &AggregateSums,
    party_rs: Option<&[Matrix]>,
    opts: CombineOptions,
) -> anyhow::Result<ScanOutput> {
    let k = agg.cty.rows;
    let m = agg.xtx.len();
    let cx = combine_base(&agg.base(), party_rs, opts)?;
    let assoc = combine_shard_parts(&cx, &agg.xty, &agg.xtx, &agg.ctx);
    Ok(ScanOutput { assoc, covariate_fit: cx.covariate_fit, n: agg.n, k, m })
}

/// §2 only (no transient covariates): multi-party plain linear regression
/// from per-party compressed statistics — one [`RegressionFit`] per
/// trait.
pub fn combine_regression(parties: &[CompressedParty]) -> anyhow::Result<Vec<RegressionFit>> {
    anyhow::ensure!(!parties.is_empty());
    let k = parties[0].k();
    let t = parties[0].t();
    let n: usize = parties.iter().map(|p| p.n).sum();
    let mut yty = vec![0.0; t];
    let mut cty = Matrix::zeros(k, t);
    let mut ctc = Matrix::zeros(k, k);
    for p in parties {
        anyhow::ensure!(p.k() == k, "covariate dimension mismatch across parties");
        anyhow::ensure!(p.t() == t, "trait dimension mismatch across parties");
        for (a, b) in yty.iter_mut().zip(&p.yty) {
            *a += b;
        }
        cty = cty.add(&p.cty);
        ctc = ctc.add(&p.ctc);
    }
    (0..t)
        .map(|tt| fit_from_sufficient(n, yty[tt], &cty.col(tt), &ctc))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::scan::compressed::{compress_party, flatten_for_sum, unflatten_sum};
    use crate::scan::ShardPlan;
    use crate::util::rng::Rng;

    fn party(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let mut ys = Matrix::randn(n, t, &mut rng);
        for i in 0..n {
            ys[(i, 0)] += 0.4 * x[(i, 0)];
        }
        (ys, c, x)
    }

    fn aggregate(cps: &[CompressedParty]) -> AggregateSums {
        let (layout, mut acc) = flatten_for_sum(&cps[0]);
        for cp in &cps[1..] {
            let (_, f) = flatten_for_sum(cp);
            for (a, b) in acc.iter_mut().zip(&f) {
                *a += b;
            }
        }
        unflatten_sum(layout, &acc).unwrap()
    }

    #[test]
    fn multiparty_equals_pooled_tsqr_and_cholesky() {
        let (y1, c1, x1) = party(40, 3, 8, 1, 140);
        let (y2, c2, x2) = party(55, 3, 8, 1, 141);
        let (y3, c3, x3) = party(33, 3, 8, 1, 142);
        let cps: Vec<CompressedParty> = [(&y1, &c1, &x1), (&y2, &c2, &x2), (&y3, &c3, &x3)]
            .iter()
            .map(|(y, c, x)| compress_party(y, c, x, 8, Some(1)))
            .collect();
        let agg = aggregate(&cps);
        let rs: Vec<Matrix> = cps.iter().map(|p| p.r.clone()).collect();

        // pooled oracle
        let ys = Matrix::vstack(&[&y1, &y2, &y3]);
        let c = Matrix::vstack(&[&c1, &c2, &c3]);
        let x = Matrix::vstack(&[&x1, &x2, &x3]);
        let pooled_cp = compress_party(&ys, &c, &x, 8, Some(1));
        let pooled_agg = aggregate(std::slice::from_ref(&pooled_cp));
        let oracle = combine_compressed(
            &pooled_agg,
            Some(std::slice::from_ref(&pooled_cp.r)),
            CombineOptions { r_method: RFactorMethod::Tsqr },
        )
        .unwrap();

        for method in [RFactorMethod::Tsqr, RFactorMethod::Cholesky] {
            let got = combine_compressed(
                &agg,
                Some(&rs),
                CombineOptions { r_method: method },
            )
            .unwrap();
            assert!(
                rel_err(&got.assoc[0].beta, &oracle.assoc[0].beta) < 1e-9,
                "{method:?} beta"
            );
            assert!(rel_err(&got.assoc[0].se, &oracle.assoc[0].se) < 1e-9, "{method:?} se");
        }
    }

    #[test]
    fn shard_by_shard_combine_is_bit_identical() {
        let (ys, c, x) = party(90, 4, 21, 2, 148);
        let cp = compress_party(&ys, &c, &x, 21, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let single = combine_compressed(&agg, None, CombineOptions::default()).unwrap();

        let ctx = combine_base(&agg.base(), None, CombineOptions::default()).unwrap();
        let plan = ShardPlan::new(21, 6); // 4 shards, ragged tail
        let mut beta = vec![Vec::new(), Vec::new()];
        let mut se = vec![Vec::new(), Vec::new()];
        for r in plan.ranges() {
            let parts = combine_shard(&ctx, &agg.shard_sums(r.j0, r.j1));
            assert_eq!(parts.len(), 2);
            for tt in 0..2 {
                beta[tt].extend_from_slice(&parts[tt].beta);
                se[tt].extend_from_slice(&parts[tt].se);
            }
        }
        // per-variant epilogue + column-wise triangular solve → bit-equal
        for tt in 0..2 {
            for j in 0..21 {
                assert_eq!(
                    beta[tt][j].to_bits(),
                    single.assoc[tt].beta[j].to_bits(),
                    "beta[{tt}][{j}]"
                );
                assert_eq!(
                    se[tt][j].to_bits(),
                    single.assoc[tt].se[j].to_bits(),
                    "se[{tt}][{j}]"
                );
            }
        }
    }

    /// Trait `t` of a multi-trait combine is bit-identical to a T = 1
    /// combine of that trait alone (the §3 amortization changes cost,
    /// never values).
    #[test]
    fn per_trait_combine_bit_identical_to_single_trait() {
        let (ys, c, x) = party(120, 4, 12, 3, 149);
        let multi_cp = compress_party(&ys, &c, &x, 12, Some(1));
        let multi_agg = aggregate(std::slice::from_ref(&multi_cp));
        let multi = combine_compressed(&multi_agg, None, CombineOptions::default()).unwrap();
        assert_eq!(multi.t(), 3);
        for tt in 0..3 {
            let cp = compress_party(&Matrix::from_col(ys.col(tt)), &c, &x, 12, Some(1));
            let agg = aggregate(std::slice::from_ref(&cp));
            let single = combine_compressed(&agg, None, CombineOptions::default()).unwrap();
            for j in 0..12 {
                assert_eq!(
                    multi.assoc[tt].beta[j].to_bits(),
                    single.assoc[0].beta[j].to_bits(),
                    "beta[{tt}][{j}]"
                );
                assert_eq!(
                    multi.assoc[tt].p[j].to_bits(),
                    single.assoc[0].p[j].to_bits(),
                    "p[{tt}][{j}]"
                );
            }
            assert_eq!(
                multi.covariate_fit[tt].gamma, single.covariate_fit[0].gamma,
                "gamma[{tt}]"
            );
        }
    }

    /// The signal trait detects its causal variant; null traits don't.
    #[test]
    fn signal_isolated_to_correct_trait() {
        let (ys, c, x) = party(400, 3, 20, 3, 213);
        let cp = compress_party(&ys, &c, &x, 20, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let res = combine_compressed(
            &agg,
            Some(std::slice::from_ref(&cp.r)),
            CombineOptions::default(),
        )
        .unwrap();
        assert!(res.assoc[0].p[0] < 1e-8, "signal trait p={}", res.assoc[0].p[0]);
        assert!(res.assoc[1].p[0] > 1e-4, "null trait 1 p={}", res.assoc[1].p[0]);
        assert!(res.assoc[2].p[0] > 1e-4, "null trait 2 p={}", res.assoc[2].p[0]);
        assert_eq!(res.hits_for(0, 1e-8).first(), Some(&0));
    }

    #[test]
    fn auto_uses_cholesky_without_rs() {
        let (ys, c, x) = party(60, 4, 5, 1, 143);
        let cp = compress_party(&ys, &c, &x, 5, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let out = combine_compressed(&agg, None, CombineOptions::default()).unwrap();
        assert_eq!(out.m, 5);
        assert!(out.min_p_value().is_some());
    }

    #[test]
    fn tsqr_without_rs_errors() {
        let (ys, c, x) = party(30, 3, 4, 1, 144);
        let cp = compress_party(&ys, &c, &x, 4, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        assert!(combine_compressed(
            &agg,
            None,
            CombineOptions { r_method: RFactorMethod::Tsqr }
        )
        .is_err());
    }

    #[test]
    fn combine_regression_matches_pooled_fit() {
        let (y1, c1, x1) = party(50, 4, 1, 2, 145);
        let (y2, c2, x2) = party(70, 4, 1, 2, 146);
        let cp1 = compress_party(&y1, &c1, &x1, 1, Some(1));
        let cp2 = compress_party(&y2, &c2, &x2, 1, Some(1));
        let fits = combine_regression(&[cp1, cp2]).unwrap();
        assert_eq!(fits.len(), 2);

        let ys = Matrix::vstack(&[&y1, &y2]);
        let c = Matrix::vstack(&[&c1, &c2]);
        for tt in 0..2 {
            let y = ys.col(tt);
            let oracle = fit_from_sufficient(
                y.len(),
                y.iter().map(|v| v * v).sum(),
                &c.t_matvec(&y),
                &c.gram(),
            )
            .unwrap();
            assert!(rel_err(&fits[tt].gamma, &oracle.gamma) < 1e-11, "trait {tt}");
            assert!(rel_err(&fits[tt].se, &oracle.se) < 1e-11, "trait {tt}");
        }
    }

    /// Promoting a variant via the rank-1 append yields the same epilogue
    /// statistics as compressing with that variant as a permanent
    /// covariate from the start.
    #[test]
    fn append_column_matches_recompressed_covariate() {
        use crate::linalg::project_append;
        use crate::stats::scan_stats_from_projected_parts;
        let (ys, c, x) = party(150, 3, 6, 150);
        let cp = compress_party(&ys, &c, &x, 6, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let mut cx = combine_base(&agg.base(), None, CombineOptions::default()).unwrap();

        // promote variant 0 using only cached sums
        let promoted = 0usize;
        let u = crate::linalg::solve_rt_b(
            &cx.r,
            &agg.ctx.col_slice(promoted, promoted + 1),
        )
        .col(0);
        let rho = cx.append_column(&u, agg.xtx[promoted], agg.xty.row(promoted)).unwrap();
        assert!(rho > 0.0);
        assert_eq!(cx.basis_k(), 4);
        assert_eq!(cx.k, 4);

        // oracle: recompress with [C | x_0] as the covariate block
        let c_aug = Matrix::vstack(&[&c.transpose(), &Matrix::from_col(x.col(0)).transpose()])
            .transpose();
        let cp2 = compress_party(&ys, &c_aug, &x, 6, Some(1));
        let agg2 = aggregate(std::slice::from_ref(&cp2));
        let cx2 = combine_base(&agg2.base(), None, CombineOptions::default()).unwrap();
        assert!(rel_err(&cx.r.data, &cx2.r.data) < 1e-9);
        assert!(rel_err(&cx.qt_y.data, &cx2.qt_y.data) < 1e-9);

        // epilogue for another variant against the augmented basis: the
        // appended projection row comes from the raw cross-product
        let probe = 3usize;
        let u_probe = crate::linalg::solve_rt_b(&cx2.r, &agg2.ctx.col_slice(probe, probe + 1));
        let mut u_inc = crate::linalg::solve_rt_b(
            &combine_base(&agg.base(), None, CombineOptions::default()).unwrap().r,
            &agg.ctx.col_slice(probe, probe + 1),
        )
        .col(0);
        let btx: f64 = x.col(promoted).iter().zip(&x.col(probe)).map(|(a, b)| a * b).sum();
        let e = project_append(&u, rho, &u_inc, btx);
        u_inc.push(e);
        assert!(rel_err(&u_inc, &u_probe.col(0)) < 1e-9);

        let a = scan_stats_from_projected_parts(
            cx.n,
            cx.k,
            cx.yty[0],
            &agg.xty.col(0)[probe..probe + 1],
            &agg.xtx[probe..probe + 1],
            &cx.qt_y.col(0),
            &Matrix::from_col(u_inc),
        );
        let b = scan_stats_from_projected_parts(
            cx2.n,
            cx2.k,
            cx2.yty[0],
            &agg2.xty.col(0)[probe..probe + 1],
            &agg2.xtx[probe..probe + 1],
            &cx2.qt_y.col(0),
            &u_probe,
        );
        assert!((a.beta[0] - b.beta[0]).abs() < 1e-8 * b.beta[0].abs().max(1.0));
        assert!((a.se[0] - b.se[0]).abs() < 1e-8 * b.se[0].abs().max(1.0));
    }

    #[test]
    fn hits_sorted_by_p() {
        let (ys, c, x) = party(200, 3, 12, 1, 147);
        let cp = compress_party(&ys, &c, &x, 12, Some(1));
        let agg = aggregate(std::slice::from_ref(&cp));
        let out = combine_compressed(&agg, None, CombineOptions::default()).unwrap();
        let hits = out.hits(0.5);
        for w in hits.windows(2) {
            assert!(out.assoc[0].p[w[0]] <= out.assoc[0].p[w[1]]);
        }
        // variant 0 carries real signal → should be the top hit
        assert_eq!(hits.first(), Some(&0));
    }
}
