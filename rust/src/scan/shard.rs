//! Variant-shard planning for the streaming scan pipeline.
//!
//! A [`ShardPlan`] splits the `M` transient covariates into fixed-width
//! column shards. The protocol runs one contribution round per shard, so
//! peak payload and leader-side working memory are `O(K·width)` instead
//! of `O(K·M)`, and parties can compress shard `s+1` while the leader is
//! still combining shard `s`. `width == 0` (or `width ≥ M`) degenerates
//! to the single-shot pipeline: exactly one shard covering all of `M`.

/// Immutable split of `M` variants into fixed-width column shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    m: usize,
    width: usize,
}

impl ShardPlan {
    /// Plan a scan over `m` variants with shard width `width`.
    /// `width == 0` means "no sharding": one shard spanning all of `m`.
    pub fn new(m: usize, width: usize) -> ShardPlan {
        let width = if width == 0 { m.max(1) } else { width };
        ShardPlan { m, width }
    }

    /// Total variants covered by the plan.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Shard width (last shard may be narrower).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of shards (≥ 1, even for `m == 0`, so every session has at
    /// least one contribution round and the degenerate case stays on the
    /// same code path).
    pub fn count(&self) -> usize {
        self.m.div_ceil(self.width).max(1)
    }

    /// Column range of shard `s`.
    pub fn range(&self, s: usize) -> ShardRange {
        assert!(s < self.count(), "shard {s} out of range (count {})", self.count());
        let j0 = s * self.width;
        let j1 = (j0 + self.width).min(self.m);
        ShardRange { index: s, j0, j1 }
    }

    /// Iterate all shard ranges in scan order.
    pub fn ranges(self) -> impl Iterator<Item = ShardRange> {
        (0..self.count()).map(move |s| self.range(s))
    }
}

/// One shard's column range `[j0, j1)` within the full variant axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    pub index: usize,
    pub j0: usize,
    pub j1: usize,
}

impl ShardRange {
    /// Number of variant columns in this shard.
    pub fn width(&self) -> usize {
        self.j1 - self.j0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_width_is_single_shot() {
        let p = ShardPlan::new(1000, 0);
        assert_eq!(p.count(), 1);
        let r = p.range(0);
        assert_eq!((r.j0, r.j1, r.width()), (0, 1000, 1000));
    }

    #[test]
    fn exact_division() {
        let p = ShardPlan::new(1024, 256);
        assert_eq!(p.count(), 4);
        assert_eq!(p.range(3), ShardRange { index: 3, j0: 768, j1: 1024 });
        assert!(p.ranges().all(|r| r.width() == 256));
    }

    #[test]
    fn ragged_tail() {
        let p = ShardPlan::new(1000, 300);
        assert_eq!(p.count(), 4);
        let last = p.range(3);
        assert_eq!((last.j0, last.j1, last.width()), (900, 1000, 100));
        let total: usize = p.ranges().map(|r| r.width()).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn width_larger_than_m() {
        let p = ShardPlan::new(10, 4096);
        assert_eq!(p.count(), 1);
        assert_eq!(p.range(0).width(), 10);
    }

    #[test]
    fn empty_m_still_has_one_round() {
        let p = ShardPlan::new(0, 0);
        assert_eq!(p.count(), 1);
        assert_eq!(p.range(0).width(), 0);
    }

    #[test]
    fn ranges_are_contiguous_and_ordered() {
        let p = ShardPlan::new(77, 8);
        let mut expect = 0;
        for r in p.ranges() {
            assert_eq!(r.j0, expect);
            assert!(r.j1 > r.j0 || p.m() == 0);
            expect = r.j1;
        }
        assert_eq!(expect, 77);
    }

    #[test]
    #[should_panic]
    fn out_of_range_shard_panics() {
        ShardPlan::new(10, 5).range(2);
    }
}
