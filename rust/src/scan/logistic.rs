//! Secure logistic regression (IRLS) over the compressed-stat pipeline.
//!
//! The linear scan secure-sums *unweighted* cross-products once. A
//! logistic scan iterates: the leader broadcasts the current null-model
//! iterate β, every party recomputes the **weighted** cross-products
//! `CᵀWC`, `CᵀWz` and the deviance locally from its shard of samples,
//! and the same secure-sum layer (plaintext / masked / Shamir — with
//! continued absolute round numbering, so pads and shares stay
//! domain-separated from the base round and the weighted shard rounds)
//! aggregates them. Once the deviance stabilizes the leader broadcasts
//! the final β and the parties stream one weighted pass over the
//! variant shards (`Xᵀ(y−μ̂)`, `diag(XᵀWX)`, `CᵀWX`), from which the
//! leader computes per-variant score tests — per-iteration traffic
//! `O(K²·T)`, per-shard traffic `O(K·shard_m·T)`, same shapes as the
//! linear scan.
//!
//! Kernels here follow the canonical-tile contract of
//! [`super::compressed`]: samples are streamed in
//! [`canonical_tile_rows`] tiles, per-tile partials are folded in
//! ascending tile order, and parallel execution computes the *same*
//! tiles in waves — so threaded output is bit-identical to serial, and
//! the reference executor (which calls these very kernels) is
//! bit-identical to the party's streaming path by construction.

use crate::linalg::{cholesky_upper, Matrix};
use crate::stats::{
    clamped_mu, deviance_converged, deviance_term, irls_beta_init, irls_solve,
    LogisticFit, logistic_fit_from_final, IRLS_BETA_GUARD,
};
use crate::util::threadpool::{effective_threads, parallel_map};

use super::compressed::canonical_tile_rows;

/// Flattened length of one IRLS base (null-model) round: per trait
/// `[CᵀWC (K²) | CᵀWz (K) | deviance (1)]`, trait-major.
pub fn irls_base_flat_len(k: usize, t: usize) -> usize {
    t * (k * k + k + 1)
}

/// Flattened length of one weighted variant shard: per trait
/// `[score Xᵀ(y−μ̂) (w) | diag(XᵀWX) (w) | CᵀWX (K·w)]`, trait-major.
pub fn irls_shard_flat_len(k: usize, t: usize, w: usize) -> usize {
    t * w * (2 + k)
}

/// Per-sample logistic working quantities at linear predictor `eta`:
/// `(μ, w, y−μ, w·z)` with `w·z = w·η + (y−μ)` — the *scaled* working
/// response, bounded even as `w → 0`, which is what keeps the encoded
/// sums inside the fixed-point envelope (see `mpc/fixed.rs`).
#[inline]
fn working(y: f64, eta: f64) -> (f64, f64, f64, f64) {
    let mu = clamped_mu(eta);
    let w = mu * (1.0 - mu);
    let resid = y - mu;
    (mu, w, resid, w * eta + resid)
}

/// Accumulate samples `[i0, i1)` of the IRLS base statistics into
/// `part` (layout [`irls_base_flat_len`], zeroed here). `beta_flat` is
/// trait-major `T·K`.
fn irls_base_tile_partial(
    part: &mut [f64],
    ys: &Matrix,
    c: &Matrix,
    beta_flat: &[f64],
    i0: usize,
    i1: usize,
) {
    let t = ys.cols;
    let k = c.cols;
    let stride = k * k + k + 1;
    part.fill(0.0);
    for i in i0..i1 {
        let c_row = c.row(i);
        let y_row = ys.row(i);
        for tt in 0..t {
            let beta = &beta_flat[tt * k..(tt + 1) * k];
            let eta: f64 = c_row.iter().zip(beta).map(|(a, b)| a * b).sum();
            let (mu, w, _resid, wz) = working(y_row[tt], eta);
            let lane = &mut part[tt * stride..(tt + 1) * stride];
            let (ctwc, rest) = lane.split_at_mut(k * k);
            let (ctwz, dev) = rest.split_at_mut(k);
            for a in 0..k {
                let ca = c_row[a];
                ctwz[a] += ca * wz;
                let row = &mut ctwc[a * k..(a + 1) * k];
                let wca = w * ca;
                for (o, &cb) in row.iter_mut().zip(c_row) {
                    *o += wca * cb;
                }
            }
            dev[0] += deviance_term(y_row[tt], mu);
        }
    }
}

/// Accumulate samples `[i0, i1)` of the weighted shard statistics for
/// the `bw` absolute variant columns starting at `x0` into `part`
/// (layout [`irls_shard_flat_len`] for width `bw`, zeroed here).
#[allow(clippy::too_many_arguments)]
fn irls_shard_tile_partial(
    part: &mut [f64],
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    beta_flat: &[f64],
    x0: usize,
    bw: usize,
    i0: usize,
    i1: usize,
) {
    let t = ys.cols;
    let k = c.cols;
    let stride = bw * (2 + k);
    part.fill(0.0);
    for i in i0..i1 {
        let c_row = c.row(i);
        let y_row = ys.row(i);
        let x_row = &x.row(i)[x0..x0 + bw];
        for tt in 0..t {
            let beta = &beta_flat[tt * k..(tt + 1) * k];
            let eta: f64 = c_row.iter().zip(beta).map(|(a, b)| a * b).sum();
            let (_mu, w, resid, _wz) = working(y_row[tt], eta);
            let lane = &mut part[tt * stride..(tt + 1) * stride];
            let (score, rest) = lane.split_at_mut(bw);
            let (xwx, cwx) = rest.split_at_mut(bw);
            for (j, &xv) in x_row.iter().enumerate() {
                score[j] += xv * resid;
                xwx[j] += w * xv * xv;
            }
            for a in 0..k {
                let wca = w * c_row[a];
                let row = &mut cwx[a * bw..(a + 1) * bw];
                for (o, &xv) in row.iter_mut().zip(x_row) {
                    *o += wca * xv;
                }
            }
        }
    }
}

/// Drive a tiled accumulation with the canonical wave schedule: tiles
/// folded in ascending order, any thread count bit-identical to serial.
fn tiled_accumulate(
    n: usize,
    len: usize,
    tile: usize,
    threads: Option<usize>,
    partial: impl Fn(&mut [f64], usize, usize) + Sync,
) -> Vec<f64> {
    let ntiles = n.div_ceil(tile).max(1);
    let mut acc = vec![0.0f64; len];
    let nthreads = effective_threads(threads).min(ntiles);
    if nthreads <= 1 {
        let mut part = vec![0.0f64; len];
        for ti in 0..ntiles {
            partial(&mut part, ti * tile, ((ti + 1) * tile).min(n));
            for (a, &p) in acc.iter_mut().zip(&part) {
                *a += p;
            }
        }
    } else {
        for wave0 in (0..ntiles).step_by(nthreads) {
            let wave_len = nthreads.min(ntiles - wave0);
            let parts = parallel_map(wave_len, Some(nthreads), |wi| {
                let ti = wave0 + wi;
                let mut part = vec![0.0f64; len];
                partial(&mut part, ti * tile, ((ti + 1) * tile).min(n));
                part
            });
            for part in parts {
                for (a, &p) in acc.iter_mut().zip(&part) {
                    *a += p;
                }
            }
        }
    }
    acc
}

/// One party's IRLS base-round contribution at the broadcast iterate
/// `beta_flat` (trait-major `T·K`): flattened `[CᵀWC | CᵀWz | dev]` per
/// trait over this party's samples. Bit-identical for any
/// `(tile_rows, threads)` with the same tile boundaries (`None` pins
/// them to [`canonical_tile_rows`]).
pub fn compress_irls_base(
    ys: &Matrix,
    c: &Matrix,
    beta_flat: &[f64],
    tile_rows: Option<usize>,
    threads: Option<usize>,
) -> Vec<f64> {
    let n = ys.rows;
    let t = ys.cols;
    let k = c.cols;
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(beta_flat.len(), t * k, "beta must be trait-major T·K");
    let tile = tile_rows.unwrap_or_else(|| canonical_tile_rows(k)).max(1);
    tiled_accumulate(n, irls_base_flat_len(k, t), tile, threads, |part, i0, i1| {
        irls_base_tile_partial(part, ys, c, beta_flat, i0, i1)
    })
}

/// One party's weighted shard contribution for variant columns
/// `[j0, j1)` at the final iterate `beta_flat`: flattened
/// `[score | xwx | cwx]` per trait. Same canonical-tile contract as
/// [`compress_irls_base`].
#[allow(clippy::too_many_arguments)]
pub fn compress_irls_shard(
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    beta_flat: &[f64],
    j0: usize,
    j1: usize,
    tile_rows: Option<usize>,
    threads: Option<usize>,
) -> Vec<f64> {
    let n = ys.rows;
    let t = ys.cols;
    let k = c.cols;
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(x.rows, n, "X rows != N");
    assert!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
    assert_eq!(beta_flat.len(), t * k, "beta must be trait-major T·K");
    let bw = j1 - j0;
    if bw == 0 {
        return Vec::new();
    }
    let tile = tile_rows.unwrap_or_else(|| canonical_tile_rows(k)).max(1);
    tiled_accumulate(n, irls_shard_flat_len(k, t, bw), tile, threads, |part, i0, i1| {
        irls_shard_tile_partial(part, ys, c, x, beta_flat, j0, bw, i0, i1)
    })
}

/// Aggregated IRLS base sums for one trait.
#[derive(Clone, Debug)]
pub struct IrlsBaseSums {
    /// `CᵀWC`, K × K
    pub ctwc: Matrix,
    /// `CᵀWz` (scaled working response), length K
    pub ctwz: Vec<f64>,
    /// binomial deviance at the broadcast iterate
    pub dev: f64,
}

/// Split an aggregated IRLS base round back into per-trait sums.
pub fn unflatten_irls_base(k: usize, t: usize, v: &[f64]) -> anyhow::Result<Vec<IrlsBaseSums>> {
    anyhow::ensure!(
        v.len() == irls_base_flat_len(k, t),
        "irls base sum length {} != expected {}",
        v.len(),
        irls_base_flat_len(k, t)
    );
    let stride = k * k + k + 1;
    let mut out = Vec::with_capacity(t);
    for tt in 0..t {
        let lane = &v[tt * stride..(tt + 1) * stride];
        out.push(IrlsBaseSums {
            ctwc: Matrix::from_vec(k, k, lane[..k * k].to_vec()),
            ctwz: lane[k * k..k * k + k].to_vec(),
            dev: lane[stride - 1],
        });
    }
    Ok(out)
}

/// Aggregated weighted shard sums for one trait.
#[derive(Clone, Debug)]
pub struct IrlsShardSums {
    /// `Xᵀ(y − μ̂)`, length w
    pub score: Vec<f64>,
    /// `diag(XᵀWX)`, length w
    pub xwx: Vec<f64>,
    /// `CᵀWX`, K × w
    pub cwx: Matrix,
}

/// Split an aggregated weighted shard back into per-trait sums.
pub fn unflatten_irls_shard(
    k: usize,
    t: usize,
    w: usize,
    v: &[f64],
) -> anyhow::Result<Vec<IrlsShardSums>> {
    anyhow::ensure!(
        v.len() == irls_shard_flat_len(k, t, w),
        "irls shard sum length {} != expected {}",
        v.len(),
        irls_shard_flat_len(k, t, w)
    );
    let stride = w * (2 + k);
    let mut out = Vec::with_capacity(t);
    for tt in 0..t {
        let lane = &v[tt * stride..(tt + 1) * stride];
        out.push(IrlsShardSums {
            score: lane[..w].to_vec(),
            xwx: lane[w..2 * w].to_vec(),
            cwx: Matrix::from_vec(k, w, lane[2 * w..].to_vec()),
        });
    }
    Ok(out)
}

/// Outcome of one leader-side IRLS step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IrlsStep {
    /// at least one trait still iterating — broadcast the new β
    Continue,
    /// every trait finished — broadcast IRLS_DONE and move to the
    /// weighted shard pass
    Stop,
}

/// Leader-side IRLS driver across `T` traits.
///
/// Protocol shape: the leader broadcasts the iterate β_i, parties
/// return weighted sums evaluated **at** β_i, and [`step`](Self::step)
/// decides per trait: converged (deviance stable vs. the previous
/// iteration) or capped traits are *frozen* — their Cholesky factor of
/// `CᵀWC` at β_i is recorded and their β stops moving (so the recorded
/// factor is exactly the one the score-test epilogue needs, and each
/// trait's final state matches a pooled single-trait oracle run with
/// the same `(max_iter, tol)`). Unfinished traits get the Newton update
/// `RᵀR β_{i+1} = CᵀWz`. Stop fires when every trait is frozen; the cap
/// guarantees it by `max_iter` rounds.
#[derive(Clone, Debug)]
pub struct IrlsState {
    pub k: usize,
    pub t: usize,
    pub max_iter: usize,
    pub tol: f64,
    /// IRLS rounds evaluated so far (also the absolute secure-sum round
    /// number of the most recent evaluation)
    pub iters: usize,
    beta: Vec<Vec<f64>>,
    prev_dev: Vec<Option<f64>>,
    done: Vec<bool>,
    trait_iters: Vec<usize>,
    trait_converged: Vec<bool>,
    final_r: Vec<Option<Matrix>>,
    deviance: Vec<f64>,
}

impl IrlsState {
    /// `n` is the pooled sample count and `sum_y[tt]` the pooled case
    /// count of trait `tt` (= row 0 of the base round's `CᵀY` when
    /// covariate column 0 is the intercept) — enough to center the
    /// shared starting point without touching per-sample data.
    pub fn new(
        k: usize,
        t: usize,
        n: f64,
        sum_y: &[f64],
        max_iter: usize,
        tol: f64,
    ) -> anyhow::Result<IrlsState> {
        anyhow::ensure!(k >= 1 && t >= 1, "need K ≥ 1 and T ≥ 1");
        anyhow::ensure!(sum_y.len() == t, "sum_y length != T");
        anyhow::ensure!(max_iter >= 1, "need at least one IRLS iteration");
        anyhow::ensure!(tol > 0.0 && tol.is_finite(), "IRLS tolerance must be positive");
        anyhow::ensure!(n > k as f64, "need N > K");
        let beta = sum_y
            .iter()
            .map(|&s| irls_beta_init(k, n, s))
            .collect();
        Ok(IrlsState {
            k,
            t,
            max_iter,
            tol,
            iters: 0,
            beta,
            prev_dev: vec![None; t],
            done: vec![false; t],
            trait_iters: vec![0; t],
            trait_converged: vec![false; t],
            final_r: vec![None; t],
            deviance: vec![0.0; t],
        })
    }

    /// Current iterate, trait-major `T·K` — the broadcast payload.
    pub fn beta_flat(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.t * self.k);
        for b in &self.beta {
            v.extend_from_slice(b);
        }
        v
    }

    pub fn beta(&self, tt: usize) -> &[f64] {
        &self.beta[tt]
    }

    pub fn is_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }

    /// Consume one round of aggregated sums (evaluated at the current
    /// iterate). Errors on non-finite deviance, a non-PD weighted Gram
    /// matrix, or an iterate escaping the divergence guard
    /// (quasi-separation) — all conditions under which continuing would
    /// push the weighted sums out of the fixed-point envelope.
    pub fn step(&mut self, sums: &[IrlsBaseSums]) -> anyhow::Result<IrlsStep> {
        anyhow::ensure!(sums.len() == self.t, "sums length != T");
        anyhow::ensure!(!self.is_done(), "IRLS already finished");
        self.iters += 1;
        for tt in 0..self.t {
            if self.done[tt] {
                continue;
            }
            let s = &sums[tt];
            anyhow::ensure!(
                s.ctwc.rows == self.k && s.ctwc.cols == self.k && s.ctwz.len() == self.k,
                "trait {tt}: bad IRLS sum shape"
            );
            anyhow::ensure!(
                s.dev.is_finite(),
                "trait {tt}: IRLS deviance diverged (non-finite)"
            );
            let stop = self
                .prev_dev[tt]
                .is_some_and(|p| deviance_converged(s.dev, p, self.tol));
            if stop || self.iters == self.max_iter {
                self.final_r[tt] = Some(cholesky_upper(&s.ctwc)?);
                self.deviance[tt] = s.dev;
                self.trait_iters[tt] = self.iters;
                self.trait_converged[tt] = stop;
                self.done[tt] = true;
            } else {
                self.prev_dev[tt] = Some(s.dev);
                let nb = irls_solve(&s.ctwc, &s.ctwz)?;
                anyhow::ensure!(
                    nb.iter().all(|b| b.abs() <= IRLS_BETA_GUARD),
                    "trait {tt}: IRLS diverged (quasi-separation?): |beta| exceeded {IRLS_BETA_GUARD}"
                );
                self.beta[tt] = nb;
            }
        }
        Ok(if self.is_done() { IrlsStep::Stop } else { IrlsStep::Continue })
    }

    /// Upper Cholesky factor of the final `CᵀWC` of trait `tt`. Panics
    /// before [`step`](Self::step) returned [`IrlsStep::Stop`] for it.
    pub fn final_r(&self, tt: usize) -> &Matrix {
        self.final_r[tt].as_ref().expect("IRLS not finished for this trait")
    }

    /// Package trait `tt`'s finished null model as a [`LogisticFit`].
    pub fn fit(&self, tt: usize) -> LogisticFit {
        logistic_fit_from_final(
            self.beta[tt].clone(),
            self.final_r(tt).clone(),
            self.deviance[tt],
            self.trait_iters[tt],
            self.trait_converged[tt],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::logistic_fit_pooled;
    use crate::util::rng::Rng;

    fn cohort(n: usize, k: usize, t: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        let mut ys = Matrix::zeros(n, t);
        for i in 0..n {
            c[(i, 0)] = 1.0;
            for tt in 0..t {
                let eta = 0.5 * c[(i, k - 1)] - 0.2 + 0.1 * tt as f64;
                let p = 1.0 / (1.0 + (-eta).exp());
                ys[(i, tt)] = if rng.uniform() < p { 1.0 } else { 0.0 };
            }
        }
        (ys, c)
    }

    #[test]
    fn base_kernel_thread_and_tile_neutral() {
        let (ys, c) = cohort(700, 3, 2, 9100);
        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.25, -0.1];
        let serial = compress_irls_base(&ys, &c, &beta, Some(64), Some(1));
        for threads in [2, 4, 7] {
            let par = compress_irls_base(&ys, &c, &beta, Some(64), Some(threads));
            assert!(
                serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} not bit-identical"
            );
        }
        // different tile heights change fold order → may differ in last
        // bits, but must agree numerically
        let other = compress_irls_base(&ys, &c, &beta, Some(13), Some(3));
        for (a, b) in serial.iter().zip(&other) {
            assert!((a - b).abs() <= 1e-9 * a.abs().max(1.0));
        }
    }

    #[test]
    fn shard_kernel_thread_neutral_and_column_consistent() {
        let (ys, c) = cohort(500, 3, 2, 9101);
        let mut rng = Rng::new(9102);
        let x = Matrix::randn(500, 12, &mut rng);
        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.25, -0.1];
        let full = compress_irls_shard(&ys, &c, &x, &beta, 0, 12, Some(64), Some(1));
        let par = compress_irls_shard(&ys, &c, &x, &beta, 0, 12, Some(64), Some(4));
        assert!(full.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()));
        // per-variant sums never mix across columns: a sub-range equals
        // the matching lanes of the full range, bit for bit
        let k = 3;
        let sub = compress_irls_shard(&ys, &c, &x, &beta, 4, 9, Some(64), Some(1));
        let subs = unflatten_irls_shard(k, 2, 5, &sub).unwrap();
        let fulls = unflatten_irls_shard(k, 2, 12, &full).unwrap();
        for tt in 0..2 {
            for j in 0..5 {
                assert_eq!(subs[tt].score[j].to_bits(), fulls[tt].score[j + 4].to_bits());
                assert_eq!(subs[tt].xwx[j].to_bits(), fulls[tt].xwx[j + 4].to_bits());
                for a in 0..k {
                    assert_eq!(
                        subs[tt].cwx[(a, j)].to_bits(),
                        fulls[tt].cwx[(a, j + 4)].to_bits()
                    );
                }
            }
        }
        assert!(compress_irls_shard(&ys, &c, &x, &beta, 3, 3, None, None).is_empty());
    }

    #[test]
    fn flat_roundtrip() {
        let (ys, c) = cohort(200, 3, 2, 9103);
        let beta = vec![0.0; 6];
        let flat = compress_irls_base(&ys, &c, &beta, None, None);
        assert_eq!(flat.len(), irls_base_flat_len(3, 2));
        let sums = unflatten_irls_base(3, 2, &flat).unwrap();
        assert_eq!(sums.len(), 2);
        // CᵀWC is symmetric by construction
        for s in &sums {
            for a in 0..3 {
                for b in 0..3 {
                    assert_eq!(s.ctwc[(a, b)].to_bits(), s.ctwc[(b, a)].to_bits());
                }
            }
            assert!(s.dev > 0.0);
        }
        assert!(unflatten_irls_base(3, 2, &flat[1..]).is_err());
        assert!(unflatten_irls_shard(3, 2, 5, &flat).is_err());
    }

    #[test]
    fn state_walks_to_the_pooled_oracle() {
        // Driving IrlsState with single-party kernel sums must land on
        // (numerically) the same fit as the pooled plaintext oracle —
        // same init, same stop rule, same per-trait freeze.
        let (ys, c) = cohort(900, 3, 2, 9104);
        let n = 900.0;
        let sum_y: Vec<f64> = (0..2).map(|tt| ys.col(tt).iter().sum()).collect();
        let mut st = IrlsState::new(3, 2, n, &sum_y, 25, 1e-8).unwrap();
        loop {
            let flat = compress_irls_base(&ys, &c, &st.beta_flat(), None, None);
            let sums = unflatten_irls_base(3, 2, &flat).unwrap();
            if st.step(&sums).unwrap() == IrlsStep::Stop {
                break;
            }
        }
        for tt in 0..2 {
            let oracle = logistic_fit_pooled(&ys.col(tt), &c, 25, 1e-8).unwrap();
            let fit = st.fit(tt);
            assert_eq!(fit.iters, oracle.iters, "trait {tt}");
            assert!(fit.converged);
            for (a, b) in fit.beta.iter().zip(&oracle.beta) {
                assert!((a - b).abs() < 1e-8, "trait {tt}: {a} vs {b}");
            }
            assert!((fit.deviance - oracle.deviance).abs() < 1e-6);
            for (a, b) in fit.p.iter().zip(&oracle.p) {
                assert!((a - b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn separation_guard_fires_through_the_state_machine() {
        // trait = indicator of covariate 1 → quasi-separation
        let n = 300;
        let mut rng = Rng::new(9105);
        let mut c = Matrix::zeros(n, 2);
        let mut ys = Matrix::zeros(n, 1);
        for i in 0..n {
            c[(i, 0)] = 1.0;
            c[(i, 1)] = rng.normal();
            ys[(i, 0)] = if c[(i, 1)] > 0.0 { 1.0 } else { 0.0 };
        }
        let sum_y: f64 = ys.col(0).iter().sum();
        let mut st = IrlsState::new(2, 1, n as f64, &[sum_y], 200, 1e-12).unwrap();
        let err = loop {
            let flat = compress_irls_base(&ys, &c, &st.beta_flat(), None, None);
            let sums = unflatten_irls_base(2, 1, &flat).unwrap();
            match st.step(&sums) {
                Ok(IrlsStep::Stop) => panic!("separated fit must not converge cleanly"),
                Ok(IrlsStep::Continue) => continue,
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("quasi-separation"), "{err:#}");
    }

    #[test]
    fn max_iter_cap_freezes_all_traits() {
        let (ys, c) = cohort(400, 3, 2, 9106);
        let sum_y: Vec<f64> = (0..2).map(|tt| ys.col(tt).iter().sum()).collect();
        let mut st = IrlsState::new(3, 2, 400.0, &sum_y, 2, 1e-15).unwrap();
        for round in 1..=2 {
            let flat = compress_irls_base(&ys, &c, &st.beta_flat(), None, None);
            let sums = unflatten_irls_base(3, 2, &flat).unwrap();
            let step = st.step(&sums).unwrap();
            assert_eq!(step == IrlsStep::Stop, round == 2);
        }
        let fit = st.fit(0);
        assert_eq!(fit.iters, 2);
        assert!(!fit.converged);
        assert!(st.is_done());
    }
}
