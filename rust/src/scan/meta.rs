//! Inverse-variance meta-analysis baseline (what consortia do when data
//! cannot be pooled — the comparator of §4's "analysts typically resort
//! to meta-analyzing within-party estimates").
//!
//! Each party runs its own covariate-adjusted scan; effect estimates are
//! combined as `β̂_meta = Σ w_p β̂_p / Σ w_p` with `w_p = 1/se_p²`. Exact
//! when parties are homogeneous and large; loses power and can be biased
//! under small per-party N or cross-party heterogeneity (Simpson's
//! paradox) — quantified in E6 against the pooled DASH scan.

use crate::gwas::Cohort;
use crate::scan::compressed::compress_party;
use crate::scan::combine::{combine_compressed, CombineOptions};
use crate::scan::compressed::{flatten_for_sum, unflatten_sum};
use crate::stats::t_two_sided_p;

/// Meta-analysis result for M variants.
#[derive(Clone, Debug)]
pub struct MetaResult {
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
    pub z: Vec<f64>,
    pub p: Vec<f64>,
}

/// Run per-party scans and inverse-variance combine. Operates on trait 0
/// (the meta-analysis baseline is a single-trait comparator; the pooled
/// scan is the path that amortizes across traits).
pub fn meta_analyze(cohort: &Cohort, block_m: usize) -> anyhow::Result<MetaResult> {
    let m = cohort.m();
    let mut wsum = vec![0.0; m];
    let mut wbsum = vec![0.0; m];
    for party in &cohort.parties {
        let cp = compress_party(&party.ys, &party.c, &party.x, block_m, None);
        let (layout, flat) = flatten_for_sum(&cp);
        let agg = unflatten_sum(layout, &flat)?;
        let out = combine_compressed(
            &agg,
            Some(std::slice::from_ref(&cp.r)),
            CombineOptions::default(),
        )?;
        for j in 0..m {
            let (b, s) = (out.assoc[0].beta[j], out.assoc[0].se[j]);
            if b.is_finite() && s.is_finite() && s > 0.0 {
                let w = 1.0 / (s * s);
                wsum[j] += w;
                wbsum[j] += w * b;
            }
        }
    }
    let mut beta = vec![f64::NAN; m];
    let mut se = vec![f64::NAN; m];
    let mut z = vec![f64::NAN; m];
    let mut p = vec![f64::NAN; m];
    for j in 0..m {
        if wsum[j] > 0.0 {
            beta[j] = wbsum[j] / wsum[j];
            se[j] = (1.0 / wsum[j]).sqrt();
            z[j] = beta[j] / se[j];
            // normal approximation, df → ∞ (standard in GWAS meta-analysis)
            p[j] = t_two_sided_p(z[j], 1e9);
        }
    }
    Ok(MetaResult { beta, se, z, p })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::{generate_cohort, pool_cohort, CohortSpec};
    use crate::scan::compressed::compress_party;

    fn pooled_scan(cohort: &Cohort) -> crate::scan::combine::ScanOutput {
        let pooled = pool_cohort(cohort);
        let cp = compress_party(&pooled.ys, &pooled.c, &pooled.x, 64, None);
        let (layout, flat) = flatten_for_sum(&cp);
        let agg = unflatten_sum(layout, &flat).unwrap();
        combine_compressed(
            &agg,
            Some(std::slice::from_ref(&cp.r)),
            CombineOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn meta_close_to_pooled_when_homogeneous() {
        // No batch effects, same admixture → meta ≈ pooled at causal SNPs
        let spec = CohortSpec {
            party_sizes: vec![400, 400],
            m_variants: 60,
            n_traits: 1,
            n_causal: 3,
            effect_sd: 0.5,
            fst: 0.01,
            party_admixture: vec![0.5, 0.5],
            ancestry_effect: 0.0,
            batch_effect_sd: 0.0,
            n_pcs: 1,
            noise_sd: 1.0,
            binary_traits: false,
        };
        let cohort = generate_cohort(&spec, 150);
        let meta = meta_analyze(&cohort, 30).unwrap();
        let pooled = pooled_scan(&cohort);
        for &j in &cohort.truth.causal_idx {
            let d = (meta.beta[j] - pooled.assoc[0].beta[j]).abs();
            let tol = 3.0 * pooled.assoc[0].se[j];
            assert!(d < tol, "variant {j}: meta={} pooled={}", meta.beta[j], pooled.assoc[0].beta[j]);
        }
    }

    #[test]
    fn meta_se_larger_than_pooled_with_small_parties() {
        // Many small parties: per-party df is low → meta se inflated.
        let spec = CohortSpec {
            party_sizes: vec![40; 8],
            m_variants: 40,
            n_traits: 1,
            n_causal: 2,
            effect_sd: 0.5,
            fst: 0.02,
            party_admixture: vec![0.5; 8],
            ancestry_effect: 0.0,
            batch_effect_sd: 0.0,
            n_pcs: 1,
            noise_sd: 1.0,
            binary_traits: false,
        };
        let cohort = generate_cohort(&spec, 151);
        let meta = meta_analyze(&cohort, 20).unwrap();
        let pooled = pooled_scan(&cohort);
        // median se ratio should favor pooled
        let mut ratios: Vec<f64> = (0..spec.m_variants)
            .filter(|&j| meta.se[j].is_finite() && pooled.assoc[0].se[j].is_finite())
            .map(|j| meta.se[j] / pooled.assoc[0].se[j])
            .collect();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = ratios[ratios.len() / 2];
        assert!(median > 0.95, "median se ratio {median}");
    }

    #[test]
    fn handles_nan_party_estimates() {
        // A party with a monomorphic variant contributes NaN — meta must
        // skip it rather than poison the combined estimate.
        let spec = CohortSpec::default_small();
        let mut cohort = generate_cohort(&spec, 152);
        // make variant 0 monomorphic at party 0
        let n0 = cohort.parties[0].n();
        for i in 0..n0 {
            cohort.parties[0].x[(i, 0)] = 0.0;
        }
        let meta = meta_analyze(&cohort, 64).unwrap();
        // still finite thanks to the other parties
        assert!(meta.beta[0].is_finite());
    }
}
