//! Compress-within stage (§2/§3/§4): per-party sufficient statistics,
//! trait-major.
//!
//! The paper's §3 extension promotes the trait vector `y` to a matrix
//! `Y` (`N × T`): biobank studies test ~4K traits, eQTL ~20K. For party
//! data `(Y, C, X)` with `N_p` samples, `T` traits, `K` permanent and
//! `M` transient covariates, compression produces
//!
//! `YᵀY (diag, T), CᵀY (K×T), CᵀC, XᵀY (M×T), X·X (diag, M), CᵀX,
//! R_p = qr(C_p).R`
//!
//! — all local plaintext. `X·X`, `CᵀX`, `CᵀC` are **shared across
//! traits**, which is the economy the paper points at: the expensive
//! `O(NKM)` genotype-side compression is paid once, each extra trait
//! costs only `O(N(M+K))`. The single-trait scan is exactly the `T = 1`
//! degenerate case — same structs, same flattened layout, bit-identical
//! values.
//!
//! The stage is split to serve the sharded streaming pipeline
//! ([`crate::scan::ShardPlan`]):
//!
//! - [`compress_base`] — the variant-independent part
//!   (`N, YᵀY, CᵀY, CᵀC, R_p`), computed once per session;
//! - [`compress_variant_block`] — the `[j0, j1)` column slice of the
//!   variant-sized statistics (`XᵀY, X·X, CᵀX`), computed once per shard
//!   with `O((K+T)·width)` memory.
//!
//! [`compress_party`] composes the two over the full column range and is
//! bit-identical to compressing shard-by-shard and concatenating (per-
//! variant sums never mix across columns), and per-trait bit-identical
//! to a `T = 1` compression of each trait column (per-trait sums never
//! mix across traits).
//!
//! ## Tiled, canonically-ordered accumulation (DESIGN.md §Parallel
//! compress)
//!
//! Both stages stream samples in fixed-height **tiles** of
//! [`canonical_tile_rows`] rows (sized so a tile of X, Y and C fits in
//! L2), accumulate each tile into private scratch, and fold the tile
//! partials into the output in **ascending tile order**. Every output
//! element is therefore the same fixed-shape sum regardless of thread
//! count, column chunking, or which worker computed which tile — the
//! threaded paths are bit-identical to the serial path by construction,
//! and the conformance matrix holds them to it.

use crate::linalg::{householder_qr, Matrix};
use crate::util::threadpool::{effective_threads, parallel_for_chunks, parallel_map};

/// Per-party compressed statistics for `T` traits. The entire secure
/// protocol operates on this — the `N_p`-row data never leaves the
/// party.
#[derive(Clone, Debug)]
pub struct CompressedParty {
    pub n: usize,
    /// Y_tᵀY_t per trait, length T
    pub yty: Vec<f64>,
    /// CᵀY, K × T
    pub cty: Matrix,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// R factor of QR(C_p), K × K (TSQR path; reveals C_pᵀC_p, so it is
    /// only transmitted in plaintext mode — see DESIGN.md §Security)
    pub r: Matrix,
    /// XᵀY, M × T (row-major: variant-major, traits contiguous)
    pub xty: Matrix,
    /// per-variant X_m·X_m, length M (shared across traits)
    pub xtx: Vec<f64>,
    /// CᵀX, K × M (shared across traits)
    pub ctx: Matrix,
}

impl CompressedParty {
    pub fn k(&self) -> usize {
        self.ctc.rows
    }

    pub fn m(&self) -> usize {
        self.xtx.len()
    }

    pub fn t(&self) -> usize {
        self.yty.len()
    }

    /// The variant-independent part of these statistics.
    pub fn base(&self) -> BaseStats {
        BaseStats {
            n: self.n,
            yty: self.yty.clone(),
            cty: self.cty.clone(),
            ctc: self.ctc.clone(),
            r: self.r.clone(),
        }
    }

    /// Column slice `[j0, j1)` of the variant-sized statistics — used by
    /// compute engines that materialize all `M` columns at once (the AOT
    /// artifact path) to feed the sharded protocol.
    pub fn variant_block(&self, j0: usize, j1: usize) -> VariantBlockStats {
        assert!(j0 <= j1 && j1 <= self.m(), "bad column range {j0}..{j1}");
        VariantBlockStats {
            j0,
            xty: self.xty.row_slice(j0, j1),
            xtx: self.xtx[j0..j1].to_vec(),
            ctx: self.ctx.col_slice(j0, j1),
        }
    }
}

/// Variant-independent compressed statistics (`O(K² + KT)` floats).
#[derive(Clone, Debug)]
pub struct BaseStats {
    pub n: usize,
    /// YᵀY diag, length T
    pub yty: Vec<f64>,
    /// CᵀY, K × T
    pub cty: Matrix,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// R factor of QR(C_p) (plaintext/TSQR path only)
    pub r: Matrix,
}

impl BaseStats {
    pub fn k(&self) -> usize {
        self.ctc.rows
    }

    pub fn t(&self) -> usize {
        self.yty.len()
    }

    /// Flatten for secure summation: `[n, YᵀY(T), CᵀY(K·T), CᵀC(K²)]`.
    /// (`R_p` is deliberately excluded — it is never securely summed.)
    /// For `T = 1` this is byte-identical to the historical single-trait
    /// layout `[n, yᵀy, Cᵀy(K), CᵀC(K²)]`.
    pub fn flatten(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(base_flat_len(self.k(), self.t()));
        v.push(self.n as f64);
        v.extend_from_slice(&self.yty);
        v.extend_from_slice(&self.cty.data);
        v.extend_from_slice(&self.ctc.data);
        debug_assert_eq!(v.len(), base_flat_len(self.k(), self.t()));
        v
    }
}

/// Length of the flattened base vector for `K` covariates and `T`
/// traits.
pub fn base_flat_len(k: usize, t: usize) -> usize {
    1 + t + k * t + k * k
}

/// Aggregate of the variant-independent statistics across parties.
#[derive(Clone, Debug)]
pub struct BaseSums {
    pub n: usize,
    /// YᵀY diag, length T
    pub yty: Vec<f64>,
    /// CᵀY, K × T
    pub cty: Matrix,
    pub ctc: Matrix,
}

impl BaseSums {
    pub fn t(&self) -> usize {
        self.yty.len()
    }
}

/// Inverse of [`BaseStats::flatten`] applied to a summed vector.
pub fn unflatten_base(k: usize, t: usize, v: &[f64]) -> anyhow::Result<BaseSums> {
    anyhow::ensure!(v.len() == base_flat_len(k, t), "base flat length mismatch");
    Ok(BaseSums {
        n: v[0].round() as usize,
        yty: v[1..1 + t].to_vec(),
        cty: Matrix::from_vec(k, t, v[1 + t..1 + t + k * t].to_vec()),
        ctc: Matrix::from_vec(k, k, v[1 + t + k * t..].to_vec()),
    })
}

/// One shard's slice of the variant-sized statistics
/// (`O((K+T)·width)`).
#[derive(Clone, Debug)]
pub struct VariantBlockStats {
    /// first absolute variant column covered by this block
    pub j0: usize,
    /// XᵀY for columns `[j0, j0+width)` — width × T
    pub xty: Matrix,
    /// per-variant X·X for the same columns
    pub xtx: Vec<f64>,
    /// CᵀX, K × width
    pub ctx: Matrix,
}

impl VariantBlockStats {
    pub fn width(&self) -> usize {
        self.xtx.len()
    }

    pub fn t(&self) -> usize {
        self.xty.cols
    }

    /// Flatten for secure summation: `[XᵀY(w·T), X·X(w), CᵀX(K·w)]` —
    /// `O((K+T)·w)`, the per-round payload bound of the streaming
    /// protocol. For `T = 1` this is byte-identical to the historical
    /// `[Xᵀy(w), X·X(w), CᵀX(K·w)]`.
    pub fn flatten(&self) -> Vec<f64> {
        let k = self.ctx.rows;
        let mut v = Vec::with_capacity(shard_flat_len(k, self.t(), self.width()));
        v.extend_from_slice(&self.xty.data);
        v.extend_from_slice(&self.xtx);
        v.extend_from_slice(&self.ctx.data);
        debug_assert_eq!(v.len(), shard_flat_len(k, self.t(), self.width()));
        v
    }
}

/// Length of the flattened shard vector for `K` covariates, `T` traits
/// and shard width `w`.
pub fn shard_flat_len(k: usize, t: usize, w: usize) -> usize {
    w * (1 + t + k)
}

/// Aggregate of one shard's variant statistics across parties.
#[derive(Clone, Debug)]
pub struct ShardSums {
    /// XᵀY, width × T
    pub xty: Matrix,
    pub xtx: Vec<f64>,
    /// CᵀX, K × width
    pub ctx: Matrix,
}

impl ShardSums {
    pub fn width(&self) -> usize {
        self.xtx.len()
    }

    pub fn t(&self) -> usize {
        self.xty.cols
    }
}

/// Inverse of [`VariantBlockStats::flatten`] applied to a summed vector.
pub fn unflatten_shard(
    k: usize,
    t: usize,
    w: usize,
    v: &[f64],
) -> anyhow::Result<ShardSums> {
    anyhow::ensure!(v.len() == shard_flat_len(k, t, w), "shard flat length mismatch");
    Ok(ShardSums {
        xty: Matrix::from_vec(w, t, v[..w * t].to_vec()),
        xtx: v[w * t..w * t + w].to_vec(),
        ctx: Matrix::from_vec(k, w, v[w * t + w..].to_vec()),
    })
}

/// Canonical sample-tile height for the compress kernels: the largest
/// row count such that a tile of X (nominal shard width), Y (nominal
/// trait batch) and C (`K` columns) stays within a conservative L2
/// budget. Deliberately a function of `K` **only** — never of the actual
/// shard width, trait count, thread count, or machine — so every code
/// path (serial, threaded, reference executor) tiles the sample
/// dimension identically and the canonical accumulation order is fixed.
pub fn canonical_tile_rows(k: usize) -> usize {
    const L2_BUDGET_BYTES: usize = 256 * 1024;
    // nominal working-set columns per sample row: 64 X lanes + 16 trait
    // lanes, plus the K covariate lanes
    const NOMINAL_COLS: usize = 80;
    (L2_BUDGET_BYTES / (8 * (k + NOMINAL_COLS))).clamp(64, 4096)
}

/// Accumulate samples `[i0, i1)` of the Y-side statistics into `part`
/// (layout `[yty(T) | cty(K·T)]`, zeroed by the caller). The per-trait
/// lanes never mix, so trait `t` of a T-trait partial is bit-identical
/// to the T = 1 partial of that trait.
fn yside_tile_partial(part: &mut [f64], ys: &Matrix, c: &Matrix, i0: usize, i1: usize) {
    let t = ys.cols;
    part.fill(0.0);
    let (yty_p, cty_p) = part.split_at_mut(t);
    for i in i0..i1 {
        let y_row = ys.row(i);
        for (o, &yv) in yty_p.iter_mut().zip(y_row) {
            *o += yv * yv;
        }
        for (kk, &cv) in c.row(i).iter().enumerate() {
            let lane = &mut cty_p[kk * t..(kk + 1) * t];
            for (o, &yv) in lane.iter_mut().zip(y_row) {
                *o += cv * yv;
            }
        }
    }
}

/// Y-side sums `(YᵀY diag, CᵀY)` via the canonical tiled accumulation —
/// the shared kernel behind [`compress_base`] and the reference
/// executor's `CompressXy`, so the two are bit-identical by
/// construction.
pub fn compress_yside(
    ys: &Matrix,
    c: &Matrix,
    tile_rows: Option<usize>,
    threads: Option<usize>,
) -> (Vec<f64>, Matrix) {
    let n = ys.rows;
    assert_eq!(c.rows, n, "C rows != N");
    assert!(ys.cols >= 1, "need at least one trait column");
    let k = c.cols;
    let t = ys.cols;
    let tile = tile_rows.unwrap_or_else(|| canonical_tile_rows(k)).max(1);
    let ntiles = n.div_ceil(tile).max(1);
    let len = t + k * t;
    let mut acc = vec![0.0f64; len];
    let nthreads = effective_threads(threads).min(ntiles);
    if nthreads <= 1 {
        let mut part = vec![0.0f64; len];
        for ti in 0..ntiles {
            yside_tile_partial(&mut part, ys, c, ti * tile, ((ti + 1) * tile).min(n));
            for (a, &p) in acc.iter_mut().zip(&part) {
                *a += p;
            }
        }
    } else {
        // Waves of ≤ nthreads tile partials computed in parallel, folded
        // in ascending tile order; each wave's scratch drops before the
        // next wave starts, bounding resident scratch at O(threads·tile).
        for wave0 in (0..ntiles).step_by(nthreads) {
            let wave_len = nthreads.min(ntiles - wave0);
            let parts = parallel_map(wave_len, Some(nthreads), |wi| {
                let ti = wave0 + wi;
                let mut part = vec![0.0f64; len];
                yside_tile_partial(&mut part, ys, c, ti * tile, ((ti + 1) * tile).min(n));
                part
            });
            for part in parts {
                for (a, &p) in acc.iter_mut().zip(&part) {
                    *a += p;
                }
            }
        }
    }
    let yty = acc[..t].to_vec();
    let cty = Matrix::from_vec(k, t, acc[t..].to_vec());
    (yty, cty)
}

/// Compress the variant-independent statistics of one party. `ys` is
/// `N × T` (row-major samples × traits).
pub fn compress_base(ys: &Matrix, c: &Matrix) -> BaseStats {
    compress_base_opts(ys, c, None, Some(1))
}

/// [`compress_base`] with explicit tile height and worker count. Any
/// `(tile_rows, threads)` combination yields bit-identical output for a
/// given `tile_rows` (the canonical fold order depends on the tile
/// boundaries alone, and `None` pins them to [`canonical_tile_rows`]).
pub fn compress_base_opts(
    ys: &Matrix,
    c: &Matrix,
    tile_rows: Option<usize>,
    threads: Option<usize>,
) -> BaseStats {
    let n = ys.rows;
    let (yty, cty) = compress_yside(ys, c, tile_rows, threads);
    BaseStats { n, yty, cty, ctc: c.gram(), r: householder_qr(c).r }
}

/// Compress the variant statistics for columns `[j0, j1)` of `X` across
/// all `T` trait columns of `ys` (pure-Rust reference path).
///
/// `block_m` controls the variant-block width for parallelism; `threads`
/// caps the worker count (None = all cores). Results are bit-identical
/// to the corresponding slice of a full-range compression: each output
/// is a sum over samples in a fixed order, independent of how the
/// columns are chunked — and independent per trait, so trait `t` of the
/// result is bit-identical to compressing that trait alone.
pub fn compress_variant_block(
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    j0: usize,
    j1: usize,
    block_m: usize,
    threads: Option<usize>,
) -> VariantBlockStats {
    compress_variant_block_opts(ys, c, x, j0, j1, block_m, None, threads)
}

/// Accumulate samples `[i0, i1)` of the X-side statistics for the `bw`
/// absolute columns starting at `x0` into `part` (layout
/// `[xty(bw·T) | xtx(bw) | ctx(K×bw)]`, zeroed here). The branch-free
/// axpy form beats the per-element `if xv == 0` skip even at ~50%
/// genotype sparsity (EXPERIMENTS.md §Perf); the trait loop vectorizes
/// over the contiguous trait lane.
#[allow(clippy::too_many_arguments)]
fn xside_tile_partial(
    part: &mut [f64],
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    x0: usize,
    bw: usize,
    i0: usize,
    i1: usize,
) {
    let t = ys.cols;
    part.fill(0.0);
    let (xty_p, rest) = part.split_at_mut(bw * t);
    let (xtx_p, ctx_p) = rest.split_at_mut(bw);
    for i in i0..i1 {
        let y_row = ys.row(i);
        let x_row = &x.row(i)[x0..x0 + bw];
        for (j, &xv) in x_row.iter().enumerate() {
            xtx_p[j] += xv * xv;
            let lane = &mut xty_p[j * t..(j + 1) * t];
            for (o, &yv) in lane.iter_mut().zip(y_row) {
                *o += xv * yv;
            }
        }
        for (kk, &cv) in c.row(i).iter().enumerate() {
            let row = &mut ctx_p[kk * bw..(kk + 1) * bw];
            for (r, &xv) in row.iter_mut().zip(x_row) {
                *r += cv * xv;
            }
        }
    }
}

/// [`compress_variant_block`] with an explicit sample-tile height.
///
/// Parallelism is two-level, and neither level perturbs the result:
///
/// - **columns** — variant columns are chunked `block_m` wide; per-
///   variant sums never mix across columns, so chunking is order-
///   neutral by construction;
/// - **samples** — each chunk streams the canonical sample tiles
///   ([`canonical_tile_rows`], or `tile_rows` for tests), accumulating
///   every tile into private scratch and folding the partials in
///   ascending tile order. When the column dimension is too narrow to
///   occupy the workers (the common one-shard-at-a-time streaming case)
///   the tile partials of a chunk are computed in parallel waves
///   instead — same tiles, same fold order, same bits.
#[allow(clippy::too_many_arguments)]
pub fn compress_variant_block_opts(
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    j0: usize,
    j1: usize,
    block_m: usize,
    tile_rows: Option<usize>,
    threads: Option<usize>,
) -> VariantBlockStats {
    let n = ys.rows;
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(x.rows, n, "X rows != N");
    assert!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
    assert!(ys.cols >= 1, "need at least one trait column");
    let k = c.cols;
    let t = ys.cols;
    let w = j1 - j0;

    let tile = tile_rows.unwrap_or_else(|| canonical_tile_rows(k)).max(1);
    let ntiles = n.div_ceil(tile).max(1);
    let chunk = block_m.max(1);
    let col_chunks = w.div_ceil(chunk).max(1);
    let nthreads = effective_threads(threads);

    let mut xty = Matrix::zeros(w, t);
    let mut xtx = vec![0.0; w];
    let mut ctx = Matrix::zeros(k, w);
    if w == 0 {
        return VariantBlockStats { j0, xty, xtx, ctx };
    }
    {
        // Disjoint column blocks → safe shared-mutable access.
        let xty_ptr = SendPtr(xty.data.as_mut_ptr());
        let xtx_ptr = SendPtr(xtx.as_mut_ptr());
        let ctx_ptr = SendPtr(ctx.data.as_mut_ptr());
        // single write-back of a chunk's accumulator into the shared
        // outputs. SAFETY: columns [b0, b1) are owned by one caller.
        let write_back = |b0: usize, bw: usize, acc: &[f64]| unsafe {
            for j in 0..bw {
                for tt in 0..t {
                    *xty_ptr.at((b0 + j) * t + tt) = acc[j * t + tt];
                }
                *xtx_ptr.at(b0 + j) = acc[bw * t + j];
            }
            for kk in 0..k {
                for j in 0..bw {
                    *ctx_ptr.at(kk * w + b0 + j) = acc[bw * (1 + t) + kk * bw + j];
                }
            }
        };
        if nthreads <= 1 || col_chunks >= nthreads || ntiles <= 1 {
            // Column-parallel: each worker owns whole column chunks and
            // streams the tiles of its chunk serially (partials reuse
            // one scratch buffer, folded ascending as they complete).
            parallel_for_chunks(w, chunk, threads, |b0, b1| {
                let bw = b1 - b0;
                let mut acc = vec![0.0f64; bw * (1 + t + k)];
                let mut part = vec![0.0f64; bw * (1 + t + k)];
                for ti in 0..ntiles {
                    let (i0, i1) = (ti * tile, ((ti + 1) * tile).min(n));
                    xside_tile_partial(&mut part, ys, c, x, j0 + b0, bw, i0, i1);
                    for (a, &p) in acc.iter_mut().zip(&part) {
                        *a += p;
                    }
                }
                write_back(b0, bw, &acc);
            });
        } else {
            // Tile-parallel: too few column chunks to occupy the
            // workers, so parallelize over sample tiles instead — waves
            // of ≤ nthreads tile partials, folded in ascending tile
            // order; a wave's scratch drops before the next wave starts
            // (resident scratch stays O(threads · chunk), not O(ntiles)).
            let mut b0 = 0usize;
            while b0 < w {
                let b1 = (b0 + chunk).min(w);
                let bw = b1 - b0;
                let mut acc = vec![0.0f64; bw * (1 + t + k)];
                for wave0 in (0..ntiles).step_by(nthreads) {
                    let wave_len = nthreads.min(ntiles - wave0);
                    let parts = parallel_map(wave_len, Some(nthreads), |wi| {
                        let ti = wave0 + wi;
                        let mut part = vec![0.0f64; bw * (1 + t + k)];
                        let (i0, i1) = (ti * tile, ((ti + 1) * tile).min(n));
                        xside_tile_partial(&mut part, ys, c, x, j0 + b0, bw, i0, i1);
                        part
                    });
                    for part in parts {
                        for (a, &p) in acc.iter_mut().zip(&part) {
                            *a += p;
                        }
                    }
                }
                write_back(b0, bw, &acc);
                b0 = b1;
            }
        }
    }

    VariantBlockStats { j0, xty, xtx, ctx }
}

/// Compress one party's data (pure-Rust reference path): the base stage
/// plus the full-range variant stage — the one-shard degenerate case of
/// the streaming pipeline. `ys` is `N × T`; pass a `N × 1` matrix
/// ([`Matrix::from_col`]) for a single-trait scan.
pub fn compress_party(
    ys: &Matrix,
    c: &Matrix,
    x: &Matrix,
    block_m: usize,
    threads: Option<usize>,
) -> CompressedParty {
    let base = compress_base_opts(ys, c, None, threads);
    let vb = compress_variant_block(ys, c, x, 0, x.cols, block_m, threads);
    CompressedParty {
        n: base.n,
        yty: base.yty,
        cty: base.cty,
        ctc: base.ctc,
        r: base.r,
        xty: vb.xty,
        xtx: vb.xtx,
        ctx: vb.ctx,
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees disjoint indices across threads.
    #[inline]
    unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

/// Layout of the flattened statistics vector used by the secure-sum
/// protocol. All parties must agree on `(K, M, T)`; the flattening is
/// `[n, yty(T), cty(K·T), ctc(K²), xty(M·T), xtx(M), ctx(K·M)]` — i.e.
/// the base segment followed by the single full-width shard segment.
/// `T = 1` reproduces the historical single-trait layout exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlatLayout {
    pub k: usize,
    pub m: usize,
    /// trait count (1 = classic single-trait scan)
    pub t: usize,
}

impl FlatLayout {
    pub fn len(&self) -> usize {
        base_flat_len(self.k, self.t) + shard_flat_len(self.k, self.t, self.m)
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Offset of the `xty` segment (== length of the base segment).
    pub fn xty_off(&self) -> usize {
        base_flat_len(self.k, self.t)
    }

    /// Offset of the `xtx` segment.
    pub fn xtx_off(&self) -> usize {
        self.xty_off() + self.m * self.t
    }

    /// Offset of the `ctx` segment (K rows × M cols, row-major).
    pub fn ctx_off(&self) -> usize {
        self.xtx_off() + self.m
    }
}

/// Flatten compressed statistics for share-wise summation. `n` rides in
/// the same vector (as a real number) so the entire combine input is one
/// secure sum.
pub fn flatten_for_sum(cp: &CompressedParty) -> (FlatLayout, Vec<f64>) {
    let layout = FlatLayout { k: cp.k(), m: cp.m(), t: cp.t() };
    let mut v = Vec::with_capacity(layout.len());
    v.push(cp.n as f64);
    v.extend_from_slice(&cp.yty);
    v.extend_from_slice(&cp.cty.data);
    v.extend_from_slice(&cp.ctc.data);
    v.extend_from_slice(&cp.xty.data);
    v.extend_from_slice(&cp.xtx);
    v.extend_from_slice(&cp.ctx.data);
    debug_assert_eq!(v.len(), layout.len());
    (layout, v)
}

/// Aggregate sums, as reconstructed by the combine stage.
#[derive(Clone, Debug)]
pub struct AggregateSums {
    pub n: usize,
    /// YᵀY diag, length T
    pub yty: Vec<f64>,
    /// CᵀY, K × T
    pub cty: Matrix,
    pub ctc: Matrix,
    /// XᵀY, M × T
    pub xty: Matrix,
    pub xtx: Vec<f64>,
    /// CᵀX, K × M
    pub ctx: Matrix,
}

impl AggregateSums {
    pub fn t(&self) -> usize {
        self.yty.len()
    }

    /// The variant-independent part of the aggregate.
    pub fn base(&self) -> BaseSums {
        BaseSums {
            n: self.n,
            yty: self.yty.clone(),
            cty: self.cty.clone(),
            ctc: self.ctc.clone(),
        }
    }

    /// Column slice `[j0, j1)` of the variant-sized sums, as one shard's
    /// [`ShardSums`] (test/simulation convenience).
    pub fn shard_sums(&self, j0: usize, j1: usize) -> ShardSums {
        ShardSums {
            xty: self.xty.row_slice(j0, j1),
            xtx: self.xtx[j0..j1].to_vec(),
            ctx: self.ctx.col_slice(j0, j1),
        }
    }
}

/// Inverse of [`flatten_for_sum`] applied to a summed vector.
pub fn unflatten_sum(layout: FlatLayout, v: &[f64]) -> anyhow::Result<AggregateSums> {
    anyhow::ensure!(v.len() == layout.len(), "flat length mismatch");
    let (k, m, t) = (layout.k, layout.m, layout.t);
    let mut pos = 0usize;
    let mut take = |n: usize| {
        let s = &v[pos..pos + n];
        pos += n;
        s
    };
    let n = take(1)[0].round() as usize;
    let yty = take(t).to_vec();
    let cty = Matrix::from_vec(k, t, take(k * t).to_vec());
    let ctc = Matrix::from_vec(k, k, take(k * k).to_vec());
    let xty = Matrix::from_vec(m, t, take(m * t).to_vec());
    let xtx = take(m).to_vec();
    let ctx = Matrix::from_vec(k, m, take(k * m).to_vec());
    Ok(AggregateSums { n, yty, cty, ctc, xty, xtx, ctx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::util::rng::Rng;

    fn make(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let ys = Matrix::randn(n, t, &mut rng);
        (ys, c, x)
    }

    #[test]
    fn matches_direct_computation() {
        let (ys, c, x) = make(80, 4, 17, 1, 130);
        let y = ys.col(0);
        let cp = compress_party(&ys, &c, &x, 5, Some(3));
        assert_eq!(cp.n, 80);
        assert_eq!((cp.k(), cp.m(), cp.t()), (4, 17, 1));
        assert!(rel_err(&cp.yty, &[y.iter().map(|v| v * v).sum::<f64>()]) < 1e-14);
        assert!(rel_err(&cp.cty.data, &c.t_matvec(&y)) < 1e-13);
        assert!(rel_err(&cp.ctc.data, &c.gram().data) < 1e-13);
        assert!(rel_err(&cp.xty.data, &x.t_matvec(&y)) < 1e-13);
        let xtx_direct: Vec<f64> =
            (0..17).map(|j| x.col(j).iter().map(|v| v * v).sum()).collect();
        assert!(rel_err(&cp.xtx, &xtx_direct) < 1e-13);
        assert!(rel_err(&cp.ctx.data, &c.t_matmul(&x).data) < 1e-13);
    }

    #[test]
    fn multi_trait_matches_direct_computation() {
        let (ys, c, x) = make(70, 3, 11, 4, 230);
        let cp = compress_party(&ys, &c, &x, 4, Some(2));
        assert_eq!((cp.k(), cp.m(), cp.t()), (3, 11, 4));
        assert!(rel_err(&cp.cty.data, &c.t_matmul(&ys).data) < 1e-13);
        assert!(rel_err(&cp.xty.data, &x.t_matmul(&ys).data) < 1e-13);
        for tt in 0..4 {
            let y = ys.col(tt);
            assert!(
                rel_err(&[cp.yty[tt]], &[y.iter().map(|v| v * v).sum::<f64>()]) < 1e-14,
                "trait {tt}"
            );
        }
    }

    /// Trait `t` of a T-trait compression is bit-identical to compressing
    /// that trait alone — the per-trait exactness the protocol relies on.
    #[test]
    fn per_trait_slices_bit_identical_to_single_trait() {
        let (ys, c, x) = make(60, 3, 14, 3, 231);
        let multi = compress_party(&ys, &c, &x, 5, Some(2));
        for tt in 0..3 {
            let single = compress_party(&Matrix::from_col(ys.col(tt)), &c, &x, 5, Some(2));
            assert_eq!(multi.yty[tt].to_bits(), single.yty[0].to_bits(), "yty {tt}");
            assert_eq!(multi.cty.col(tt), single.cty.data, "cty {tt}");
            assert_eq!(multi.xty.col(tt), single.xty.data, "xty {tt}");
            // shared pieces identical regardless of T
            assert_eq!(multi.xtx, single.xtx);
            assert_eq!(multi.ctx.data, single.ctx.data);
            assert_eq!(multi.ctc.data, single.ctc.data);
        }
    }

    #[test]
    fn block_and_thread_invariance() {
        let (ys, c, x) = make(60, 3, 23, 2, 131);
        let a = compress_party(&ys, &c, &x, 23, Some(1));
        let b = compress_party(&ys, &c, &x, 4, Some(4));
        // identical up to fp addition order within a column (same order
        // actually — rows are always scanned in order within a block)
        assert!(rel_err(&a.xty.data, &b.xty.data) < 1e-14);
        assert!(rel_err(&a.ctx.data, &b.ctx.data) < 1e-14);
    }

    /// The tentpole contract: for a fixed tile height, every
    /// (threads × block_m) combination produces bit-identical output —
    /// the canonical ascending-tile fold is independent of who computes
    /// which tile partial and of how the columns are chunked.
    #[test]
    fn threaded_compress_bit_identical_to_serial_across_tiles() {
        let n = 57;
        let (ys, c, x) = make(n, 3, 19, 2, 140);
        for tile in [1usize, 13, 64, n] {
            let serial =
                compress_variant_block_opts(&ys, &c, &x, 0, 19, 19, Some(tile), Some(1));
            let (yty_s, cty_s) = compress_yside(&ys, &c, Some(tile), Some(1));
            for threads in [2usize, 4, 7] {
                for block_m in [1usize, 5, 19] {
                    let par = compress_variant_block_opts(
                        &ys,
                        &c,
                        &x,
                        0,
                        19,
                        block_m,
                        Some(tile),
                        Some(threads),
                    );
                    let tag = format!("tile={tile} threads={threads} block_m={block_m}");
                    assert_eq!(par.xty.data, serial.xty.data, "xty {tag}");
                    assert_eq!(par.xtx, serial.xtx, "xtx {tag}");
                    assert_eq!(par.ctx.data, serial.ctx.data, "ctx {tag}");
                }
                let (yty_p, cty_p) = compress_yside(&ys, &c, Some(tile), Some(threads));
                assert_eq!(yty_p, yty_s, "yty tile={tile} threads={threads}");
                assert_eq!(cty_p.data, cty_s.data, "cty tile={tile} threads={threads}");
            }
        }
    }

    #[test]
    fn canonical_tile_rows_depends_on_k_only_and_is_bounded() {
        // monotone non-increasing in K, clamped into [64, 4096]
        let mut prev = usize::MAX;
        for k in [1usize, 2, 8, 16, 64, 1024, 1 << 20] {
            let t = canonical_tile_rows(k);
            assert!((64..=4096).contains(&t), "tile {t} out of bounds at k={k}");
            assert!(t <= prev, "tile height must not grow with K");
            prev = t;
        }
    }

    #[test]
    fn sharded_compress_is_bit_identical_to_full() {
        let (ys, c, x) = make(50, 4, 29, 2, 136);
        let full = compress_party(&ys, &c, &x, 7, Some(2));
        // three ragged shards: [0,10), [10,20), [20,29)
        for (j0, j1) in [(0usize, 10usize), (10, 20), (20, 29)] {
            let vb = compress_variant_block(&ys, &c, &x, j0, j1, 7, Some(2));
            assert_eq!(vb.xty.data, full.xty.row_slice(j0, j1).data, "xty {j0}..{j1}");
            assert_eq!(vb.xtx, full.xtx[j0..j1], "xtx {j0}..{j1}");
            assert_eq!(vb.ctx.data, full.ctx.col_slice(j0, j1).data, "ctx {j0}..{j1}");
            // and the cached-engine slicing path agrees too
            let sliced = full.variant_block(j0, j1);
            assert_eq!(sliced.xty.data, vb.xty.data);
            assert_eq!(sliced.ctx.data, vb.ctx.data);
        }
    }

    #[test]
    fn base_flatten_roundtrip() {
        let (ys, c, _) = make(40, 3, 2, 2, 137);
        let base = compress_base(&ys, &c);
        let flat = base.flatten();
        assert_eq!(flat.len(), base_flat_len(3, 2));
        let sums = unflatten_base(3, 2, &flat).unwrap();
        assert_eq!(sums.n, 40);
        assert_eq!(sums.yty, base.yty);
        assert_eq!(sums.cty.data, base.cty.data);
        assert_eq!(sums.ctc.data, base.ctc.data);
        assert!(unflatten_base(4, 2, &flat).is_err());
        assert!(unflatten_base(3, 3, &flat).is_err());
    }

    #[test]
    fn shard_flatten_roundtrip() {
        let (ys, c, x) = make(30, 3, 12, 3, 138);
        let vb = compress_variant_block(&ys, &c, &x, 4, 9, 3, Some(1));
        let flat = vb.flatten();
        assert_eq!(flat.len(), shard_flat_len(3, 3, 5));
        let sums = unflatten_shard(3, 3, 5, &flat).unwrap();
        assert_eq!(sums.xty.data, vb.xty.data);
        assert_eq!(sums.xtx, vb.xtx);
        assert_eq!(sums.ctx.data, vb.ctx.data);
        assert!(unflatten_shard(3, 3, 6, &flat).is_err());
        assert!(unflatten_shard(3, 2, 5, &flat).is_err());
    }

    #[test]
    fn sparse_zero_columns_ok() {
        let (ys, c, mut x) = make(40, 3, 5, 1, 132);
        for i in 0..40 {
            x[(i, 2)] = 0.0;
        }
        let cp = compress_party(&ys, &c, &x, 2, Some(2));
        assert_eq!(cp.xtx[2], 0.0);
        assert_eq!(cp.xty[(2, 0)], 0.0);
    }

    #[test]
    fn flatten_roundtrip() {
        let (ys, c, x) = make(50, 4, 9, 2, 133);
        let cp = compress_party(&ys, &c, &x, 9, Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        assert_eq!(flat.len(), layout.len());
        let agg = unflatten_sum(layout, &flat).unwrap();
        assert_eq!(agg.n, cp.n);
        assert!(rel_err(&agg.cty.data, &cp.cty.data) < 1e-15);
        assert!(rel_err(&agg.ctx.data, &cp.ctx.data) < 1e-15);
        assert!(rel_err(&agg.xty.data, &cp.xty.data) < 1e-15);
        assert!(rel_err(&agg.xtx, &cp.xtx) < 1e-15);
    }

    #[test]
    fn full_flat_is_base_then_shard_segments() {
        // the full layout is exactly [base | xty | xtx | ctx]; the shard
        // machinery relies on these offsets to scatter shard deltas
        let (ys, c, x) = make(35, 3, 8, 2, 139);
        let cp = compress_party(&ys, &c, &x, 8, Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        assert_eq!(&flat[..layout.xty_off()], cp.base().flatten().as_slice());
        let vb = cp.variant_block(0, 8);
        assert_eq!(&flat[layout.xty_off()..], vb.flatten().as_slice());
        assert_eq!(layout.ctx_off() + layout.k * layout.m, layout.len());
    }

    #[test]
    fn flat_sum_equals_pooled_stats() {
        // Σ_p flatten(party_p) == flatten-ish of pooled data, per trait
        let (ys1, c1, x1) = make(30, 3, 7, 2, 134);
        let (ys2, c2, x2) = make(45, 3, 7, 2, 135);
        let cp1 = compress_party(&ys1, &c1, &x1, 7, Some(1));
        let cp2 = compress_party(&ys2, &c2, &x2, 7, Some(1));
        let (layout, f1) = flatten_for_sum(&cp1);
        let (_, f2) = flatten_for_sum(&cp2);
        let sum: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let agg = unflatten_sum(layout, &sum).unwrap();

        let ys = Matrix::vstack(&[&ys1, &ys2]);
        let c = Matrix::vstack(&[&c1, &c2]);
        let x = Matrix::vstack(&[&x1, &x2]);
        let pooled = compress_party(&ys, &c, &x, 7, Some(1));
        assert_eq!(agg.n, 75);
        assert!(rel_err(&agg.ctc.data, &pooled.ctc.data) < 1e-13);
        assert!(rel_err(&agg.xty.data, &pooled.xty.data) < 1e-13);
        assert!(rel_err(&agg.ctx.data, &pooled.ctx.data) < 1e-13);
    }

    #[test]
    fn layout_len_single_trait_matches_historical() {
        let l = FlatLayout { k: 3, m: 10, t: 1 };
        assert_eq!(l.len(), 2 + 3 + 9 + 20 + 30);
        assert_eq!(l.xty_off(), 14);
        assert_eq!(l.xtx_off(), 24);
        assert_eq!(l.ctx_off(), 34);
        assert_eq!(base_flat_len(3, 1), 2 + 3 + 9);
        assert_eq!(shard_flat_len(3, 1, 10), 10 * (2 + 3));
    }

    #[test]
    fn layout_len_multi_trait() {
        let l = FlatLayout { k: 3, m: 10, t: 4 };
        // [n | yty(4) | cty(12) | ctc(9) | xty(40) | xtx(10) | ctx(30)]
        assert_eq!(l.xty_off(), 1 + 4 + 12 + 9);
        assert_eq!(l.xtx_off(), l.xty_off() + 40);
        assert_eq!(l.ctx_off(), l.xtx_off() + 10);
        assert_eq!(l.len(), l.ctx_off() + 30);
    }
}
