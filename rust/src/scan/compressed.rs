//! Compress-within stage (§2/§4): per-party sufficient statistics.
//!
//! For party data `(y, C, X)` with `N_p` samples, `K` permanent and `M`
//! transient covariates, compression produces
//!
//! `yᵀy, Cᵀy, CᵀC, Xᵀy, X·X (diag), CᵀX, R_p = qr(C_p).R`
//!
//! — `O(N_p K (K + M))` work, all local plaintext. The `M`-sized pieces
//! are computed in parallel over variant blocks ([`parallel_for_chunks`]),
//! which is the paper's `O(NKM/C)` term.
//!
//! The stage is split to serve the sharded streaming pipeline
//! ([`crate::scan::ShardPlan`]):
//!
//! - [`compress_base`] — the variant-independent part
//!   (`N, yᵀy, Cᵀy, CᵀC, R_p`), computed once per session;
//! - [`compress_variant_block`] — the `[j0, j1)` column slice of the
//!   variant-sized statistics (`Xᵀy, X·X, CᵀX`), computed once per shard
//!   with `O(K·width)` memory.
//!
//! [`compress_party`] composes the two over the full column range and is
//! bit-identical to compressing shard-by-shard and concatenating (per-
//! variant sums never mix across columns).

use crate::linalg::{householder_qr, Matrix};
use crate::util::threadpool::parallel_for_chunks;

/// Per-party compressed statistics. The entire secure protocol operates
/// on this — the `N_p`-row data never leaves the party.
#[derive(Clone, Debug)]
pub struct CompressedParty {
    pub n: usize,
    pub yty: f64,
    /// Cᵀy, length K
    pub cty: Vec<f64>,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// R factor of QR(C_p), K × K (TSQR path; reveals C_pᵀC_p, so it is
    /// only transmitted in plaintext mode — see DESIGN.md §Security)
    pub r: Matrix,
    /// Xᵀy, length M
    pub xty: Vec<f64>,
    /// per-variant X_m·X_m, length M
    pub xtx: Vec<f64>,
    /// CᵀX, K × M
    pub ctx: Matrix,
}

impl CompressedParty {
    pub fn k(&self) -> usize {
        self.cty.len()
    }

    pub fn m(&self) -> usize {
        self.xty.len()
    }

    /// The variant-independent part of these statistics.
    pub fn base(&self) -> BaseStats {
        BaseStats {
            n: self.n,
            yty: self.yty,
            cty: self.cty.clone(),
            ctc: self.ctc.clone(),
            r: self.r.clone(),
        }
    }

    /// Column slice `[j0, j1)` of the variant-sized statistics — used by
    /// compute engines that materialize all `M` columns at once (the AOT
    /// artifact path) to feed the sharded protocol.
    pub fn variant_block(&self, j0: usize, j1: usize) -> VariantBlockStats {
        assert!(j0 <= j1 && j1 <= self.m(), "bad column range {j0}..{j1}");
        VariantBlockStats {
            j0,
            xty: self.xty[j0..j1].to_vec(),
            xtx: self.xtx[j0..j1].to_vec(),
            ctx: self.ctx.col_slice(j0, j1),
        }
    }
}

/// Variant-independent compressed statistics (`O(K²)` floats).
#[derive(Clone, Debug)]
pub struct BaseStats {
    pub n: usize,
    pub yty: f64,
    /// Cᵀy, length K
    pub cty: Vec<f64>,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// R factor of QR(C_p) (plaintext/TSQR path only)
    pub r: Matrix,
}

impl BaseStats {
    pub fn k(&self) -> usize {
        self.cty.len()
    }

    /// Flatten for secure summation: `[n, yᵀy, Cᵀy(K), CᵀC(K²)]`.
    /// (`R_p` is deliberately excluded — it is never securely summed.)
    pub fn flatten(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(base_flat_len(self.k()));
        v.push(self.n as f64);
        v.push(self.yty);
        v.extend_from_slice(&self.cty);
        v.extend_from_slice(&self.ctc.data);
        debug_assert_eq!(v.len(), base_flat_len(self.k()));
        v
    }
}

/// Length of the flattened base vector for `K` covariates.
pub fn base_flat_len(k: usize) -> usize {
    2 + k + k * k
}

/// Aggregate of the variant-independent statistics across parties.
#[derive(Clone, Debug)]
pub struct BaseSums {
    pub n: usize,
    pub yty: f64,
    pub cty: Vec<f64>,
    pub ctc: Matrix,
}

/// Inverse of [`BaseStats::flatten`] applied to a summed vector.
pub fn unflatten_base(k: usize, v: &[f64]) -> anyhow::Result<BaseSums> {
    anyhow::ensure!(v.len() == base_flat_len(k), "base flat length mismatch");
    Ok(BaseSums {
        n: v[0].round() as usize,
        yty: v[1],
        cty: v[2..2 + k].to_vec(),
        ctc: Matrix::from_vec(k, k, v[2 + k..].to_vec()),
    })
}

/// One shard's slice of the variant-sized statistics (`O(K·width)`).
#[derive(Clone, Debug)]
pub struct VariantBlockStats {
    /// first absolute variant column covered by this block
    pub j0: usize,
    /// Xᵀy for columns `[j0, j0+width)`
    pub xty: Vec<f64>,
    /// per-variant X·X for the same columns
    pub xtx: Vec<f64>,
    /// CᵀX, K × width
    pub ctx: Matrix,
}

impl VariantBlockStats {
    pub fn width(&self) -> usize {
        self.xty.len()
    }

    /// Flatten for secure summation: `[Xᵀy(w), X·X(w), CᵀX(K·w)]`.
    pub fn flatten(&self) -> Vec<f64> {
        let k = self.ctx.rows;
        let mut v = Vec::with_capacity(shard_flat_len(k, self.width()));
        v.extend_from_slice(&self.xty);
        v.extend_from_slice(&self.xtx);
        v.extend_from_slice(&self.ctx.data);
        debug_assert_eq!(v.len(), shard_flat_len(k, self.width()));
        v
    }
}

/// Length of the flattened shard vector for `K` covariates and shard
/// width `w`.
pub fn shard_flat_len(k: usize, w: usize) -> usize {
    w * (2 + k)
}

/// Aggregate of one shard's variant statistics across parties.
#[derive(Clone, Debug)]
pub struct ShardSums {
    pub xty: Vec<f64>,
    pub xtx: Vec<f64>,
    /// CᵀX, K × width
    pub ctx: Matrix,
}

/// Inverse of [`VariantBlockStats::flatten`] applied to a summed vector.
pub fn unflatten_shard(k: usize, w: usize, v: &[f64]) -> anyhow::Result<ShardSums> {
    anyhow::ensure!(v.len() == shard_flat_len(k, w), "shard flat length mismatch");
    Ok(ShardSums {
        xty: v[..w].to_vec(),
        xtx: v[w..2 * w].to_vec(),
        ctx: Matrix::from_vec(k, w, v[2 * w..].to_vec()),
    })
}

/// Compress the variant-independent statistics of one party.
pub fn compress_base(y: &[f64], c: &Matrix) -> BaseStats {
    let n = y.len();
    assert_eq!(c.rows, n, "C rows != N");
    BaseStats {
        n,
        yty: y.iter().map(|v| v * v).sum(),
        cty: c.t_matvec(y),
        ctc: c.gram(),
        r: householder_qr(c).r,
    }
}

/// Compress the variant statistics for columns `[j0, j1)` of `X`
/// (pure-Rust reference path).
///
/// `block_m` controls the variant-block width for parallelism; `threads`
/// caps the worker count (None = all cores). Results are bit-identical
/// to the corresponding slice of a full-range compression: each output
/// column is a sum over samples in a fixed order, independent of how the
/// columns are chunked.
pub fn compress_variant_block(
    y: &[f64],
    c: &Matrix,
    x: &Matrix,
    j0: usize,
    j1: usize,
    block_m: usize,
    threads: Option<usize>,
) -> VariantBlockStats {
    let n = y.len();
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(x.rows, n, "X rows != N");
    assert!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
    let k = c.cols;
    let w = j1 - j0;

    // Blocked over variants. Each chunk accumulates into a chunk-local
    // contiguous buffer (xty/xtx/ctx interleaved per block) and writes
    // back once — the strided `ctx[kk·w + j]` stores of the naive loop
    // thrash the cache at K ≥ 16 (see EXPERIMENTS.md §Perf).
    let mut xty = vec![0.0; w];
    let mut xtx = vec![0.0; w];
    let mut ctx = Matrix::zeros(k, w);
    {
        // Disjoint column blocks → safe shared-mutable access.
        let xty_ptr = SendPtr(xty.as_mut_ptr());
        let xtx_ptr = SendPtr(xtx.as_mut_ptr());
        let ctx_ptr = SendPtr(ctx.data.as_mut_ptr());
        parallel_for_chunks(w, block_m.max(1), threads, |b0, b1| {
            let bw = b1 - b0;
            // local accumulators: [xty(bw) | xtx(bw) | ctx(k×bw)]
            let mut local = vec![0.0f64; bw * (2 + k)];
            for i in 0..n {
                let yi = y[i];
                let x_row = &x.row(i)[j0 + b0..j0 + b1];
                let c_row = c.row(i);
                let (xty_l, rest) = local.split_at_mut(bw);
                let (xtx_l, ctx_l) = rest.split_at_mut(bw);
                // branch-free axpy form: one vectorizable pass per output
                // row (beats the per-element `if xv == 0` skip even at
                // ~50% genotype sparsity — see EXPERIMENTS.md §Perf)
                for (j, &xv) in x_row.iter().enumerate() {
                    xty_l[j] += xv * yi;
                    xtx_l[j] += xv * xv;
                }
                for (kk, &cv) in c_row.iter().enumerate() {
                    let row = &mut ctx_l[kk * bw..(kk + 1) * bw];
                    for (r, &xv) in row.iter_mut().zip(x_row) {
                        *r += cv * xv;
                    }
                }
            }
            // single write-back into the shared outputs
            // SAFETY: columns [b0, b1) are owned by this chunk.
            unsafe {
                for j in 0..bw {
                    *xty_ptr.at(b0 + j) = local[j];
                    *xtx_ptr.at(b0 + j) = local[bw + j];
                }
                for kk in 0..k {
                    for j in 0..bw {
                        *ctx_ptr.at(kk * w + b0 + j) = local[(2 + kk) * bw + j];
                    }
                }
            }
        });
    }

    VariantBlockStats { j0, xty, xtx, ctx }
}

/// Compress one party's data (pure-Rust reference path): the base stage
/// plus the full-range variant stage — the one-shard degenerate case of
/// the streaming pipeline.
pub fn compress_party(
    y: &[f64],
    c: &Matrix,
    x: &Matrix,
    block_m: usize,
    threads: Option<usize>,
) -> CompressedParty {
    let base = compress_base(y, c);
    let vb = compress_variant_block(y, c, x, 0, x.cols, block_m, threads);
    CompressedParty {
        n: base.n,
        yty: base.yty,
        cty: base.cty,
        ctc: base.ctc,
        r: base.r,
        xty: vb.xty,
        xtx: vb.xtx,
        ctx: vb.ctx,
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees disjoint indices across threads.
    #[inline]
    unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

/// Layout of the flattened statistics vector used by the secure-sum
/// protocol. All parties must agree on `(K, M)`; the flattening is
/// `[n, yty, cty(K), ctc(K²), xty(M), xtx(M), ctx(K·M)]` — i.e. the base
/// segment followed by the single full-width shard segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlatLayout {
    pub k: usize,
    pub m: usize,
}

impl FlatLayout {
    pub fn len(&self) -> usize {
        base_flat_len(self.k) + shard_flat_len(self.k, self.m)
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Offset of the `xty` segment (== length of the base segment).
    pub fn xty_off(&self) -> usize {
        base_flat_len(self.k)
    }

    /// Offset of the `xtx` segment.
    pub fn xtx_off(&self) -> usize {
        self.xty_off() + self.m
    }

    /// Offset of the `ctx` segment (K rows × M cols, row-major).
    pub fn ctx_off(&self) -> usize {
        self.xtx_off() + self.m
    }
}

/// Flatten compressed statistics for share-wise summation. `n` rides in
/// the same vector (as a real number) so the entire combine input is one
/// secure sum.
pub fn flatten_for_sum(cp: &CompressedParty) -> (FlatLayout, Vec<f64>) {
    let layout = FlatLayout { k: cp.k(), m: cp.m() };
    let mut v = Vec::with_capacity(layout.len());
    v.push(cp.n as f64);
    v.push(cp.yty);
    v.extend_from_slice(&cp.cty);
    v.extend_from_slice(&cp.ctc.data);
    v.extend_from_slice(&cp.xty);
    v.extend_from_slice(&cp.xtx);
    v.extend_from_slice(&cp.ctx.data);
    debug_assert_eq!(v.len(), layout.len());
    (layout, v)
}

/// Aggregate sums, as reconstructed by the combine stage.
#[derive(Clone, Debug)]
pub struct AggregateSums {
    pub n: usize,
    pub yty: f64,
    pub cty: Vec<f64>,
    pub ctc: Matrix,
    pub xty: Vec<f64>,
    pub xtx: Vec<f64>,
    pub ctx: Matrix,
}

impl AggregateSums {
    /// The variant-independent part of the aggregate.
    pub fn base(&self) -> BaseSums {
        BaseSums {
            n: self.n,
            yty: self.yty,
            cty: self.cty.clone(),
            ctc: self.ctc.clone(),
        }
    }
}

/// Inverse of [`flatten_for_sum`] applied to a summed vector.
pub fn unflatten_sum(layout: FlatLayout, v: &[f64]) -> anyhow::Result<AggregateSums> {
    anyhow::ensure!(v.len() == layout.len(), "flat length mismatch");
    let (k, m) = (layout.k, layout.m);
    let mut pos = 0usize;
    let mut take = |n: usize| {
        let s = &v[pos..pos + n];
        pos += n;
        s
    };
    let n = take(1)[0].round() as usize;
    let yty = take(1)[0];
    let cty = take(k).to_vec();
    let ctc = Matrix::from_vec(k, k, take(k * k).to_vec());
    let xty = take(m).to_vec();
    let xtx = take(m).to_vec();
    let ctx = Matrix::from_vec(k, m, take(k * m).to_vec());
    Ok(AggregateSums { n, yty, cty, ctc, xty, xtx, ctx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::util::rng::Rng;

    fn make(n: usize, k: usize, m: usize, seed: u64) -> (Vec<f64>, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (y, c, x)
    }

    #[test]
    fn matches_direct_computation() {
        let (y, c, x) = make(80, 4, 17, 130);
        let cp = compress_party(&y, &c, &x, 5, Some(3));
        assert_eq!(cp.n, 80);
        assert!(rel_err(&[cp.yty], &[y.iter().map(|v| v * v).sum::<f64>()]) < 1e-14);
        assert!(rel_err(&cp.cty, &c.t_matvec(&y)) < 1e-13);
        assert!(rel_err(&cp.ctc.data, &c.gram().data) < 1e-13);
        assert!(rel_err(&cp.xty, &x.t_matvec(&y)) < 1e-13);
        let xtx_direct: Vec<f64> =
            (0..17).map(|j| x.col(j).iter().map(|v| v * v).sum()).collect();
        assert!(rel_err(&cp.xtx, &xtx_direct) < 1e-13);
        assert!(rel_err(&cp.ctx.data, &c.t_matmul(&x).data) < 1e-13);
    }

    #[test]
    fn block_and_thread_invariance() {
        let (y, c, x) = make(60, 3, 23, 131);
        let a = compress_party(&y, &c, &x, 23, Some(1));
        let b = compress_party(&y, &c, &x, 4, Some(4));
        // identical up to fp addition order within a column (same order
        // actually — rows are always scanned in order within a block)
        assert!(rel_err(&a.xty, &b.xty) < 1e-14);
        assert!(rel_err(&a.ctx.data, &b.ctx.data) < 1e-14);
    }

    #[test]
    fn sharded_compress_is_bit_identical_to_full() {
        let (y, c, x) = make(50, 4, 29, 136);
        let full = compress_party(&y, &c, &x, 7, Some(2));
        // three ragged shards: [0,10), [10,20), [20,29)
        for (j0, j1) in [(0usize, 10usize), (10, 20), (20, 29)] {
            let vb = compress_variant_block(&y, &c, &x, j0, j1, 7, Some(2));
            assert_eq!(vb.xty, full.xty[j0..j1], "xty {j0}..{j1}");
            assert_eq!(vb.xtx, full.xtx[j0..j1], "xtx {j0}..{j1}");
            assert_eq!(vb.ctx.data, full.ctx.col_slice(j0, j1).data, "ctx {j0}..{j1}");
            // and the cached-engine slicing path agrees too
            let sliced = full.variant_block(j0, j1);
            assert_eq!(sliced.xty, vb.xty);
            assert_eq!(sliced.ctx.data, vb.ctx.data);
        }
    }

    #[test]
    fn base_flatten_roundtrip() {
        let (y, c, _) = make(40, 3, 2, 137);
        let base = compress_base(&y, &c);
        let flat = base.flatten();
        assert_eq!(flat.len(), base_flat_len(3));
        let sums = unflatten_base(3, &flat).unwrap();
        assert_eq!(sums.n, 40);
        assert_eq!(sums.yty, base.yty);
        assert_eq!(sums.cty, base.cty);
        assert_eq!(sums.ctc.data, base.ctc.data);
        assert!(unflatten_base(4, &flat).is_err());
    }

    #[test]
    fn shard_flatten_roundtrip() {
        let (y, c, x) = make(30, 3, 12, 138);
        let vb = compress_variant_block(&y, &c, &x, 4, 9, 3, Some(1));
        let flat = vb.flatten();
        assert_eq!(flat.len(), shard_flat_len(3, 5));
        let sums = unflatten_shard(3, 5, &flat).unwrap();
        assert_eq!(sums.xty, vb.xty);
        assert_eq!(sums.xtx, vb.xtx);
        assert_eq!(sums.ctx.data, vb.ctx.data);
        assert!(unflatten_shard(3, 6, &flat).is_err());
    }

    #[test]
    fn sparse_zero_columns_ok() {
        let (y, c, mut x) = make(40, 3, 5, 132);
        for i in 0..40 {
            x[(i, 2)] = 0.0;
        }
        let cp = compress_party(&y, &c, &x, 2, Some(2));
        assert_eq!(cp.xtx[2], 0.0);
        assert_eq!(cp.xty[2], 0.0);
    }

    #[test]
    fn flatten_roundtrip() {
        let (y, c, x) = make(50, 4, 9, 133);
        let cp = compress_party(&y, &c, &x, 9, Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        assert_eq!(flat.len(), layout.len());
        let agg = unflatten_sum(layout, &flat).unwrap();
        assert_eq!(agg.n, cp.n);
        assert!(rel_err(&agg.cty, &cp.cty) < 1e-15);
        assert!(rel_err(&agg.ctx.data, &cp.ctx.data) < 1e-15);
        assert!(rel_err(&agg.xtx, &cp.xtx) < 1e-15);
    }

    #[test]
    fn full_flat_is_base_then_shard_segments() {
        // the full layout is exactly [base | xty | xtx | ctx]; the shard
        // machinery relies on these offsets to scatter shard deltas
        let (y, c, x) = make(35, 3, 8, 139);
        let cp = compress_party(&y, &c, &x, 8, Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        assert_eq!(&flat[..layout.xty_off()], cp.base().flatten().as_slice());
        let vb = cp.variant_block(0, 8);
        assert_eq!(&flat[layout.xty_off()..], vb.flatten().as_slice());
        assert_eq!(layout.ctx_off() + layout.k * layout.m, layout.len());
    }

    #[test]
    fn flat_sum_equals_pooled_stats() {
        // Σ_p flatten(party_p) == flatten-ish of pooled data
        let (y1, c1, x1) = make(30, 3, 7, 134);
        let (y2, c2, x2) = make(45, 3, 7, 135);
        let cp1 = compress_party(&y1, &c1, &x1, 7, Some(1));
        let cp2 = compress_party(&y2, &c2, &x2, 7, Some(1));
        let (layout, f1) = flatten_for_sum(&cp1);
        let (_, f2) = flatten_for_sum(&cp2);
        let sum: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let agg = unflatten_sum(layout, &sum).unwrap();

        let y: Vec<f64> = y1.iter().chain(&y2).copied().collect();
        let c = Matrix::vstack(&[&c1, &c2]);
        let x = Matrix::vstack(&[&x1, &x2]);
        let pooled = compress_party(&y, &c, &x, 7, Some(1));
        assert_eq!(agg.n, 75);
        assert!(rel_err(&agg.ctc.data, &pooled.ctc.data) < 1e-13);
        assert!(rel_err(&agg.xty, &pooled.xty) < 1e-13);
        assert!(rel_err(&agg.ctx.data, &pooled.ctx.data) < 1e-13);
    }

    #[test]
    fn layout_len() {
        let l = FlatLayout { k: 3, m: 10 };
        assert_eq!(l.len(), 2 + 3 + 9 + 20 + 30);
        assert_eq!(l.xty_off(), 14);
        assert_eq!(l.xtx_off(), 24);
        assert_eq!(l.ctx_off(), 34);
    }
}
