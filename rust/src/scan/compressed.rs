//! Compress-within stage (§2/§4): per-party sufficient statistics.
//!
//! For party data `(y, C, X)` with `N_p` samples, `K` permanent and `M`
//! transient covariates, compression produces
//!
//! `yᵀy, Cᵀy, CᵀC, Xᵀy, X·X (diag), CᵀX, R_p = qr(C_p).R`
//!
//! — `O(N_p K (K + M))` work, all local plaintext. The `M`-sized pieces
//! are computed in parallel over variant blocks ([`parallel_for_chunks`]),
//! which is the paper's `O(NKM/C)` term.

use crate::linalg::{householder_qr, Matrix};
use crate::util::threadpool::parallel_for_chunks;

/// Per-party compressed statistics. The entire secure protocol operates
/// on this — the `N_p`-row data never leaves the party.
#[derive(Clone, Debug)]
pub struct CompressedParty {
    pub n: usize,
    pub yty: f64,
    /// Cᵀy, length K
    pub cty: Vec<f64>,
    /// CᵀC, K × K
    pub ctc: Matrix,
    /// R factor of QR(C_p), K × K (TSQR path; reveals C_pᵀC_p, so it is
    /// only transmitted in plaintext mode — see DESIGN.md §Security)
    pub r: Matrix,
    /// Xᵀy, length M
    pub xty: Vec<f64>,
    /// per-variant X_m·X_m, length M
    pub xtx: Vec<f64>,
    /// CᵀX, K × M
    pub ctx: Matrix,
}

impl CompressedParty {
    pub fn k(&self) -> usize {
        self.cty.len()
    }

    pub fn m(&self) -> usize {
        self.xty.len()
    }
}

/// Compress one party's data (pure-Rust reference path).
///
/// `block_m` controls the variant-block width for parallelism; `threads`
/// caps the worker count (None = all cores).
pub fn compress_party(
    y: &[f64],
    c: &Matrix,
    x: &Matrix,
    block_m: usize,
    threads: Option<usize>,
) -> CompressedParty {
    let n = y.len();
    assert_eq!(c.rows, n, "C rows != N");
    assert_eq!(x.rows, n, "X rows != N");
    let k = c.cols;
    let m = x.cols;

    let yty: f64 = y.iter().map(|v| v * v).sum();
    let cty = c.t_matvec(y);
    let ctc = c.gram();
    let r = householder_qr(c).r;

    // M-sized pieces, blocked over variants. Each chunk accumulates into
    // a chunk-local contiguous buffer (xty/xtx/ctx interleaved per block)
    // and writes back once — the strided `ctx[kk·m + j]` stores of the
    // naive loop thrash the cache at K ≥ 16 (see EXPERIMENTS.md §Perf).
    let mut xty = vec![0.0; m];
    let mut xtx = vec![0.0; m];
    let mut ctx = Matrix::zeros(k, m);
    {
        // Disjoint column blocks → safe shared-mutable access.
        let xty_ptr = SendPtr(xty.as_mut_ptr());
        let xtx_ptr = SendPtr(xtx.as_mut_ptr());
        let ctx_ptr = SendPtr(ctx.data.as_mut_ptr());
        parallel_for_chunks(m, block_m.max(1), threads, |j0, j1| {
            let w = j1 - j0;
            // local accumulators: [xty(w) | xtx(w) | ctx(k×w)]
            let mut local = vec![0.0f64; w * (2 + k)];
            for i in 0..n {
                let yi = y[i];
                let x_row = &x.row(i)[j0..j1];
                let c_row = c.row(i);
                let (xty_l, rest) = local.split_at_mut(w);
                let (xtx_l, ctx_l) = rest.split_at_mut(w);
                // branch-free axpy form: one vectorizable pass per output
                // row (beats the per-element `if xv == 0` skip even at
                // ~50% genotype sparsity — see EXPERIMENTS.md §Perf)
                for (j, &xv) in x_row.iter().enumerate() {
                    xty_l[j] += xv * yi;
                    xtx_l[j] += xv * xv;
                }
                for (kk, &cv) in c_row.iter().enumerate() {
                    let row = &mut ctx_l[kk * w..(kk + 1) * w];
                    for (r, &xv) in row.iter_mut().zip(x_row) {
                        *r += cv * xv;
                    }
                }
            }
            // single write-back into the shared outputs
            // SAFETY: columns [j0, j1) are owned by this chunk.
            unsafe {
                for j in 0..w {
                    *xty_ptr.at(j0 + j) = local[j];
                    *xtx_ptr.at(j0 + j) = local[w + j];
                }
                for kk in 0..k {
                    for j in 0..w {
                        *ctx_ptr.at(kk * m + j0 + j) = local[(2 + kk) * w + j];
                    }
                }
            }
        });
    }

    CompressedParty { n, yty, cty, ctc, r, xty, xtx, ctx }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees disjoint indices across threads.
    #[inline]
    unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

/// Layout of the flattened statistics vector used by the secure-sum
/// protocol. All parties must agree on `(K, M)`; the flattening is
/// `[n, yty, cty(K), ctc(K²), xty(M), xtx(M), ctx(K·M)]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlatLayout {
    pub k: usize,
    pub m: usize,
}

impl FlatLayout {
    pub fn len(&self) -> usize {
        2 + self.k + self.k * self.k + 2 * self.m + self.k * self.m
    }

    pub fn is_empty(&self) -> bool {
        false
    }
}

/// Flatten compressed statistics for share-wise summation. `n` rides in
/// the same vector (as a real number) so the entire combine input is one
/// secure sum.
pub fn flatten_for_sum(cp: &CompressedParty) -> (FlatLayout, Vec<f64>) {
    let layout = FlatLayout { k: cp.k(), m: cp.m() };
    let mut v = Vec::with_capacity(layout.len());
    v.push(cp.n as f64);
    v.push(cp.yty);
    v.extend_from_slice(&cp.cty);
    v.extend_from_slice(&cp.ctc.data);
    v.extend_from_slice(&cp.xty);
    v.extend_from_slice(&cp.xtx);
    v.extend_from_slice(&cp.ctx.data);
    debug_assert_eq!(v.len(), layout.len());
    (layout, v)
}

/// Aggregate sums, as reconstructed by the combine stage.
#[derive(Clone, Debug)]
pub struct AggregateSums {
    pub n: usize,
    pub yty: f64,
    pub cty: Vec<f64>,
    pub ctc: Matrix,
    pub xty: Vec<f64>,
    pub xtx: Vec<f64>,
    pub ctx: Matrix,
}

/// Inverse of [`flatten_for_sum`] applied to a summed vector.
pub fn unflatten_sum(layout: FlatLayout, v: &[f64]) -> anyhow::Result<AggregateSums> {
    anyhow::ensure!(v.len() == layout.len(), "flat length mismatch");
    let (k, m) = (layout.k, layout.m);
    let mut pos = 0usize;
    let mut take = |n: usize| {
        let s = &v[pos..pos + n];
        pos += n;
        s
    };
    let n = take(1)[0].round() as usize;
    let yty = take(1)[0];
    let cty = take(k).to_vec();
    let ctc = Matrix::from_vec(k, k, take(k * k).to_vec());
    let xty = take(m).to_vec();
    let xtx = take(m).to_vec();
    let ctx = Matrix::from_vec(k, m, take(k * m).to_vec());
    Ok(AggregateSums { n, yty, cty, ctc, xty, xtx, ctx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::util::rng::Rng;

    fn make(n: usize, k: usize, m: usize, seed: u64) -> (Vec<f64>, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (y, c, x)
    }

    #[test]
    fn matches_direct_computation() {
        let (y, c, x) = make(80, 4, 17, 130);
        let cp = compress_party(&y, &c, &x, 5, Some(3));
        assert_eq!(cp.n, 80);
        assert!(rel_err(&[cp.yty], &[y.iter().map(|v| v * v).sum::<f64>()]) < 1e-14);
        assert!(rel_err(&cp.cty, &c.t_matvec(&y)) < 1e-13);
        assert!(rel_err(&cp.ctc.data, &c.gram().data) < 1e-13);
        assert!(rel_err(&cp.xty, &x.t_matvec(&y)) < 1e-13);
        let xtx_direct: Vec<f64> =
            (0..17).map(|j| x.col(j).iter().map(|v| v * v).sum()).collect();
        assert!(rel_err(&cp.xtx, &xtx_direct) < 1e-13);
        assert!(rel_err(&cp.ctx.data, &c.t_matmul(&x).data) < 1e-13);
    }

    #[test]
    fn block_and_thread_invariance() {
        let (y, c, x) = make(60, 3, 23, 131);
        let a = compress_party(&y, &c, &x, 23, Some(1));
        let b = compress_party(&y, &c, &x, 4, Some(4));
        // identical up to fp addition order within a column (same order
        // actually — rows are always scanned in order within a block)
        assert!(rel_err(&a.xty, &b.xty) < 1e-14);
        assert!(rel_err(&a.ctx.data, &b.ctx.data) < 1e-14);
    }

    #[test]
    fn sparse_zero_columns_ok() {
        let (y, c, mut x) = make(40, 3, 5, 132);
        for i in 0..40 {
            x[(i, 2)] = 0.0;
        }
        let cp = compress_party(&y, &c, &x, 2, Some(2));
        assert_eq!(cp.xtx[2], 0.0);
        assert_eq!(cp.xty[2], 0.0);
    }

    #[test]
    fn flatten_roundtrip() {
        let (y, c, x) = make(50, 4, 9, 133);
        let cp = compress_party(&y, &c, &x, 9, Some(1));
        let (layout, flat) = flatten_for_sum(&cp);
        assert_eq!(flat.len(), layout.len());
        let agg = unflatten_sum(layout, &flat).unwrap();
        assert_eq!(agg.n, cp.n);
        assert!(rel_err(&agg.cty, &cp.cty) < 1e-15);
        assert!(rel_err(&agg.ctx.data, &cp.ctx.data) < 1e-15);
        assert!(rel_err(&agg.xtx, &cp.xtx) < 1e-15);
    }

    #[test]
    fn flat_sum_equals_pooled_stats() {
        // Σ_p flatten(party_p) == flatten-ish of pooled data
        let (y1, c1, x1) = make(30, 3, 7, 134);
        let (y2, c2, x2) = make(45, 3, 7, 135);
        let cp1 = compress_party(&y1, &c1, &x1, 7, Some(1));
        let cp2 = compress_party(&y2, &c2, &x2, 7, Some(1));
        let (layout, f1) = flatten_for_sum(&cp1);
        let (_, f2) = flatten_for_sum(&cp2);
        let sum: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let agg = unflatten_sum(layout, &sum).unwrap();

        let y: Vec<f64> = y1.iter().chain(&y2).copied().collect();
        let c = Matrix::vstack(&[&c1, &c2]);
        let x = Matrix::vstack(&[&x1, &x2]);
        let pooled = compress_party(&y, &c, &x, 7, Some(1));
        assert_eq!(agg.n, 75);
        assert!(rel_err(&agg.ctc.data, &pooled.ctc.data) < 1e-13);
        assert!(rel_err(&agg.xty, &pooled.xty) < 1e-13);
        assert!(rel_err(&agg.ctx.data, &pooled.ctx.data) < 1e-13);
    }

    #[test]
    fn layout_len() {
        let l = FlatLayout { k: 3, m: 10 };
        assert_eq!(l.len(), 2 + 3 + 9 + 20 + 30);
    }
}
