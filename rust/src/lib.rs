//! # DASH — Distributed Association Scan Hammer
//!
//! A production-oriented implementation of *Secure multi-party linear
//! regression at plaintext speed* (Jonathan M. Bloom, 2019).
//!
//! The library is organised in three layers:
//!
//! - **Layer 3 (this crate)** — the multi-party *coordinator*: party and
//!   leader state machines ([`coordinator`]), an SMC substrate ([`mpc`]),
//!   byte-metered transports ([`net`]), and the high-level scan engine
//!   ([`scan`]). The protocol is **trait-major**: all statistics carry a
//!   trait dimension `T` (§3's "promote y to a matrix Y"), the
//!   genotype-sized pieces are shared across traits, and the classic
//!   single-trait scan is the degenerate `T = 1` case. Scans stream over
//!   a **variant-shard pipeline** ([`scan::ShardPlan`],
//!   [`scan::ScanConfig::shard_m`]): each shard is one secure-sum round
//!   of `O((K+T)·width)` bytes, parties compress shard `s+1` while the
//!   leader combines shard `s`, and the classic single-shot protocol is
//!   the degenerate one-shard plan. Results are bit-identical across
//!   shard widths and across trait batching.
//! - **Layer 2** — a JAX model (`python/compile/model.py`) computing the
//!   compressed sufficient statistics and the Lemma 3.1 epilogue, lowered
//!   once to HLO text artifacts.
//! - **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   blocked Gram/cross-product hot spot, lowered into the same HLO.
//!
//! At runtime the Rust binary dispatches a parameterized artifact kernel
//! suite ([`runtime`]) keyed on `(kind, shard width, trait batch)`:
//! compiled HLO entries through the PJRT C API when available, else a
//! bit-identical pure-Rust reference executor. Python is never on the
//! request path.

pub mod util;
pub mod linalg;
pub mod stats;
pub mod mpc;
pub mod net;
pub mod gwas;
pub mod scan;
pub mod runtime;
pub mod coordinator;
pub mod config;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
