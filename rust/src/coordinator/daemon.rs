//! Scan-as-a-service control plane: a leader daemon wrapping
//! [`run_session_batch`] behind a dependency-free HTTP/JSON API
//! (DESIGN.md §Control plane).
//!
//! Routes:
//!
//! - `POST /jobs` — submit a scan/SELECT job (body: `{"tenant",
//!   "config": <RunConfig JSON>}`); returns `201 {"job": id}` or `429`
//!   + `Retry-After` when admission control rejects.
//! - `GET /jobs/{id}` — lifecycle status plus [`SessionMetrics`] once
//!   the job ran.
//! - `GET /jobs/{id}/result` — full scan output with every statistic as
//!   an exact f64 bit pattern (`%016x` hex), so clients round-trip
//!   results without any decimal-formatting loss; `409` until done.
//! - `DELETE /jobs/{id}` — cancel: a queued job is dropped from the
//!   queue, a running one has its [`CancelToken`] fired (the batch
//!   watcher then closes its mux queues, waking any blocked receive).
//! - `GET /healthz` — liveness + registry counters.
//!
//! Admission control is deliberately bounded: `max_jobs` worker threads
//! run jobs, at most `queue_cap` more may wait, and each tenant may
//! hold at most `max_jobs_per_tenant` active (queued + running) jobs.
//! Anything beyond that is rejected *immediately* with `429` and a
//! `Retry-After` hint — the daemon never queues forever, so a client
//! can always distinguish "busy, try later" from "accepted".
//!
//! Jobs are not resumable across daemon restarts (the registry is in
//! memory), so per-job checkpoints under `checkpoint_root/job-{id}`
//! are removed whenever a job leaves the system — clean, failed, or
//! cancelled — and a startup GC sweeps every `job-*` directory left by
//! a previous process. That is what keeps a long-lived daemon from
//! accumulating orphaned snapshots (the checkpoint-leak bug this
//! module's tests pin down).

use super::checkpoint;
use super::leader::SessionMetrics;
use super::session::{run_session_batch, BatchOptions, CancelToken, SessionRun, SessionSpec};
use crate::config::RunConfig;
use crate::gwas::generate_cohort;
use crate::net::chaos::{FaultDir, FaultMode, FaultSpec};
use crate::net::http::{HttpServer, Request, Response};
use crate::scan::{ScanOutput, SelectOutput};
use crate::util::json::Json;
use crate::util::lock_unpoisoned;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Deployment knobs for one daemon instance.
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// listen address (`host:port`; port 0 binds an ephemeral port)
    pub listen: String,
    /// worker pool size — jobs running concurrently
    pub max_jobs: usize,
    /// jobs allowed to wait behind the pool before submits get 429
    pub queue_cap: usize,
    /// active (queued + running) jobs any one tenant may hold
    pub max_jobs_per_tenant: usize,
    /// `Retry-After` seconds attached to every 429
    pub retry_after_s: u64,
    /// per-job checkpoint root ("" disables checkpointing); job `i`
    /// writes under `{root}/job-{i}`, removed when the job settles
    pub checkpoint_root: String,
}

impl Default for DaemonOptions {
    fn default() -> Self {
        DaemonOptions {
            listen: "127.0.0.1:0".to_string(),
            max_jobs: 2,
            queue_cap: 4,
            max_jobs_per_tenant: 2,
            retry_after_s: 1,
            checkpoint_root: String::new(),
        }
    }
}

/// Client-visible job lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    fn active(self) -> bool {
        matches!(self, JobStatus::Queued | JobStatus::Running)
    }
}

/// Chaos handle carried by a job submission (`"fault": "panic" |
/// "stall"`): `Panic` makes the leader-side session worker panic
/// mid-run (the daemon-survives-a-panicking-session regression),
/// `Stall` drops a leader-bound frame so the job blocks mid-scan until
/// cancelled or timed out (the deterministic cancel-mid-scan handle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobFault {
    None,
    Panic,
    Stall,
}

struct Job {
    tenant: String,
    cfg: RunConfig,
    /// cancellable pre-run delay — lets tests pin a worker for a
    /// deterministic amount of time to drive saturation
    hold_ms: u64,
    fault: JobFault,
    status: JobStatus,
    error: String,
    cancel: CancelToken,
    run: Option<SessionRun>,
    residual_sessions: usize,
    wall_s: f64,
}

struct Registry {
    next_id: u64,
    jobs: BTreeMap<u64, Job>,
    queue: VecDeque<u64>,
}

struct DaemonInner {
    opts: DaemonOptions,
    reg: Mutex<Registry>,
    cv: Condvar,
    stop: AtomicBool,
}

/// A running daemon: HTTP server + worker pool + job registry.
/// Dropping it shuts everything down (cancelling active jobs first).
pub struct Daemon {
    inner: Arc<DaemonInner>,
    server: HttpServer,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Daemon {
    pub fn start(opts: DaemonOptions) -> anyhow::Result<Daemon> {
        anyhow::ensure!(opts.max_jobs >= 1, "max_jobs must be ≥ 1");
        anyhow::ensure!(opts.max_jobs_per_tenant >= 1, "max_jobs_per_tenant must be ≥ 1");
        if !opts.checkpoint_root.is_empty() {
            gc_checkpoint_root(&opts.checkpoint_root)?;
        }
        let inner = Arc::new(DaemonInner {
            opts: opts.clone(),
            reg: Mutex::new(Registry {
                next_id: 1,
                jobs: BTreeMap::new(),
                queue: VecDeque::new(),
            }),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        for _ in 0..opts.max_jobs {
            let w = Arc::clone(&inner);
            workers.push(std::thread::spawn(move || worker_loop(&w)));
        }
        let h = Arc::clone(&inner);
        let server = HttpServer::bind(&opts.listen, Arc::new(move |req: &Request| route(&h, req)))?;
        Ok(Daemon { inner, server, workers: Mutex::new(workers) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.local_addr()
    }

    /// Stop serving: reject new work, cancel queued and running jobs,
    /// drain the workers, then stop the HTTP server. Idempotent.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        {
            let mut reg = lock_unpoisoned(&self.inner.reg);
            let queued: Vec<u64> = reg.queue.drain(..).collect();
            for id in queued {
                if let Some(job) = reg.jobs.get_mut(&id) {
                    if job.status == JobStatus::Queued {
                        job.status = JobStatus::Cancelled;
                        job.error = "daemon shut down".to_string();
                        job.cancel.cancel();
                    }
                }
            }
            for job in reg.jobs.values() {
                if job.status == JobStatus::Running {
                    job.cancel.cancel();
                }
            }
        }
        self.inner.cv.notify_all();
        let workers: Vec<JoinHandle<()>> = lock_unpoisoned(&self.workers).drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
        self.server.shutdown();
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Checkpoint directory of one job.
pub fn job_checkpoint_dir(root: &str, id: u64) -> String {
    format!("{root}/job-{id}")
}

/// Startup GC: a daemon's registry does not survive a restart, so no
/// checkpoint under the root is resumable — sweep every `job-*`
/// directory and remove the emptied directories. Returns how many
/// checkpoint files were deleted. Unrelated entries under the root are
/// never touched.
pub fn gc_checkpoint_root(root: &str) -> anyhow::Result<usize> {
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(e.into()),
    };
    let mut removed = 0usize;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with("job-") || !entry.path().is_dir() {
            continue;
        }
        let Some(dir) = entry.path().to_str().map(String::from) else { continue };
        removed += checkpoint::sweep(&dir, &[])?;
        let _ = std::fs::remove_dir(entry.path());
    }
    Ok(removed)
}

// ---------------------------------------------------------------------
// worker pool
// ---------------------------------------------------------------------

fn worker_loop(inner: &DaemonInner) {
    loop {
        let id = {
            let mut reg = lock_unpoisoned(&inner.reg);
            loop {
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = reg.queue.pop_front() {
                    break id;
                }
                reg = inner
                    .cv
                    .wait_timeout(reg, Duration::from_millis(100))
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .0;
            }
        };
        run_job(inner, id);
    }
}

fn run_job(inner: &DaemonInner, id: u64) {
    // Claim the job (a cancel may have settled it while it was queued).
    let (cfg, hold_ms, fault, token) = {
        let mut reg = lock_unpoisoned(&inner.reg);
        let Some(job) = reg.jobs.get_mut(&id) else { return };
        if job.status != JobStatus::Queued {
            return;
        }
        job.status = JobStatus::Running;
        (job.cfg.clone(), job.hold_ms, job.fault, job.cancel.clone())
    };

    let t0 = std::time::Instant::now();

    // Cancellable pre-run hold (admission / cancellation test handle).
    let mut held = 0u64;
    let mut cancelled = token.is_cancelled() || inner.stop.load(Ordering::SeqCst);
    while !cancelled && held < hold_ms {
        let step = (hold_ms - held).min(20);
        cancelled =
            token.wait_timeout(Duration::from_millis(step)) || inner.stop.load(Ordering::SeqCst);
        held += step;
    }

    let outcome = if cancelled {
        Err(anyhow::anyhow!("job {id} cancelled before it started"))
    } else {
        execute(inner, id, &cfg, fault, &token)
    };
    let wall_s = t0.elapsed().as_secs_f64();

    // Daemon jobs are not resumable: drop the job's checkpoints on any
    // exit — clean, failed, or cancelled — *before* publishing the
    // terminal status, so a client that observes "cancelled" can rely
    // on the snapshot being gone.
    if !inner.opts.checkpoint_root.is_empty() {
        let _ = std::fs::remove_dir_all(job_checkpoint_dir(&inner.opts.checkpoint_root, id));
    }

    {
        let mut reg = lock_unpoisoned(&inner.reg);
        if let Some(job) = reg.jobs.get_mut(&id) {
            job.wall_s = wall_s;
            match outcome {
                Ok((run, residual)) => {
                    job.residual_sessions = residual;
                    match run {
                        Ok(r) => {
                            job.run = Some(r);
                            job.status = JobStatus::Done;
                        }
                        Err(e) => {
                            job.error = format!("{e:#}");
                            job.status = if job.cancel.is_cancelled() {
                                JobStatus::Cancelled
                            } else {
                                JobStatus::Failed
                            };
                        }
                    }
                }
                Err(e) => {
                    job.error = format!("{e:#}");
                    job.status = if job.cancel.is_cancelled() {
                        JobStatus::Cancelled
                    } else {
                        JobStatus::Failed
                    };
                }
            }
        }
    }
    inner.cv.notify_all();
}

/// Run one job as a single-session batch. Returns the batch-level
/// result (setup errors are the outer `Err`) with the per-session
/// outcome and the residual-session count inside.
#[allow(clippy::type_complexity)]
fn execute(
    inner: &DaemonInner,
    id: u64,
    cfg: &RunConfig,
    fault: JobFault,
    token: &CancelToken,
) -> anyhow::Result<(anyhow::Result<SessionRun>, usize)> {
    let mut scan = cfg.scan.clone();
    if !inner.opts.checkpoint_root.is_empty() {
        scan.checkpoint_dir = job_checkpoint_dir(&inner.opts.checkpoint_root, id);
        // jobs never resume across restarts — the startup GC removed
        // anything a previous process left behind
        scan.resume = false;
    }
    let cohort = generate_cohort(&cfg.cohort, cfg.seed);
    let specs = vec![SessionSpec { cfg: scan, seed: cfg.seed }];
    let opts = BatchOptions {
        transport: cfg.transport,
        max_concurrent: 1,
        cancel: Some(token.clone()),
        panic_session: (fault == JobFault::Panic).then_some(1),
        fault: (fault == JobFault::Stall).then_some(FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Drop,
            session: 1,
            nth: 2,
        }),
        ..BatchOptions::default()
    };
    let batch = run_session_batch(&cohort, &specs, &opts)?;
    let run = batch
        .runs
        .into_iter()
        .next()
        .unwrap_or_else(|| Err(anyhow::anyhow!("batch returned no session result")));
    Ok((run, batch.residual_sessions))
}

// ---------------------------------------------------------------------
// HTTP routes
// ---------------------------------------------------------------------

fn route(inner: &DaemonInner, req: &Request) -> Response {
    let path = if req.path.len() > 1 {
        req.path.trim_end_matches('/')
    } else {
        req.path.as_str()
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => health(inner),
        ("POST", "/jobs") => submit(inner, req),
        (method, path) => {
            if let Some(rest) = path.strip_prefix("/jobs/") {
                if let Some(idstr) = rest.strip_suffix("/result") {
                    return match method {
                        "GET" => result(inner, idstr),
                        _ => err_json(405, "result is GET-only"),
                    };
                }
                return match method {
                    "GET" => status(inner, rest),
                    "DELETE" => cancel(inner, rest),
                    _ => err_json(405, "job routes are GET/DELETE"),
                };
            }
            err_json(404, "no such route")
        }
    }
}

fn err_json(status: u16, msg: &str) -> Response {
    let mut o = Json::obj();
    o.set("error", msg);
    Response::json(status, &o)
}

/// 429 with the mandatory `Retry-After` hint — the admission-control
/// rejection, never a silent queue.
fn busy(inner: &DaemonInner, why: &str) -> Response {
    let mut o = Json::obj();
    o.set("error", why).set("retry_after_s", inner.opts.retry_after_s);
    Response::json(429, &o).with_header("retry-after", &inner.opts.retry_after_s.to_string())
}

fn parse_id(idstr: &str) -> Option<u64> {
    idstr.parse::<u64>().ok()
}

fn submit(inner: &DaemonInner, req: &Request) -> Response {
    if inner.stop.load(Ordering::SeqCst) {
        return err_json(409, "daemon is shutting down");
    }
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return err_json(400, "body is not UTF-8"),
    };
    let v = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return err_json(400, &format!("malformed JSON body: {e:#}")),
    };
    let cfg = match v.get("config") {
        Some(c) => match RunConfig::from_json(c) {
            Ok(cfg) => cfg,
            Err(e) => return err_json(400, &format!("bad config: {e:#}")),
        },
        None => RunConfig::default(),
    };
    let tenant = v
        .get("tenant")
        .and_then(Json::as_str)
        .or_else(|| req.header("x-tenant"))
        .unwrap_or("anon")
        .to_string();
    let hold_ms = v.get("hold_ms").and_then(Json::as_usize).unwrap_or(0) as u64;
    let fault = match v.get("fault").and_then(Json::as_str) {
        None => JobFault::None,
        Some("panic") => JobFault::Panic,
        Some("stall") => JobFault::Stall,
        Some(other) => return err_json(400, &format!("unknown fault `{other}`")),
    };

    let id = {
        let mut reg = lock_unpoisoned(&inner.reg);
        if reg.queue.len() >= inner.opts.queue_cap {
            return busy(inner, "worker pool and admission queue are full");
        }
        let tenant_active =
            reg.jobs.values().filter(|j| j.status.active() && j.tenant == tenant).count();
        if tenant_active >= inner.opts.max_jobs_per_tenant {
            return busy(inner, &format!("tenant `{tenant}` is at its active-job quota"));
        }
        let id = reg.next_id;
        reg.next_id += 1;
        reg.jobs.insert(
            id,
            Job {
                tenant: tenant.clone(),
                cfg,
                hold_ms,
                fault,
                status: JobStatus::Queued,
                error: String::new(),
                cancel: CancelToken::new(),
                run: None,
                residual_sessions: 0,
                wall_s: 0.0,
            },
        );
        reg.queue.push_back(id);
        id
    };
    inner.cv.notify_all();
    let mut o = Json::obj();
    o.set("job", id).set("tenant", tenant).set("status", JobStatus::Queued.name());
    Response::json(201, &o)
}

fn status(inner: &DaemonInner, idstr: &str) -> Response {
    let Some(id) = parse_id(idstr) else {
        return err_json(400, "job id must be an integer");
    };
    let reg = lock_unpoisoned(&inner.reg);
    let Some(job) = reg.jobs.get(&id) else {
        return err_json(404, "no such job");
    };
    let mut o = Json::obj();
    o.set("job", id)
        .set("tenant", job.tenant.as_str())
        .set("status", job.status.name())
        .set("wall_s", job.wall_s)
        .set("residual_sessions", job.residual_sessions);
    if !job.error.is_empty() {
        o.set("error", job.error.as_str());
    }
    if let Some(run) = &job.run {
        o.set("metrics", metrics_json(&run.metrics));
    }
    Response::json(200, &o)
}

fn result(inner: &DaemonInner, idstr: &str) -> Response {
    let Some(id) = parse_id(idstr) else {
        return err_json(400, "job id must be an integer");
    };
    let reg = lock_unpoisoned(&inner.reg);
    let Some(job) = reg.jobs.get(&id) else {
        return err_json(404, "no such job");
    };
    match (&job.status, &job.run) {
        (JobStatus::Done, Some(run)) => Response::json(200, &result_json(id, run)),
        (st, _) => {
            let mut o = Json::obj();
            o.set("error", "job has no result").set("status", st.name());
            if !job.error.is_empty() {
                o.set("detail", job.error.as_str());
            }
            Response::json(409, &o)
        }
    }
}

fn cancel(inner: &DaemonInner, idstr: &str) -> Response {
    let Some(id) = parse_id(idstr) else {
        return err_json(400, "job id must be an integer");
    };
    let mut reg = lock_unpoisoned(&inner.reg);
    let Some(job) = reg.jobs.get_mut(&id) else {
        return err_json(404, "no such job");
    };
    let (code, state) = match job.status {
        JobStatus::Queued => {
            job.status = JobStatus::Cancelled;
            job.error = "cancelled while queued".to_string();
            job.cancel.cancel();
            reg.queue.retain(|&q| q != id);
            (202, JobStatus::Cancelled.name())
        }
        JobStatus::Running => {
            // fire the token; the worker settles the job (and removes
            // its checkpoints) once the batch unwinds
            job.cancel.cancel();
            (202, "cancelling")
        }
        st => (200, st.name()),
    };
    drop(reg);
    inner.cv.notify_all();
    let mut o = Json::obj();
    o.set("job", id).set("status", state);
    Response::json(code, &o)
}

fn health(inner: &DaemonInner) -> Response {
    let reg = lock_unpoisoned(&inner.reg);
    let mut by = [0usize; 5];
    for job in reg.jobs.values() {
        let i = match job.status {
            JobStatus::Queued => 0,
            JobStatus::Running => 1,
            JobStatus::Done => 2,
            JobStatus::Failed => 3,
            JobStatus::Cancelled => 4,
        };
        by[i] += 1;
    }
    let mut o = Json::obj();
    o.set("ok", true)
        .set("jobs", reg.jobs.len())
        .set("queued", by[0])
        .set("running", by[1])
        .set("done", by[2])
        .set("failed", by[3])
        .set("cancelled", by[4])
        .set("max_jobs", inner.opts.max_jobs)
        .set("queue_cap", inner.opts.queue_cap);
    Response::json(200, &o)
}

// ---------------------------------------------------------------------
// result rendering
// ---------------------------------------------------------------------

fn hex_bits(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(format!("{:016x}", x.to_bits()))).collect())
}

/// Render a finished run. Statistics travel as `%016x` f64 bit
/// patterns because [`Json`] numbers are f64s printed in decimal —
/// fine for humans, lossy for a bit-parity check. `result_fp` is
/// [`result_fingerprint`] over the same bits, so two results agree iff
/// their fingerprints do.
pub fn result_json(id: u64, run: &SessionRun) -> Json {
    let out = &run.output;
    let mut o = Json::obj();
    o.set("job", id)
        .set("session", run.session)
        .set("n", out.n)
        .set("k", out.k)
        .set("m", out.m)
        .set("traits", out.assoc.len());
    let assoc: Vec<Json> = out
        .assoc
        .iter()
        .enumerate()
        .map(|(t, a)| {
            let mut row = Json::obj();
            row.set("trait", t)
                .set("beta_bits", hex_bits(&a.beta))
                .set("se_bits", hex_bits(&a.se))
                .set("p_bits", hex_bits(&a.p))
                .set("df", a.df);
            row
        })
        .collect();
    o.set("assoc", Json::Arr(assoc));
    if let Some(sel) = &run.select {
        let mut s = Json::obj();
        s.set("lanes", sel.lanes());
        let selected: Vec<Vec<usize>> = (0..sel.lanes()).map(|l| sel.selected(l)).collect();
        s.set("selected", selected);
        o.set("select", s);
    }
    o.set("metrics", metrics_json(&run.metrics));
    o.set("result_fp", format!("{:016x}", result_fingerprint(out, run.select.as_ref())));
    o
}

pub fn metrics_json(m: &SessionMetrics) -> Json {
    let mut o = Json::obj();
    o.set("compress_wall_s", m.compress_wall_s)
        .set("combine_s", m.combine_s)
        .set("total_s", m.total_s)
        .set("bytes_total", m.bytes_total)
        .set("messages_total", m.messages_total)
        .set("bytes_result", m.bytes_result)
        .set("shards", m.shards)
        .set("bytes_max_round", m.bytes_max_round)
        .set("select_rounds", m.select_rounds)
        .set("bytes_select", m.bytes_select)
        .set("bytes_max_select_round", m.bytes_max_select_round)
        .set("shards_skipped", m.shards_skipped)
        .set("dropouts", m.dropouts.len());
    o
}

/// Order-sensitive FNV-1a over the exact bit patterns of every
/// reported statistic (β, SE, p, df per trait) plus the scan shape and
/// the SELECT choices. Two runs fingerprint equal iff their outputs
/// are bit-identical — the daemon/one-shot parity oracle used by the
/// CLI (`result_fp` line), the e2e smoke, and the integration tests.
pub fn result_fingerprint(output: &ScanOutput, select: Option<&SelectOutput>) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(output.n as u64);
    mix(output.k as u64);
    mix(output.m as u64);
    for a in &output.assoc {
        for xs in [&a.beta, &a.se, &a.p] {
            for &x in xs.iter() {
                mix(x.to_bits());
            }
        }
        mix(a.df.to_bits());
    }
    if let Some(sel) = select {
        mix(sel.lanes() as u64);
        for lane in 0..sel.lanes() {
            for v in sel.selected(lane) {
                mix(v as u64);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::http::http_request;
    use crate::stats::AssocResult;

    fn output(beta1: f64) -> ScanOutput {
        ScanOutput {
            assoc: vec![AssocResult {
                beta: vec![1.5, beta1],
                se: vec![0.1, 0.2],
                t: vec![1.0, 2.0],
                p: vec![0.5, 0.25],
                df: 10.0,
            }],
            covariate_fit: vec![],
            n: 100,
            k: 3,
            m: 2,
        }
    }

    #[test]
    fn fingerprint_is_stable_and_bit_sensitive() {
        let a = result_fingerprint(&output(2.5), None);
        assert_eq!(a, result_fingerprint(&output(2.5), None));
        // a single flipped mantissa bit changes the fingerprint
        let tweaked = f64::from_bits(2.5f64.to_bits() ^ 1);
        assert_ne!(a, result_fingerprint(&output(tweaked), None));
    }

    #[test]
    fn startup_gc_sweeps_orphaned_job_checkpoints() {
        let root = std::env::temp_dir().join(format!("dash-daemon-gc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let root_s = root.to_str().unwrap().to_string();
        // nothing to sweep when the root does not exist yet
        assert_eq!(gc_checkpoint_root(&root_s).unwrap(), 0);
        // two orphaned job dirs with checkpoints, one unrelated entry
        for id in [3u64, 7] {
            let dir = job_checkpoint_dir(&root_s, id);
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(format!("{dir}/session-1.ckpt"), b"stale").unwrap();
        }
        std::fs::create_dir_all(root.join("not-a-job")).unwrap();
        std::fs::write(root.join("not-a-job/keep.txt"), b"keep").unwrap();
        assert_eq!(gc_checkpoint_root(&root_s).unwrap(), 2);
        assert!(!root.join("job-3").exists());
        assert!(!root.join("job-7").exists());
        assert!(root.join("not-a-job/keep.txt").exists());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bad_requests_get_typed_errors_without_running_anything() {
        let daemon = Daemon::start(DaemonOptions::default()).unwrap();
        let addr = daemon.addr().to_string();
        let r = http_request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json_body().unwrap().get("ok").and_then(Json::as_bool), Some(true));
        let r = http_request(&addr, "GET", "/jobs/999", None).unwrap();
        assert_eq!(r.status, 404);
        let r = http_request(&addr, "GET", "/jobs/999/result", None).unwrap();
        assert_eq!(r.status, 404);
        let r = http_request(&addr, "DELETE", "/jobs/999", None).unwrap();
        assert_eq!(r.status, 404);
        let r = http_request(&addr, "GET", "/jobs/banana", None).unwrap();
        assert_eq!(r.status, 400);
        let r = http_request(&addr, "POST", "/jobs", Some(b"{not json")).unwrap();
        assert_eq!(r.status, 400);
        let r = http_request(&addr, "POST", "/jobs", Some(br#"{"fault":"meteor"}"#)).unwrap();
        assert_eq!(r.status, 400);
        let r = http_request(&addr, "PUT", "/jobs/1", None).unwrap();
        assert_eq!(r.status, 405);
        let r = http_request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(r.status, 404);
        daemon.shutdown();
    }
}
