//! Protocol messages between leader and parties, over [`Frame`]s.
//!
//! One round-trip per phase: SETUP (session parameters + pairwise-mask
//! seeds — in production these come from a DH exchange; the simulation
//! delivers them in SETUP and the byte meter counts them), COMPRESS
//! (kick off compress-within), one backend-specific contribution
//! (PLAIN / MASKED / SHAMIR share routing), and RESULT broadcast.

use crate::linalg::Matrix;
use crate::net::Frame;

pub const TAG_SETUP: u32 = 1;
pub const TAG_COMPRESS: u32 = 2;
pub const TAG_PLAIN_STATS: u32 = 3;
pub const TAG_MASKED_STATS: u32 = 4;
pub const TAG_SHAMIR_OUT: u32 = 5;
pub const TAG_SHAMIR_IN: u32 = 6;
pub const TAG_SHAMIR_SUM: u32 = 7;
pub const TAG_RESULT: u32 = 8;
pub const TAG_SHUTDOWN: u32 = 9;
pub const TAG_ERROR: u32 = 10;

/// Session parameters delivered to each party at SETUP.
#[derive(Clone, Debug, PartialEq)]
pub struct Setup {
    pub party_index: u64,
    pub parties: u64,
    /// 0 = plaintext, 1 = masked, 2 = shamir
    pub backend: u64,
    pub shamir_threshold: u64,
    pub frac_bits: u64,
    pub k: u64,
    pub m: u64,
    pub block_m: u64,
    /// pairwise seeds, row `party_index` of the symmetric seed matrix
    pub seeds: Vec<u64>,
}

impl Setup {
    pub fn to_frame(&self) -> Frame {
        let mut f = Frame::new(TAG_SETUP);
        f.put_u64(self.party_index)
            .put_u64(self.parties)
            .put_u64(self.backend)
            .put_u64(self.shamir_threshold)
            .put_u64(self.frac_bits)
            .put_u64(self.k)
            .put_u64(self.m)
            .put_u64(self.block_m)
            .put_u64_slice(&self.seeds);
        f
    }

    pub fn from_frame(f: &Frame) -> anyhow::Result<Setup> {
        anyhow::ensure!(f.tag == TAG_SETUP, "expected SETUP, got tag {}", f.tag);
        let mut r = f.reader();
        Ok(Setup {
            party_index: r.u64()?,
            parties: r.u64()?,
            backend: r.u64()?,
            shamir_threshold: r.u64()?,
            frac_bits: r.u64()?,
            k: r.u64()?,
            m: r.u64()?,
            block_m: r.u64()?,
            seeds: r.u64_vec()?,
        })
    }
}

/// Plaintext contribution: flat statistics + the party's R factor
/// (for the TSQR combine path).
pub fn plain_stats_frame(flat: &[f64], r: &Matrix) -> Frame {
    let mut f = Frame::new(TAG_PLAIN_STATS);
    f.put_f64_slice(flat);
    f.put_u64(r.rows as u64);
    f.put_f64_slice(&r.data);
    f
}

pub fn parse_plain_stats(f: &Frame) -> anyhow::Result<(Vec<f64>, Matrix)> {
    anyhow::ensure!(f.tag == TAG_PLAIN_STATS, "expected PLAIN_STATS");
    let mut rd = f.reader();
    let flat = rd.f64_vec()?;
    let k = rd.u64()? as usize;
    let data = rd.f64_vec()?;
    anyhow::ensure!(data.len() == k * k, "R not square");
    Ok((flat, Matrix::from_vec(k, k, data)))
}

/// Masked contribution: ring elements after fixed-point encode + masking.
pub fn masked_stats_frame(masked: &[u64]) -> Frame {
    let mut f = Frame::new(TAG_MASKED_STATS);
    f.put_u64_slice(masked);
    f
}

pub fn parse_masked_stats(f: &Frame) -> anyhow::Result<Vec<u64>> {
    anyhow::ensure!(f.tag == TAG_MASKED_STATS, "expected MASKED_STATS");
    f.reader().u64_vec()
}

/// Shamir share fan-out: the `parties` share vectors produced by this
/// party, destined one per recipient (routed by the leader; encrypted
/// pairwise in a real deployment).
pub fn shamir_out_frame(share_ys: &[Vec<u64>]) -> Frame {
    let mut f = Frame::new(TAG_SHAMIR_OUT);
    f.put_u64(share_ys.len() as u64);
    for v in share_ys {
        f.put_u64_slice(v);
    }
    f
}

pub fn parse_shamir_out(f: &Frame) -> anyhow::Result<Vec<Vec<u64>>> {
    anyhow::ensure!(f.tag == TAG_SHAMIR_OUT, "expected SHAMIR_OUT");
    let mut rd = f.reader();
    let p = rd.u64()? as usize;
    (0..p).map(|_| rd.u64_vec()).collect()
}

/// Shares routed to one party: one vector per contributor.
pub fn shamir_in_frame(shares: &[Vec<u64>]) -> Frame {
    let mut f = Frame::new(TAG_SHAMIR_IN);
    f.put_u64(shares.len() as u64);
    for v in shares {
        f.put_u64_slice(v);
    }
    f
}

pub fn parse_shamir_in(f: &Frame) -> anyhow::Result<Vec<Vec<u64>>> {
    anyhow::ensure!(f.tag == TAG_SHAMIR_IN, "expected SHAMIR_IN");
    let mut rd = f.reader();
    let p = rd.u64()? as usize;
    (0..p).map(|_| rd.u64_vec()).collect()
}

/// Per-party share-sum returned to the leader for reconstruction.
pub fn shamir_sum_frame(sum: &[u64]) -> Frame {
    let mut f = Frame::new(TAG_SHAMIR_SUM);
    f.put_u64_slice(sum);
    f
}

pub fn parse_shamir_sum(f: &Frame) -> anyhow::Result<Vec<u64>> {
    anyhow::ensure!(f.tag == TAG_SHAMIR_SUM, "expected SHAMIR_SUM");
    f.reader().u64_vec()
}

/// Result broadcast: β̂ and σ̂ per variant (the `O(M)` downlink).
pub fn result_frame(beta: &[f64], se: &[f64]) -> Frame {
    let mut f = Frame::new(TAG_RESULT);
    f.put_f64_slice(beta);
    f.put_f64_slice(se);
    f
}

pub fn parse_result(f: &Frame) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
    anyhow::ensure!(f.tag == TAG_RESULT, "expected RESULT");
    let mut rd = f.reader();
    Ok((rd.f64_vec()?, rd.f64_vec()?))
}

/// Error report from a party.
pub fn error_frame(msg: &str) -> Frame {
    let mut f = Frame::new(TAG_ERROR);
    f.put_bytes(msg.as_bytes());
    f
}

pub fn parse_error(f: &Frame) -> String {
    f.reader()
        .bytes()
        .ok()
        .and_then(|b| String::from_utf8(b).ok())
        .unwrap_or_else(|| "<malformed error>".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setup_roundtrip() {
        let s = Setup {
            party_index: 2,
            parties: 5,
            backend: 1,
            shamir_threshold: 3,
            frac_bits: 24,
            k: 12,
            m: 1000,
            block_m: 256,
            seeds: vec![1, 2, 3, 4, 5],
        };
        assert_eq!(Setup::from_frame(&s.to_frame()).unwrap(), s);
    }

    #[test]
    fn plain_stats_roundtrip() {
        let r = Matrix::from_rows(&[&[1.0, 2.0], &[0.0, 3.0]]);
        let f = plain_stats_frame(&[1.5, -2.5], &r);
        let (flat, r2) = parse_plain_stats(&f).unwrap();
        assert_eq!(flat, vec![1.5, -2.5]);
        assert_eq!(r2, r);
    }

    #[test]
    fn masked_roundtrip() {
        let f = masked_stats_frame(&[u64::MAX, 0, 42]);
        assert_eq!(parse_masked_stats(&f).unwrap(), vec![u64::MAX, 0, 42]);
    }

    #[test]
    fn shamir_roundtrips() {
        let shares = vec![vec![1u64, 2], vec![3, 4], vec![5, 6]];
        assert_eq!(parse_shamir_out(&shamir_out_frame(&shares)).unwrap(), shares);
        assert_eq!(parse_shamir_in(&shamir_in_frame(&shares)).unwrap(), shares);
        assert_eq!(parse_shamir_sum(&shamir_sum_frame(&shares[0])).unwrap(), shares[0]);
    }

    #[test]
    fn result_roundtrip() {
        let f = result_frame(&[0.1, f64::NAN], &[1.0, 2.0]);
        let (b, s) = parse_result(&f).unwrap();
        assert_eq!(b[0], 0.1);
        assert!(b[1].is_nan());
        assert_eq!(s, vec![1.0, 2.0]);
    }

    #[test]
    fn wrong_tag_rejected() {
        let f = Frame::new(TAG_COMPRESS);
        assert!(parse_result(&f).is_err());
        assert!(Setup::from_frame(&f).is_err());
    }

    #[test]
    fn error_frame_roundtrip() {
        let f = error_frame("boom");
        assert_eq!(parse_error(&f), "boom");
    }
}
