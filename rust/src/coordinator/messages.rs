//! Protocol messages between leader and parties, over [`Frame`]s.
//!
//! Every message implements [`WireMessage`] — one field walk, encoded by
//! the [`crate::net::Codec`] layer (binary on the wire; lossless JSON
//! for debugging). The sharded session shape is:
//!
//! ```text
//! SETUP            session params incl. shard plan, trait count T,
//!                  pairwise-mask seeds
//! COMPRESS         kick off the streaming compress
//! base round       one backend-specific contribution of the O(K² + KT)
//!                  base stats (PLAIN_BASE / MASKED_BASE / SHAMIR_*
//!                  round 0)
//! shard round s    one contribution per variant shard, O((K+T)·width)
//!                  (PLAIN_SHARD / MASKED_SHARD / SHAMIR_* round s+1)
//! SELECT_SETUP     [select_k > 0] candidate shortlist; parties answer
//!                  with one shard-shaped round over the H candidates
//! PROMOTE r        [per SELECT round] per-lane promoted variants;
//!                  parties answer with O(lanes·H) cross-product sums
//!                  (secure-sum round shards+1+r)
//! SELECT_DONE      number of completed SELECT rounds
//! SHARD_RESULT s   per-shard partial results (β̂, σ̂ per trait)
//! SELECT_RESULT r  per-round promoted variants + entry statistics
//! SHUTDOWN
//! ```
//!
//! The single-shot protocol is the degenerate one-shard case of the same
//! message flow, and the single-trait protocol is the degenerate `T = 1`
//! case of the same frames (identical flattened statistics layout). In
//! production the pairwise-mask seeds come from a DH exchange; the
//! simulation delivers them in SETUP and the byte meter counts them.

use crate::linalg::Matrix;
use crate::net::{FieldSink, FieldSource, Frame, WireMessage};

pub const TAG_SETUP: u32 = 1;
pub const TAG_COMPRESS: u32 = 2;
pub const TAG_PLAIN_BASE: u32 = 3;
pub const TAG_MASKED_BASE: u32 = 4;
pub const TAG_SHAMIR_OUT: u32 = 5;
pub const TAG_SHAMIR_IN: u32 = 6;
pub const TAG_SHAMIR_SUM: u32 = 7;
pub const TAG_SHARD_RESULT: u32 = 8;
pub const TAG_SHUTDOWN: u32 = 9;
pub const TAG_ERROR: u32 = 10;
pub const TAG_PLAIN_SHARD: u32 = 11;
pub const TAG_MASKED_SHARD: u32 = 12;
pub const TAG_SELECT_SETUP: u32 = 13;
pub const TAG_PROMOTE: u32 = 14;
pub const TAG_SELECT_RESULT: u32 = 15;
pub const TAG_SELECT_DONE: u32 = 16;
pub const TAG_CHECKPOINT: u32 = 17;
pub const TAG_IRLS_SETUP: u32 = 18;
pub const TAG_IRLS_ROUND: u32 = 19;
pub const TAG_IRLS_DONE: u32 = 20;

/// Checkpoint frame format version (bumped on layout changes; loaders
/// reject other versions rather than guess).
pub const CHECKPOINT_VERSION: u64 = 1;

/// Sentinel variant index in PROMOTE/SELECT_RESULT lane vectors: the
/// lane has already stopped and promotes nothing this round.
pub const LANE_INACTIVE: u64 = u64::MAX;

/// Session parameters delivered to each party at SETUP.
#[derive(Clone, Debug, PartialEq)]
pub struct Setup {
    /// protocol session id (0 on dedicated connections; the multiplexed
    /// session id otherwise). Keys the secure-sum mask/share domains so
    /// concurrent sessions never reuse a PRG stream.
    pub session: u64,
    pub party_index: u64,
    pub parties: u64,
    /// 0 = plaintext, 1 = masked, 2 = shamir
    pub backend: u64,
    pub shamir_threshold: u64,
    pub frac_bits: u64,
    pub k: u64,
    pub m: u64,
    /// trait count T (1 = classic single-trait scan)
    pub t: u64,
    pub block_m: u64,
    /// variant-shard width (0 = single shot, one shard over all of M)
    pub shard_m: u64,
    /// maximum SELECT rounds after the scan (0 = scan only; > 0 tells
    /// the party to expect a SELECT_SETUP frame after its shard rounds)
    pub select_k: u64,
    /// GLM wire code ([`crate::scan::Glm`]): 0 = linear, 1 = logistic.
    /// Logistic replaces the linear shard rounds with IRLS_SETUP, one
    /// IRLS_ROUND per Newton iteration (secure-sum round = iteration,
    /// 1-based), IRLS_DONE, then one *weighted* round per variant shard
    /// at absolute round `iters + 1 + shard` — the continued numbering
    /// keeps every mask/share PRG domain distinct from the base round
    /// and from each other.
    pub glm: u64,
    /// pairwise seeds, row `party_index` of the symmetric seed matrix
    pub seeds: Vec<u64>,
    /// shards already combined by a previous (interrupted) run of this
    /// session — the party skips their compress+contribute rounds on
    /// resume. Empty = fresh session. Round numbering stays absolute
    /// (round s+1 for shard s), so the PRG mask/share domains of the
    /// remaining rounds are untouched by the skips.
    pub done_shards: Vec<u64>,
}

impl WireMessage for Setup {
    const TAG: u32 = TAG_SETUP;
    const NAME: &'static str = "SETUP";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("session", self.session);
        s.u64("party_index", self.party_index);
        s.u64("parties", self.parties);
        s.u64("backend", self.backend);
        s.u64("shamir_threshold", self.shamir_threshold);
        s.u64("frac_bits", self.frac_bits);
        s.u64("k", self.k);
        s.u64("m", self.m);
        s.u64("t", self.t);
        s.u64("block_m", self.block_m);
        s.u64("shard_m", self.shard_m);
        s.u64("select_k", self.select_k);
        s.u64("glm", self.glm);
        s.u64s("seeds", &self.seeds);
        s.u64s("done_shards", &self.done_shards);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(Setup {
            session: s.u64("session")?,
            party_index: s.u64("party_index")?,
            parties: s.u64("parties")?,
            backend: s.u64("backend")?,
            shamir_threshold: s.u64("shamir_threshold")?,
            frac_bits: s.u64("frac_bits")?,
            k: s.u64("k")?,
            m: s.u64("m")?,
            t: s.u64("t")?,
            block_m: s.u64("block_m")?,
            shard_m: s.u64("shard_m")?,
            select_k: s.u64("select_k")?,
            glm: s.u64("glm")?,
            seeds: s.u64s("seeds")?,
            done_shards: s.u64s("done_shards")?,
        })
    }
}

/// COMPRESS kick-off (no payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Compress;

impl WireMessage for Compress {
    const TAG: u32 = TAG_COMPRESS;
    const NAME: &'static str = "COMPRESS";
    fn write_fields<S: FieldSink>(&self, _s: &mut S) {}
    fn read_fields<S: FieldSource>(_s: &mut S) -> anyhow::Result<Self> {
        Ok(Compress)
    }
}

/// Session end (no payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shutdown;

impl WireMessage for Shutdown {
    const TAG: u32 = TAG_SHUTDOWN;
    const NAME: &'static str = "SHUTDOWN";
    fn write_fields<S: FieldSink>(&self, _s: &mut S) {}
    fn read_fields<S: FieldSource>(_s: &mut S) -> anyhow::Result<Self> {
        Ok(Shutdown)
    }
}

/// Plaintext base contribution: flattened `[n, yᵀy, Cᵀy, CᵀC]` + the
/// party's R factor (for the TSQR combine path).
#[derive(Clone, Debug, PartialEq)]
pub struct PlainBase {
    pub flat: Vec<f64>,
    pub r: Matrix,
}

impl WireMessage for PlainBase {
    const TAG: u32 = TAG_PLAIN_BASE;
    const NAME: &'static str = "PLAIN_BASE";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.f64s("flat", &self.flat);
        s.u64("r_rows", self.r.rows as u64);
        s.f64s("r_data", &self.r.data);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let flat = s.f64s("flat")?;
        let k = s.u64("r_rows")? as usize;
        let data = s.f64s("r_data")?;
        anyhow::ensure!(data.len() == k * k, "R not square");
        Ok(PlainBase { flat, r: Matrix::from_vec(k, k, data) })
    }
}

/// Masked base contribution: ring elements after fixed-point encode +
/// pairwise masking (mask round 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MaskedBase {
    pub enc: Vec<u64>,
}

impl WireMessage for MaskedBase {
    const TAG: u32 = TAG_MASKED_BASE;
    const NAME: &'static str = "MASKED_BASE";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64s("enc", &self.enc);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(MaskedBase { enc: s.u64s("enc")? })
    }
}

/// Plaintext shard contribution: flattened `[Xᵀy(w), X·X(w), CᵀX(K·w)]`
/// for shard `shard`.
#[derive(Clone, Debug, PartialEq)]
pub struct PlainShard {
    pub shard: u64,
    pub flat: Vec<f64>,
}

impl WireMessage for PlainShard {
    const TAG: u32 = TAG_PLAIN_SHARD;
    const NAME: &'static str = "PLAIN_SHARD";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("shard", self.shard);
        s.f64s("flat", &self.flat);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(PlainShard { shard: s.u64("shard")?, flat: s.f64s("flat")? })
    }
}

/// Masked shard contribution (mask round `shard + 1`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MaskedShard {
    pub shard: u64,
    pub enc: Vec<u64>,
}

impl WireMessage for MaskedShard {
    const TAG: u32 = TAG_MASKED_SHARD;
    const NAME: &'static str = "MASKED_SHARD";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("shard", self.shard);
        s.u64s("enc", &self.enc);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(MaskedShard { shard: s.u64("shard")?, enc: s.u64s("enc")? })
    }
}

/// Shamir share fan-out: the `parties` share vectors produced by this
/// party for secure-sum round `round` (0 = base, s+1 = shard s),
/// destined one per recipient (routed by the leader; encrypted pairwise
/// in a real deployment).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShamirOut {
    pub round: u64,
    pub shares: Vec<Vec<u64>>,
}

impl WireMessage for ShamirOut {
    const TAG: u32 = TAG_SHAMIR_OUT;
    const NAME: &'static str = "SHAMIR_OUT";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("round", self.round);
        write_share_vecs(s, &self.shares);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(ShamirOut { round: s.u64("round")?, shares: read_share_vecs(s)? })
    }
}

/// Shares routed to one party for round `round`: one vector per
/// contributor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShamirIn {
    pub round: u64,
    pub shares: Vec<Vec<u64>>,
}

impl WireMessage for ShamirIn {
    const TAG: u32 = TAG_SHAMIR_IN;
    const NAME: &'static str = "SHAMIR_IN";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("round", self.round);
        write_share_vecs(s, &self.shares);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(ShamirIn { round: s.u64("round")?, shares: read_share_vecs(s)? })
    }
}

/// Per-party share-sum returned to the leader for reconstruction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShamirSum {
    pub round: u64,
    pub sum: Vec<u64>,
}

impl WireMessage for ShamirSum {
    const TAG: u32 = TAG_SHAMIR_SUM;
    const NAME: &'static str = "SHAMIR_SUM";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("round", self.round);
        s.u64s("sum", &self.sum);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(ShamirSum { round: s.u64("round")?, sum: s.u64s("sum")? })
    }
}

fn write_share_vecs<S: FieldSink>(s: &mut S, shares: &[Vec<u64>]) {
    s.u64("count", shares.len() as u64);
    for v in shares {
        s.u64s("share", v);
    }
}

fn read_share_vecs<S: FieldSource>(s: &mut S) -> anyhow::Result<Vec<Vec<u64>>> {
    let p = s.u64("count")? as usize;
    anyhow::ensure!(p <= 1 << 20, "implausible share fan-out {p}");
    (0..p).map(|_| s.u64s("share")).collect()
}

/// Partial-result broadcast for one shard: β̂ and σ̂ for variant columns
/// `[j0, j0 + width)` across all `traits` traits (the per-shard slice of
/// the `O(M·T)` downlink). `beta`/`se` are trait-major concatenations:
/// `[trait 0's width values | trait 1's | ...]` — for `traits == 1` this
/// is exactly the historical single-trait frame plus the count field.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardResult {
    pub shard: u64,
    pub j0: u64,
    /// trait count T (≥ 1); beta/se carry `width · T` values each
    pub traits: u64,
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
}

impl ShardResult {
    /// Variant columns covered by this frame.
    pub fn width(&self) -> usize {
        self.beta.len() / self.traits.max(1) as usize
    }

    /// Slice of `beta` belonging to trait `tt`.
    pub fn beta_for(&self, tt: usize) -> &[f64] {
        let w = self.width();
        &self.beta[tt * w..(tt + 1) * w]
    }

    /// Slice of `se` belonging to trait `tt`.
    pub fn se_for(&self, tt: usize) -> &[f64] {
        let w = self.width();
        &self.se[tt * w..(tt + 1) * w]
    }
}

impl WireMessage for ShardResult {
    const TAG: u32 = TAG_SHARD_RESULT;
    const NAME: &'static str = "SHARD_RESULT";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("shard", self.shard);
        s.u64("j0", self.j0);
        s.u64("traits", self.traits);
        s.f64s("beta", &self.beta);
        s.f64s("se", &self.se);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let r = ShardResult {
            shard: s.u64("shard")?,
            j0: s.u64("j0")?,
            traits: s.u64("traits")?,
            beta: s.f64s("beta")?,
            se: s.f64s("se")?,
        };
        anyhow::ensure!(r.beta.len() == r.se.len(), "beta/se length mismatch");
        anyhow::ensure!(r.traits >= 1, "trait count must be ≥ 1");
        anyhow::ensure!(
            r.beta.len() % r.traits as usize == 0,
            "beta length not divisible by trait count"
        );
        Ok(r)
    }
}

/// SELECT-phase kickoff: the leader's candidate shortlist (absolute
/// variant indices, strictly increasing) plus the selection parameters.
/// Parties answer with one shard-shaped secure-sum round over the
/// gathered candidate columns (`[XᵀY(H·T), X·X(H), CᵀX(K·H)]`).
#[derive(Clone, Debug, PartialEq)]
pub struct SelectSetup {
    /// maximum SELECT rounds
    pub k: u64,
    /// [`crate::scan::SelectPolicy`] wire code (0 = union, 1 = per-trait)
    pub policy: u64,
    /// number of selection lanes (1 for union, T for per-trait)
    pub lanes: u64,
    /// entry p-value threshold (stop rule)
    pub p_enter: f64,
    pub candidates: Vec<u64>,
}

impl WireMessage for SelectSetup {
    const TAG: u32 = TAG_SELECT_SETUP;
    const NAME: &'static str = "SELECT_SETUP";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("k", self.k);
        s.u64("policy", self.policy);
        s.u64("lanes", self.lanes);
        s.f64("p_enter", self.p_enter);
        s.u64s("candidates", &self.candidates);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = SelectSetup {
            k: s.u64("k")?,
            policy: s.u64("policy")?,
            lanes: s.u64("lanes")?,
            p_enter: s.f64("p_enter")?,
            candidates: s.u64s("candidates")?,
        };
        anyhow::ensure!(m.lanes >= 1, "need at least one selection lane");
        for w in m.candidates.windows(2) {
            anyhow::ensure!(w[0] < w[1], "candidates must be strictly increasing");
        }
        Ok(m)
    }
}

/// One SELECT round's promotion broadcast: the variant each lane
/// promotes ([`LANE_INACTIVE`] = lane already stopped). Parties answer
/// with the secure sum of each *active* lane's promoted-column
/// cross-products against the shortlist, concatenated in lane order
/// (`O(lanes·H)` — independent of M).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Promote {
    /// 1-based SELECT round
    pub round: u64,
    /// per-lane promoted variant (absolute index), length = lanes
    pub variants: Vec<u64>,
}

impl Promote {
    /// Lanes that actually promote this round.
    pub fn active(&self) -> usize {
        self.variants.iter().filter(|&&v| v != LANE_INACTIVE).count()
    }
}

impl WireMessage for Promote {
    const TAG: u32 = TAG_PROMOTE;
    const NAME: &'static str = "PROMOTE";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("round", self.round);
        s.u64s("variants", &self.variants);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = Promote { round: s.u64("round")?, variants: s.u64s("variants")? };
        anyhow::ensure!(m.round >= 1, "promote rounds are 1-based");
        anyhow::ensure!(m.active() >= 1, "promote frame with no active lane");
        Ok(m)
    }
}

/// End of the SELECT phase: how many promote rounds completed (the
/// party then expects that many SELECT_RESULT frames after the shard
/// results).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SelectDone {
    pub rounds: u64,
}

impl WireMessage for SelectDone {
    const TAG: u32 = TAG_SELECT_DONE;
    const NAME: &'static str = "SELECT_DONE";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("rounds", self.rounds);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        Ok(SelectDone { rounds: s.u64("rounds")? })
    }
}

/// Per-round SELECT result broadcast: what each lane promoted and the
/// released entry statistics (β̂, σ̂, p at entry) — the same leakage
/// class as the scan's SHARD_RESULT release, one argmax index plus its
/// published statistics per lane per round. Inactive lanes carry
/// [`LANE_INACTIVE`] and NaN statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectResult {
    /// 1-based SELECT round
    pub round: u64,
    /// per-lane promoted variant, length = lanes
    pub variants: Vec<u64>,
    /// per-lane winning trait index
    pub traits: Vec<u64>,
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
    pub p: Vec<f64>,
}

impl WireMessage for SelectResult {
    const TAG: u32 = TAG_SELECT_RESULT;
    const NAME: &'static str = "SELECT_RESULT";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("round", self.round);
        s.u64s("variants", &self.variants);
        s.u64s("traits", &self.traits);
        s.f64s("beta", &self.beta);
        s.f64s("se", &self.se);
        s.f64s("p", &self.p);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = SelectResult {
            round: s.u64("round")?,
            variants: s.u64s("variants")?,
            traits: s.u64s("traits")?,
            beta: s.f64s("beta")?,
            se: s.f64s("se")?,
            p: s.f64s("p")?,
        };
        let lanes = m.variants.len();
        anyhow::ensure!(
            m.traits.len() == lanes
                && m.beta.len() == lanes
                && m.se.len() == lanes
                && m.p.len() == lanes,
            "select result lane-vector length mismatch"
        );
        Ok(m)
    }
}

/// Leader-side per-session scan checkpoint, written after every
/// combined shard. Self-describing: the session fingerprint fields
/// (`seed`/`backend`/`m`/`k`/`t`/`shard_m`/`select_k`) must match the
/// resuming run's config or the snapshot is rejected — resuming a
/// different session from a stale file would silently mix statistics.
///
/// Only the *assembled shard statistics* are snapshotted: `done` lists
/// the combined shards, `df`/`stats` the assembler's filled state
/// (`stats` is the flat `[β̂ | σ̂ | t | p]` quadruple per trait,
/// `4·T·M` values, NaN at unfilled columns). The base round and the
/// SELECT phase are deliberately NOT checkpointed — both are cheap and
/// deterministic, so a resume re-runs them bit-identically.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub version: u64,
    pub session: u64,
    /// cohort seed (fingerprint only — parties re-derive their data)
    pub seed: u64,
    /// backend wire code, as in [`Setup::backend`]
    pub backend: u64,
    pub m: u64,
    pub k: u64,
    pub t: u64,
    pub shard_m: u64,
    pub select_k: u64,
    /// combined shard indices, strictly increasing
    pub done: Vec<u64>,
    /// residual degrees of freedom (NaN = not yet set)
    pub df: f64,
    /// flat per-trait stats, `4·t·m` values: for each trait,
    /// `[beta(m) | se(m) | tstat(m) | p(m)]`; NaN where unfilled
    pub stats: Vec<f64>,
}

impl WireMessage for Checkpoint {
    const TAG: u32 = TAG_CHECKPOINT;
    const NAME: &'static str = "CHECKPOINT";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("version", self.version);
        s.u64("session", self.session);
        s.u64("seed", self.seed);
        s.u64("backend", self.backend);
        s.u64("m", self.m);
        s.u64("k", self.k);
        s.u64("t", self.t);
        s.u64("shard_m", self.shard_m);
        s.u64("select_k", self.select_k);
        s.u64s("done", &self.done);
        s.f64("df", self.df);
        s.f64s("stats", &self.stats);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let c = Checkpoint {
            version: s.u64("version")?,
            session: s.u64("session")?,
            seed: s.u64("seed")?,
            backend: s.u64("backend")?,
            m: s.u64("m")?,
            k: s.u64("k")?,
            t: s.u64("t")?,
            shard_m: s.u64("shard_m")?,
            select_k: s.u64("select_k")?,
            done: s.u64s("done")?,
            df: s.f64("df")?,
            stats: s.f64s("stats")?,
        };
        anyhow::ensure!(
            c.version == CHECKPOINT_VERSION,
            "unsupported checkpoint version {} (want {})",
            c.version,
            CHECKPOINT_VERSION
        );
        anyhow::ensure!(c.t >= 1, "trait count must be ≥ 1");
        let want = 4usize
            .checked_mul(c.t as usize)
            .and_then(|x| x.checked_mul(c.m as usize));
        anyhow::ensure!(
            want == Some(c.stats.len()),
            "checkpoint stats length {} != 4·t·m",
            c.stats.len()
        );
        for w in c.done.windows(2) {
            anyhow::ensure!(w[0] < w[1], "done shards must be strictly increasing");
        }
        Ok(c)
    }
}

/// Logistic-mode kickoff: IRLS loop parameters. The party bounds its
/// round loop by `max_iter` (a hostile leader cannot spin it forever)
/// and answers each subsequent IRLS_ROUND with one secure-sum
/// contribution of the weighted null-model sums.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IrlsSetup {
    /// IRLS iteration cap (≥ 1)
    pub max_iter: u64,
    /// deviance stop tolerance (leader-side; informational for parties)
    pub tol: f64,
}

impl WireMessage for IrlsSetup {
    const TAG: u32 = TAG_IRLS_SETUP;
    const NAME: &'static str = "IRLS_SETUP";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("max_iter", self.max_iter);
        s.f64("tol", self.tol);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = IrlsSetup { max_iter: s.u64("max_iter")?, tol: s.f64("tol")? };
        anyhow::ensure!(m.max_iter >= 1, "IRLS needs at least one iteration");
        anyhow::ensure!(
            m.tol.is_finite() && m.tol > 0.0,
            "IRLS tolerance must be positive and finite"
        );
        Ok(m)
    }
}

/// One IRLS iteration broadcast: the current null-model iterate β
/// (trait-major `T·K`). The party answers with the weighted sums
/// `[CᵀWC | CᵀWz | deviance]` per trait, secure-summed at absolute
/// round `iter` (1-based; round 0 is the base round).
#[derive(Clone, Debug, PartialEq)]
pub struct IrlsRound {
    /// 1-based IRLS iteration = absolute secure-sum round
    pub iter: u64,
    /// trait-major `T·K` iterate
    pub beta: Vec<f64>,
}

impl WireMessage for IrlsRound {
    const TAG: u32 = TAG_IRLS_ROUND;
    const NAME: &'static str = "IRLS_ROUND";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("iter", self.iter);
        s.f64s("beta", &self.beta);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = IrlsRound { iter: s.u64("iter")?, beta: s.f64s("beta")? };
        anyhow::ensure!(m.iter >= 1, "IRLS rounds are 1-based");
        anyhow::ensure!(
            m.beta.iter().all(|b| b.is_finite()),
            "IRLS iterate must be finite"
        );
        Ok(m)
    }
}

/// End of the IRLS loop: how many iterations ran plus the final iterate
/// (trait-major `T·K`). The party then streams one *weighted* shard
/// round per variant shard at this β, secure-summed at absolute round
/// `iters + 1 + shard`.
#[derive(Clone, Debug, PartialEq)]
pub struct IrlsDone {
    /// IRLS iterations evaluated (≥ 1)
    pub iters: u64,
    /// trait-major `T·K` final iterate
    pub beta: Vec<f64>,
}

impl WireMessage for IrlsDone {
    const TAG: u32 = TAG_IRLS_DONE;
    const NAME: &'static str = "IRLS_DONE";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.u64("iters", self.iters);
        s.f64s("beta", &self.beta);
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let m = IrlsDone { iters: s.u64("iters")?, beta: s.f64s("beta")? };
        anyhow::ensure!(m.iters >= 1, "IRLS runs at least one iteration");
        anyhow::ensure!(
            m.beta.iter().all(|b| b.is_finite()),
            "IRLS iterate must be finite"
        );
        Ok(m)
    }
}

/// Error report from a party.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ErrorMsg {
    pub msg: String,
}

impl WireMessage for ErrorMsg {
    const TAG: u32 = TAG_ERROR;
    const NAME: &'static str = "ERROR";

    fn write_fields<S: FieldSink>(&self, s: &mut S) {
        s.bytes("msg", self.msg.as_bytes());
    }

    fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
        let raw = s.bytes("msg")?;
        Ok(ErrorMsg {
            msg: String::from_utf8(raw).unwrap_or_else(|_| "<malformed error>".to_string()),
        })
    }
}

/// Build an error frame from a message string.
pub fn error_frame(msg: &str) -> Frame {
    ErrorMsg { msg: msg.to_string() }.to_frame()
}

/// Extract the message from an error frame (best effort).
pub fn parse_error(f: &Frame) -> String {
    ErrorMsg::from_frame(f)
        .map(|e| e.msg)
        .unwrap_or_else(|_| "<malformed error>".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Codec;

    fn setup() -> Setup {
        Setup {
            session: 11,
            party_index: 2,
            parties: 5,
            backend: 1,
            shamir_threshold: 3,
            frac_bits: 24,
            k: 12,
            m: 1000,
            t: 4,
            block_m: 256,
            shard_m: 128,
            select_k: 3,
            glm: 0,
            seeds: vec![1, 2, 3, 4, u64::MAX],
            done_shards: vec![0, 3],
        }
    }

    /// Round-trip a message through both codecs.
    fn roundtrip<M: WireMessage + PartialEq + std::fmt::Debug + Clone>(m: &M) {
        assert_eq!(&M::from_frame(&m.to_frame()).unwrap(), m, "binary");
        let js = Codec::JsonDebug.encode(m);
        assert_eq!(&Codec::JsonDebug.decode::<M>(&js).unwrap(), m, "json");
    }

    #[test]
    fn setup_roundtrip() {
        roundtrip(&setup());
    }

    #[test]
    fn tag_only_roundtrips() {
        roundtrip(&Compress);
        roundtrip(&Shutdown);
        assert!(Compress.to_frame().payload.is_empty());
    }

    #[test]
    fn plain_base_roundtrip() {
        let r = Matrix::from_rows(&[&[1.0, 2.0], &[0.0, 3.0]]);
        roundtrip(&PlainBase { flat: vec![1.5, -2.5], r });
    }

    #[test]
    fn plain_base_rejects_non_square_r() {
        let r = Matrix::from_rows(&[&[1.0, 2.0], &[0.0, 3.0]]);
        let mut f = PlainBase { flat: vec![], r }.to_frame();
        // corrupt r_rows (first u64 after the empty flat vec's length)
        f.payload[8..16].copy_from_slice(&3u64.to_le_bytes());
        assert!(PlainBase::from_frame(&f).is_err());
    }

    #[test]
    fn masked_roundtrips() {
        roundtrip(&MaskedBase { enc: vec![u64::MAX, 0, 42] });
        roundtrip(&MaskedShard { shard: 7, enc: vec![1, 2, 3] });
    }

    #[test]
    fn plain_shard_roundtrip() {
        roundtrip(&PlainShard { shard: 3, flat: vec![0.25, -1.0, f64::MIN_POSITIVE] });
    }

    #[test]
    fn shamir_roundtrips() {
        let shares = vec![vec![1u64, 2], vec![3, 4], vec![5, 6]];
        roundtrip(&ShamirOut { round: 0, shares: shares.clone() });
        roundtrip(&ShamirIn { round: 4, shares: shares.clone() });
        roundtrip(&ShamirSum { round: 9, sum: shares[0].clone() });
    }

    #[test]
    fn shard_result_roundtrip() {
        let m = ShardResult {
            shard: 2,
            j0: 512,
            traits: 2,
            beta: vec![0.1, f64::NAN],
            se: vec![1.0, 2.0],
        };
        // NaN breaks PartialEq — check fields manually on the binary path
        let got = ShardResult::from_frame(&m.to_frame()).unwrap();
        assert_eq!(got.shard, 2);
        assert_eq!(got.j0, 512);
        assert_eq!(got.traits, 2);
        assert_eq!(got.width(), 1);
        assert_eq!(got.beta_for(0), &[0.1]);
        assert!(got.beta_for(1)[0].is_nan());
        assert_eq!(got.se_for(1), &[2.0]);
        assert_eq!(got.beta[0], 0.1);
        assert!(got.beta[1].is_nan());
        assert_eq!(got.se, vec![1.0, 2.0]);
        // and the lossless JSON path preserves the NaN bit pattern
        let js = Codec::JsonDebug.encode(&m);
        let got2: ShardResult = Codec::JsonDebug.decode(&js).unwrap();
        assert_eq!(got2.beta[1].to_bits(), m.beta[1].to_bits());
    }

    #[test]
    fn shard_result_rejects_mismatched_lengths() {
        let mut f = Frame::new(TAG_SHARD_RESULT);
        f.put_u64(0)
            .put_u64(0)
            .put_u64(1)
            .put_f64_slice(&[1.0, 2.0])
            .put_f64_slice(&[1.0]);
        assert!(ShardResult::from_frame(&f).is_err());
    }

    #[test]
    fn shard_result_rejects_bad_trait_count() {
        // traits = 0
        let mut f = Frame::new(TAG_SHARD_RESULT);
        f.put_u64(0)
            .put_u64(0)
            .put_u64(0)
            .put_f64_slice(&[1.0, 2.0])
            .put_f64_slice(&[1.0, 2.0]);
        assert!(ShardResult::from_frame(&f).is_err());
        // length not divisible by traits
        let mut f = Frame::new(TAG_SHARD_RESULT);
        f.put_u64(0)
            .put_u64(0)
            .put_u64(3)
            .put_f64_slice(&[1.0, 2.0])
            .put_f64_slice(&[1.0, 2.0]);
        assert!(ShardResult::from_frame(&f).is_err());
    }

    #[test]
    fn wrong_tag_rejected() {
        let f = Compress.to_frame();
        assert!(ShardResult::from_frame(&f).is_err());
        assert!(Setup::from_frame(&f).is_err());
        assert!(MaskedShard::from_frame(&f).is_err());
    }

    #[test]
    fn select_frames_roundtrip() {
        roundtrip(&SelectSetup {
            k: 3,
            policy: 1,
            lanes: 4,
            p_enter: 1e-4,
            candidates: vec![0, 7, 9, 1000],
        });
        roundtrip(&Promote { round: 1, variants: vec![7, LANE_INACTIVE, 9, 0] });
        roundtrip(&SelectDone { rounds: 2 });
        let sr = SelectResult {
            round: 2,
            variants: vec![7, LANE_INACTIVE],
            traits: vec![0, LANE_INACTIVE],
            beta: vec![0.25, f64::NAN],
            se: vec![0.1, f64::NAN],
            p: vec![1e-9, f64::NAN],
        };
        // NaN breaks PartialEq — check fields on the binary path
        let got = SelectResult::from_frame(&sr.to_frame()).unwrap();
        assert_eq!(got.round, 2);
        assert_eq!(got.variants, sr.variants);
        assert_eq!(got.beta[0], 0.25);
        assert!(got.beta[1].is_nan());
        let js = Codec::JsonDebug.encode(&sr);
        let got2: SelectResult = Codec::JsonDebug.decode(&js).unwrap();
        assert_eq!(got2.p[1].to_bits(), sr.p[1].to_bits());
    }

    #[test]
    fn select_frames_reject_malformed() {
        // non-increasing candidate list
        let mut f = Frame::new(TAG_SELECT_SETUP);
        f.put_u64(2).put_u64(0).put_u64(1).put_f64(0.5).put_u64_slice(&[3, 3]);
        assert!(SelectSetup::from_frame(&f).is_err());
        // zero lanes
        let mut f = Frame::new(TAG_SELECT_SETUP);
        f.put_u64(2).put_u64(0).put_u64(0).put_f64(0.5).put_u64_slice(&[3]);
        assert!(SelectSetup::from_frame(&f).is_err());
        // promote with no active lane
        let mut f = Frame::new(TAG_PROMOTE);
        f.put_u64(1).put_u64_slice(&[LANE_INACTIVE]);
        assert!(Promote::from_frame(&f).is_err());
        // 0-based promote round
        let mut f = Frame::new(TAG_PROMOTE);
        f.put_u64(0).put_u64_slice(&[5]);
        assert!(Promote::from_frame(&f).is_err());
        // lane-vector length mismatch
        let mut f = Frame::new(TAG_SELECT_RESULT);
        f.put_u64(1)
            .put_u64_slice(&[1, 2])
            .put_u64_slice(&[0])
            .put_f64_slice(&[0.1, 0.2])
            .put_f64_slice(&[0.1, 0.2])
            .put_f64_slice(&[0.5, 0.5]);
        assert!(SelectResult::from_frame(&f).is_err());
    }

    #[test]
    fn checkpoint_roundtrip_and_rejects() {
        let m = 3u64;
        let t = 2u64;
        let mut stats = vec![f64::NAN; (4 * t * m) as usize];
        stats[0] = 0.5;
        stats[7] = -1.25;
        let c = Checkpoint {
            version: CHECKPOINT_VERSION,
            session: 4,
            seed: 0xC4A0,
            backend: 2,
            m,
            k: 5,
            t,
            shard_m: 2,
            select_k: 0,
            done: vec![0, 1],
            df: f64::NAN,
            stats,
        };
        // NaN breaks PartialEq — compare bit patterns on the binary path
        let got = Checkpoint::from_frame(&c.to_frame()).unwrap();
        assert_eq!(got.session, 4);
        assert_eq!(got.seed, 0xC4A0);
        assert_eq!(got.done, vec![0, 1]);
        assert!(got.df.is_nan());
        assert_eq!(got.stats.len(), c.stats.len());
        for (a, b) in got.stats.iter().zip(&c.stats) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // wrong version
        let mut bad = c.clone();
        bad.version = CHECKPOINT_VERSION + 1;
        assert!(Checkpoint::from_frame(&bad.to_frame()).is_err());
        // stats length not 4·t·m
        let mut bad = c.clone();
        bad.stats.pop();
        assert!(Checkpoint::from_frame(&bad.to_frame()).is_err());
        // non-increasing done list
        let mut bad = c.clone();
        bad.done = vec![1, 1];
        assert!(Checkpoint::from_frame(&bad.to_frame()).is_err());
    }

    #[test]
    fn irls_frames_roundtrip() {
        roundtrip(&IrlsSetup { max_iter: 25, tol: 1e-8 });
        roundtrip(&IrlsRound { iter: 3, beta: vec![0.5, -1.25, 0.0] });
        roundtrip(&IrlsDone { iters: 7, beta: vec![2.0, -0.5] });
    }

    #[test]
    fn irls_frames_reject_malformed() {
        // zero max_iter
        let mut f = Frame::new(TAG_IRLS_SETUP);
        f.put_u64(0).put_f64(1e-8);
        assert!(IrlsSetup::from_frame(&f).is_err());
        // non-positive / non-finite tolerance
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut f = Frame::new(TAG_IRLS_SETUP);
            f.put_u64(10).put_f64(bad);
            assert!(IrlsSetup::from_frame(&f).is_err(), "tol={bad}");
        }
        // 0-based IRLS round
        let mut f = Frame::new(TAG_IRLS_ROUND);
        f.put_u64(0).put_f64_slice(&[0.5]);
        assert!(IrlsRound::from_frame(&f).is_err());
        // non-finite iterate
        let mut f = Frame::new(TAG_IRLS_ROUND);
        f.put_u64(1).put_f64_slice(&[f64::NAN]);
        assert!(IrlsRound::from_frame(&f).is_err());
        // zero iterations in DONE
        let mut f = Frame::new(TAG_IRLS_DONE);
        f.put_u64(0).put_f64_slice(&[0.5]);
        assert!(IrlsDone::from_frame(&f).is_err());
        // non-finite final iterate
        let mut f = Frame::new(TAG_IRLS_DONE);
        f.put_u64(2).put_f64_slice(&[f64::INFINITY]);
        assert!(IrlsDone::from_frame(&f).is_err());
        // wrong tag
        assert!(IrlsSetup::from_frame(&Compress.to_frame()).is_err());
        assert!(IrlsRound::from_frame(&Compress.to_frame()).is_err());
        assert!(IrlsDone::from_frame(&Compress.to_frame()).is_err());
    }

    #[test]
    fn error_frame_roundtrip() {
        let f = error_frame("boom");
        assert_eq!(parse_error(&f), "boom");
        roundtrip(&ErrorMsg { msg: "kaputt".to_string() });
    }

    #[test]
    fn tags_are_distinct() {
        let tags = [
            TAG_SETUP,
            TAG_COMPRESS,
            TAG_PLAIN_BASE,
            TAG_MASKED_BASE,
            TAG_SHAMIR_OUT,
            TAG_SHAMIR_IN,
            TAG_SHAMIR_SUM,
            TAG_SHARD_RESULT,
            TAG_SHUTDOWN,
            TAG_ERROR,
            TAG_PLAIN_SHARD,
            TAG_MASKED_SHARD,
            TAG_SELECT_SETUP,
            TAG_PROMOTE,
            TAG_SELECT_RESULT,
            TAG_SELECT_DONE,
            TAG_CHECKPOINT,
            TAG_IRLS_SETUP,
            TAG_IRLS_ROUND,
            TAG_IRLS_DONE,
        ];
        for (i, a) in tags.iter().enumerate() {
            for b in &tags[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn setup_json_debug_is_readable() {
        let text = Codec::debug_string(&setup());
        assert!(text.contains("\"SETUP\""), "{text}");
        assert!(text.contains("shard_m"), "{text}");
    }
}
