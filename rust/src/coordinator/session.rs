//! Concurrent session service: many scan+SELECT sessions multiplexed
//! over one shared connection per party.
//!
//! The classic deployment ([`super::run_multi_party_scan`]) runs exactly
//! one session per process over dedicated connections. This module is
//! the scale-out axis: a leader-side [`SessionManager`] schedules any
//! number of [`SessionSpec`]s onto a bounded worker pool, each session
//! running the unmodified [`Leader`] state machine over per-session
//! [`crate::net::SessionChannel`]s of shared [`crate::net::SessionMux`]
//! connections; a party-side [`party_service`] accepts sessions as their
//! first frames arrive and serves each with the unmodified party state
//! machine on its own bounded pool. Sessions are isolated end to end:
//!
//! - **framing** — every frame carries its session id (codec v2), the
//!   demux routes by id, and late/unknown frames are dropped, not
//!   misdelivered;
//! - **masking** — secure-sum PRG streams are keyed by session id
//!   (`SETUP.session`), so concurrent sessions never reuse a mask or
//!   share stream even under identical seeds;
//! - **compute** — parties share one [`Engine`] (and its lowering
//!   cache) across all sessions, so artifact-mode kernels are lowered
//!   once per shape, not once per session;
//! - **metering** — each session carries its own byte meter; the shared
//!   connection meter tallies the multiplexed total.
//!
//! [`run_session_batch`] wires a full in-process deployment of the
//! above (in-proc channels or localhost TCP, optional fault injection
//! for the chaos battery) and is what the `--sessions` CLI flag, the
//! conformance matrix, and `bench_sessions` drive.

use super::leader::{Leader, SessionMetrics};
use super::party::{self, ComputeBackend};
use super::Transport;
use crate::gwas::Cohort;
use crate::net::chaos::{FaultSink, FaultSpec, FaultyTransport};
use crate::net::{duplex_pair, tcp_pair, tcp_stream_pair, ByteMeter, FrameSink, MuxOptions,
    Reactor, SessionMux, SessionTransport};
use crate::runtime::{Engine, EngineOptions, KernelMeter};
use crate::scan::{ScanConfig, ScanOutput, SelectOutput};
use crate::util::lock_unpoisoned;
use crate::util::threadpool::parallel_map;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One session to run: protocol knobs plus the leader-side seed.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub cfg: ScanConfig,
    pub seed: u64,
}

/// Cooperative cancellation handle for a session batch — the daemon's
/// `DELETE /jobs/{id}` path. `cancel()` is sticky and wakes every
/// waiter; [`run_session_batch`] arms a watcher that closes the batch's
/// per-session mux queues on cancellation, which makes any blocked
/// per-session receive fail promptly instead of waiting out its
/// timeout.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken { inner: Arc::new((Mutex::new(false), Condvar::new())) }
    }

    /// Fire the token (idempotent) and wake every waiter.
    pub fn cancel(&self) {
        *lock_unpoisoned(&self.inner.0) = true;
        self.inner.1.notify_all();
    }

    pub fn is_cancelled(&self) -> bool {
        *lock_unpoisoned(&self.inner.0)
    }

    /// Block up to `d` for a cancellation; returns the fired state.
    pub fn wait_timeout(&self, d: Duration) -> bool {
        let g = lock_unpoisoned(&self.inner.0);
        if *g {
            return true;
        }
        let (g, _) = self
            .inner
            .1
            .wait_timeout(g, d)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        *g
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

/// Typed per-session failure: the session was torn down by an external
/// cancellation (its queues were closed under it).
#[derive(Clone, Debug)]
pub struct SessionCancelled {
    pub session: u64,
}

impl std::fmt::Display for SessionCancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session {} cancelled", self.session)
    }
}

impl std::error::Error for SessionCancelled {}

/// Typed per-session failure: the leader-side worker panicked. The
/// panic is contained to this session — the rest of the batch (and a
/// daemon scheduling it) keeps running.
#[derive(Clone, Debug)]
pub struct SessionPanicked {
    pub session: u64,
    pub message: String,
}

impl std::fmt::Display for SessionPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session {} panicked: {}", self.session, self.message)
    }
}

impl std::error::Error for SessionPanicked {}

/// Best-effort text of a caught panic payload.
fn panic_payload(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Scheduler-visible lifecycle of one session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    Queued,
    Running,
    Done,
    Failed,
}

/// Scheduler-side state of one session: id, lifecycle, and (once
/// finished) the headline metering snapshot.
#[derive(Clone, Debug)]
pub struct SessionState {
    pub session: u64,
    pub status: SessionStatus,
    /// shards streamed (0 until the session finishes)
    pub shards: usize,
    /// SELECT promote rounds completed
    pub select_rounds: usize,
    /// session wire bytes, both directions across all parties
    pub bytes: u64,
}

/// A completed session's results.
#[derive(Clone, Debug)]
pub struct SessionRun {
    pub session: u64,
    pub output: ScanOutput,
    pub select: Option<SelectOutput>,
    pub metrics: SessionMetrics,
}

/// Leader-side scheduler: runs sessions over shared per-party muxes with
/// a bounded worker pool. Session `i` of a batch gets id `i + 1` (0 is
/// reserved for dedicated-connection deployments).
pub struct SessionManager<'a> {
    muxes: &'a [SessionMux],
    k: usize,
    m: usize,
    t: usize,
    max_concurrent: usize,
    states: Mutex<Vec<SessionState>>,
    cancel: Option<CancelToken>,
    panic_session: Option<u64>,
}

impl<'a> SessionManager<'a> {
    pub fn new(
        muxes: &'a [SessionMux],
        k: usize,
        m: usize,
        t: usize,
        max_concurrent: usize,
    ) -> SessionManager<'a> {
        SessionManager {
            muxes,
            k,
            m,
            t,
            max_concurrent: max_concurrent.max(1),
            states: Mutex::new(Vec::new()),
            cancel: None,
            panic_session: None,
        }
    }

    /// Arm a cancellation token: once fired, sessions that have not
    /// started fail with the typed [`SessionCancelled`] instead of
    /// running, and in-flight sessions map their teardown error to the
    /// same type.
    pub fn set_cancel(&mut self, token: CancelToken) {
        self.cancel = Some(token);
    }

    /// Chaos handle: the worker of this session id panics mid-run,
    /// exercising the panic-containment path deterministically.
    pub fn set_panic_session(&mut self, session: Option<u64>) {
        self.panic_session = session;
    }

    fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.is_cancelled())
    }

    /// Snapshot of every session's scheduler state. Recovers from lock
    /// poisoning: a crashed worker must not cascade panics into every
    /// later status query (the daemon keeps answering `GET /jobs/{id}`
    /// after one job dies).
    pub fn states(&self) -> Vec<SessionState> {
        lock_unpoisoned(&self.states).clone()
    }

    /// Run all `specs` to completion (bounded concurrency), returning
    /// per-session results in spec order. A failed session yields its
    /// error without disturbing the others.
    pub fn run(&self, specs: &[SessionSpec]) -> Vec<anyhow::Result<SessionRun>> {
        *lock_unpoisoned(&self.states) = (0..specs.len())
            .map(|i| SessionState {
                session: (i + 1) as u64,
                status: SessionStatus::Queued,
                shards: 0,
                select_rounds: 0,
                bytes: 0,
            })
            .collect();
        // the bounded worker pool is util::threadpool's dynamic-dispatch
        // map: `max_concurrent` workers pulling session indices, results
        // collected in spec order
        parallel_map(specs.len(), Some(self.max_concurrent), |i| {
            let sid = (i + 1) as u64;
            self.set_status(i, SessionStatus::Running);
            let res = self.run_one(sid, &specs[i]);
            let mut st = lock_unpoisoned(&self.states);
            let slot = &mut st[i];
            match &res {
                Ok(run) => {
                    slot.status = SessionStatus::Done;
                    slot.shards = run.metrics.shards;
                    slot.select_rounds = run.metrics.select_rounds;
                    slot.bytes = run.metrics.bytes_total;
                }
                Err(_) => slot.status = SessionStatus::Failed,
            }
            drop(st);
            res
        })
    }

    fn set_status(&self, i: usize, status: SessionStatus) {
        lock_unpoisoned(&self.states)[i].status = status;
    }

    fn run_one(&self, sid: u64, spec: &SessionSpec) -> anyhow::Result<SessionRun> {
        if self.cancelled() {
            return Err(SessionCancelled { session: sid }.into());
        }
        let mut channels = Vec::with_capacity(self.muxes.len());
        for mux in self.muxes {
            match mux.open(sid) {
                Ok(ch) => channels.push(ch),
                Err(e) => {
                    // roll back partially-opened queues before bailing
                    for mux in self.muxes {
                        mux.close(sid);
                    }
                    return Err(e);
                }
            }
        }
        // re-check after the opens: a cancel firing between the first
        // check and here would race the watcher's close sweep and let
        // this session run on freshly re-created queues
        if self.cancelled() {
            for mux in self.muxes {
                mux.close(sid);
            }
            return Err(SessionCancelled { session: sid }.into());
        }
        let leader = Leader {
            endpoints: &channels,
            cfg: &spec.cfg,
            k: self.k,
            m: self.m,
            t: self.t,
            session: sid,
        };
        // Panic containment: a panicking session worker yields a typed
        // per-session failure, never a batch-wide (or daemon-wide)
        // abort. The channels outlive the catch so the failure can be
        // broadcast to the parties.
        let out = std::panic::catch_unwind(AssertUnwindSafe(|| {
            if self.panic_session == Some(sid) {
                panic!("injected session panic (chaos handle)");
            }
            leader.run(spec.seed)
        }))
        .unwrap_or_else(|p| {
            let message = panic_payload(p.as_ref());
            // best-effort broadcast so the party workers fail this
            // session immediately instead of waiting out their receive
            // timeout
            let f = super::messages::error_frame(&format!(
                "session {sid} panicked at the leader: {message}"
            ));
            for ch in &channels {
                let _ = crate::net::Channel::send(ch, &f);
            }
            Err(SessionPanicked { session: sid, message }.into())
        });
        // free the per-session queues whether the session succeeded or
        // not — the soak test asserts no state survives a session
        for mux in self.muxes {
            mux.close(sid);
        }
        let (output, select, metrics) = out.map_err(|e| {
            // a cancel surfaces as whatever receive error the queue
            // teardown caused; give it its typed identity
            if self.cancelled() {
                anyhow::Error::from(SessionCancelled { session: sid })
            } else {
                e
            }
        })?;
        Ok(SessionRun { session: sid, output, select, metrics })
    }
}

/// Party-side service: accept sessions from a multiplexed connection and
/// serve each on a bounded worker pool, all workers sharing one compute
/// backend (hence one artifact engine + lowering cache). Returns
/// `(served, failed)` once the leader announces shutdown; per-session
/// protocol errors are reported over the wire by the party state machine
/// and do not stop the service. A *panicking* session worker is equally
/// contained — counted as failed, queue freed, worker back to
/// accepting — because under a long-lived daemon one poisoned session
/// must never take the whole party service (and with it every other
/// tenant's sessions) down.
pub fn party_service(
    mux: &SessionMux,
    data: &crate::gwas::PartyData,
    compute: &ComputeBackend,
    max_workers: usize,
) -> (usize, usize) {
    let served = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..max_workers.max(1) {
            s.spawn(|| loop {
                match mux.accept() {
                    Ok(Some(ch)) => {
                        let sid = ch.session();
                        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                            party::serve(&ch, data, compute)
                        }));
                        match res {
                            Ok(Ok(_)) => served.fetch_add(1, Ordering::SeqCst),
                            Ok(Err(_)) | Err(_) => failed.fetch_add(1, Ordering::SeqCst),
                        };
                        mux.close(sid);
                    }
                    Ok(None) | Err(_) => break,
                }
            });
        }
    });
    // orderly two-way teardown: tell the leader we are done, then wait
    // for our pump (which already saw the leader's shutdown) to exit
    mux.shutdown();
    mux.join();
    (served.load(Ordering::SeqCst), failed.load(Ordering::SeqCst))
}

/// Build a reactor-driven [`SessionMux`] over one raw TCP stream. The
/// connection handle is the mux's send side (optionally wrapped in the
/// fault injector), the mux's frame sink (optionally wrapped in the
/// receive-side fault injector) is what the reactor pushes decoded
/// frames into, and the inbox-backpressure resume hook is wired back to
/// the connection so a drained session re-arms its reads.
pub(crate) fn reactor_mux(
    reactor: &Reactor,
    stream: std::net::TcpStream,
    opts: MuxOptions,
    meter: ByteMeter,
    party: usize,
    fault: Option<FaultSpec>,
) -> anyhow::Result<SessionMux> {
    let handle = reactor.connect(stream, meter)?;
    let raw = FaultyTransport::wrap_if(Box::new(handle.clone()), party, fault);
    let (mux, sink) = SessionMux::driven(raw, opts);
    let sink: Arc<dyn FrameSink> = FaultSink::wrap_if(sink, party, fault);
    let resume = handle.clone();
    mux.set_resume_hook(Box::new(move || resume.resume()));
    handle.activate(sink)?;
    Ok(mux)
}

/// Deployment knobs for [`run_session_batch`].
#[derive(Clone, Debug)]
pub struct BatchOptions {
    pub transport: Transport,
    /// bound on concurrently-running sessions, leader and party side
    pub max_concurrent: usize,
    /// per-frame receive timeout (bounds how long a session can wait on
    /// a frame a faulty transport swallowed)
    pub recv_timeout: Option<Duration>,
    /// chaos battery: perturb one frame on one party's shared connection
    pub fault: Option<FaultSpec>,
    /// external cancellation: when the token fires, a watcher closes
    /// every batch session's queues (waking blocked receives) and
    /// sessions fail with the typed [`SessionCancelled`]
    pub cancel: Option<CancelToken>,
    /// chaos handle: the leader-side worker of this session id panics
    /// mid-run (drives the panic-containment regression tests)
    pub panic_session: Option<u64>,
    /// chaos handle: this party's whole service thread panics before
    /// serving (drives the service-join regression tests)
    pub panic_party_service: Option<usize>,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            transport: Transport::InProc,
            max_concurrent: 4,
            recv_timeout: Some(Duration::from_secs(30)),
            fault: None,
            cancel: None,
            panic_session: None,
            panic_party_service: None,
        }
    }
}

/// Result of a multiplexed session batch.
pub struct SessionBatchResult {
    /// per-session results, in spec order
    pub runs: Vec<anyhow::Result<SessionRun>>,
    /// the manager's final per-session scheduler states (spec order)
    pub states: Vec<SessionState>,
    /// shared-connection wire bytes per party (all sessions + control)
    pub conn_bytes: Vec<u64>,
    /// per-party kernel-suite telemetry — one engine per party shared by
    /// every session, so `lowered_entries` must not scale with sessions
    pub party_kernels: Vec<KernelMeter>,
    /// sessions the party services completed / failed (summed)
    pub served: usize,
    pub failed: usize,
    /// party service threads that died on a panic — a counted, typed
    /// outcome (their sessions fail individually), never a batch abort
    pub service_panics: usize,
    /// leader-side sessions still open right after the batch (must be 0
    /// — the soak-test handle)
    pub residual_sessions: usize,
    /// batch wall time
    pub wall_s: f64,
}

/// Run a batch of sessions over one shared connection pair per party:
/// the full multiplexed deployment (leader manager + party services) in
/// one process. All specs must agree on the compute path
/// (`use_artifacts`), which is fixed per party service.
pub fn run_session_batch(
    cohort: &Cohort,
    specs: &[SessionSpec],
    opts: &BatchOptions,
) -> anyhow::Result<SessionBatchResult> {
    anyhow::ensure!(!specs.is_empty(), "session batch needs at least one spec");
    let parties = cohort.parties.len();
    anyhow::ensure!(parties >= 1, "need at least one party");
    let first = &specs[0].cfg;
    anyhow::ensure!(
        specs.iter().all(|s| s.cfg.use_artifacts == first.use_artifacts),
        "all sessions of a batch must share the compute path (use_artifacts)"
    );

    // Shared connections: one byte-metered pair per party, the leader
    // side optionally wrapped in the fault injector. Reactor mode drives
    // both ends of every pair from one readiness thread; the connection
    // meter lives on the leader-side handle, where local sends plus
    // decoded inbound frames cover both directions exactly once — the
    // same total the pull-mode shared meter records at its two send
    // sites.
    let reactor = match opts.transport {
        Transport::Reactor => Some(Reactor::new()?),
        _ => None,
    };
    let l_opts = MuxOptions {
        accept: false,
        recv_timeout: opts.recv_timeout,
        ..Default::default()
    };
    let p_opts = MuxOptions {
        accept: true,
        recv_timeout: opts.recv_timeout,
        ..Default::default()
    };
    let mut conn_meters = Vec::with_capacity(parties);
    let mut leader_muxes = Vec::with_capacity(parties);
    let mut party_muxes = Vec::with_capacity(parties);
    for p in 0..parties {
        let meter = ByteMeter::new();
        match opts.transport {
            Transport::Reactor => {
                // typed failure, not a daemon-killing panic, if the
                // construction above ever stops covering this arm
                let r = reactor.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("reactor transport selected but no reactor was built")
                })?;
                let (ls, ps) = tcp_stream_pair()?;
                leader_muxes.push(reactor_mux(
                    r, ls, l_opts.clone(), meter.clone(), p, opts.fault,
                )?);
                party_muxes.push(reactor_mux(
                    r, ps, p_opts.clone(), ByteMeter::new(), p, None,
                )?);
            }
            Transport::InProc | Transport::Tcp => {
                let (l, pp) = match opts.transport {
                    Transport::InProc => duplex_pair(meter.clone()),
                    _ => tcp_pair(meter.clone())?,
                };
                let raw: Box<dyn SessionTransport> =
                    FaultyTransport::wrap_if(Box::new(l), p, opts.fault);
                leader_muxes.push(SessionMux::new(raw, l_opts.clone()));
                party_muxes.push(SessionMux::over(pp, p_opts.clone()));
            }
        }
        conn_meters.push(meter);
    }

    // One compute backend per party, built up front so an engine-open
    // failure surfaces before any thread is spawned. Artifact engines
    // are shared across every session the service runs.
    //
    // Thread budget: session workers × per-session compress threads must
    // not exceed the batch's global compress budget, so the budget is
    // divided across the concurrent session workers (floor 1). A batch
    // of 4 concurrent sessions on an 8-thread budget gives each session
    // 2 compress workers — never 4 × 8. Result-neutral by the canonical
    // tiled-fold contract.
    let budget = crate::util::threadpool::effective_threads(
        first.effective_compress_threads(),
    );
    let per_session = (budget / opts.max_concurrent.max(1)).max(1);
    let kernel_meters: Vec<KernelMeter> = (0..parties).map(|_| KernelMeter::new()).collect();
    let mut computes = Vec::with_capacity(parties);
    for km in &kernel_meters {
        computes.push(if first.use_artifacts {
            ComputeBackend::Artifacts(Arc::new(Engine::open(&EngineOptions {
                dir: first.artifacts_dir.clone(),
                exec: first.artifact_exec,
                policy: first.entry_policy(),
                meter: km.clone(),
                threads: Some(per_session),
            })?))
        } else {
            ComputeBackend::Rust { threads: Some(per_session) }
        });
    }

    let t0 = Instant::now();
    let mut manager = SessionManager::new(
        &leader_muxes,
        cohort.k(),
        cohort.m(),
        cohort.t(),
        opts.max_concurrent,
    );
    if let Some(token) = &opts.cancel {
        manager.set_cancel(token.clone());
    }
    manager.set_panic_session(opts.panic_session);
    let batch_sessions = specs.len() as u64;
    let (runs, states, served, failed, service_panics, residual_sessions) =
        std::thread::scope(|s| {
            // cancellation watcher: once the token fires, sweep-close
            // every batch session on the leader muxes (waking blocked
            // receives) until the batch drains — the repeated sweep also
            // covers sessions whose queues open after the first pass
            let batch_done = AtomicBool::new(false);
            let done = &batch_done;
            if let Some(token) = opts.cancel.clone() {
                let muxes = &leader_muxes;
                s.spawn(move || {
                    loop {
                        if done.load(Ordering::SeqCst) {
                            return;
                        }
                        if token.wait_timeout(Duration::from_millis(20)) {
                            break;
                        }
                    }
                    while !done.load(Ordering::SeqCst) {
                        for mux in muxes {
                            for sid in 1..=batch_sessions {
                                mux.close(sid);
                            }
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                });
            }
            let mut svc = Vec::with_capacity(parties);
            for (p, mux) in party_muxes.iter().enumerate() {
                let data = &cohort.parties[p];
                let compute = &computes[p];
                let workers = opts.max_concurrent;
                let panic_service = opts.panic_party_service == Some(p);
                svc.push(s.spawn(move || {
                    let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        if panic_service {
                            panic!("injected party service panic (chaos handle)");
                        }
                        party_service(mux, data, compute, workers)
                    }));
                    match res {
                        Ok(counts) => counts,
                        Err(p) => {
                            // a dead service must still answer the
                            // teardown handshake or the leader-side
                            // pumps would wait forever
                            mux.shutdown();
                            mux.join();
                            std::panic::resume_unwind(p);
                        }
                    }
                }));
            }
            let runs = manager.run(specs);
            let states = manager.states();
            batch_done.store(true, Ordering::SeqCst);
            let residual: usize = leader_muxes.iter().map(|m| m.open_sessions()).sum();
            // teardown handshake: announce shutdown to every party
            // service, collect them, then wait for our pumps (fed by
            // their answering shutdown frames) to exit
            for mux in leader_muxes.iter() {
                mux.shutdown();
            }
            let mut served = 0usize;
            let mut failed = 0usize;
            let mut service_panics = 0usize;
            for h in svc {
                // a panicked service is a counted outcome, not a batch
                // abort: its sessions already failed individually on
                // their receive timeouts
                match h.join() {
                    Ok((ok, bad)) => {
                        served += ok;
                        failed += bad;
                    }
                    Err(_) => service_panics += 1,
                }
            }
            for mux in leader_muxes.iter() {
                mux.join();
            }
            (runs, states, served, failed, service_panics, residual)
        });
    // every mux has completed its teardown handshake: stop the readiness
    // loop and close the sockets it drove
    if let Some(r) = &reactor {
        r.shutdown();
    }
    let wall_s = t0.elapsed().as_secs_f64();

    Ok(SessionBatchResult {
        runs,
        states,
        conn_bytes: conn_meters.iter().map(|m| m.bytes()).collect(),
        party_kernels: kernel_meters,
        served,
        failed,
        service_panics,
        residual_sessions,
        wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::{generate_cohort, CohortSpec};
    use crate::mpc::Backend;
    use crate::net::chaos::{FaultDir, FaultMode};

    fn batch_cfg(backend: Backend) -> ScanConfig {
        ScanConfig {
            backend,
            shard_m: 8,
            block_m: 16,
            threads: Some(1),
            ..ScanConfig::default()
        }
    }

    #[test]
    fn multiplexed_batch_matches_dedicated_connections() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 321);
        let cfg = batch_cfg(Backend::Masked);
        let serial =
            super::super::run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 42)
                .unwrap();
        let specs: Vec<SessionSpec> =
            (0..3).map(|_| SessionSpec { cfg: cfg.clone(), seed: 42 }).collect();
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions { max_concurrent: 3, ..Default::default() },
        )
        .unwrap();
        // 3 sessions served by each of the 3 party services
        assert_eq!(batch.served, 9);
        assert_eq!(batch.failed, 0);
        assert_eq!(batch.residual_sessions, 0);
        for run in &batch.runs {
            let run = run.as_ref().expect("session failed");
            for tt in 0..serial.output.t() {
                for (a, b) in
                    run.output.assoc[tt].beta.iter().zip(&serial.output.assoc[tt].beta)
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn batch_metrics_populated() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 322);
        let cfg = batch_cfg(Backend::Plaintext);
        let specs: Vec<SessionSpec> =
            (0..2).map(|i| SessionSpec { cfg: cfg.clone(), seed: 50 + i }).collect();
        let batch = run_session_batch(&cohort, &specs, &BatchOptions::default()).unwrap();
        assert!(batch.runs.iter().all(|r| r.is_ok()));
        assert!(batch.wall_s > 0.0);
        // the manager's scheduler states settled to Done with metering
        assert_eq!(batch.states.len(), 2);
        for (i, st) in batch.states.iter().enumerate() {
            assert_eq!(st.session, (i + 1) as u64);
            assert_eq!(st.status, SessionStatus::Done);
            assert!(st.shards > 0);
            assert!(st.bytes > 0);
        }
        let bytes: Vec<u64> = batch
            .runs
            .iter()
            .map(|r| r.as_ref().unwrap().metrics.bytes_total)
            .collect();
        assert!(bytes.iter().all(|&b| b > 0));
        // the shared connections carry every session plus control frames
        let conn_total: u64 = batch.conn_bytes.iter().sum();
        assert!(conn_total > bytes.iter().sum::<u64>() / 2);
    }

    #[test]
    fn injected_session_panic_is_contained_and_typed() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 324);
        let cfg = batch_cfg(Backend::Plaintext);
        let specs: Vec<SessionSpec> =
            (0..3).map(|i| SessionSpec { cfg: cfg.clone(), seed: 60 + i }).collect();
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions {
                max_concurrent: 3,
                panic_session: Some(2),
                ..Default::default()
            },
        )
        .unwrap();
        // the panicked session failed with the typed error...
        let err = batch.runs[1].as_ref().unwrap_err();
        assert!(err.downcast_ref::<SessionPanicked>().is_some(), "{err:#}");
        // ...every other session completed, the scheduler states stayed
        // queryable, and no per-session queue leaked
        assert!(batch.runs[0].is_ok() && batch.runs[2].is_ok());
        assert_eq!(batch.states[1].status, SessionStatus::Failed);
        assert_eq!(batch.states[0].status, SessionStatus::Done);
        assert_eq!(batch.residual_sessions, 0);
        assert_eq!(batch.service_panics, 0);
        // the broadcast error frame failed the session at all 3 parties
        // immediately (no timeout waits)
        assert_eq!(batch.failed, 3);
        assert_eq!(batch.served, 6);
    }

    #[test]
    fn cancel_before_start_fails_every_session_typed() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 325);
        let cfg = batch_cfg(Backend::Masked);
        let token = CancelToken::new();
        token.cancel();
        let specs: Vec<SessionSpec> =
            (0..2).map(|i| SessionSpec { cfg: cfg.clone(), seed: 70 + i }).collect();
        let t0 = Instant::now();
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions { cancel: Some(token), ..Default::default() },
        )
        .unwrap();
        // prompt teardown — nothing waited out a 30 s receive timeout
        assert!(t0.elapsed() < Duration::from_secs(10));
        for run in &batch.runs {
            let err = run.as_ref().unwrap_err();
            assert!(err.downcast_ref::<SessionCancelled>().is_some(), "{err:#}");
        }
        assert_eq!(batch.residual_sessions, 0);
    }

    #[test]
    fn cancel_mid_scan_wakes_a_stalled_session() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 326);
        let cfg = batch_cfg(Backend::Masked);
        let token = CancelToken::new();
        let canceller = token.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            canceller.cancel();
        });
        let specs = vec![SessionSpec { cfg, seed: 80 }];
        let t0 = Instant::now();
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions {
                // swallow one of party 0's contributions: the leader
                // stalls mid-scan, and only the cancel sweep (closing
                // the session's queues) can release it before the 30 s
                // receive timeout
                fault: Some(FaultSpec {
                    party: 0,
                    dir: FaultDir::Recv,
                    mode: FaultMode::Drop,
                    session: 1,
                    nth: 2,
                }),
                cancel: Some(token),
                max_concurrent: 1,
                ..Default::default()
            },
        )
        .unwrap();
        h.join().unwrap();
        let err = batch.runs[0].as_ref().unwrap_err();
        assert!(err.downcast_ref::<SessionCancelled>().is_some(), "{err:#}");
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "cancel did not wake the stalled session"
        );
        assert_eq!(batch.residual_sessions, 0);
    }

    #[test]
    fn party_service_panic_is_counted_not_fatal() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 327);
        let cfg = batch_cfg(Backend::Plaintext);
        let specs = vec![SessionSpec { cfg, seed: 90 }];
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions {
                panic_party_service: Some(1),
                // fallback bound for the dead service's sessions
                recv_timeout: Some(Duration::from_millis(500)),
                ..Default::default()
            },
        )
        .unwrap();
        // the join error became a counted outcome, not a batch abort
        assert_eq!(batch.service_panics, 1);
        assert!(batch.runs[0].is_err());
        assert_eq!(batch.residual_sessions, 0);
        // scheduler state stayed queryable after the crash
        assert_eq!(batch.states[0].status, SessionStatus::Failed);
    }

    #[test]
    fn mixed_session_specs_run_in_one_batch() {
        // sessions with different SELECT knobs and seeds share the muxes
        let cohort = generate_cohort(&CohortSpec::default_small(), 323);
        let mut with_select = batch_cfg(Backend::Plaintext);
        with_select.select_k = 1;
        with_select.select_alpha = 0.9;
        with_select.select_candidates = 8;
        let specs = vec![
            SessionSpec { cfg: batch_cfg(Backend::Plaintext), seed: 1 },
            SessionSpec { cfg: with_select.clone(), seed: 2 },
        ];
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions { max_concurrent: 2, ..Default::default() },
        )
        .unwrap();
        let r0 = batch.runs[0].as_ref().unwrap();
        let r1 = batch.runs[1].as_ref().unwrap();
        assert!(r0.select.is_none());
        assert!(r1.select.is_some());
        // per-session serial equivalents agree bit-for-bit
        for (spec, run) in specs.iter().zip([r0, r1]) {
            let serial = super::super::run_multi_party_scan_t(
                &cohort,
                &spec.cfg,
                Transport::InProc,
                spec.seed,
            )
            .unwrap();
            for (a, b) in
                run.output.assoc[0].beta.iter().zip(&serial.output.assoc[0].beta)
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
