//! Leader-side protocol: session setup, streaming per-shard contribution
//! collection, secure aggregation, incremental combine, result broadcast.
//!
//! The leader never materializes the `O((K+T)·M)` aggregate: each
//! shard's contributions are aggregated (`O(P·(K+T)·width)`), combined
//! through the [`ScanAssembler`] (`O((K² + KT)·width)`, the `QᵀX`
//! projection shared across all T traits), and dropped — while the
//! parties are already compressing the next shard. Only the `O(M·T)`
//! output vectors and the per-shard result frames accumulate. Partial results
//! are broadcast after the last shard so the single leader↔party stream
//! never carries traffic in both directions at once (no head-of-line
//! deadlock over TCP, any shard width).

use super::incremental::ScanAssembler;
use super::messages::*;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::aggregate_masked;
use crate::mpc::masking::PairwiseMasker;
use crate::mpc::Backend;
use crate::net::{Channel, Frame, WireMessage};
use crate::scan::{
    base_flat_len, choose_candidates, irls_base_flat_len, irls_shard_flat_len, shard_flat_len,
    unflatten_base, unflatten_irls_base, unflatten_irls_shard, unflatten_shard, BaseSums,
    CombineContext, Glm, IrlsState, IrlsStep, ScanConfig, ScanOutput, SelectOutput, SelectPolicy,
    SelectState, ShardPlan,
};
use crate::stats::{score_assoc_from_sums, AssocResult, LogisticFit};
use crate::util::rng::Rng;
use std::time::Instant;

/// Phase timings + communication tallies for one session.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    /// wall time from COMPRESS broadcast to last contribution received
    pub compress_wall_s: f64,
    /// leader-side combine time (aggregation + factorization + epilogue)
    pub combine_s: f64,
    /// total session wall time
    pub total_s: f64,
    /// bytes over all leader↔party links (both directions)
    pub bytes_total: u64,
    /// messages over all links
    pub messages_total: u64,
    /// bytes of the result broadcast alone (the O(M) downlink)
    pub bytes_result: u64,
    /// number of variant shards the scan streamed over
    pub shards: usize,
    /// peak wire bytes of any single contribution round (base or shard),
    /// counted from the frames of that round — bounded by the shard
    /// width, not by M (the memory claim, E4'). Deterministic across
    /// transports and unaffected by parties streaming ahead.
    pub bytes_max_round: u64,
    /// completed SELECT promote rounds (0 when `select_k == 0` or
    /// nothing passed the stop rule)
    pub select_rounds: usize,
    /// total wire bytes of the SELECT phase uplink/control traffic
    /// (setup broadcast, candidate round, promote rounds, done frames);
    /// the post-scan SELECT_RESULT broadcast is counted in
    /// `bytes_result` alongside the shard results
    pub bytes_select: u64,
    /// peak wire bytes of any single SELECT promote round (PROMOTE
    /// broadcast + cross-product sums) — `O(lanes·H)`, independent of M
    /// (the E9 claim, asserted in `integration_select.rs`)
    pub bytes_max_select_round: u64,
    /// IRLS iterations the logistic null-model fit ran (0 for linear
    /// scans)
    pub irls_iters: usize,
    /// total wire bytes of the IRLS phase (setup/round/done broadcasts
    /// plus every null-model secure-sum round) — `O(iters·K²·T)`,
    /// independent of M
    pub bytes_irls: u64,
    /// peak wire bytes of any single IRLS round (broadcast + sums)
    pub bytes_max_irls_round: u64,
    /// shards restored from a checkpoint instead of recomputed (resume)
    pub shards_skipped: u64,
    /// parties that went silent mid-session but were survived — Shamir
    /// share-sum reconstruction from a surviving quorum (the Degraded
    /// completion; empty for a clean run)
    pub dropouts: Vec<Dropout>,
}

/// A party that went silent, and at which secure-sum round (0 = base,
/// s+1 = shard s, shards+1+r = SELECT round r).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dropout {
    pub party: u64,
    pub round: u64,
}

/// Typed session failure: a party stopped responding at a point where
/// its contribution is unrecoverable — any round under the plaintext or
/// masked backends (masks only cancel with every party present), or a
/// Shamir round whose share fan-out never arrived. When a checkpoint
/// dir is configured the state up to the last combined shard is already
/// on disk, so the caller retries with `resume` instead of restarting
/// from zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartyDropped {
    pub party: u64,
    pub round: u64,
}

impl std::fmt::Display for PartyDropped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "party {} dropped at secure-sum round {}",
            self.party, self.round
        )
    }
}

impl std::error::Error for PartyDropped {}

/// Leader state for one scan session over connected party channels —
/// dedicated [`crate::net::Endpoint`]s (the classic deployment, session
/// id 0) or per-session [`crate::net::SessionChannel`]s of a multiplexed
/// connection (driven by [`super::session::SessionManager`]).
pub struct Leader<'a, C: Channel> {
    pub endpoints: &'a [C],
    pub cfg: &'a ScanConfig,
    pub k: usize,
    pub m: usize,
    /// trait count T (1 = classic single-trait scan)
    pub t: usize,
    /// protocol session id, delivered in SETUP; keys the parties'
    /// mask/share domains (0 on dedicated connections)
    pub session: u64,
}

impl<C: Channel> Leader<'_, C> {
    /// Run the full session; returns scan output, SELECT output (when
    /// `select_k > 0` and the shortlist was non-empty) and metrics.
    pub fn run(
        &self,
        seed: u64,
    ) -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
        match self.run_inner(seed) {
            Ok(v) => Ok(v),
            Err(e) => {
                // Best-effort protocol ErrorMsg so parties fail fast on a
                // leader-side protocol violation (duplicate/out-of-order
                // frames, bad lengths, …) instead of hanging on a dead
                // stream.
                for ep in self.endpoints {
                    let _ = ep.send(&error_frame(&format!("{e:#}")));
                }
                Err(e)
            }
        }
    }

    fn run_inner(
        &self,
        seed: u64,
    ) -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
        let t_start = Instant::now();
        let parties = self.endpoints.len();
        anyhow::ensure!(parties >= 1, "need at least one party");
        let mut metrics = SessionMetrics::default();
        let plan = ShardPlan::new(self.m, self.cfg.shard_m);
        metrics.shards = plan.count();
        let codec = FixedCodec::try_new(self.cfg.frac_bits)?;
        let mut rng = Rng::new(seed);
        let backend_code = match self.cfg.backend {
            Backend::Plaintext => 0u64,
            Backend::Masked => 1,
            Backend::Shamir { .. } => 2,
        };
        let threshold = match self.cfg.backend {
            Backend::Shamir { threshold } => threshold,
            _ => 0,
        };

        // Logistic scans replace the linear shard rounds with the IRLS
        // loop + one weighted shard pass; the phases that depend on the
        // linear assembler (SELECT, checkpoint/resume) are rejected up
        // front instead of failing obscurely mid-session.
        if self.cfg.glm == Glm::Logistic {
            anyhow::ensure!(
                self.cfg.select_k == 0,
                "logistic scans do not support the SELECT phase"
            );
            anyhow::ensure!(
                self.cfg.checkpoint_dir.is_empty() && !self.cfg.resume,
                "logistic scans do not support checkpoint/resume"
            );
        }

        // Resume: load the session's snapshot and check its fingerprint
        // against this run's configuration — resuming across different
        // seeds/backends/layouts would silently mix statistics.
        let ckpt = if self.cfg.resume && !self.cfg.checkpoint_dir.is_empty() {
            super::checkpoint::load(&self.cfg.checkpoint_dir, self.session)?
        } else {
            None
        };
        if let Some(c) = &ckpt {
            anyhow::ensure!(
                c.seed == seed
                    && c.backend == backend_code
                    && c.m == self.m as u64
                    && c.k == self.k as u64
                    && c.t == self.t as u64
                    && c.shard_m == self.cfg.shard_m as u64
                    && c.select_k == self.cfg.select_k as u64,
                "checkpoint for session {} is from a different run configuration",
                self.session
            );
            anyhow::ensure!(
                c.done.iter().all(|&s| (s as usize) < plan.count()),
                "checkpoint shard index beyond the shard plan"
            );
        }
        let done: Vec<u64> = ckpt.as_ref().map_or_else(Vec::new, |c| c.done.clone());

        // SETUP: pairwise seeds (simulated DH — delivered over the
        // metered link so their cost is visible) + session params.
        let seed_matrix = PairwiseMasker::session_seeds(parties, &mut rng);
        for (p, ep) in self.endpoints.iter().enumerate() {
            let setup = Setup {
                session: self.session,
                party_index: p as u64,
                parties: parties as u64,
                backend: backend_code,
                shamir_threshold: threshold as u64,
                frac_bits: self.cfg.frac_bits as u64,
                k: self.k as u64,
                m: self.m as u64,
                t: self.t as u64,
                block_m: self.cfg.block_m as u64,
                shard_m: self.cfg.shard_m as u64,
                select_k: self.cfg.select_k as u64,
                glm: self.cfg.glm.code(),
                seeds: seed_matrix[p].clone(),
                done_shards: done.clone(),
            };
            ep.send(&setup.to_frame())?;
        }

        // COMPRESS kick-off.
        let t_compress = Instant::now();
        for ep in self.endpoints {
            ep.send(&Compress.to_frame())?;
        }

        // Base round: collect + aggregate the O(K² + KT) covariate and
        // trait stats. Always re-run on resume — it is cheap and
        // deterministic, and re-derives the CombineContext the snapshot
        // deliberately leaves out.
        let mut dropouts: Vec<Dropout> = Vec::new();
        let (base_flat, party_rs, round_bytes) =
            self.collect_round(&codec, 0, base_flat_len(self.k, self.t), &mut dropouts)?;
        metrics.bytes_max_round = round_bytes;
        let base = unflatten_base(self.k, self.t, &base_flat)?;

        // Logistic mode: secure IRLS null model + one weighted shard
        // pass, then the same results/shutdown downlink as the linear
        // scan. The linear assembler below is never built.
        if self.cfg.glm == Glm::Logistic {
            let (out, results) =
                self.logistic_phase(&codec, &plan, &base, t_compress, &mut metrics, &mut dropouts)?;
            let bytes_before = self.total_bytes();
            for ep in self.endpoints {
                for res in &results {
                    ep.send(&res.to_frame())?;
                }
                ep.send(&Shutdown.to_frame())?;
            }
            metrics.bytes_result = self.total_bytes() - bytes_before;
            metrics.total_s = t_start.elapsed().as_secs_f64();
            metrics.bytes_total = self.total_bytes();
            metrics.messages_total =
                self.endpoints.iter().map(|e| e.meter().messages()).sum();
            metrics.dropouts = dropouts;
            return Ok((out, None, metrics));
        }

        // Factorize the covariate block once (O(K³)). Auto resolution of
        // the R-factor method (TSQR when per-party factors exist) lives
        // in combine_base.
        let t0 = Instant::now();
        let mut asm = ScanAssembler::new(
            &base,
            party_rs.as_deref(),
            crate::scan::CombineOptions { r_method: self.cfg.r_method },
            self.m,
        )?;
        metrics.combine_s += t0.elapsed().as_secs_f64();

        // Restore checkpointed shards into the fresh assembler: their
        // columns are marked assembled and their statistics scattered
        // back, so only the remaining shards run secure-sum rounds.
        if let Some(c) = &ckpt {
            let ranges: Vec<_> = c.done.iter().map(|&s| plan.range(s as usize)).collect();
            asm.restore(&ranges, c.df, &c.stats)?;
            metrics.shards_skipped = c.done.len() as u64;
        }

        // Shard rounds: aggregate + combine each shard as it arrives;
        // buffer the partial-result frames for the post-scan broadcast.
        // compress_wall_s stops at the last contribution received, so it
        // excludes the trailing combine (in pipelined runs the two phases
        // overlap, so compress_wall_s + combine_s may exceed total_s).
        let mut results = Vec::with_capacity(plan.count());
        let mut done_now = done.clone();
        let mut last_contribution = Instant::now();
        for range in plan.ranges() {
            if done.binary_search(&(range.index as u64)).is_ok() {
                // restored from the checkpoint — re-broadcast the
                // snapshot's partial result without a secure-sum round
                let (beta, se) = asm.result_slices(range)?;
                results.push(ShardResult {
                    shard: range.index as u64,
                    j0: range.j0 as u64,
                    traits: self.t as u64,
                    beta,
                    se,
                });
                continue;
            }
            let w = range.width();
            let (flat, _, round_bytes) = self.collect_round(
                &codec,
                range.index + 1,
                shard_flat_len(self.k, self.t, w),
                &mut dropouts,
            )?;
            last_contribution = Instant::now();
            metrics.bytes_max_round = metrics.bytes_max_round.max(round_bytes);
            let t0 = Instant::now();
            let sums = unflatten_shard(self.k, self.t, w, &flat)?;
            let parts = asm.add_shard(range, &sums)?;
            metrics.combine_s += t0.elapsed().as_secs_f64();
            // trait-major concatenation: [trait 0's w values | trait 1's | ...]
            let mut beta = Vec::with_capacity(w * self.t);
            let mut se = Vec::with_capacity(w * self.t);
            for part in &parts {
                beta.extend_from_slice(&part.beta);
                se.extend_from_slice(&part.se);
            }
            results.push(ShardResult {
                shard: range.index as u64,
                j0: range.j0 as u64,
                traits: self.t as u64,
                beta,
                se,
            });
            done_now.push(range.index as u64);
            // Snapshot after every combined shard: a later death costs at
            // most one shard of recompute. Written regardless of dropout
            // state — the file is removed again on clean completion.
            if !self.cfg.checkpoint_dir.is_empty() {
                let (df, stats) = asm.snapshot_stats();
                let mut done_sorted = done_now.clone();
                done_sorted.sort_unstable();
                super::checkpoint::save(
                    &self.cfg.checkpoint_dir,
                    &Checkpoint {
                        version: CHECKPOINT_VERSION,
                        session: self.session,
                        seed,
                        backend: backend_code,
                        m: self.m as u64,
                        k: self.k as u64,
                        t: self.t as u64,
                        shard_m: self.cfg.shard_m as u64,
                        select_k: self.cfg.select_k as u64,
                        done: done_sorted,
                        df,
                        stats,
                    },
                )?;
            }
        }
        metrics.compress_wall_s = last_contribution.duration_since(t_compress).as_secs_f64();

        let t0 = Instant::now();
        let (out, cx) = asm.finish_with_context()?;
        metrics.combine_s += t0.elapsed().as_secs_f64();

        // SELECT phase: iterative forward stepwise over the cached
        // context (rank-1 basis growth, O(lanes·H) traffic per round).
        // A degraded quorum finished the scan from survivor share-sums,
        // but SELECT needs fresh contributions from *every* party — with
        // dropouts on record, follow the empty-shortlist path instead so
        // the surviving parties exit cleanly.
        let mut select_results: Vec<SelectResult> = Vec::new();
        let select = if self.cfg.select_k > 0 {
            if dropouts.is_empty() {
                self.select_phase(
                    &codec,
                    &out,
                    cx,
                    plan.count(),
                    &mut metrics,
                    &mut select_results,
                    &mut dropouts,
                )?
            } else {
                let sf = SelectSetup {
                    k: self.cfg.select_k as u64,
                    policy: self.cfg.select_policy.code(),
                    lanes: 1,
                    p_enter: self.cfg.select_alpha,
                    candidates: vec![],
                }
                .to_frame();
                let done_f = SelectDone { rounds: 0 }.to_frame();
                for ep in self.endpoints {
                    metrics.bytes_select += sf.wire_len() + done_f.wire_len();
                    ep.send(&sf)?;
                    ep.send(&done_f)?;
                }
                None
            }
        } else {
            None
        };

        // Per-shard RESULT + per-round SELECT_RESULT broadcast + shutdown
        // (the O(M·T) downlink).
        let bytes_before = self.total_bytes();
        for ep in self.endpoints {
            for res in &results {
                ep.send(&res.to_frame())?;
            }
            for sr in &select_results {
                ep.send(&sr.to_frame())?;
            }
            ep.send(&Shutdown.to_frame())?;
        }
        metrics.bytes_result = self.total_bytes() - bytes_before;
        metrics.total_s = t_start.elapsed().as_secs_f64();
        metrics.bytes_total = self.total_bytes();
        metrics.messages_total =
            self.endpoints.iter().map(|e| e.meter().messages()).sum();
        metrics.dropouts = dropouts;
        // Clean completion: the snapshot has served its purpose.
        if !self.cfg.checkpoint_dir.is_empty() {
            super::checkpoint::remove(&self.cfg.checkpoint_dir, self.session)?;
        }
        Ok((out, select, metrics))
    }

    /// Run the logistic workload after the base round: broadcast the
    /// IRLS parameters, iterate (broadcast β_i, secure-sum the weighted
    /// null-model stats evaluated at β_i, Newton-update) until the
    /// deviance stabilizes for every trait or the cap fires, broadcast
    /// IRLS_DONE with the final β, then collect one *weighted* shard
    /// round per variant shard (absolute round `iters + 1 + shard`, so
    /// every mask/share PRG domain stays distinct) and reduce each to
    /// per-variant score tests. Per-iteration traffic is `O(K²·T)`,
    /// per-shard traffic `O(K·width·T)` — same envelope as the linear
    /// scan plus the iteration count.
    fn logistic_phase(
        &self,
        codec: &FixedCodec,
        plan: &ShardPlan,
        base: &BaseSums,
        t_compress: Instant,
        metrics: &mut SessionMetrics,
        dropouts: &mut Vec<Dropout>,
    ) -> anyhow::Result<(ScanOutput, Vec<ShardResult>)> {
        let (k, t) = (self.k, self.t);
        // Case counts per trait from the already-aggregated base round:
        // row 0 of CᵀY is Σy when covariate column 0 is the intercept
        // (every cohort in this codebase; a non-intercept first column
        // only de-centers the shared starting point).
        let sum_y: Vec<f64> = (0..t).map(|tt| base.cty[(0, tt)]).collect();
        let mut st = IrlsState::new(
            k,
            t,
            base.n as f64,
            &sum_y,
            self.cfg.irls_max_iter,
            self.cfg.irls_tol,
        )?;

        let sf = IrlsSetup {
            max_iter: self.cfg.irls_max_iter as u64,
            tol: self.cfg.irls_tol,
        }
        .to_frame();
        for ep in self.endpoints {
            metrics.bytes_irls += sf.wire_len();
            ep.send(&sf)?;
        }

        // IRLS loop: iteration i is secure-sum round i (1-based; the
        // base round was round 0).
        let mut last_contribution = Instant::now();
        loop {
            let iter = st.iters + 1;
            let rf = IrlsRound { iter: iter as u64, beta: st.beta_flat() }.to_frame();
            let mut round_bytes = 0u64;
            for ep in self.endpoints {
                round_bytes += rf.wire_len();
                ep.send(&rf)?;
            }
            let (flat, _, rb) =
                self.collect_round(codec, iter, irls_base_flat_len(k, t), dropouts)?;
            last_contribution = Instant::now();
            round_bytes += rb;
            metrics.bytes_irls += round_bytes;
            metrics.bytes_max_irls_round = metrics.bytes_max_irls_round.max(round_bytes);
            let t0 = Instant::now();
            let sums = unflatten_irls_base(k, t, &flat)?;
            let step = st.step(&sums)?;
            metrics.combine_s += t0.elapsed().as_secs_f64();
            if step == IrlsStep::Stop {
                break;
            }
        }
        metrics.irls_iters = st.iters;
        let df = IrlsDone { iters: st.iters as u64, beta: st.beta_flat() }.to_frame();
        for ep in self.endpoints {
            metrics.bytes_irls += df.wire_len();
            ep.send(&df)?;
        }
        let fits: Vec<LogisticFit> = (0..t).map(|tt| st.fit(tt)).collect();

        // Weighted shard pass at the final β: per-variant score tests
        // against each trait's cached CᵀWC Cholesky factor.
        let mut results = Vec::with_capacity(plan.count());
        let mut assoc: Vec<AssocResult> = (0..t)
            .map(|_| AssocResult {
                beta: vec![f64::NAN; self.m],
                se: vec![f64::NAN; self.m],
                t: vec![f64::NAN; self.m],
                p: vec![f64::NAN; self.m],
                df: (base.n as f64) - (k as f64) - 1.0,
            })
            .collect();
        for range in plan.ranges() {
            let w = range.width();
            let round = st.iters + 1 + range.index;
            let (flat, _, rb) =
                self.collect_round(codec, round, irls_shard_flat_len(k, t, w), dropouts)?;
            last_contribution = Instant::now();
            metrics.bytes_max_round = metrics.bytes_max_round.max(rb);
            let t0 = Instant::now();
            let sums = unflatten_irls_shard(k, t, w, &flat)?;
            let mut beta = Vec::with_capacity(w * t);
            let mut se = Vec::with_capacity(w * t);
            for tt in 0..t {
                let a = score_assoc_from_sums(
                    base.n,
                    k,
                    st.final_r(tt),
                    &sums[tt].score,
                    &sums[tt].xwx,
                    &sums[tt].cwx,
                );
                for j in 0..w {
                    assoc[tt].beta[range.j0 + j] = a.beta[j];
                    assoc[tt].se[range.j0 + j] = a.se[j];
                    assoc[tt].t[range.j0 + j] = a.t[j];
                    assoc[tt].p[range.j0 + j] = a.p[j];
                }
                beta.extend_from_slice(&a.beta);
                se.extend_from_slice(&a.se);
            }
            metrics.combine_s += t0.elapsed().as_secs_f64();
            results.push(ShardResult {
                shard: range.index as u64,
                j0: range.j0 as u64,
                traits: t as u64,
                beta,
                se,
            });
        }
        metrics.compress_wall_s = last_contribution.duration_since(t_compress).as_secs_f64();

        let covariate_fit = fits.iter().map(|f| f.to_regression_fit(base.n)).collect();
        let out = ScanOutput {
            assoc,
            covariate_fit,
            n: base.n,
            k,
            m: self.m,
        };
        Ok((out, results))
    }

    /// Run the SELECT rounds: broadcast the candidate shortlist, collect
    /// the shard-shaped candidate sums, then per round broadcast the
    /// promotions and fold the returning cross-product sums into the
    /// grown bases. Returns `None` when the shortlist is empty (nothing
    /// with a finite scan p-value).
    #[allow(clippy::too_many_arguments)]
    fn select_phase(
        &self,
        codec: &FixedCodec,
        out: &ScanOutput,
        cx: CombineContext,
        shards: usize,
        metrics: &mut SessionMetrics,
        results: &mut Vec<SelectResult>,
        dropouts: &mut Vec<Dropout>,
    ) -> anyhow::Result<Option<SelectOutput>> {
        let cand = choose_candidates(out, self.cfg.select_candidates.max(1));
        let lanes = match self.cfg.select_policy {
            SelectPolicy::Union => 1,
            SelectPolicy::PerTrait => self.t,
        };
        let mut bytes_select = 0u64;
        let setup = SelectSetup {
            k: self.cfg.select_k as u64,
            policy: self.cfg.select_policy.code(),
            lanes: lanes as u64,
            p_enter: self.cfg.select_alpha,
            candidates: cand.iter().map(|&c| c as u64).collect(),
        };
        let sf = setup.to_frame();
        for ep in self.endpoints {
            bytes_select += sf.wire_len();
            ep.send(&sf)?;
        }
        if cand.is_empty() {
            let done = SelectDone { rounds: 0 }.to_frame();
            for ep in self.endpoints {
                bytes_select += done.wire_len();
                ep.send(&done)?;
            }
            metrics.bytes_select = bytes_select;
            return Ok(None);
        }
        let h = cand.len();

        // Candidate round: one shard-shaped secure sum over the gathered
        // shortlist columns (all of it already in the parties' cached
        // compressed statistics — no fresh O(N·M·K) compress).
        let (flat, _, rb) =
            self.collect_round(codec, shards + 1, shard_flat_len(self.k, self.t, h), dropouts)?;
        bytes_select += rb;
        let sums = unflatten_shard(self.k, self.t, h, &flat)?;
        let mut st =
            SelectState::new(&cx, cand, &sums, self.cfg.select_policy, self.cfg.select_alpha)?;

        for round in 1..=self.cfg.select_k {
            let picks = st.propose();
            if picks.iter().all(|p| p.is_none()) {
                break;
            }
            let promote = Promote {
                round: round as u64,
                variants: picks
                    .iter()
                    .map(|p| p.as_ref().map_or(LANE_INACTIVE, |p| p.variant as u64))
                    .collect(),
            };
            let pf = promote.to_frame();
            let mut round_bytes = 0u64;
            for ep in self.endpoints {
                round_bytes += pf.wire_len();
                ep.send(&pf)?;
            }
            let (flat, _, rb) =
                self.collect_round(codec, shards + 1 + round, promote.active() * h, dropouts)?;
            round_bytes += rb;
            st.fold(&picks, &flat)?;
            metrics.select_rounds += 1;
            metrics.bytes_max_select_round = metrics.bytes_max_select_round.max(round_bytes);
            bytes_select += round_bytes;
            results.push(SelectResult {
                round: round as u64,
                variants: promote.variants.clone(),
                traits: picks
                    .iter()
                    .map(|p| p.as_ref().map_or(LANE_INACTIVE, |p| p.trait_idx as u64))
                    .collect(),
                beta: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.beta)).collect(),
                se: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.se)).collect(),
                p: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.p)).collect(),
            });
        }
        let done = SelectDone { rounds: results.len() as u64 }.to_frame();
        for ep in self.endpoints {
            bytes_select += done.wire_len();
            ep.send(&done)?;
        }
        metrics.bytes_select = bytes_select;
        Ok(Some(st.into_output()))
    }

    /// Collect one secure-sum round (round 0 = base, s+1 = shard s) from
    /// every party and reduce it to the aggregate flat vector. Plaintext
    /// round 0 additionally returns the per-party R factors for TSQR.
    /// The third return value is the round's wire bytes, counted from
    /// the round's own frames (meter deltas would also pick up shards
    /// the parties have already streamed ahead).
    ///
    /// Dropout handling: a transport-dead party fails the round with a
    /// typed [`PartyDropped`] — except the Shamir share-sum leg, where
    /// every survivor's sum already folds in the dead party's
    /// contribution, so the round reconstructs exactly from any
    /// surviving quorum and records the death in `dropouts` instead.
    fn collect_round(
        &self,
        codec: &FixedCodec,
        round: usize,
        expect_len: usize,
        dropouts: &mut Vec<Dropout>,
    ) -> anyhow::Result<(Vec<f64>, Option<Vec<crate::linalg::Matrix>>, u64)> {
        let parties = self.endpoints.len();
        let mut round_bytes = 0u64;
        match self.cfg.backend {
            Backend::Plaintext => {
                let mut sum = vec![0.0f64; expect_len];
                let mut rs = Vec::with_capacity(parties);
                for (p, ep) in self.endpoints.iter().enumerate() {
                    let f = recv_or_dropped(ep, p, round)?;
                    round_bytes += f.wire_len();
                    let flat = if round == 0 {
                        let msg = PlainBase::from_frame(&f)?;
                        rs.push(msg.r);
                        msg.flat
                    } else {
                        let msg = PlainShard::from_frame(&f)?;
                        anyhow::ensure!(
                            msg.shard == (round - 1) as u64,
                            "plain shard out of order: {} vs {}",
                            msg.shard,
                            round - 1
                        );
                        msg.flat
                    };
                    anyhow::ensure!(flat.len() == expect_len, "flat length mismatch");
                    for (a, b) in sum.iter_mut().zip(&flat) {
                        *a += b;
                    }
                }
                let rs = if round == 0 { Some(rs) } else { None };
                Ok((sum, rs, round_bytes))
            }
            Backend::Masked => {
                let mut contributions = Vec::with_capacity(parties);
                for (p, ep) in self.endpoints.iter().enumerate() {
                    let f = recv_or_dropped(ep, p, round)?;
                    round_bytes += f.wire_len();
                    let enc = if round == 0 {
                        MaskedBase::from_frame(&f)?.enc
                    } else {
                        let msg = MaskedShard::from_frame(&f)?;
                        anyhow::ensure!(
                            msg.shard == (round - 1) as u64,
                            "masked shard out of order: {} vs {}",
                            msg.shard,
                            round - 1
                        );
                        msg.enc
                    };
                    anyhow::ensure!(enc.len() == expect_len, "masked length mismatch");
                    contributions.push(enc);
                }
                let ring_sum = aggregate_masked(&contributions);
                Ok((codec.decode_vec(&ring_sum), None, round_bytes))
            }
            Backend::Shamir { threshold } => {
                // Round trip 1: collect each party's share fan-out. A
                // death here is unrecoverable — the party's data for
                // this round was never shared with anyone — so it fails
                // typed, naming the party and round. A party already on
                // the dropout list fails fast without waiting out a
                // second recv timeout.
                let mut outgoing: Vec<Vec<Vec<u64>>> = Vec::with_capacity(parties);
                for (p, ep) in self.endpoints.iter().enumerate() {
                    if dropouts.iter().any(|d| d.party == p as u64) {
                        return Err(anyhow::Error::new(PartyDropped {
                            party: p as u64,
                            round: round as u64,
                        })
                        .context(format!(
                            "party {p} already dropped in an earlier round"
                        )));
                    }
                    let f = recv_or_dropped(ep, p, round)?;
                    round_bytes += f.wire_len();
                    let msg = ShamirOut::from_frame(&f)?;
                    anyhow::ensure!(
                        msg.round == round as u64,
                        "shamir round out of sync: {} vs {round}",
                        msg.round
                    );
                    anyhow::ensure!(msg.shares.len() == parties, "share fan-out mismatch");
                    outgoing.push(msg.shares);
                }
                // Route: party q receives the q-th vector from every p.
                for (q, ep) in self.endpoints.iter().enumerate() {
                    let routed: Vec<Vec<u64>> =
                        outgoing.iter().map(|o| o[q].clone()).collect();
                    let f = ShamirIn { round: round as u64, shares: routed }.to_frame();
                    round_bytes += f.wire_len();
                    ep.send(&f)?;
                }
                // Round trip 2: collect share-sums. Every survivor's
                // sum folds in ALL parties' round contributions (the
                // fan-out above reached everyone), so a death on this
                // leg loses nothing: reconstruct from the first
                // `threshold` *surviving* parties at their true
                // evaluation points — field-exact for any quorum, hence
                // bit-identical to the no-dropout run — and record the
                // death for the metrics' Degraded verdict.
                let quorum = threshold.min(parties);
                let mut sums: Vec<Option<Vec<u64>>> = vec![None; parties];
                for (p, ep) in self.endpoints.iter().enumerate() {
                    match ep.recv() {
                        Ok(f) if f.tag == TAG_ERROR => {
                            anyhow::bail!("party error: {}", parse_error(&f))
                        }
                        Ok(f) => {
                            round_bytes += f.wire_len();
                            let msg = ShamirSum::from_frame(&f)?;
                            anyhow::ensure!(
                                msg.round == round as u64,
                                "shamir sum round out of sync: {} vs {round}",
                                msg.round
                            );
                            anyhow::ensure!(
                                msg.sum.len() == expect_len,
                                "share sum length mismatch"
                            );
                            sums[p] = Some(msg.sum);
                        }
                        Err(_) => {
                            dropouts.push(Dropout { party: p as u64, round: round as u64 })
                        }
                    }
                }
                let live: Vec<usize> = (0..parties).filter(|&p| sums[p].is_some()).collect();
                if live.len() < quorum {
                    let d = dropouts.last().copied().unwrap_or(Dropout {
                        party: 0,
                        round: round as u64,
                    });
                    return Err(anyhow::Error::new(PartyDropped {
                        party: d.party,
                        round: round as u64,
                    })
                    .context(format!(
                        "quorum lost at round {round}: {} of {parties} share-sums \
                         arrived, threshold {quorum}",
                        live.len()
                    )));
                }
                let points: Vec<u64> = live[..quorum].iter().map(|&p| p as u64 + 1).collect();
                let vecs: Vec<&[u64]> =
                    live[..quorum].iter().map(|&p| sums[p].as_deref().unwrap()).collect();
                let flat: Vec<f64> = crate::mpc::shamir::reconstruct_sums(&points, &vecs)
                    .iter()
                    .map(|fe| fe.to_i64() as f64 / codec.scale())
                    .collect();
                Ok((flat, None, round_bytes))
            }
        }
    }

    fn total_bytes(&self) -> u64 {
        self.endpoints.iter().map(|e| e.meter().bytes()).sum()
    }
}

/// Receive a frame, converting a party-side ERROR report into an Err
/// and a dead transport (closed stream, recv timeout) into a typed
/// [`PartyDropped`] naming the party and secure-sum round.
fn recv_or_dropped<C: Channel>(ep: &C, party: usize, round: usize) -> anyhow::Result<Frame> {
    match ep.recv() {
        Ok(f) if f.tag == TAG_ERROR => anyhow::bail!("party error: {}", parse_error(&f)),
        Ok(f) => Ok(f),
        Err(e) => Err(anyhow::Error::new(PartyDropped {
            party: party as u64,
            round: round as u64,
        })
        .context(format!("recv from party {party}: {e:#}"))),
    }
}
