//! Leader-side protocol: session setup, contribution collection,
//! secure aggregation, combine, result broadcast.

use super::messages::*;
use crate::mpc::field::Fe;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::{aggregate_masked, PairwiseMasker};
use crate::mpc::Backend;
use crate::net::{Endpoint, Frame};
use crate::scan::{
    combine_compressed, unflatten_sum, CombineOptions, FlatLayout, RFactorMethod, ScanConfig,
    ScanOutput,
};
use crate::util::rng::Rng;
use std::time::Instant;

/// Phase timings + communication tallies for one session.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    /// wall time from COMPRESS broadcast to last contribution received
    pub compress_wall_s: f64,
    /// leader-side combine time (aggregation + factorization + epilogue)
    pub combine_s: f64,
    /// total session wall time
    pub total_s: f64,
    /// bytes over all leader↔party links (both directions)
    pub bytes_total: u64,
    /// messages over all links
    pub messages_total: u64,
    /// bytes of the result broadcast alone (the O(M) downlink)
    pub bytes_result: u64,
}

/// Leader state for one scan session over connected party endpoints.
pub struct Leader<'a> {
    pub endpoints: &'a [Endpoint],
    pub cfg: &'a ScanConfig,
    pub k: usize,
    pub m: usize,
}

impl<'a> Leader<'a> {
    /// Run the full session; returns scan output + metrics.
    pub fn run(&self, seed: u64) -> anyhow::Result<(ScanOutput, SessionMetrics)> {
        let t_start = Instant::now();
        let parties = self.endpoints.len();
        anyhow::ensure!(parties >= 1, "need at least one party");
        let mut metrics = SessionMetrics::default();
        let layout = FlatLayout { k: self.k, m: self.m };
        let codec = FixedCodec::new(self.cfg.frac_bits);
        let mut rng = Rng::new(seed);

        // SETUP: pairwise seeds (simulated DH — delivered over the
        // metered link so their cost is visible) + session params.
        let backend_code = match self.cfg.backend {
            Backend::Plaintext => 0u64,
            Backend::Masked => 1,
            Backend::Shamir { .. } => 2,
        };
        let threshold = match self.cfg.backend {
            Backend::Shamir { threshold } => threshold,
            _ => 0,
        };
        let seed_matrix = PairwiseMasker::session_seeds(parties, &mut rng);
        for (p, ep) in self.endpoints.iter().enumerate() {
            let setup = Setup {
                party_index: p as u64,
                parties: parties as u64,
                backend: backend_code,
                shamir_threshold: threshold as u64,
                frac_bits: self.cfg.frac_bits as u64,
                k: self.k as u64,
                m: self.m as u64,
                block_m: self.cfg.block_m as u64,
                seeds: seed_matrix[p].clone(),
            };
            ep.send(&setup.to_frame())?;
        }

        // COMPRESS kick-off.
        let t_compress = Instant::now();
        for ep in self.endpoints {
            ep.send(&Frame::new(TAG_COMPRESS))?;
        }

        // Collect contributions and aggregate by backend.
        let (agg, party_rs) = match self.cfg.backend {
            Backend::Plaintext => {
                let mut sum = vec![0.0f64; layout.len()];
                let mut rs = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    let (flat, r) = parse_plain_stats(&f)?;
                    anyhow::ensure!(flat.len() == layout.len(), "flat length mismatch");
                    for (a, b) in sum.iter_mut().zip(&flat) {
                        *a += b;
                    }
                    rs.push(r);
                }
                (unflatten_sum(layout, &sum)?, Some(rs))
            }
            Backend::Masked => {
                let mut contributions = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    let enc = parse_masked_stats(&f)?;
                    anyhow::ensure!(enc.len() == layout.len(), "masked length mismatch");
                    contributions.push(enc);
                }
                let ring_sum = aggregate_masked(&contributions);
                (unflatten_sum(layout, &codec.decode_vec(&ring_sum))?, None)
            }
            Backend::Shamir { threshold } => {
                // Round 1: collect each party's share fan-out.
                let mut outgoing: Vec<Vec<Vec<u64>>> = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    outgoing.push(parse_shamir_out(&f)?);
                }
                // Route: party q receives the q-th vector from every p.
                for (q, ep) in self.endpoints.iter().enumerate() {
                    let routed: Vec<Vec<u64>> =
                        outgoing.iter().map(|o| o[q].clone()).collect();
                    ep.send(&shamir_in_frame(&routed))?;
                }
                // Round 2: collect share-sums, reconstruct from the first
                // `threshold` parties (any quorum works; tested).
                let mut sums: Vec<Vec<u64>> = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    sums.push(parse_shamir_sum(&f)?);
                }
                let quorum = threshold.min(parties);
                let len = layout.len();
                let mut flat = vec![0.0f64; len];
                for (i, slot) in flat.iter_mut().enumerate() {
                    let shares: Vec<crate::mpc::shamir::Share> = (0..quorum)
                        .map(|q| crate::mpc::shamir::Share {
                            x: q as u64 + 1,
                            y: Fe(sums[q][i]),
                        })
                        .collect();
                    let fe = crate::mpc::shamir::reconstruct(&shares);
                    *slot = fe.to_i64() as f64 / codec.scale();
                }
                (unflatten_sum(layout, &flat)?, None)
            }
        };
        metrics.compress_wall_s = t_compress.elapsed().as_secs_f64();

        // COMBINE (leader-local, O(K³ + K²M), independent of N).
        let t_combine = Instant::now();
        let r_method = match (self.cfg.r_method, &party_rs) {
            (RFactorMethod::Auto, Some(_)) => RFactorMethod::Tsqr,
            (RFactorMethod::Auto, None) => RFactorMethod::Cholesky,
            (m, _) => m,
        };
        let out = combine_compressed(
            &agg,
            party_rs.as_deref(),
            CombineOptions { r_method },
        )?;
        metrics.combine_s = t_combine.elapsed().as_secs_f64();

        // RESULT broadcast + shutdown (the O(M) downlink).
        let bytes_before = self.total_bytes();
        for ep in self.endpoints {
            ep.send(&result_frame(&out.assoc.beta, &out.assoc.se))?;
            ep.send(&Frame::new(TAG_SHUTDOWN))?;
        }
        metrics.bytes_result = self.total_bytes() - bytes_before;
        metrics.total_s = t_start.elapsed().as_secs_f64();
        metrics.bytes_total = self.total_bytes();
        metrics.messages_total =
            self.endpoints.iter().map(|e| e.meter().messages()).sum();
        Ok((out, metrics))
    }

    fn total_bytes(&self) -> u64 {
        self.endpoints.iter().map(|e| e.meter().bytes()).sum()
    }
}

/// Receive a frame, converting a party-side ERROR report into an Err.
fn recv_ok(ep: &Endpoint) -> anyhow::Result<Frame> {
    let f = ep.recv()?;
    if f.tag == TAG_ERROR {
        anyhow::bail!("party error: {}", parse_error(&f));
    }
    Ok(f)
}
