//! Leader-side protocol: session setup, streaming per-shard contribution
//! collection, secure aggregation, incremental combine, result broadcast.
//!
//! The leader never materializes the `O((K+T)·M)` aggregate: each
//! shard's contributions are aggregated (`O(P·(K+T)·width)`), combined
//! through the [`ScanAssembler`] (`O((K² + KT)·width)`, the `QᵀX`
//! projection shared across all T traits), and dropped — while the
//! parties are already compressing the next shard. Only the `O(M·T)`
//! output vectors and the per-shard result frames accumulate. Partial results
//! are broadcast after the last shard so the single leader↔party stream
//! never carries traffic in both directions at once (no head-of-line
//! deadlock over TCP, any shard width).

use super::incremental::ScanAssembler;
use super::messages::*;
use crate::mpc::field::Fe;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::aggregate_masked;
use crate::mpc::masking::PairwiseMasker;
use crate::mpc::Backend;
use crate::net::{Channel, Frame, WireMessage};
use crate::scan::{
    base_flat_len, choose_candidates, shard_flat_len, unflatten_base, unflatten_shard,
    CombineContext, ScanConfig, ScanOutput, SelectOutput, SelectPolicy, SelectState, ShardPlan,
};
use crate::util::rng::Rng;
use std::time::Instant;

/// Phase timings + communication tallies for one session.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    /// wall time from COMPRESS broadcast to last contribution received
    pub compress_wall_s: f64,
    /// leader-side combine time (aggregation + factorization + epilogue)
    pub combine_s: f64,
    /// total session wall time
    pub total_s: f64,
    /// bytes over all leader↔party links (both directions)
    pub bytes_total: u64,
    /// messages over all links
    pub messages_total: u64,
    /// bytes of the result broadcast alone (the O(M) downlink)
    pub bytes_result: u64,
    /// number of variant shards the scan streamed over
    pub shards: usize,
    /// peak wire bytes of any single contribution round (base or shard),
    /// counted from the frames of that round — bounded by the shard
    /// width, not by M (the memory claim, E4'). Deterministic across
    /// transports and unaffected by parties streaming ahead.
    pub bytes_max_round: u64,
    /// completed SELECT promote rounds (0 when `select_k == 0` or
    /// nothing passed the stop rule)
    pub select_rounds: usize,
    /// total wire bytes of the SELECT phase uplink/control traffic
    /// (setup broadcast, candidate round, promote rounds, done frames);
    /// the post-scan SELECT_RESULT broadcast is counted in
    /// `bytes_result` alongside the shard results
    pub bytes_select: u64,
    /// peak wire bytes of any single SELECT promote round (PROMOTE
    /// broadcast + cross-product sums) — `O(lanes·H)`, independent of M
    /// (the E9 claim, asserted in `integration_select.rs`)
    pub bytes_max_select_round: u64,
}

/// Leader state for one scan session over connected party channels —
/// dedicated [`crate::net::Endpoint`]s (the classic deployment, session
/// id 0) or per-session [`crate::net::SessionChannel`]s of a multiplexed
/// connection (driven by [`super::session::SessionManager`]).
pub struct Leader<'a, C: Channel> {
    pub endpoints: &'a [C],
    pub cfg: &'a ScanConfig,
    pub k: usize,
    pub m: usize,
    /// trait count T (1 = classic single-trait scan)
    pub t: usize,
    /// protocol session id, delivered in SETUP; keys the parties'
    /// mask/share domains (0 on dedicated connections)
    pub session: u64,
}

impl<C: Channel> Leader<'_, C> {
    /// Run the full session; returns scan output, SELECT output (when
    /// `select_k > 0` and the shortlist was non-empty) and metrics.
    pub fn run(
        &self,
        seed: u64,
    ) -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
        match self.run_inner(seed) {
            Ok(v) => Ok(v),
            Err(e) => {
                // Best-effort protocol ErrorMsg so parties fail fast on a
                // leader-side protocol violation (duplicate/out-of-order
                // frames, bad lengths, …) instead of hanging on a dead
                // stream.
                for ep in self.endpoints {
                    let _ = ep.send(&error_frame(&format!("{e:#}")));
                }
                Err(e)
            }
        }
    }

    fn run_inner(
        &self,
        seed: u64,
    ) -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
        let t_start = Instant::now();
        let parties = self.endpoints.len();
        anyhow::ensure!(parties >= 1, "need at least one party");
        let mut metrics = SessionMetrics::default();
        let plan = ShardPlan::new(self.m, self.cfg.shard_m);
        metrics.shards = plan.count();
        let codec = FixedCodec::new(self.cfg.frac_bits);
        let mut rng = Rng::new(seed);

        // SETUP: pairwise seeds (simulated DH — delivered over the
        // metered link so their cost is visible) + session params.
        let backend_code = match self.cfg.backend {
            Backend::Plaintext => 0u64,
            Backend::Masked => 1,
            Backend::Shamir { .. } => 2,
        };
        let threshold = match self.cfg.backend {
            Backend::Shamir { threshold } => threshold,
            _ => 0,
        };
        let seed_matrix = PairwiseMasker::session_seeds(parties, &mut rng);
        for (p, ep) in self.endpoints.iter().enumerate() {
            let setup = Setup {
                session: self.session,
                party_index: p as u64,
                parties: parties as u64,
                backend: backend_code,
                shamir_threshold: threshold as u64,
                frac_bits: self.cfg.frac_bits as u64,
                k: self.k as u64,
                m: self.m as u64,
                t: self.t as u64,
                block_m: self.cfg.block_m as u64,
                shard_m: self.cfg.shard_m as u64,
                select_k: self.cfg.select_k as u64,
                seeds: seed_matrix[p].clone(),
            };
            ep.send(&setup.to_frame())?;
        }

        // COMPRESS kick-off.
        let t_compress = Instant::now();
        for ep in self.endpoints {
            ep.send(&Compress.to_frame())?;
        }

        // Base round: collect + aggregate the O(K² + KT) covariate and
        // trait stats.
        let (base_flat, party_rs, round_bytes) =
            self.collect_round(&codec, 0, base_flat_len(self.k, self.t))?;
        metrics.bytes_max_round = round_bytes;
        let base = unflatten_base(self.k, self.t, &base_flat)?;

        // Factorize the covariate block once (O(K³)). Auto resolution of
        // the R-factor method (TSQR when per-party factors exist) lives
        // in combine_base.
        let t0 = Instant::now();
        let mut asm = ScanAssembler::new(
            &base,
            party_rs.as_deref(),
            crate::scan::CombineOptions { r_method: self.cfg.r_method },
            self.m,
        )?;
        metrics.combine_s += t0.elapsed().as_secs_f64();

        // Shard rounds: aggregate + combine each shard as it arrives;
        // buffer the partial-result frames for the post-scan broadcast.
        // compress_wall_s stops at the last contribution received, so it
        // excludes the trailing combine (in pipelined runs the two phases
        // overlap, so compress_wall_s + combine_s may exceed total_s).
        let mut results = Vec::with_capacity(plan.count());
        let mut last_contribution = Instant::now();
        for range in plan.ranges() {
            let w = range.width();
            let (flat, _, round_bytes) = self.collect_round(
                &codec,
                range.index + 1,
                shard_flat_len(self.k, self.t, w),
            )?;
            last_contribution = Instant::now();
            metrics.bytes_max_round = metrics.bytes_max_round.max(round_bytes);
            let t0 = Instant::now();
            let sums = unflatten_shard(self.k, self.t, w, &flat)?;
            let parts = asm.add_shard(range, &sums)?;
            metrics.combine_s += t0.elapsed().as_secs_f64();
            // trait-major concatenation: [trait 0's w values | trait 1's | ...]
            let mut beta = Vec::with_capacity(w * self.t);
            let mut se = Vec::with_capacity(w * self.t);
            for part in &parts {
                beta.extend_from_slice(&part.beta);
                se.extend_from_slice(&part.se);
            }
            results.push(ShardResult {
                shard: range.index as u64,
                j0: range.j0 as u64,
                traits: self.t as u64,
                beta,
                se,
            });
        }
        metrics.compress_wall_s = last_contribution.duration_since(t_compress).as_secs_f64();

        let t0 = Instant::now();
        let (out, cx) = asm.finish_with_context()?;
        metrics.combine_s += t0.elapsed().as_secs_f64();

        // SELECT phase: iterative forward stepwise over the cached
        // context (rank-1 basis growth, O(lanes·H) traffic per round).
        let mut select_results: Vec<SelectResult> = Vec::new();
        let select = if self.cfg.select_k > 0 {
            self.select_phase(&codec, &out, cx, plan.count(), &mut metrics, &mut select_results)?
        } else {
            None
        };

        // Per-shard RESULT + per-round SELECT_RESULT broadcast + shutdown
        // (the O(M·T) downlink).
        let bytes_before = self.total_bytes();
        for ep in self.endpoints {
            for res in &results {
                ep.send(&res.to_frame())?;
            }
            for sr in &select_results {
                ep.send(&sr.to_frame())?;
            }
            ep.send(&Shutdown.to_frame())?;
        }
        metrics.bytes_result = self.total_bytes() - bytes_before;
        metrics.total_s = t_start.elapsed().as_secs_f64();
        metrics.bytes_total = self.total_bytes();
        metrics.messages_total =
            self.endpoints.iter().map(|e| e.meter().messages()).sum();
        Ok((out, select, metrics))
    }

    /// Run the SELECT rounds: broadcast the candidate shortlist, collect
    /// the shard-shaped candidate sums, then per round broadcast the
    /// promotions and fold the returning cross-product sums into the
    /// grown bases. Returns `None` when the shortlist is empty (nothing
    /// with a finite scan p-value).
    fn select_phase(
        &self,
        codec: &FixedCodec,
        out: &ScanOutput,
        cx: CombineContext,
        shards: usize,
        metrics: &mut SessionMetrics,
        results: &mut Vec<SelectResult>,
    ) -> anyhow::Result<Option<SelectOutput>> {
        let cand = choose_candidates(out, self.cfg.select_candidates.max(1));
        let lanes = match self.cfg.select_policy {
            SelectPolicy::Union => 1,
            SelectPolicy::PerTrait => self.t,
        };
        let mut bytes_select = 0u64;
        let setup = SelectSetup {
            k: self.cfg.select_k as u64,
            policy: self.cfg.select_policy.code(),
            lanes: lanes as u64,
            p_enter: self.cfg.select_alpha,
            candidates: cand.iter().map(|&c| c as u64).collect(),
        };
        let sf = setup.to_frame();
        for ep in self.endpoints {
            bytes_select += sf.wire_len();
            ep.send(&sf)?;
        }
        if cand.is_empty() {
            let done = SelectDone { rounds: 0 }.to_frame();
            for ep in self.endpoints {
                bytes_select += done.wire_len();
                ep.send(&done)?;
            }
            metrics.bytes_select = bytes_select;
            return Ok(None);
        }
        let h = cand.len();

        // Candidate round: one shard-shaped secure sum over the gathered
        // shortlist columns (all of it already in the parties' cached
        // compressed statistics — no fresh O(N·M·K) compress).
        let (flat, _, rb) =
            self.collect_round(codec, shards + 1, shard_flat_len(self.k, self.t, h))?;
        bytes_select += rb;
        let sums = unflatten_shard(self.k, self.t, h, &flat)?;
        let mut st =
            SelectState::new(&cx, cand, &sums, self.cfg.select_policy, self.cfg.select_alpha)?;

        for round in 1..=self.cfg.select_k {
            let picks = st.propose();
            if picks.iter().all(|p| p.is_none()) {
                break;
            }
            let promote = Promote {
                round: round as u64,
                variants: picks
                    .iter()
                    .map(|p| p.as_ref().map_or(LANE_INACTIVE, |p| p.variant as u64))
                    .collect(),
            };
            let pf = promote.to_frame();
            let mut round_bytes = 0u64;
            for ep in self.endpoints {
                round_bytes += pf.wire_len();
                ep.send(&pf)?;
            }
            let (flat, _, rb) =
                self.collect_round(codec, shards + 1 + round, promote.active() * h)?;
            round_bytes += rb;
            st.fold(&picks, &flat)?;
            metrics.select_rounds += 1;
            metrics.bytes_max_select_round = metrics.bytes_max_select_round.max(round_bytes);
            bytes_select += round_bytes;
            results.push(SelectResult {
                round: round as u64,
                variants: promote.variants.clone(),
                traits: picks
                    .iter()
                    .map(|p| p.as_ref().map_or(LANE_INACTIVE, |p| p.trait_idx as u64))
                    .collect(),
                beta: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.beta)).collect(),
                se: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.se)).collect(),
                p: picks.iter().map(|p| p.as_ref().map_or(f64::NAN, |p| p.p)).collect(),
            });
        }
        let done = SelectDone { rounds: results.len() as u64 }.to_frame();
        for ep in self.endpoints {
            bytes_select += done.wire_len();
            ep.send(&done)?;
        }
        metrics.bytes_select = bytes_select;
        Ok(Some(st.into_output()))
    }

    /// Collect one secure-sum round (round 0 = base, s+1 = shard s) from
    /// every party and reduce it to the aggregate flat vector. Plaintext
    /// round 0 additionally returns the per-party R factors for TSQR.
    /// The third return value is the round's wire bytes, counted from
    /// the round's own frames (meter deltas would also pick up shards
    /// the parties have already streamed ahead).
    fn collect_round(
        &self,
        codec: &FixedCodec,
        round: usize,
        expect_len: usize,
    ) -> anyhow::Result<(Vec<f64>, Option<Vec<crate::linalg::Matrix>>, u64)> {
        let parties = self.endpoints.len();
        let mut round_bytes = 0u64;
        match self.cfg.backend {
            Backend::Plaintext => {
                let mut sum = vec![0.0f64; expect_len];
                let mut rs = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    round_bytes += f.wire_len();
                    let flat = if round == 0 {
                        let msg = PlainBase::from_frame(&f)?;
                        rs.push(msg.r);
                        msg.flat
                    } else {
                        let msg = PlainShard::from_frame(&f)?;
                        anyhow::ensure!(
                            msg.shard == (round - 1) as u64,
                            "plain shard out of order: {} vs {}",
                            msg.shard,
                            round - 1
                        );
                        msg.flat
                    };
                    anyhow::ensure!(flat.len() == expect_len, "flat length mismatch");
                    for (a, b) in sum.iter_mut().zip(&flat) {
                        *a += b;
                    }
                }
                let rs = if round == 0 { Some(rs) } else { None };
                Ok((sum, rs, round_bytes))
            }
            Backend::Masked => {
                let mut contributions = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    round_bytes += f.wire_len();
                    let enc = if round == 0 {
                        MaskedBase::from_frame(&f)?.enc
                    } else {
                        let msg = MaskedShard::from_frame(&f)?;
                        anyhow::ensure!(
                            msg.shard == (round - 1) as u64,
                            "masked shard out of order: {} vs {}",
                            msg.shard,
                            round - 1
                        );
                        msg.enc
                    };
                    anyhow::ensure!(enc.len() == expect_len, "masked length mismatch");
                    contributions.push(enc);
                }
                let ring_sum = aggregate_masked(&contributions);
                Ok((codec.decode_vec(&ring_sum), None, round_bytes))
            }
            Backend::Shamir { threshold } => {
                // Round trip 1: collect each party's share fan-out.
                let mut outgoing: Vec<Vec<Vec<u64>>> = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    round_bytes += f.wire_len();
                    let msg = ShamirOut::from_frame(&f)?;
                    anyhow::ensure!(
                        msg.round == round as u64,
                        "shamir round out of sync: {} vs {round}",
                        msg.round
                    );
                    anyhow::ensure!(msg.shares.len() == parties, "share fan-out mismatch");
                    outgoing.push(msg.shares);
                }
                // Route: party q receives the q-th vector from every p.
                for (q, ep) in self.endpoints.iter().enumerate() {
                    let routed: Vec<Vec<u64>> =
                        outgoing.iter().map(|o| o[q].clone()).collect();
                    let f = ShamirIn { round: round as u64, shares: routed }.to_frame();
                    round_bytes += f.wire_len();
                    ep.send(&f)?;
                }
                // Round trip 2: collect share-sums, reconstruct from the
                // first `threshold` parties (any quorum works; tested).
                let mut sums: Vec<Vec<u64>> = Vec::with_capacity(parties);
                for ep in self.endpoints {
                    let f = recv_ok(ep)?;
                    round_bytes += f.wire_len();
                    let msg = ShamirSum::from_frame(&f)?;
                    anyhow::ensure!(
                        msg.round == round as u64,
                        "shamir sum round out of sync: {} vs {round}",
                        msg.round
                    );
                    anyhow::ensure!(msg.sum.len() == expect_len, "share sum length mismatch");
                    sums.push(msg.sum);
                }
                let quorum = threshold.min(parties);
                let mut flat = vec![0.0f64; expect_len];
                for (i, slot) in flat.iter_mut().enumerate() {
                    let shares: Vec<crate::mpc::shamir::Share> = (0..quorum)
                        .map(|q| crate::mpc::shamir::Share {
                            x: q as u64 + 1,
                            y: Fe(sums[q][i]),
                        })
                        .collect();
                    let fe = crate::mpc::shamir::reconstruct(&shares);
                    *slot = fe.to_i64() as f64 / codec.scale();
                }
                Ok((flat, None, round_bytes))
            }
        }
    }

    fn total_bytes(&self) -> u64 {
        self.endpoints.iter().map(|e| e.meter().bytes()).sum()
    }
}

/// Receive a frame, converting a party-side ERROR report into an Err.
fn recv_ok<C: Channel>(ep: &C) -> anyhow::Result<Frame> {
    let f = ep.recv()?;
    if f.tag == TAG_ERROR {
        anyhow::bail!("party error: {}", parse_error(&f));
    }
    Ok(f)
}
