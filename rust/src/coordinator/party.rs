//! Party-side protocol state machine.
//!
//! A party owns its local `(y, C, X)` and an [`Endpoint`] to the leader.
//! [`serve`] runs the sharded session: SETUP → COMPRESS → base
//! contribution → one contribution per variant shard → per-shard RESULT
//! frames → SHUTDOWN. The raw data never crosses the endpoint; only
//! compressed (and, in secure modes, encoded+masked/shared) statistics
//! do.
//!
//! ## Streaming and overlap
//!
//! In plaintext/masked mode the party pushes its shard contributions as
//! fast as it can compress them and only then drains the per-shard
//! results — so while the leader is aggregating + combining shard `s`,
//! this thread is already compressing shard `s+1` (the transport
//! buffers, or applies backpressure, in between). Peak memory here is
//! `O(N_p·K)` input plus `O(K·width)` per-shard statistics; the full
//! `O(K·M)` statistics block is never materialized. Shamir mode
//! interposes a share-routing round trip per shard, which serializes
//! parties per shard but keeps the same bounded-memory shape.
//!
//! The AOT artifact engine currently lowers the whole-`M` compress, so
//! in artifact mode the party computes the full block once and slices
//! shards out of it — protocol traffic stays shard-bounded, local
//! memory does not (tracked in ROADMAP: per-shard artifact lowering).

use super::messages::*;
use crate::gwas::PartyData;
use crate::mpc::field::Fe;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::PairwiseMasker;
use crate::mpc::shamir;
use crate::net::{Endpoint, WireMessage};
use crate::runtime::Engine;
use crate::scan::{
    compress_base, compress_variant_block, BaseStats, CompressedParty, ShardPlan, ShardRange,
    VariantBlockStats,
};

/// How a party computes its compress stage.
pub enum ComputeBackend {
    /// pure-Rust reference path
    Rust { threads: Option<usize> },
    /// AOT artifacts through the PJRT runtime
    Artifacts(Box<Engine>),
}

/// Per-session compute state: either stream shard-by-shard (pure Rust)
/// or slice a cached whole-`M` block (artifact engine).
enum CompressState<'a> {
    Streaming {
        data: &'a PartyData,
        block_m: usize,
        threads: Option<usize>,
    },
    Cached(Box<CompressedParty>),
}

impl CompressState<'_> {
    fn base(&self) -> BaseStats {
        match self {
            CompressState::Streaming { data, .. } => compress_base(&data.y, &data.c),
            CompressState::Cached(cp) => cp.base(),
        }
    }

    fn shard(&self, r: ShardRange) -> VariantBlockStats {
        match self {
            CompressState::Streaming { data, block_m, threads } => {
                compress_variant_block(&data.y, &data.c, &data.x, r.j0, r.j1, *block_m, *threads)
            }
            CompressState::Cached(cp) => cp.variant_block(r.j0, r.j1),
        }
    }
}

/// Result a party receives at the end of a session.
#[derive(Clone, Debug)]
pub struct PartyResult {
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
}

/// Run the party side of one scan session. Returns the assembled
/// broadcast result.
pub fn serve(
    endpoint: &Endpoint,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    match serve_inner(endpoint, data, compute) {
        Ok(r) => Ok(r),
        Err(e) => {
            // Best-effort error report so the leader can fail fast.
            let _ = endpoint.send(&error_frame(&format!("{e:#}")));
            Err(e)
        }
    }
}

fn serve_inner(
    endpoint: &Endpoint,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    let setup = Setup::from_frame(&endpoint.recv()?)?;
    anyhow::ensure!(setup.k as usize == data.c.cols, "setup K mismatch");
    anyhow::ensure!(setup.m as usize == data.x.cols, "setup M mismatch");
    let m = setup.m as usize;
    let plan = ShardPlan::new(m, setup.shard_m as usize);

    Compress::from_frame(&endpoint.recv()?)?;

    let state = match compute {
        ComputeBackend::Rust { threads } => CompressState::Streaming {
            data,
            block_m: setup.block_m as usize,
            threads: *threads,
        },
        ComputeBackend::Artifacts(engine) => CompressState::Cached(Box::new(
            engine.compress_party(&data.y, &data.c, &data.x)?,
        )),
    };

    let codec = FixedCodec::new(setup.frac_bits as u32);
    let base = state.base();

    // Backend-specific secure-sum context, shared by the base round and
    // every shard round.
    enum Secure {
        Plain,
        Masked(PairwiseMasker),
        Shamir {
            parties: usize,
            threshold: usize,
            rng: crate::util::rng::Rng,
        },
    }
    let mut secure = match setup.backend {
        0 => Secure::Plain,
        1 => Secure::Masked(PairwiseMasker::new(
            setup.party_index as usize,
            setup.parties as usize,
            setup.seeds.clone(),
        )),
        2 => Secure::Shamir {
            parties: setup.parties as usize,
            threshold: setup.shamir_threshold as usize,
            rng: crate::util::rng::Rng::new(
                setup.seeds.iter().fold(0x5A17u64, |a, &s| a ^ s.rotate_left(17))
                    ^ setup.party_index.wrapping_mul(0x9E3779B97F4A7C15),
            ),
        },
        b => anyhow::bail!("unknown backend {b}"),
    };

    // One secure-sum contribution round: round 0 carries the base stats,
    // round s+1 carries shard s.
    let mut contribute = |flat: &[f64], round: usize| -> anyhow::Result<()> {
        match &mut secure {
            Secure::Plain => {
                if round == 0 {
                    endpoint
                        .send(&PlainBase { flat: flat.to_vec(), r: base.r.clone() }.to_frame())?;
                } else {
                    endpoint.send(
                        &PlainShard { shard: (round - 1) as u64, flat: flat.to_vec() }
                            .to_frame(),
                    )?;
                }
            }
            Secure::Masked(masker) => {
                let mut enc = codec.encode_vec(flat)?;
                masker.mask_in_place(&mut enc);
                if round == 0 {
                    endpoint.send(&MaskedBase { enc }.to_frame())?;
                } else {
                    endpoint.send(&MaskedShard { shard: (round - 1) as u64, enc }.to_frame())?;
                }
            }
            Secure::Shamir { parties, threshold, rng } => {
                // Share the encoded vector to all parties via the leader.
                let secrets: Vec<Fe> = flat
                    .iter()
                    .map(|&v| Ok(Fe::from_i64(codec.encode(v)? as i64)))
                    .collect::<anyhow::Result<_>>()?;
                let share_vecs = shamir::share_vec(&secrets, *parties, *threshold, rng);
                // ship y-values only; x is implied by recipient index + 1
                let ys: Vec<Vec<u64>> = share_vecs
                    .iter()
                    .map(|sv| sv.iter().map(|s| s.y.0).collect())
                    .collect();
                endpoint.send(&ShamirOut { round: round as u64, shares: ys }.to_frame())?;
                // receive the shares routed to me, sum share-wise, return
                let incoming = ShamirIn::from_frame(&endpoint.recv()?)?;
                anyhow::ensure!(
                    incoming.round == round as u64,
                    "share routing out of sync (round {} vs {round})",
                    incoming.round
                );
                anyhow::ensure!(!incoming.shares.is_empty(), "no shares routed");
                let mut acc = vec![0u64; incoming.shares[0].len()];
                for sv in &incoming.shares {
                    // field addition per element
                    anyhow::ensure!(sv.len() == acc.len(), "share length mismatch");
                    for (a, &s) in acc.iter_mut().zip(sv) {
                        *a = Fe(*a).add(Fe(s)).0;
                    }
                }
                endpoint.send(&ShamirSum { round: round as u64, sum: acc }.to_frame())?;
            }
        }
        Ok(())
    };

    // Base round, then stream every shard. The leader consumes shards in
    // order while we keep compressing ahead of it.
    contribute(&base.flatten(), 0)?;
    for r in plan.ranges() {
        let flat = state.shard(r).flatten();
        contribute(&flat, r.index + 1)?;
    }

    // Drain the per-shard partial results in scan order.
    let mut beta = Vec::with_capacity(m);
    let mut se = Vec::with_capacity(m);
    for r in plan.ranges() {
        let sr = ShardResult::from_frame(&endpoint.recv()?)?;
        anyhow::ensure!(
            sr.shard == r.index as u64 && sr.j0 == r.j0 as u64,
            "shard result out of order: got shard {} at j0={}, expected shard {} at j0={}",
            sr.shard,
            sr.j0,
            r.index,
            r.j0
        );
        anyhow::ensure!(sr.beta.len() == r.width(), "shard result width mismatch");
        beta.extend_from_slice(&sr.beta);
        se.extend_from_slice(&sr.se);
    }

    Shutdown::from_frame(&endpoint.recv()?)?;
    Ok(PartyResult { beta, se })
}
