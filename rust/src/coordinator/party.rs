//! Party-side protocol state machine.
//!
//! A party owns its local `(Y, C, X)` — `Y` being the `N_p × T` trait
//! matrix, `T = 1` for a classic single-trait scan — and a frame
//! [`Channel`] to the leader (a dedicated [`crate::net::Endpoint`], or
//! one session of a multiplexed connection).
//! [`serve`] runs the sharded session: SETUP → COMPRESS →
//! base contribution → one contribution per variant shard → per-shard
//! RESULT frames → SHUTDOWN. The raw data never crosses the endpoint;
//! only compressed (and, in secure modes, encoded+masked/shared)
//! statistics do.
//!
//! ## Streaming and overlap
//!
//! In plaintext/masked mode the party pushes its shard contributions as
//! fast as it can compress them and only then drains the per-shard
//! results — so while the leader is aggregating + combining shard `s`,
//! this thread is already compressing shard `s+1` (the transport
//! buffers, or applies backpressure, in between). Peak memory here is
//! `O(N_p·(K+T))` input plus `O((K+T)·width)` per-shard statistics; the
//! full `O((K+T)·M)` statistics block is never materialized. Shamir mode
//! interposes a share-routing round trip per shard, which serializes
//! parties per shard but keeps the same bounded-memory shape.
//!
//! Artifact mode streams the same way: the parameterized kernel suite
//! ([`crate::runtime`]) serves a shard-width entry per shard
//! (`CompressState::Cached` dispatches it directly, with the lowering
//! cache de-duplicating canonical shapes), so peak artifact-side block
//! memory is `O(shard_m·N_p)` — no transient whole-`M` materialization
//! at compress time. SELECT rounds dispatch the gathered-columns and
//! cross-product entries through the same engine.

use super::messages::*;
use crate::gwas::PartyData;
use crate::mpc::field::Fe;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::PairwiseMasker;
use crate::mpc::shamir;
use crate::net::{Channel, Frame, WireMessage};
use crate::runtime::Engine;
use crate::scan::{
    compress_base_opts, compress_irls_base, compress_irls_shard, compress_variant_block,
    compress_variant_block_opts, cross_products, BaseStats, ShardPlan, ShardRange,
    VariantBlockStats,
};
use crate::util::threadpool::{effective_threads, parallel_map};
use std::sync::Arc;

/// How a party computes its compress stage.
pub enum ComputeBackend {
    /// pure-Rust reference path
    Rust { threads: Option<usize> },
    /// the artifact kernel suite (PJRT or reference executor — see
    /// [`crate::runtime::ArtifactExec`]). Shared (`Arc`) so a party
    /// service serving many concurrent sessions amortizes one engine —
    /// and its lowering cache — across all of them.
    Artifacts(Arc<Engine>),
}

/// Per-session compute state: stream shard-by-shard through the
/// pure-Rust kernels, or through the engine's cached (lowered-once,
/// executed-per-shard) parameterized artifact entries.
enum CompressState<'a> {
    Streaming {
        data: &'a PartyData,
        block_m: usize,
        threads: Option<usize>,
    },
    /// Artifact suite: each shard dispatches the shard-width
    /// `compress_x` entry directly; the engine's lowering cache keyed on
    /// canonical shapes makes shard `s+1` a cache hit of shard `s`.
    Cached {
        engine: &'a Engine,
        data: &'a PartyData,
    },
}

impl CompressState<'_> {
    fn base(&self) -> anyhow::Result<BaseStats> {
        match self {
            CompressState::Streaming { data, threads, .. } => {
                Ok(compress_base_opts(&data.ys, &data.c, None, *threads))
            }
            CompressState::Cached { engine, data } => {
                engine.compress_base(&data.ys, &data.c)
            }
        }
    }

    fn shard(&self, r: ShardRange) -> anyhow::Result<VariantBlockStats> {
        match self {
            CompressState::Streaming { data, block_m, threads } => Ok(compress_variant_block(
                &data.ys,
                &data.c,
                &data.x,
                r.j0,
                r.j1,
                *block_m,
                *threads,
            )),
            CompressState::Cached { engine, data } => {
                engine.compress_shard(&data.ys, &data.c, &data.x, r.j0, r.j1)
            }
        }
    }

    /// Like [`Self::shard`] but with intra-shard threading pinned to one
    /// worker — used when whole shards fan out across the pool, so the
    /// shard-level parallelism *is* the budget (no `threads²`
    /// oversubscription). Bit-identical to [`Self::shard`] by the
    /// canonical-fold contract.
    fn shard_single_threaded(&self, r: ShardRange) -> anyhow::Result<VariantBlockStats> {
        match self {
            CompressState::Streaming { data, block_m, .. } => {
                Ok(compress_variant_block_opts(
                    &data.ys,
                    &data.c,
                    &data.x,
                    r.j0,
                    r.j1,
                    *block_m,
                    None,
                    Some(1),
                ))
            }
            CompressState::Cached { .. } => self.shard(r),
        }
    }

    /// How many independent shards to compress concurrently. Streaming
    /// mode uses the compress worker budget; cached (artifact) mode
    /// stays sequential — each dispatch meters one resident canonical
    /// block, and the `O(shard_m·N_p)` peak-bytes contract is per block.
    fn shard_fanout(&self, nshards: usize) -> usize {
        match self {
            CompressState::Streaming { threads, .. } => {
                effective_threads(*threads).min(nshards)
            }
            CompressState::Cached { .. } => 1,
        }
    }
}

/// Result a party receives at the end of a session: per-trait β̂ / σ̂
/// vectors (index `[trait][variant]`; `T = 1` sessions have exactly one
/// entry each) plus the per-round SELECT results (empty when the
/// session ran without a SELECT phase).
#[derive(Clone, Debug)]
pub struct PartyResult {
    pub beta: Vec<Vec<f64>>,
    pub se: Vec<Vec<f64>>,
    pub select: Vec<SelectResult>,
}

/// Run the party side of one scan session. Returns the assembled
/// broadcast result. `endpoint` is a dedicated [`crate::net::Endpoint`]
/// or one [`crate::net::SessionChannel`] of a multiplexed connection.
pub fn serve<C: Channel>(
    endpoint: &C,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    match serve_inner(endpoint, data, compute) {
        Ok(r) => Ok(r),
        Err(e) => {
            // Best-effort error report so the leader can fail fast.
            let _ = endpoint.send(&error_frame(&format!("{e:#}")));
            Err(e)
        }
    }
}

fn serve_inner<C: Channel>(
    endpoint: &C,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    let setup = Setup::from_frame(&recv_checked(endpoint)?)?;
    anyhow::ensure!(setup.k as usize == data.c.cols, "setup K mismatch");
    anyhow::ensure!(setup.m as usize == data.x.cols, "setup M mismatch");
    anyhow::ensure!(setup.t as usize == data.ys.cols, "setup trait-count mismatch");
    let m = setup.m as usize;
    let t = setup.t as usize;
    let plan = ShardPlan::new(m, setup.shard_m as usize);
    for w in setup.done_shards.windows(2) {
        anyhow::ensure!(w[0] < w[1], "done shards must be strictly increasing");
    }
    for &s in &setup.done_shards {
        anyhow::ensure!((s as usize) < plan.count(), "done shard {s} beyond the shard plan");
    }
    anyhow::ensure!(setup.glm <= 1, "unknown glm code {}", setup.glm);
    if setup.glm == 1 {
        // Logistic mode preconditions, enforced before any data leaves:
        // no SELECT phase, no resume (both are linear-assembler
        // features), and strictly 0/1 traits — the IRLS weighted sums
        // are only meaningful (and only envelope-bounded) for binary y.
        anyhow::ensure!(
            setup.select_k == 0,
            "logistic scans do not support the SELECT phase"
        );
        anyhow::ensure!(
            setup.done_shards.is_empty(),
            "logistic scans do not support checkpoint resume"
        );
        for &v in &data.ys.data {
            anyhow::ensure!(
                v == 0.0 || v == 1.0,
                "logistic traits must be 0/1 (found {v}); generate the cohort \
                 with binary traits (--binary-traits)"
            );
        }
    }

    Compress::from_frame(&recv_checked(endpoint)?)?;

    let state = match compute {
        ComputeBackend::Rust { threads } => CompressState::Streaming {
            data,
            block_m: setup.block_m as usize,
            threads: *threads,
        },
        ComputeBackend::Artifacts(engine) => {
            CompressState::Cached { engine: engine.as_ref(), data }
        }
    };

    // The wire carries frac_bits as a u64; reject anything past the
    // codec's supported range instead of panicking on a hostile SETUP.
    let codec = FixedCodec::try_new(u32::try_from(setup.frac_bits).unwrap_or(u32::MAX))?;
    let base = state.base()?;

    // Backend-specific secure-sum context, shared by the base round and
    // every shard round.
    enum Secure {
        Plain,
        Masked(PairwiseMasker),
        Shamir {
            parties: usize,
            threshold: usize,
            rng: crate::util::rng::Rng,
        },
    }
    // Mask/share PRG streams are keyed by the session id, so concurrent
    // sessions multiplexed over one connection (or sharing seeds) stay
    // domain-separated.
    let mut secure = match setup.backend {
        0 => Secure::Plain,
        1 => Secure::Masked(PairwiseMasker::with_domain(
            setup.party_index as usize,
            setup.parties as usize,
            setup.seeds.clone(),
            setup.session,
        )),
        2 => Secure::Shamir {
            parties: setup.parties as usize,
            threshold: setup.shamir_threshold as usize,
            rng: shamir::session_rng(&setup.seeds, setup.party_index, setup.session),
        },
        b => anyhow::bail!("unknown backend {b}"),
    };

    // One secure-sum contribution round: round 0 carries the base stats,
    // round s+1 carries shard s.
    let mut contribute = |flat: &[f64], round: usize| -> anyhow::Result<()> {
        match &mut secure {
            Secure::Plain => {
                if round == 0 {
                    endpoint
                        .send(&PlainBase { flat: flat.to_vec(), r: base.r.clone() }.to_frame())?;
                } else {
                    endpoint.send(
                        &PlainShard { shard: (round - 1) as u64, flat: flat.to_vec() }
                            .to_frame(),
                    )?;
                }
            }
            Secure::Masked(masker) => {
                let mut enc = codec.encode_vec(flat)?;
                // Key the pad by the absolute protocol round, not the
                // call count: with checkpointed shards skipped on
                // resume, the remaining rounds must use exactly the
                // mask domains an uninterrupted run would — a pad
                // position never re-keys onto a different plaintext.
                masker.round = round as u64;
                masker.mask_in_place(&mut enc);
                if round == 0 {
                    endpoint.send(&MaskedBase { enc }.to_frame())?;
                } else {
                    endpoint.send(&MaskedShard { shard: (round - 1) as u64, enc }.to_frame())?;
                }
            }
            Secure::Shamir { parties, threshold, rng } => {
                // Share the encoded vector to all parties via the leader.
                let secrets: Vec<Fe> = flat
                    .iter()
                    .map(|&v| Ok(Fe::from_i64(codec.encode(v)? as i64)))
                    .collect::<anyhow::Result<_>>()?;
                // Per-round share randomness (the Shamir analogue of the
                // masked pad's absolute-round keying): skipped shards
                // never shift the polynomial stream onto different
                // secrets, so a resumed session reuses no randomness.
                let mut round_rng = rng.derive(round as u64);
                let share_vecs =
                    shamir::share_vec(&secrets, *parties, *threshold, &mut round_rng);
                // ship y-values only; x is implied by recipient index + 1
                let ys: Vec<Vec<u64>> = share_vecs
                    .iter()
                    .map(|sv| sv.iter().map(|s| s.y.0).collect())
                    .collect();
                endpoint.send(&ShamirOut { round: round as u64, shares: ys }.to_frame())?;
                // receive the shares routed to me, sum share-wise, return
                let incoming = ShamirIn::from_frame(&recv_checked(endpoint)?)?;
                anyhow::ensure!(
                    incoming.round == round as u64,
                    "share routing out of sync (round {} vs {round})",
                    incoming.round
                );
                anyhow::ensure!(!incoming.shares.is_empty(), "no shares routed");
                let mut acc = vec![0u64; incoming.shares[0].len()];
                for sv in &incoming.shares {
                    // field addition per element
                    anyhow::ensure!(sv.len() == acc.len(), "share length mismatch");
                    for (a, &s) in acc.iter_mut().zip(sv) {
                        *a = Fe(*a).add(Fe(s)).0;
                    }
                }
                endpoint.send(&ShamirSum { round: round as u64, sum: acc }.to_frame())?;
            }
        }
        Ok(())
    };

    // Base round, then stream every shard. The leader consumes shards in
    // order while we keep compressing ahead of it; in cached mode each
    // shard's columns are freed right after this send.
    contribute(&base.flatten(), 0)?;

    // Logistic mode: the linear shard stream and the SELECT phase are
    // replaced by the leader-driven IRLS loop — one weighted null-model
    // secure sum per broadcast iterate (absolute round = iteration,
    // 1-based) — followed by one *weighted* pass over the variant
    // shards at the final iterate (absolute round `iters + 1 + shard`).
    // The continued absolute numbering keeps every mask pad / share
    // polynomial domain-separated from the base round and from each
    // other. The result drain below is unchanged: the leader broadcasts
    // the same ShardResult frames either way.
    if setup.glm == 1 {
        let k = setup.k as usize;
        let irls = IrlsSetup::from_frame(&recv_checked(endpoint)?)?;
        // The cap bounds our round loop — a hostile leader cannot spin
        // this party through unbounded recompute rounds.
        anyhow::ensure!(
            irls.max_iter <= 100_000,
            "implausible IRLS iteration cap {}",
            irls.max_iter
        );
        let mut rounds_seen = 0u64;
        let (iters, final_beta) = loop {
            let f = recv_checked(endpoint)?;
            match f.tag {
                TAG_IRLS_ROUND => {
                    let r = IrlsRound::from_frame(&f)?;
                    anyhow::ensure!(
                        r.iter <= irls.max_iter,
                        "IRLS round {} beyond the advertised cap {}",
                        r.iter,
                        irls.max_iter
                    );
                    anyhow::ensure!(
                        r.iter == rounds_seen + 1,
                        "IRLS round out of order: {} after {rounds_seen}",
                        r.iter
                    );
                    anyhow::ensure!(
                        r.beta.len() == t * k,
                        "IRLS iterate length {} != T·K",
                        r.beta.len()
                    );
                    rounds_seen = r.iter;
                    let flat = match compute {
                        ComputeBackend::Rust { threads } => {
                            compress_irls_base(&data.ys, &data.c, &r.beta, None, *threads)
                        }
                        ComputeBackend::Artifacts(engine) => {
                            engine.compress_irls_base(&data.ys, &data.c, &r.beta)?
                        }
                    };
                    contribute(&flat, r.iter as usize)?;
                }
                TAG_IRLS_DONE => {
                    let d = IrlsDone::from_frame(&f)?;
                    anyhow::ensure!(
                        d.iters == rounds_seen && rounds_seen >= 1,
                        "IRLS_DONE iteration count {} != rounds served {rounds_seen}",
                        d.iters
                    );
                    anyhow::ensure!(
                        d.beta.len() == t * k,
                        "final IRLS iterate length {} != T·K",
                        d.beta.len()
                    );
                    break (d.iters as usize, d.beta);
                }
                other => anyhow::bail!("unexpected frame tag {other} in IRLS phase"),
            }
        };
        for r in plan.ranges() {
            let flat = match compute {
                ComputeBackend::Rust { threads } => compress_irls_shard(
                    &data.ys,
                    &data.c,
                    &data.x,
                    &final_beta,
                    r.j0,
                    r.j1,
                    None,
                    *threads,
                ),
                ComputeBackend::Artifacts(engine) => engine.compress_irls_shard(
                    &data.ys,
                    &data.c,
                    &data.x,
                    &final_beta,
                    r.j0,
                    r.j1,
                )?,
            };
            contribute(&flat, iters + 1 + r.index)?;
        }
    }

    // Shards the leader restored from a checkpoint need no fresh
    // contribution — drop them from the compress stream. Round numbers
    // stay absolute (r.index + 1), so the remaining rounds keep the
    // mask/share domains of an uninterrupted run, and the result drain
    // below still expects every shard's broadcast frame. (Logistic
    // sessions contributed their weighted rounds above — nothing left
    // to stream here.)
    let ranges: Vec<ShardRange> = if setup.glm == 1 {
        Vec::new()
    } else {
        plan.ranges()
            .filter(|r| setup.done_shards.binary_search(&(r.index as u64)).is_err())
            .collect()
    };
    let fanout = state.shard_fanout(ranges.len());
    if fanout <= 1 {
        for r in ranges {
            let flat = state.shard(r)?.flatten();
            contribute(&flat, r.index + 1)?;
        }
    } else {
        // Independent shards fan out across the worker pool in bounded
        // waves; contributions still go out strictly in shard order (the
        // wire protocol and the leader's streaming consumption are
        // unchanged, including Shamir's per-round share round trip), and
        // a wave's statistics are freed before the next wave compresses.
        for wave in ranges.chunks(fanout) {
            let flats = parallel_map(wave.len(), Some(fanout), |i| {
                state.shard_single_threaded(wave[i]).map(|vb| vb.flatten())
            });
            for (r, flat) in wave.iter().zip(flats) {
                contribute(&flat?, r.index + 1)?;
            }
        }
    }

    // SELECT phase: the leader drives, we answer. Round `shards + 1`
    // carries the candidate shortlist's cached column statistics (a
    // shard-shaped flatten over the gathered columns — no fresh compress
    // of the full block); each PROMOTE round `r` answers with the
    // promoted columns' cross-products against the shortlist, an
    // O(lanes·H) vector independent of M.
    let mut select_rounds = 0u64;
    if setup.select_k > 0 {
        let ss = SelectSetup::from_frame(&recv_checked(endpoint)?)?;
        let idx: Vec<usize> = ss.candidates.iter().map(|&c| c as usize).collect();
        for &j in &idx {
            anyhow::ensure!(j < m, "candidate {j} beyond M={m}");
        }
        if idx.is_empty() {
            select_rounds = SelectDone::from_frame(&recv_checked(endpoint)?)?.rounds;
            anyhow::ensure!(select_rounds == 0, "select rounds without candidates");
        } else {
            let xs = data.x.gather_cols(&idx);
            // Candidate round: gathered-shortlist statistics — the
            // `compress_x` entry family in artifact mode, the streaming
            // kernel otherwise.
            let vb = match compute {
                ComputeBackend::Rust { threads } => compress_variant_block(
                    &data.ys,
                    &data.c,
                    &xs,
                    0,
                    xs.cols,
                    setup.block_m as usize,
                    *threads,
                ),
                ComputeBackend::Artifacts(engine) => {
                    engine.compress_gathered(&data.ys, &data.c, &xs)?
                }
            };
            contribute(&vb.flatten(), plan.count() + 1)?;
            loop {
                let f = recv_checked(endpoint)?;
                match f.tag {
                    TAG_PROMOTE => {
                        let pr = Promote::from_frame(&f)?;
                        anyhow::ensure!(
                            pr.variants.len() as u64 == ss.lanes,
                            "promote lane-count mismatch"
                        );
                        let mut flat = Vec::with_capacity(pr.active() * idx.len());
                        for &v in &pr.variants {
                            if v == LANE_INACTIVE {
                                continue;
                            }
                            anyhow::ensure!((v as usize) < m, "promoted variant beyond M");
                            // promote round: the gathered-columns SELECT
                            // entry in artifact mode
                            let cp = match compute {
                                ComputeBackend::Rust { .. } => {
                                    cross_products(&data.x, v as usize, &xs)
                                }
                                ComputeBackend::Artifacts(engine) => {
                                    engine.cross_products(&data.x, v as usize, &xs)?
                                }
                            };
                            flat.extend(cp);
                        }
                        contribute(&flat, plan.count() + 1 + pr.round as usize)?;
                    }
                    TAG_SELECT_DONE => {
                        select_rounds = SelectDone::from_frame(&f)?.rounds;
                        break;
                    }
                    other => anyhow::bail!("unexpected frame tag {other} in SELECT phase"),
                }
            }
        }
    }

    // Drain the per-shard partial results in scan order, de-interleaving
    // the trait-major frames into per-trait vectors.
    let mut beta = vec![Vec::with_capacity(m); t];
    let mut se = vec![Vec::with_capacity(m); t];
    for r in plan.ranges() {
        let sr = ShardResult::from_frame(&recv_checked(endpoint)?)?;
        anyhow::ensure!(
            sr.shard == r.index as u64 && sr.j0 == r.j0 as u64,
            "shard result out of order: got shard {} at j0={}, expected shard {} at j0={}",
            sr.shard,
            sr.j0,
            r.index,
            r.j0
        );
        anyhow::ensure!(sr.traits as usize == t, "shard result trait-count mismatch");
        anyhow::ensure!(sr.width() == r.width(), "shard result width mismatch");
        for tt in 0..t {
            beta[tt].extend_from_slice(sr.beta_for(tt));
            se[tt].extend_from_slice(sr.se_for(tt));
        }
    }

    // Then the per-round SELECT results announced by SELECT_DONE.
    let mut select = Vec::with_capacity(select_rounds as usize);
    for r in 0..select_rounds {
        let sr = SelectResult::from_frame(&recv_checked(endpoint)?)?;
        anyhow::ensure!(sr.round == r + 1, "select result out of order");
        select.push(sr);
    }

    Shutdown::from_frame(&recv_checked(endpoint)?)?;
    Ok(PartyResult { beta, se, select })
}

/// Receive a frame, converting a leader-side ERROR broadcast into an Err.
fn recv_checked<C: Channel>(ep: &C) -> anyhow::Result<Frame> {
    let f = ep.recv()?;
    if f.tag == TAG_ERROR {
        anyhow::bail!("leader error: {}", parse_error(&f));
    }
    Ok(f)
}
