//! Party-side protocol state machine.
//!
//! A party owns its local `(y, C, X)` and an [`Endpoint`] to the leader.
//! [`serve`] runs the session: SETUP → COMPRESS → backend-specific
//! contribution → (shamir share routing) → RESULT → SHUTDOWN. The raw
//! data never crosses the endpoint; only compressed (and, in secure
//! modes, encoded+masked/shared) statistics do.

use super::messages::*;
use crate::gwas::PartyData;
use crate::mpc::field::Fe;
use crate::mpc::fixed::FixedCodec;
use crate::mpc::masking::PairwiseMasker;
use crate::mpc::shamir;
use crate::net::Endpoint;
use crate::runtime::Engine;
use crate::scan::{compress_party, flatten_for_sum, CompressedParty};

/// How a party computes its compress stage.
pub enum ComputeBackend {
    /// pure-Rust reference path
    Rust { threads: Option<usize> },
    /// AOT artifacts through the PJRT runtime
    Artifacts(Box<Engine>),
}

impl ComputeBackend {
    fn compress(
        &self,
        data: &PartyData,
        block_m: usize,
    ) -> anyhow::Result<CompressedParty> {
        match self {
            ComputeBackend::Rust { threads } => {
                Ok(compress_party(&data.y, &data.c, &data.x, block_m, *threads))
            }
            ComputeBackend::Artifacts(engine) => engine.compress_party(&data.y, &data.c, &data.x),
        }
    }
}

/// Result a party receives at the end of a session.
#[derive(Clone, Debug)]
pub struct PartyResult {
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
}

/// Run the party side of one scan session. Returns the broadcast result.
pub fn serve(
    endpoint: &Endpoint,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    match serve_inner(endpoint, data, compute) {
        Ok(r) => Ok(r),
        Err(e) => {
            // Best-effort error report so the leader can fail fast.
            let _ = endpoint.send(&error_frame(&format!("{e:#}")));
            Err(e)
        }
    }
}

fn serve_inner(
    endpoint: &Endpoint,
    data: &PartyData,
    compute: &ComputeBackend,
) -> anyhow::Result<PartyResult> {
    let setup = Setup::from_frame(&endpoint.recv()?)?;
    anyhow::ensure!(setup.k as usize == data.c.cols, "setup K mismatch");
    anyhow::ensure!(setup.m as usize == data.x.cols, "setup M mismatch");

    let f = endpoint.recv()?;
    anyhow::ensure!(f.tag == TAG_COMPRESS, "expected COMPRESS, got {}", f.tag);

    let cp = compute.compress(data, setup.block_m as usize)?;
    let (_, flat) = flatten_for_sum(&cp);
    let codec = FixedCodec::new(setup.frac_bits as u32);

    match setup.backend {
        0 => {
            // plaintext: flat stats + R_p for the TSQR combine
            endpoint.send(&plain_stats_frame(&flat, &cp.r))?;
        }
        1 => {
            // masked secure aggregation
            let mut enc = codec.encode_vec(&flat)?;
            let mut masker = PairwiseMasker::new(
                setup.party_index as usize,
                setup.parties as usize,
                setup.seeds.clone(),
            );
            masker.mask_in_place(&mut enc);
            endpoint.send(&masked_stats_frame(&enc))?;
        }
        2 => {
            // Shamir: share the encoded vector to all parties via leader
            let parties = setup.parties as usize;
            let threshold = setup.shamir_threshold as usize;
            let mut rng = crate::util::rng::Rng::new(
                setup.seeds.iter().fold(0x5A17u64, |a, &s| a ^ s.rotate_left(17))
                    ^ setup.party_index.wrapping_mul(0x9E3779B97F4A7C15),
            );
            let secrets: Vec<Fe> = flat
                .iter()
                .map(|&v| Ok(Fe::from_i64(codec.encode(v)? as i64)))
                .collect::<anyhow::Result<_>>()?;
            let share_vecs = shamir::share_vec(&secrets, parties, threshold, &mut rng);
            // ship y-values only; x is implied by recipient index + 1
            let ys: Vec<Vec<u64>> = share_vecs
                .iter()
                .map(|sv| sv.iter().map(|s| s.y.0).collect())
                .collect();
            endpoint.send(&shamir_out_frame(&ys))?;
            // receive the shares routed to me, sum share-wise, return
            let incoming = parse_shamir_in(&endpoint.recv()?)?;
            anyhow::ensure!(!incoming.is_empty(), "no shares routed");
            let mut acc = vec![0u64; incoming[0].len()];
            for sv in &incoming {
                // field addition per element
                anyhow::ensure!(sv.len() == acc.len(), "share length mismatch");
                for (a, &s) in acc.iter_mut().zip(sv) {
                    *a = Fe(*a).add(Fe(s)).0;
                }
            }
            endpoint.send(&shamir_sum_frame(&acc))?;
        }
        b => anyhow::bail!("unknown backend {b}"),
    }

    let (beta, se) = parse_result(&endpoint.recv()?)?;
    let f = endpoint.recv()?;
    anyhow::ensure!(f.tag == TAG_SHUTDOWN, "expected SHUTDOWN");
    Ok(PartyResult { beta, se })
}
