//! Multi-party coordinator — the paper's system contribution.
//!
//! The leader ([`leader`]) orchestrates sessions over byte-metered
//! endpoints; parties ([`party`]) run compress-within locally (pure Rust
//! or the AOT artifacts) and participate in the secure combine. Sessions
//! stream over a variant-shard plan ([`crate::scan::ShardPlan`],
//! [`crate::scan::ScanConfig::shard_m`]): one secure-sum round per
//! shard, parties compressing shard `s+1` while the leader combines
//! shard `s`, with the single-shot protocol as the one-shard degenerate
//! case. [`run_multi_party_scan`] wires an in-process deployment (one
//! thread per party), which is also what the benches and examples drive;
//! `--transport tcp` in the launcher swaps in localhost sockets with the
//! same protocol bytes.
//!
//! Multiplexed deployments ([`session`], `--sessions N`) run many
//! concurrent scan+SELECT sessions over *one* shared connection pair
//! per party: a leader-side [`SessionManager`] with a bounded worker
//! pool, party-side [`party_service`]s sharing one artifact engine, and
//! session-keyed mask domains — the same protocol state machines over
//! [`crate::net::SessionChannel`]s instead of dedicated endpoints.
//!
//! Scan-as-a-service ([`daemon`], `dash serve`) puts those batches
//! behind an HTTP/JSON control plane: bounded admission (429 +
//! `Retry-After`, per-tenant quotas), typed job lifecycle, cooperative
//! cancellation, and checkpoint GC for jobs that never finish.

pub mod checkpoint;
pub mod daemon;
pub mod messages;
pub mod party;
pub mod leader;
pub mod incremental;
pub mod session;

pub use daemon::{result_fingerprint, Daemon, DaemonOptions, JobStatus};
pub use incremental::{IncrementalAggregate, ScanAssembler};
pub use leader::{Dropout, Leader, PartyDropped, SessionMetrics};
pub use party::{ComputeBackend, PartyResult};
pub use session::{
    party_service, run_session_batch, BatchOptions, CancelToken, SessionBatchResult,
    SessionCancelled, SessionManager, SessionPanicked, SessionRun, SessionSpec, SessionState,
    SessionStatus,
};

use crate::gwas::Cohort;
use crate::net::{duplex_pair, tcp_pair, tcp_stream_pair, ByteMeter, MuxOptions, Reactor};
use crate::runtime::{EngineOptions, KernelMeter};
use crate::scan::{ScanConfig, ScanOutput, SelectOutput};

/// Which transport an in-process deployment uses between leader and
/// parties.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    InProc,
    /// localhost TCP, one blocking pump thread per shared connection
    Tcp,
    /// localhost TCP driven by one epoll readiness thread for every
    /// connection ([`crate::net::Reactor`]); linux only
    Reactor,
}

/// Result of [`run_multi_party_scan`].
pub struct MultiPartyScanResult {
    pub output: ScanOutput,
    /// SELECT-phase output (forward stepwise), present when
    /// `ScanConfig::select_k > 0` and the candidate shortlist was
    /// non-empty
    pub select: Option<SelectOutput>,
    pub metrics: SessionMetrics,
    /// per-party link byte counts (uplink + downlink)
    pub party_bytes: Vec<u64>,
    /// per-party artifact kernel-suite telemetry (lowering cache, pass
    /// counts, peak resident block bytes); all-zero for Rust-path
    /// sessions
    pub party_kernels: Vec<KernelMeter>,
}

/// Run a full multi-party scan over a cohort with one thread per party.
pub fn run_multi_party_scan(
    cohort: &Cohort,
    cfg: &ScanConfig,
) -> anyhow::Result<MultiPartyScanResult> {
    run_multi_party_scan_t(cohort, cfg, Transport::InProc, 0xDA5 << 16)
}

/// As [`run_multi_party_scan`] with explicit transport and session seed.
pub fn run_multi_party_scan_t(
    cohort: &Cohort,
    cfg: &ScanConfig,
    transport: Transport,
    seed: u64,
) -> anyhow::Result<MultiPartyScanResult> {
    if transport == Transport::Reactor {
        return run_multi_party_scan_reactor(cohort, cfg, seed);
    }
    let parties = cohort.parties.len();
    let k = cohort.k();
    let m = cohort.m();
    let t = cohort.t();

    let mut leader_eps = Vec::with_capacity(parties);
    let mut party_eps = Vec::with_capacity(parties);
    let mut meters = Vec::with_capacity(parties);
    for _ in 0..parties {
        let meter = ByteMeter::new();
        let (l, p) = match transport {
            Transport::InProc => duplex_pair(meter.clone()),
            Transport::Tcp => tcp_pair(meter.clone())?,
            Transport::Reactor => unreachable!("dispatched above"),
        };
        leader_eps.push(l);
        party_eps.push(p);
        meters.push(meter);
    }

    let cfg2 = cfg.clone();
    let kernel_meters: Vec<KernelMeter> = (0..parties).map(|_| KernelMeter::new()).collect();
    let output = std::thread::scope(
        |s| -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
            let mut handles = Vec::with_capacity(parties);
            for (idx, ep) in party_eps.into_iter().enumerate() {
                let data = &cohort.parties[idx];
                let cfg = &cfg2;
                let kernel_meter = kernel_meters[idx].clone();
                handles.push(s.spawn(move || -> anyhow::Result<PartyResult> {
                    let compute = if cfg.use_artifacts {
                        // each party owns its engine (PJRT handles are
                        // !Send); telemetry flows out via the shared meter
                        party::ComputeBackend::Artifacts(std::sync::Arc::new(
                            crate::runtime::Engine::open(&EngineOptions {
                                dir: cfg.artifacts_dir.clone(),
                                exec: cfg.artifact_exec,
                                policy: cfg.entry_policy(),
                                meter: kernel_meter,
                                threads: cfg.effective_compress_threads(),
                            })?,
                        ))
                    } else {
                        party::ComputeBackend::Rust {
                            threads: cfg.effective_compress_threads(),
                        }
                    };
                    party::serve(&ep, data, &compute)
                }));
            }
            let leader = Leader { endpoints: &leader_eps, cfg: &cfg2, k, m, t, session: 0 };
            let out = leader.run(seed);
            for (i, h) in handles.into_iter().enumerate() {
                let joined = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("party {i} thread panicked"))?;
                joined.map_err(|e| anyhow::anyhow!("party {i}: {e:#}"))?;
            }
            out
        },
    )?;

    Ok(MultiPartyScanResult {
        output: output.0,
        select: output.1,
        metrics: output.2,
        party_bytes: meters.iter().map(|m| m.bytes()).collect(),
        party_kernels: kernel_meters,
    })
}

/// Reactor deployment of the classic scan: one epoll readiness thread
/// drives every party's connection, the protocol running as session 0
/// of a driven [`crate::net::SessionMux`] pair per party — the
/// unchanged leader and party state machines over
/// [`crate::net::SessionChannel`]s. Frames gain the 12-byte v2 session
/// envelope, so byte totals sit above the dedicated-connection runs by
/// exactly `frames × FRAME_V2_OVERHEAD` plus the teardown handshake.
fn run_multi_party_scan_reactor(
    cohort: &Cohort,
    cfg: &ScanConfig,
    seed: u64,
) -> anyhow::Result<MultiPartyScanResult> {
    let parties = cohort.parties.len();
    let k = cohort.k();
    let m = cohort.m();
    let t = cohort.t();

    let reactor = Reactor::new()?;
    let mut leader_muxes = Vec::with_capacity(parties);
    let mut party_muxes = Vec::with_capacity(parties);
    let mut meters = Vec::with_capacity(parties);
    for p in 0..parties {
        let meter = ByteMeter::new();
        let (ls, ps) = tcp_stream_pair()?;
        // the connection meter lives on the leader-side handle: local
        // sends plus decoded inbound frames count both directions once
        leader_muxes.push(session::reactor_mux(
            &reactor,
            ls,
            MuxOptions { accept: false, ..Default::default() },
            meter.clone(),
            p,
            None,
        )?);
        party_muxes.push(session::reactor_mux(
            &reactor,
            ps,
            MuxOptions { accept: true, ..Default::default() },
            ByteMeter::new(),
            p,
            None,
        )?);
        meters.push(meter);
    }
    let mut leader_chs = Vec::with_capacity(parties);
    for mux in &leader_muxes {
        leader_chs.push(mux.open(0)?);
    }

    let cfg2 = cfg.clone();
    let kernel_meters: Vec<KernelMeter> = (0..parties).map(|_| KernelMeter::new()).collect();
    let output = std::thread::scope(
        |s| -> anyhow::Result<(ScanOutput, Option<SelectOutput>, SessionMetrics)> {
            let mut handles = Vec::with_capacity(parties);
            for (idx, pmux) in party_muxes.iter().enumerate() {
                let data = &cohort.parties[idx];
                let cfg = &cfg2;
                let kernel_meter = kernel_meters[idx].clone();
                handles.push(s.spawn(move || -> anyhow::Result<PartyResult> {
                    let compute = if cfg.use_artifacts {
                        party::ComputeBackend::Artifacts(std::sync::Arc::new(
                            crate::runtime::Engine::open(&EngineOptions {
                                dir: cfg.artifacts_dir.clone(),
                                exec: cfg.artifact_exec,
                                policy: cfg.entry_policy(),
                                meter: kernel_meter,
                                threads: cfg.effective_compress_threads(),
                            })?,
                        ))
                    } else {
                        party::ComputeBackend::Rust {
                            threads: cfg.effective_compress_threads(),
                        }
                    };
                    let ch = pmux.accept()?.ok_or_else(|| {
                        anyhow::anyhow!("connection shut down before the session arrived")
                    })?;
                    let res = party::serve(&ch, data, &compute);
                    // orderly teardown: wait for the leader's shutdown,
                    // then answer it
                    while let Some(stale) = pmux.accept()? {
                        drop(stale);
                    }
                    pmux.shutdown();
                    pmux.join();
                    res
                }));
            }
            let leader = Leader { endpoints: &leader_chs, cfg: &cfg2, k, m, t, session: 0 };
            let out = leader.run(seed);
            for mux in leader_muxes.iter() {
                mux.shutdown();
            }
            for (i, h) in handles.into_iter().enumerate() {
                let joined = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("party {i} thread panicked"))?;
                joined.map_err(|e| anyhow::anyhow!("party {i}: {e:#}"))?;
            }
            for mux in leader_muxes.iter() {
                mux.join();
            }
            out
        },
    )?;
    reactor.shutdown();

    Ok(MultiPartyScanResult {
        output: output.0,
        select: output.1,
        metrics: output.2,
        party_bytes: meters.iter().map(|m| m.bytes()).collect(),
        party_kernels: kernel_meters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::{generate_cohort, pool_cohort, CohortSpec};
    use crate::linalg::rel_err;
    use crate::mpc::Backend;
    use crate::scan::{combine_compressed, compress_party, flatten_for_sum, unflatten_sum,
        CombineOptions, RFactorMethod};

    fn pooled_oracle(cohort: &crate::gwas::Cohort) -> crate::scan::ScanOutput {
        let pooled = pool_cohort(cohort);
        let cp = compress_party(&pooled.ys, &pooled.c, &pooled.x, 64, Some(2));
        let (layout, flat) = flatten_for_sum(&cp);
        let agg = unflatten_sum(layout, &flat).unwrap();
        combine_compressed(
            &agg,
            Some(std::slice::from_ref(&cp.r)),
            CombineOptions { r_method: RFactorMethod::Tsqr },
        )
        .unwrap()
    }

    fn small_cfg(backend: Backend) -> ScanConfig {
        ScanConfig { backend, block_m: 64, threads: Some(2), ..ScanConfig::default() }
    }

    #[test]
    fn plaintext_backend_matches_pooled_oracle() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 160);
        let res =
            run_multi_party_scan(&cohort, &small_cfg(Backend::Plaintext)).unwrap();
        let oracle = pooled_oracle(&cohort);
        assert!(rel_err(&res.output.assoc[0].beta, &oracle.assoc[0].beta) < 1e-10);
        assert!(rel_err(&res.output.assoc[0].se, &oracle.assoc[0].se) < 1e-10);
    }

    #[test]
    fn masked_backend_matches_oracle_to_fixed_point() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 161);
        let res = run_multi_party_scan(&cohort, &small_cfg(Backend::Masked)).unwrap();
        let oracle = pooled_oracle(&cohort);
        // fixed-point: absolute error ~2^-24 on sums, relative ~1e-6 on stats
        for j in 0..cohort.m() {
            let (a, b) = (res.output.assoc[0].beta[j], oracle.assoc[0].beta[j]);
            if a.is_finite() && b.is_finite() {
                assert!(
                    (a - b).abs() < 1e-4 * b.abs().max(1.0),
                    "beta[{j}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn shamir_backend_matches_oracle_to_fixed_point() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 162);
        let res = run_multi_party_scan(
            &cohort,
            &small_cfg(Backend::Shamir { threshold: 2 }),
        )
        .unwrap();
        let oracle = pooled_oracle(&cohort);
        for j in 0..cohort.m() {
            let (a, b) = (res.output.assoc[0].beta[j], oracle.assoc[0].beta[j]);
            if a.is_finite() && b.is_finite() {
                assert!(
                    (a - b).abs() < 1e-4 * b.abs().max(1.0),
                    "beta[{j}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn tcp_transport_gives_same_answer_and_bytes() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 163);
        let cfg = small_cfg(Backend::Masked);
        let a = run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 99).unwrap();
        // The TCP run contends for sockets/threads with the rest of the
        // parallel test suite; allow one retry before judging (the byte
        // accounting itself is deterministic — see net::transport's
        // byte_counts_match_across_transports).
        let mut last = None;
        for _attempt in 0..2 {
            let b = run_multi_party_scan_t(&cohort, &cfg, Transport::Tcp, 99).unwrap();
            let ok = rel_err(&a.output.assoc[0].beta, &b.output.assoc[0].beta) < 1e-12
                && a.metrics.bytes_total == b.metrics.bytes_total;
            last = Some((b.metrics.bytes_total, ok));
            if ok {
                return;
            }
        }
        panic!(
            "tcp mismatch after retry: inproc {} bytes vs tcp {:?}",
            a.metrics.bytes_total, last
        );
    }

    #[test]
    fn metrics_populated() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 164);
        let res = run_multi_party_scan(&cohort, &small_cfg(Backend::Masked)).unwrap();
        assert!(res.metrics.bytes_total > 0);
        assert!(res.metrics.bytes_result > 0);
        assert!(res.metrics.total_s > 0.0);
        assert_eq!(res.party_bytes.len(), 3);
        assert!(res.party_bytes.iter().all(|&b| b > 0));
    }

    #[test]
    fn detects_top_causal_hits() {
        let mut spec = CohortSpec::default_small();
        spec.effect_sd = 0.8;
        spec.party_sizes = vec![400, 350, 300];
        let cohort = generate_cohort(&spec, 165);
        let res = run_multi_party_scan(&cohort, &small_cfg(Backend::Masked)).unwrap();
        let hits = res.output.hits(1e-6);
        // at least one strong causal variant should surface
        assert!(
            hits.iter().any(|h| cohort.truth.causal_idx.contains(h)),
            "hits {hits:?} vs causal {:?}",
            cohort.truth.causal_idx
        );
    }
}
