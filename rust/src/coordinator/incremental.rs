//! Incremental folding of aggregate statistics — generalized from
//! "add a cohort" to "add a shard", trait-major throughout.
//!
//! Two fold units share this module:
//!
//! - **Cohort rounds** ([`IncrementalAggregate`]): new centers or sample
//!   batches join after the initial combine at cost independent of the
//!   original N (paper §1 fn.1). The leader retains only the aggregate
//!   sufficient statistics — a `O((K+T)·M)` object — and folds a joining
//!   batch's securely-summed delta over the *full* layout.
//! - **Variant shards** ([`IncrementalAggregate::add_shard_flat`] and
//!   [`ScanAssembler`]): within one session, the sharded streaming
//!   protocol delivers the same aggregate one `O((K+T)·width)` column
//!   shard at a time. `add_shard_flat` scatters a shard delta into the
//!   full layout (for leaders that retain the aggregate for later cohort
//!   joins); `ScanAssembler` is the bounded-memory path that combines
//!   each shard on arrival and keeps only the `O(M·T)` outputs. The
//!   assembler is **order-agnostic**: shards scatter into place by
//!   column range, so delayed or reordered per-shard frames assemble the
//!   same scan (disjointness is still enforced — a duplicate or
//!   overlapping shard fails the session).
//!
//! Privacy note (DESIGN.md §Security): consecutive aggregates differ by
//! the joining batch's total — with a *single* joining party that delta
//! equals its contribution. This is inherent to the functionality
//! (difference of two published aggregates), not a protocol leak; batches
//! of ≥ 2 parties have the same guarantee as the initial round.

use crate::linalg::Matrix;
use crate::scan::compressed::AggregateSums;
use crate::scan::{
    combine_base, combine_compressed, combine_shard, flatten_for_sum, unflatten_sum, BaseSums,
    CombineContext, CombineOptions, CompressedParty, FlatLayout, RFactorMethod, ScanOutput,
    ShardRange, ShardSums,
};
use crate::stats::AssocResult;

/// The leader's retained state between rounds.
#[derive(Clone, Debug)]
pub struct IncrementalAggregate {
    layout: FlatLayout,
    flat: Vec<f64>,
    rounds: usize,
    /// per-column arrival mask for the current sharded session: a
    /// re-delivered or overlapping shard delta is a protocol error, not
    /// a silent double-count. `None` for aggregates built whole (every
    /// column already present).
    shard_filled: Option<Vec<bool>>,
}

impl IncrementalAggregate {
    /// Start from a first round's aggregate flat vector.
    pub fn new(layout: FlatLayout, flat: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(flat.len() == layout.len(), "layout mismatch");
        Ok(IncrementalAggregate { layout, flat, rounds: 1, shard_filled: None })
    }

    /// Start a sharded session's aggregate: base sums known, variant
    /// segments zeroed, shards folded in as they arrive
    /// ([`add_shard_flat`](Self::add_shard_flat)).
    pub fn from_base_flat(layout: FlatLayout, base_flat: &[f64]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            base_flat.len() == layout.xty_off(),
            "base flat length mismatch"
        );
        let mut flat = vec![0.0; layout.len()];
        flat[..base_flat.len()].copy_from_slice(base_flat);
        Ok(IncrementalAggregate {
            layout,
            flat,
            rounds: 1,
            shard_filled: Some(vec![false; layout.m]),
        })
    }

    /// Convenience: build from per-party compressed statistics.
    pub fn from_parties(parties: &[CompressedParty]) -> anyhow::Result<Self> {
        anyhow::ensure!(!parties.is_empty());
        let (layout, mut acc) = flatten_for_sum(&parties[0]);
        for p in &parties[1..] {
            let (l2, f) = flatten_for_sum(p);
            anyhow::ensure!(l2 == layout, "party layout mismatch");
            for (a, b) in acc.iter_mut().zip(&f) {
                *a += b;
            }
        }
        Self::new(layout, acc)
    }

    pub fn layout(&self) -> FlatLayout {
        self.layout
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Total samples aggregated so far.
    pub fn n_total(&self) -> usize {
        self.flat[0].round() as usize
    }

    /// Fold in a new round's aggregate (already securely summed across
    /// the joining batch). O(len) — independent of original N.
    pub fn add_round_flat(&mut self, flat: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(flat.len() == self.flat.len(), "layout mismatch");
        for (a, b) in self.flat.iter_mut().zip(flat) {
            *a += b;
        }
        self.rounds += 1;
        Ok(())
    }

    /// Fold one shard's summed variant statistics (`[xty(w·T), xtx(w),
    /// ctx(K·w)]`, see [`crate::scan::shard_flat_len`]) into the variant
    /// segments of the full layout — the shard-shaped fold unit.
    /// O((K+T)·width); does not advance the cohort-round counter. Within
    /// a sharded session a re-delivered or overlapping shard is rejected
    /// (it would otherwise double-count silently).
    pub fn add_shard_flat(&mut self, range: ShardRange, flat: &[f64]) -> anyhow::Result<()> {
        let (k, m, t) = (self.layout.k, self.layout.m, self.layout.t);
        anyhow::ensure!(range.j0 <= range.j1, "degenerate shard range");
        let w = range.width();
        anyhow::ensure!(range.j1 <= m, "shard range beyond layout");
        anyhow::ensure!(
            flat.len() == crate::scan::shard_flat_len(k, t, w),
            "shard flat length mismatch"
        );
        if let Some(filled) = &mut self.shard_filled {
            anyhow::ensure!(
                !filled[range.j0..range.j1].iter().any(|&f| f),
                "shard [{}, {}) overlaps columns already folded",
                range.j0,
                range.j1
            );
            filled[range.j0..range.j1].fill(true);
        }
        let (xty_off, xtx_off, ctx_off) =
            (self.layout.xty_off(), self.layout.xtx_off(), self.layout.ctx_off());
        // xty: rows [j0, j1) of the M × T trait-major block
        for j in 0..w {
            for tt in 0..t {
                self.flat[xty_off + (range.j0 + j) * t + tt] += flat[j * t + tt];
            }
        }
        for j in 0..w {
            self.flat[xtx_off + range.j0 + j] += flat[w * t + j];
        }
        for kk in 0..k {
            for j in 0..w {
                self.flat[ctx_off + kk * m + range.j0 + j] += flat[w * (t + 1) + kk * w + j];
            }
        }
        Ok(())
    }

    /// Fold in new parties directly (plaintext-simulation convenience).
    pub fn add_parties(&mut self, parties: &[CompressedParty]) -> anyhow::Result<()> {
        anyhow::ensure!(!parties.is_empty());
        let delta = Self::from_parties(parties)?;
        anyhow::ensure!(delta.layout == self.layout, "layout mismatch");
        self.add_round_flat(&delta.flat)
    }

    /// Current aggregate sums.
    pub fn sums(&self) -> anyhow::Result<AggregateSums> {
        unflatten_sum(self.layout, &self.flat)
    }

    /// Re-run the combine on the current aggregate — `O(K³ + K²M + KMT)`,
    /// independent of total N (secure path: Gram + Cholesky).
    pub fn recombine(&self) -> anyhow::Result<ScanOutput> {
        combine_compressed(
            &self.sums()?,
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
    }
}

/// Per-trait output accumulators of a sharded scan session.
struct TraitAcc {
    beta: Vec<f64>,
    se: Vec<f64>,
    t: Vec<f64>,
    p: Vec<f64>,
}

/// Bounded-memory assembler for a sharded scan session.
///
/// Built from the session's aggregate *base* sums, it factorizes the
/// covariate block once ([`combine_base`]) and then folds shard sums in
/// any order: each [`add_shard`](Self::add_shard) runs the Lemma 3.1
/// epilogue for that shard (`O((K² + KT)·width)`, the `QᵀX` projection
/// shared across traits) and scatters into the `O(M·T)` output vectors
/// by column range — the shard sums themselves are dropped immediately,
/// so peak state is `O(K² + (K+T)·width + M·T)` regardless of shard
/// count. Out-of-order and delayed shard frames assemble identically;
/// overlapping or duplicate shards are rejected.
pub struct ScanAssembler {
    ctx: CombineContext,
    m: usize,
    /// per-column arrival mask (disjointness + completeness check)
    filled: Vec<bool>,
    assembled: usize,
    /// residual df as reported by the per-shard epilogue (set on the
    /// first shard; identical across shards by construction)
    df: Option<f64>,
    traits: Vec<TraitAcc>,
}

impl ScanAssembler {
    /// Factorize the covariate block and prepare to receive shards of an
    /// `M`-variant scan.
    pub fn new(
        base: &BaseSums,
        party_rs: Option<&[Matrix]>,
        opts: CombineOptions,
        m: usize,
    ) -> anyhow::Result<ScanAssembler> {
        let ctx = combine_base(base, party_rs, opts)?;
        let traits = (0..ctx.t())
            .map(|_| TraitAcc {
                beta: vec![f64::NAN; m],
                se: vec![f64::NAN; m],
                t: vec![f64::NAN; m],
                p: vec![f64::NAN; m],
            })
            .collect();
        Ok(ScanAssembler { ctx, m, filled: vec![false; m], assembled: 0, df: None, traits })
    }

    /// Number of variant columns assembled so far.
    pub fn assembled(&self) -> usize {
        self.assembled
    }

    /// Combine one shard's aggregate sums and scatter the partial result
    /// into place. Shards may arrive in any order but must be disjoint;
    /// returns the shard's per-trait association statistics (for the
    /// partial-RESULT broadcast).
    pub fn add_shard(
        &mut self,
        range: ShardRange,
        sums: &ShardSums,
    ) -> anyhow::Result<Vec<AssocResult>> {
        anyhow::ensure!(
            range.j0 <= range.j1,
            "degenerate shard range [{}, {})",
            range.j0,
            range.j1
        );
        anyhow::ensure!(range.j1 <= self.m, "shard range beyond M");
        anyhow::ensure!(sums.width() == range.width(), "shard width mismatch");
        anyhow::ensure!(sums.t() == self.ctx.t(), "shard trait-count mismatch");
        anyhow::ensure!(
            !self.filled[range.j0..range.j1].iter().any(|&f| f),
            "shard [{}, {}) overlaps columns already assembled",
            range.j0,
            range.j1
        );
        let parts = combine_shard(&self.ctx, sums);
        for (acc, part) in self.traits.iter_mut().zip(&parts) {
            self.df.get_or_insert(part.df);
            acc.beta[range.j0..range.j1].copy_from_slice(&part.beta);
            acc.se[range.j0..range.j1].copy_from_slice(&part.se);
            acc.t[range.j0..range.j1].copy_from_slice(&part.t);
            acc.p[range.j0..range.j1].copy_from_slice(&part.p);
        }
        self.filled[range.j0..range.j1].fill(true);
        self.assembled += range.width();
        Ok(parts)
    }

    /// Snapshot the assembled per-trait statistics for checkpointing:
    /// `(df, flat)` where `df` is NaN until the first shard lands and
    /// `flat` is `[β̂(m) | σ̂(m) | t(m) | p(m)]` per trait (`4·T·m`
    /// values, NaN at columns not yet assembled). Together with the list
    /// of combined shard ranges this is the assembler's complete
    /// mutable state — the [`CombineContext`] is deliberately excluded
    /// (the base round is cheap and deterministic, so a resuming run
    /// re-derives it bit-identically).
    pub fn snapshot_stats(&self) -> (f64, Vec<f64>) {
        let mut flat = Vec::with_capacity(4 * self.traits.len() * self.m);
        for acc in &self.traits {
            flat.extend_from_slice(&acc.beta);
            flat.extend_from_slice(&acc.se);
            flat.extend_from_slice(&acc.t);
            flat.extend_from_slice(&acc.p);
        }
        (self.df.unwrap_or(f64::NAN), flat)
    }

    /// Restore a fresh assembler from a checkpoint snapshot: mark each
    /// checkpointed shard range as assembled and scatter its statistics
    /// back into place. Must be called before any
    /// [`add_shard`](Self::add_shard) (ranges overlapping assembled
    /// columns are rejected, same as a duplicate shard frame).
    pub fn restore(
        &mut self,
        ranges: &[ShardRange],
        df: f64,
        flat: &[f64],
    ) -> anyhow::Result<()> {
        let t = self.traits.len();
        anyhow::ensure!(
            flat.len() == 4 * t * self.m,
            "checkpoint stats length {} != 4·T·M",
            flat.len()
        );
        for r in ranges {
            anyhow::ensure!(r.j0 <= r.j1 && r.j1 <= self.m, "checkpoint range beyond M");
            anyhow::ensure!(
                !self.filled[r.j0..r.j1].iter().any(|&f| f),
                "checkpoint shard [{}, {}) overlaps columns already assembled",
                r.j0,
                r.j1
            );
            for (tt, acc) in self.traits.iter_mut().enumerate() {
                let base = tt * 4 * self.m;
                acc.beta[r.j0..r.j1].copy_from_slice(&flat[base + r.j0..base + r.j1]);
                acc.se[r.j0..r.j1]
                    .copy_from_slice(&flat[base + self.m + r.j0..base + self.m + r.j1]);
                acc.t[r.j0..r.j1]
                    .copy_from_slice(&flat[base + 2 * self.m + r.j0..base + 2 * self.m + r.j1]);
                acc.p[r.j0..r.j1]
                    .copy_from_slice(&flat[base + 3 * self.m + r.j0..base + 3 * self.m + r.j1]);
            }
            self.filled[r.j0..r.j1].fill(true);
            self.assembled += r.width();
        }
        if df.is_finite() {
            self.df.get_or_insert(df);
        }
        Ok(())
    }

    /// Per-trait `(β̂, σ̂)` for an already-assembled column range, in the
    /// trait-major concatenated layout of a SHARD_RESULT frame — lets a
    /// resuming leader re-broadcast the partial results of shards it
    /// skipped.
    pub fn result_slices(&self, range: ShardRange) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        anyhow::ensure!(range.j0 <= range.j1 && range.j1 <= self.m, "range beyond M");
        anyhow::ensure!(
            self.filled[range.j0..range.j1].iter().all(|&f| f),
            "range [{}, {}) not fully assembled",
            range.j0,
            range.j1
        );
        let mut beta = Vec::with_capacity(range.width() * self.traits.len());
        let mut se = Vec::with_capacity(range.width() * self.traits.len());
        for acc in &self.traits {
            beta.extend_from_slice(&acc.beta[range.j0..range.j1]);
            se.extend_from_slice(&acc.se[range.j0..range.j1]);
        }
        Ok((beta, se))
    }

    /// Finish the session, checking every column arrived.
    pub fn finish(self) -> anyhow::Result<ScanOutput> {
        Ok(self.finish_with_context()?.0)
    }

    /// As [`finish`](Self::finish), additionally handing back the
    /// factorized [`CombineContext`] so follow-on phases (SELECT rounds)
    /// can keep growing the cached basis instead of re-deriving it.
    pub fn finish_with_context(self) -> anyhow::Result<(ScanOutput, CombineContext)> {
        anyhow::ensure!(
            self.assembled == self.m,
            "incomplete scan: {} of {} columns assembled",
            self.assembled,
            self.m
        );
        // df comes from the per-shard epilogue (single source of truth in
        // stats::regression); the fallback only fires for an M == 0 scan.
        let df = self
            .df
            .unwrap_or((self.ctx.n as f64) - (self.ctx.k as f64) - 1.0);
        let assoc = self
            .traits
            .into_iter()
            .map(|a| AssocResult { beta: a.beta, se: a.se, t: a.t, p: a.p, df })
            .collect();
        let out = ScanOutput {
            assoc,
            covariate_fit: self.ctx.covariate_fit.clone(),
            n: self.ctx.n,
            k: self.ctx.k,
            m: self.m,
        };
        Ok((out, self.ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{rel_err, Matrix};
    use crate::scan::{compress_party, ShardPlan};
    use crate::util::rng::Rng;

    fn party_t(n: usize, k: usize, m: usize, t: usize, seed: u64) -> CompressedParty {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let mut ys = Matrix::randn(n, t, &mut rng);
        for i in 0..n {
            ys[(i, 0)] += 0.3 * x[(i, 0)];
        }
        compress_party(&ys, &c, &x, m, Some(1))
    }

    fn party(n: usize, k: usize, m: usize, seed: u64) -> CompressedParty {
        party_t(n, k, m, 1, seed)
    }

    #[test]
    fn incremental_equals_batch_recompute() {
        let p1 = party(60, 3, 10, 170);
        let p2 = party(80, 3, 10, 171);
        let p3 = party(45, 3, 10, 172);
        let p4 = party(90, 3, 10, 173);

        // incremental: {p1,p2} then add {p3,p4}
        let mut inc = IncrementalAggregate::from_parties(&[p1.clone(), p2.clone()]).unwrap();
        inc.add_parties(&[p3.clone(), p4.clone()]).unwrap();
        let inc_out = inc.recombine().unwrap();

        // batch: all four at once
        let all = IncrementalAggregate::from_parties(&[p1, p2, p3, p4]).unwrap();
        let all_out = all.recombine().unwrap();

        assert_eq!(inc.n_total(), all.n_total());
        assert!(rel_err(&inc_out.assoc[0].beta, &all_out.assoc[0].beta) < 1e-12);
        assert!(rel_err(&inc_out.assoc[0].se, &all_out.assoc[0].se) < 1e-12);
        assert_eq!(inc.rounds(), 2);
    }

    #[test]
    fn shard_folds_equal_cohort_fold() {
        // folding shard-by-shard reconstructs exactly the full aggregate,
        // trait dimension included
        let p1 = party_t(70, 3, 12, 2, 180);
        let p2 = party_t(55, 3, 12, 2, 181);
        let full = IncrementalAggregate::from_parties(&[p1.clone(), p2.clone()]).unwrap();

        let (layout, f1) = flatten_for_sum(&p1);
        let (_, f2) = flatten_for_sum(&p2);
        let summed: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let base_flat = &summed[..layout.xty_off()];
        let mut sharded = IncrementalAggregate::from_base_flat(layout, base_flat).unwrap();

        let plan = ShardPlan::new(12, 5); // 3 shards, ragged tail
        let t = layout.t;
        for r in plan.ranges() {
            // build the shard's flat delta from the summed full vector
            let w = r.width();
            let mut flat = Vec::with_capacity(crate::scan::shard_flat_len(3, t, w));
            flat.extend_from_slice(
                &summed[layout.xty_off() + r.j0 * t..layout.xty_off() + r.j1 * t],
            );
            flat.extend_from_slice(&summed[layout.xtx_off() + r.j0..layout.xtx_off() + r.j1]);
            for kk in 0..3 {
                let off = layout.ctx_off() + kk * 12;
                flat.extend_from_slice(&summed[off + r.j0..off + r.j1]);
            }
            sharded.add_shard_flat(r, &flat).unwrap();
        }
        assert_eq!(sharded.flat, full.flat);
        let a = sharded.recombine().unwrap();
        let b = full.recombine().unwrap();
        assert_eq!(a.t(), 2);
        for tt in 0..2 {
            for j in 0..12 {
                assert_eq!(a.assoc[tt].beta[j].to_bits(), b.assoc[tt].beta[j].to_bits());
            }
        }
    }

    #[test]
    fn assembler_matches_single_shot() {
        let p1 = party_t(64, 4, 15, 3, 182);
        let p2 = party_t(48, 4, 15, 3, 183);
        let inc = IncrementalAggregate::from_parties(&[p1, p2]).unwrap();
        let agg = inc.sums().unwrap();
        let single = combine_compressed(
            &agg,
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
        .unwrap();

        let mut asm = ScanAssembler::new(
            &agg.base(),
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
            15,
        )
        .unwrap();
        let plan = ShardPlan::new(15, 4);
        for r in plan.ranges() {
            let parts = asm.add_shard(r, &agg.shard_sums(r.j0, r.j1)).unwrap();
            assert_eq!(parts.len(), 3);
            assert_eq!(parts[0].beta.len(), r.width());
        }
        let out = asm.finish().unwrap();
        for tt in 0..3 {
            for j in 0..15 {
                assert_eq!(
                    out.assoc[tt].beta[j].to_bits(),
                    single.assoc[tt].beta[j].to_bits()
                );
                assert_eq!(out.assoc[tt].p[j].to_bits(), single.assoc[tt].p[j].to_bits());
            }
            assert_eq!(out.assoc[tt].df, single.assoc[tt].df);
        }
    }

    #[test]
    fn assembler_accepts_out_of_order_shards() {
        // per-shard frames delivered out of scan order scatter into the
        // same output as in-order delivery
        let p1 = party_t(80, 3, 13, 2, 190);
        let inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        let agg = inc.sums().unwrap();
        let opts = CombineOptions { r_method: RFactorMethod::Cholesky };
        let plan = ShardPlan::new(13, 4); // shards [0,4) [4,8) [8,12) [12,13)

        let mut in_order = ScanAssembler::new(&agg.base(), None, opts, 13).unwrap();
        for r in plan.ranges() {
            in_order.add_shard(r, &agg.shard_sums(r.j0, r.j1)).unwrap();
        }
        let a = in_order.finish().unwrap();

        let mut shuffled = ScanAssembler::new(&agg.base(), None, opts, 13).unwrap();
        for s in [2usize, 0, 3, 1] {
            let r = plan.range(s);
            shuffled.add_shard(r, &agg.shard_sums(r.j0, r.j1)).unwrap();
        }
        assert_eq!(shuffled.assembled(), 13);
        let b = shuffled.finish().unwrap();
        for tt in 0..2 {
            for j in 0..13 {
                assert_eq!(a.assoc[tt].beta[j].to_bits(), b.assoc[tt].beta[j].to_bits());
                assert_eq!(a.assoc[tt].p[j].to_bits(), b.assoc[tt].p[j].to_bits());
            }
        }
    }

    /// Snapshot after a partial assembly, restore into a fresh assembler,
    /// finish with the remaining shards: bit-identical to an
    /// uninterrupted run (the checkpoint/resume invariant).
    #[test]
    fn snapshot_restore_matches_uninterrupted() {
        let p1 = party_t(72, 3, 14, 2, 185);
        let inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        let agg = inc.sums().unwrap();
        let opts = CombineOptions { r_method: RFactorMethod::Cholesky };
        let plan = ShardPlan::new(14, 5); // shards [0,5) [5,10) [10,14)

        let mut full = ScanAssembler::new(&agg.base(), None, opts, 14).unwrap();
        for r in plan.ranges() {
            full.add_shard(r, &agg.shard_sums(r.j0, r.j1)).unwrap();
        }
        let want = full.finish().unwrap();

        // interrupted after two shards
        let mut first = ScanAssembler::new(&agg.base(), None, opts, 14).unwrap();
        for s in [0usize, 1] {
            let r = plan.range(s);
            first.add_shard(r, &agg.shard_sums(r.j0, r.j1)).unwrap();
        }
        let (df, flat) = first.snapshot_stats();
        assert!(df.is_finite());
        assert_eq!(flat.len(), 4 * 2 * 14);

        // resumed: restore the two done shards, replay only the third
        let mut resumed = ScanAssembler::new(&agg.base(), None, opts, 14).unwrap();
        let done = [plan.range(0), plan.range(1)];
        resumed.restore(&done, df, &flat).unwrap();
        assert_eq!(resumed.assembled(), 10);
        // restored ranges re-broadcast the same partial results
        let (beta0, se0) = resumed.result_slices(plan.range(0)).unwrap();
        assert_eq!(beta0.len(), 2 * 5);
        assert_eq!(se0.len(), 2 * 5);
        // overlapping restore is rejected like a duplicate shard
        assert!(resumed.restore(&[plan.range(1)], df, &flat).is_err());
        let r2 = plan.range(2);
        resumed.add_shard(r2, &agg.shard_sums(r2.j0, r2.j1)).unwrap();
        let got = resumed.finish().unwrap();
        for tt in 0..2 {
            assert_eq!(got.assoc[tt].df, want.assoc[tt].df);
            for j in 0..14 {
                assert_eq!(
                    got.assoc[tt].beta[j].to_bits(),
                    want.assoc[tt].beta[j].to_bits()
                );
                assert_eq!(got.assoc[tt].p[j].to_bits(), want.assoc[tt].p[j].to_bits());
            }
        }
    }

    #[test]
    fn assembler_rejects_duplicate_and_incomplete() {
        let p1 = party(40, 3, 8, 184);
        let inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        let agg = inc.sums().unwrap();
        let opts = CombineOptions { r_method: RFactorMethod::Cholesky };
        let mut asm = ScanAssembler::new(&agg.base(), None, opts, 8).unwrap();
        let plan = ShardPlan::new(8, 4);
        let r0 = plan.range(0);
        asm.add_shard(r0, &agg.shard_sums(r0.j0, r0.j1)).unwrap();
        assert_eq!(asm.assembled(), 4);
        // duplicate shard: overlaps already-assembled columns
        assert!(asm.add_shard(r0, &agg.shard_sums(r0.j0, r0.j1)).is_err());
        // incomplete: only shard 0 arrived
        assert!(asm.finish().is_err());
    }

    /// Regression (duplicate/overlapping frame handling): a partially
    /// overlapping or degenerate column range must yield a clean error —
    /// never a panic or a silent double-count.
    #[test]
    fn assembler_rejects_overlapping_and_degenerate_ranges() {
        let p1 = party(40, 3, 8, 186);
        let inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        let agg = inc.sums().unwrap();
        let opts = CombineOptions { r_method: RFactorMethod::Cholesky };
        let mut asm = ScanAssembler::new(&agg.base(), None, opts, 8).unwrap();
        asm.add_shard(ShardRange { index: 0, j0: 0, j1: 4 }, &agg.shard_sums(0, 4)).unwrap();
        // partial overlap [2, 6) with already-assembled [0, 4)
        assert!(asm
            .add_shard(ShardRange { index: 1, j0: 2, j1: 6 }, &agg.shard_sums(2, 6))
            .is_err());
        // inverted range: error, not an arithmetic panic
        assert!(asm
            .add_shard(ShardRange { index: 2, j0: 5, j1: 4 }, &agg.shard_sums(4, 5))
            .is_err());
        // beyond M
        assert!(asm
            .add_shard(ShardRange { index: 3, j0: 6, j1: 9 }, &agg.shard_sums(5, 8))
            .is_err());
        // the valid disjoint remainder still lands
        asm.add_shard(ShardRange { index: 4, j0: 4, j1: 8 }, &agg.shard_sums(4, 8)).unwrap();
        assert_eq!(asm.assembled(), 8);
        assert!(asm.finish().is_ok());
    }

    /// Regression: folding the same shard delta twice into a sharded
    /// session's aggregate must error (it used to double-count).
    #[test]
    fn shard_fold_rejects_redelivery() {
        let p = party_t(50, 3, 6, 2, 187);
        let (layout, flat) = flatten_for_sum(&p);
        let base_flat = &flat[..layout.xty_off()];
        let mut inc = IncrementalAggregate::from_base_flat(layout, base_flat).unwrap();
        let r0 = ShardRange { index: 0, j0: 0, j1: 3 };
        let delta = vec![0.5; crate::scan::shard_flat_len(3, 2, 3)];
        inc.add_shard_flat(r0, &delta).unwrap();
        // exact re-delivery
        assert!(inc.add_shard_flat(r0, &delta).is_err());
        // partial overlap
        let r_overlap = ShardRange { index: 1, j0: 2, j1: 5 };
        assert!(inc
            .add_shard_flat(r_overlap, &vec![0.5; crate::scan::shard_flat_len(3, 2, 3)])
            .is_err());
        // degenerate range
        assert!(inc
            .add_shard_flat(ShardRange { index: 2, j0: 4, j1: 3 }, &[])
            .is_err());
        // the disjoint remainder is fine
        inc.add_shard_flat(
            ShardRange { index: 3, j0: 3, j1: 6 },
            &vec![0.25; crate::scan::shard_flat_len(3, 2, 3)],
        )
        .unwrap();
        // whole-cohort folds (a later joining batch) remain unrestricted
        let p2 = party_t(40, 3, 6, 2, 188);
        inc.add_parties(std::slice::from_ref(&p2)).unwrap();
    }

    #[test]
    fn n_total_tracks_samples() {
        let p1 = party(60, 3, 5, 174);
        let p2 = party(40, 3, 5, 175);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert_eq!(inc.n_total(), 60);
        inc.add_parties(std::slice::from_ref(&p2)).unwrap();
        assert_eq!(inc.n_total(), 100);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let p1 = party(60, 3, 5, 176);
        let p2 = party(40, 4, 5, 177); // different K
        let p3 = party_t(40, 3, 5, 2, 179); // different T
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert!(inc.add_parties(std::slice::from_ref(&p2)).is_err());
        assert!(inc.add_parties(std::slice::from_ref(&p3)).is_err());
    }

    #[test]
    fn update_cost_independent_of_history() {
        // add_round_flat touches only the O((K+T)·M) aggregate — its cost
        // can't depend on how many samples are already folded in. Here we
        // just assert the state size is constant across rounds.
        let p = party(50, 3, 20, 178);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p)).unwrap();
        let size0 = inc.flat.len();
        for seed in 0..5 {
            let q = party(50, 3, 20, 200 + seed);
            inc.add_parties(std::slice::from_ref(&q)).unwrap();
            assert_eq!(inc.flat.len(), size0);
        }
        assert_eq!(inc.rounds(), 6);
    }
}
