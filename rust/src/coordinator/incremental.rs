//! Incremental updates: new centers or sample batches join after the
//! initial combine at cost independent of the original N (paper §1 fn.1).
//!
//! The leader retains only the aggregate sufficient statistics — a
//! `O(K·M)` object. When a batch of new parties joins, they run a fresh
//! secure-aggregation round among themselves; the leader adds the round's
//! aggregate to the stored one and re-runs the `O(K³ + K²M)` combine. No
//! original party participates, no original data is touched: the update
//! cost depends only on the new batch's size (E7).
//!
//! Privacy note (DESIGN.md §Security): consecutive aggregates differ by
//! the joining batch's total — with a *single* joining party that delta
//! equals its contribution. This is inherent to the functionality
//! (difference of two published aggregates), not a protocol leak; batches
//! of ≥ 2 parties have the same guarantee as the initial round.

use crate::scan::compressed::AggregateSums;
use crate::scan::{
    combine_compressed, flatten_for_sum, unflatten_sum, CombineOptions, CompressedParty,
    FlatLayout, RFactorMethod, ScanOutput,
};

/// The leader's retained state between rounds.
#[derive(Clone, Debug)]
pub struct IncrementalAggregate {
    layout: FlatLayout,
    flat: Vec<f64>,
    rounds: usize,
}

impl IncrementalAggregate {
    /// Start from a first round's aggregate flat vector.
    pub fn new(layout: FlatLayout, flat: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(flat.len() == layout.len(), "layout mismatch");
        Ok(IncrementalAggregate { layout, flat, rounds: 1 })
    }

    /// Convenience: build from per-party compressed statistics.
    pub fn from_parties(parties: &[CompressedParty]) -> anyhow::Result<Self> {
        anyhow::ensure!(!parties.is_empty());
        let (layout, mut acc) = flatten_for_sum(&parties[0]);
        for p in &parties[1..] {
            let (l2, f) = flatten_for_sum(p);
            anyhow::ensure!(l2 == layout, "party layout mismatch");
            for (a, b) in acc.iter_mut().zip(&f) {
                *a += b;
            }
        }
        Self::new(layout, acc)
    }

    pub fn layout(&self) -> FlatLayout {
        self.layout
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Total samples aggregated so far.
    pub fn n_total(&self) -> usize {
        self.flat[0].round() as usize
    }

    /// Fold in a new round's aggregate (already securely summed across
    /// the joining batch). O(len) — independent of original N.
    pub fn add_round_flat(&mut self, flat: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(flat.len() == self.flat.len(), "layout mismatch");
        for (a, b) in self.flat.iter_mut().zip(flat) {
            *a += b;
        }
        self.rounds += 1;
        Ok(())
    }

    /// Fold in new parties directly (plaintext-simulation convenience).
    pub fn add_parties(&mut self, parties: &[CompressedParty]) -> anyhow::Result<()> {
        anyhow::ensure!(!parties.is_empty());
        let delta = Self::from_parties(parties)?;
        anyhow::ensure!(delta.layout == self.layout, "layout mismatch");
        self.add_round_flat(&delta.flat)
    }

    /// Current aggregate sums.
    pub fn sums(&self) -> anyhow::Result<AggregateSums> {
        unflatten_sum(self.layout, &self.flat)
    }

    /// Re-run the combine on the current aggregate — `O(K³ + K²M)`,
    /// independent of total N (secure path: Gram + Cholesky).
    pub fn recombine(&self) -> anyhow::Result<ScanOutput> {
        combine_compressed(
            &self.sums()?,
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{rel_err, Matrix};
    use crate::scan::compress_party;
    use crate::util::rng::Rng;

    fn party(n: usize, k: usize, m: usize, seed: u64) -> CompressedParty {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|i| 0.3 * x[(i, 0)] + rng.normal()).collect();
        compress_party(&y, &c, &x, m, Some(1))
    }

    #[test]
    fn incremental_equals_batch_recompute() {
        let p1 = party(60, 3, 10, 170);
        let p2 = party(80, 3, 10, 171);
        let p3 = party(45, 3, 10, 172);
        let p4 = party(90, 3, 10, 173);

        // incremental: {p1,p2} then add {p3,p4}
        let mut inc = IncrementalAggregate::from_parties(&[p1.clone(), p2.clone()]).unwrap();
        inc.add_parties(&[p3.clone(), p4.clone()]).unwrap();
        let inc_out = inc.recombine().unwrap();

        // batch: all four at once
        let all = IncrementalAggregate::from_parties(&[p1, p2, p3, p4]).unwrap();
        let all_out = all.recombine().unwrap();

        assert_eq!(inc.n_total(), all.n_total());
        assert!(rel_err(&inc_out.assoc.beta, &all_out.assoc.beta) < 1e-12);
        assert!(rel_err(&inc_out.assoc.se, &all_out.assoc.se) < 1e-12);
        assert_eq!(inc.rounds(), 2);
    }

    #[test]
    fn n_total_tracks_samples() {
        let p1 = party(60, 3, 5, 174);
        let p2 = party(40, 3, 5, 175);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert_eq!(inc.n_total(), 60);
        inc.add_parties(std::slice::from_ref(&p2)).unwrap();
        assert_eq!(inc.n_total(), 100);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let p1 = party(60, 3, 5, 176);
        let p2 = party(40, 4, 5, 177); // different K
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert!(inc.add_parties(std::slice::from_ref(&p2)).is_err());
    }

    #[test]
    fn update_cost_independent_of_history() {
        // add_round_flat touches only the O(K·M) aggregate — its cost
        // can't depend on how many samples are already folded in. Here we
        // just assert the state size is constant across rounds.
        let p = party(50, 3, 20, 178);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p)).unwrap();
        let size0 = inc.flat.len();
        for seed in 0..5 {
            let q = party(50, 3, 20, 200 + seed);
            inc.add_parties(std::slice::from_ref(&q)).unwrap();
            assert_eq!(inc.flat.len(), size0);
        }
        assert_eq!(inc.rounds(), 6);
    }
}
