//! Incremental folding of aggregate statistics — generalized from
//! "add a cohort" to "add a shard".
//!
//! Two fold units share this module:
//!
//! - **Cohort rounds** ([`IncrementalAggregate`]): new centers or sample
//!   batches join after the initial combine at cost independent of the
//!   original N (paper §1 fn.1). The leader retains only the aggregate
//!   sufficient statistics — a `O(K·M)` object — and folds a joining
//!   batch's securely-summed delta over the *full* layout.
//! - **Variant shards** ([`IncrementalAggregate::add_shard_flat`] and
//!   [`ScanAssembler`]): within one session, the sharded streaming
//!   protocol delivers the same aggregate one `O(K·width)` column shard
//!   at a time. `add_shard_flat` scatters a shard delta into the full
//!   layout (for leaders that retain the aggregate for later cohort
//!   joins); `ScanAssembler` is the bounded-memory path that combines
//!   each shard on arrival and keeps only the `O(M)` outputs.
//!
//! Privacy note (DESIGN.md §Security): consecutive aggregates differ by
//! the joining batch's total — with a *single* joining party that delta
//! equals its contribution. This is inherent to the functionality
//! (difference of two published aggregates), not a protocol leak; batches
//! of ≥ 2 parties have the same guarantee as the initial round.

use crate::linalg::Matrix;
use crate::scan::compressed::AggregateSums;
use crate::scan::{
    combine_base, combine_compressed, combine_shard, flatten_for_sum, unflatten_sum, BaseSums,
    CombineContext, CombineOptions, CompressedParty, FlatLayout, RFactorMethod, ScanOutput,
    ShardRange, ShardSums,
};
use crate::stats::AssocResult;

/// The leader's retained state between rounds.
#[derive(Clone, Debug)]
pub struct IncrementalAggregate {
    layout: FlatLayout,
    flat: Vec<f64>,
    rounds: usize,
}

impl IncrementalAggregate {
    /// Start from a first round's aggregate flat vector.
    pub fn new(layout: FlatLayout, flat: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(flat.len() == layout.len(), "layout mismatch");
        Ok(IncrementalAggregate { layout, flat, rounds: 1 })
    }

    /// Start a sharded session's aggregate: base sums known, variant
    /// segments zeroed, shards folded in as they arrive
    /// ([`add_shard_flat`](Self::add_shard_flat)).
    pub fn from_base_flat(layout: FlatLayout, base_flat: &[f64]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            base_flat.len() == layout.xty_off(),
            "base flat length mismatch"
        );
        let mut flat = vec![0.0; layout.len()];
        flat[..base_flat.len()].copy_from_slice(base_flat);
        Ok(IncrementalAggregate { layout, flat, rounds: 1 })
    }

    /// Convenience: build from per-party compressed statistics.
    pub fn from_parties(parties: &[CompressedParty]) -> anyhow::Result<Self> {
        anyhow::ensure!(!parties.is_empty());
        let (layout, mut acc) = flatten_for_sum(&parties[0]);
        for p in &parties[1..] {
            let (l2, f) = flatten_for_sum(p);
            anyhow::ensure!(l2 == layout, "party layout mismatch");
            for (a, b) in acc.iter_mut().zip(&f) {
                *a += b;
            }
        }
        Self::new(layout, acc)
    }

    pub fn layout(&self) -> FlatLayout {
        self.layout
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Total samples aggregated so far.
    pub fn n_total(&self) -> usize {
        self.flat[0].round() as usize
    }

    /// Fold in a new round's aggregate (already securely summed across
    /// the joining batch). O(len) — independent of original N.
    pub fn add_round_flat(&mut self, flat: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(flat.len() == self.flat.len(), "layout mismatch");
        for (a, b) in self.flat.iter_mut().zip(flat) {
            *a += b;
        }
        self.rounds += 1;
        Ok(())
    }

    /// Fold one shard's summed variant statistics (`[xty(w), xtx(w),
    /// ctx(K·w)]`, see [`crate::scan::shard_flat_len`]) into the variant
    /// segments of the full layout — the shard-shaped fold unit.
    /// O(K·width); does not advance the cohort-round counter.
    pub fn add_shard_flat(&mut self, range: ShardRange, flat: &[f64]) -> anyhow::Result<()> {
        let (k, m) = (self.layout.k, self.layout.m);
        let w = range.width();
        anyhow::ensure!(range.j1 <= m, "shard range beyond layout");
        anyhow::ensure!(
            flat.len() == crate::scan::shard_flat_len(k, w),
            "shard flat length mismatch"
        );
        let (xty_off, xtx_off, ctx_off) =
            (self.layout.xty_off(), self.layout.xtx_off(), self.layout.ctx_off());
        for j in 0..w {
            self.flat[xty_off + range.j0 + j] += flat[j];
            self.flat[xtx_off + range.j0 + j] += flat[w + j];
        }
        for kk in 0..k {
            for j in 0..w {
                self.flat[ctx_off + kk * m + range.j0 + j] += flat[(2 + kk) * w + j];
            }
        }
        Ok(())
    }

    /// Fold in new parties directly (plaintext-simulation convenience).
    pub fn add_parties(&mut self, parties: &[CompressedParty]) -> anyhow::Result<()> {
        anyhow::ensure!(!parties.is_empty());
        let delta = Self::from_parties(parties)?;
        anyhow::ensure!(delta.layout == self.layout, "layout mismatch");
        self.add_round_flat(&delta.flat)
    }

    /// Current aggregate sums.
    pub fn sums(&self) -> anyhow::Result<AggregateSums> {
        unflatten_sum(self.layout, &self.flat)
    }

    /// Re-run the combine on the current aggregate — `O(K³ + K²M)`,
    /// independent of total N (secure path: Gram + Cholesky).
    pub fn recombine(&self) -> anyhow::Result<ScanOutput> {
        combine_compressed(
            &self.sums()?,
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
    }
}

/// Bounded-memory assembler for a sharded scan session.
///
/// Built from the session's aggregate *base* sums, it factorizes the
/// covariate block once ([`combine_base`]) and then folds shard sums in
/// scan order: each [`add_shard`](Self::add_shard) runs the Lemma 3.1
/// epilogue for that shard (`O(K²·width)`) and appends into the `O(M)`
/// output vectors — the shard sums themselves are dropped immediately,
/// so peak state is `O(K² + K·width + M)` regardless of shard count.
pub struct ScanAssembler {
    ctx: CombineContext,
    m: usize,
    next_j0: usize,
    /// residual df as reported by the per-shard epilogue (set on the
    /// first shard; identical across shards by construction)
    df: Option<f64>,
    beta: Vec<f64>,
    se: Vec<f64>,
    t: Vec<f64>,
    p: Vec<f64>,
}

impl ScanAssembler {
    /// Factorize the covariate block and prepare to receive shards of an
    /// `M`-variant scan.
    pub fn new(
        base: &BaseSums,
        party_rs: Option<&[Matrix]>,
        opts: CombineOptions,
        m: usize,
    ) -> anyhow::Result<ScanAssembler> {
        let ctx = combine_base(base, party_rs, opts)?;
        Ok(ScanAssembler {
            ctx,
            m,
            next_j0: 0,
            df: None,
            beta: Vec::with_capacity(m),
            se: Vec::with_capacity(m),
            t: Vec::with_capacity(m),
            p: Vec::with_capacity(m),
        })
    }

    /// Number of variant columns assembled so far.
    pub fn assembled(&self) -> usize {
        self.next_j0
    }

    /// Combine one shard's aggregate sums and fold the partial result in.
    /// Shards must arrive in scan order; returns the shard's association
    /// statistics (for the partial-RESULT broadcast).
    pub fn add_shard(
        &mut self,
        range: ShardRange,
        sums: &ShardSums,
    ) -> anyhow::Result<AssocResult> {
        anyhow::ensure!(
            range.j0 == self.next_j0,
            "shard out of order: got [{}, {}), expected start {}",
            range.j0,
            range.j1,
            self.next_j0
        );
        anyhow::ensure!(range.j1 <= self.m, "shard range beyond M");
        anyhow::ensure!(sums.xty.len() == range.width(), "shard width mismatch");
        let part = combine_shard(&self.ctx, sums);
        self.df.get_or_insert(part.df);
        self.beta.extend_from_slice(&part.beta);
        self.se.extend_from_slice(&part.se);
        self.t.extend_from_slice(&part.t);
        self.p.extend_from_slice(&part.p);
        self.next_j0 = range.j1;
        Ok(part)
    }

    /// Finish the session, checking every column arrived.
    pub fn finish(self) -> anyhow::Result<ScanOutput> {
        anyhow::ensure!(
            self.next_j0 == self.m,
            "incomplete scan: {} of {} columns assembled",
            self.next_j0,
            self.m
        );
        // df comes from the per-shard epilogue (single source of truth in
        // stats::regression); the fallback only fires for an M == 0 scan.
        let df = self
            .df
            .unwrap_or((self.ctx.n as f64) - (self.ctx.k as f64) - 1.0);
        Ok(ScanOutput {
            assoc: AssocResult { beta: self.beta, se: self.se, t: self.t, p: self.p, df },
            covariate_fit: self.ctx.covariate_fit,
            n: self.ctx.n,
            k: self.ctx.k,
            m: self.m,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{rel_err, Matrix};
    use crate::scan::{compress_party, ShardPlan};
    use crate::util::rng::Rng;

    fn party(n: usize, k: usize, m: usize, seed: u64) -> CompressedParty {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|i| 0.3 * x[(i, 0)] + rng.normal()).collect();
        compress_party(&y, &c, &x, m, Some(1))
    }

    #[test]
    fn incremental_equals_batch_recompute() {
        let p1 = party(60, 3, 10, 170);
        let p2 = party(80, 3, 10, 171);
        let p3 = party(45, 3, 10, 172);
        let p4 = party(90, 3, 10, 173);

        // incremental: {p1,p2} then add {p3,p4}
        let mut inc = IncrementalAggregate::from_parties(&[p1.clone(), p2.clone()]).unwrap();
        inc.add_parties(&[p3.clone(), p4.clone()]).unwrap();
        let inc_out = inc.recombine().unwrap();

        // batch: all four at once
        let all = IncrementalAggregate::from_parties(&[p1, p2, p3, p4]).unwrap();
        let all_out = all.recombine().unwrap();

        assert_eq!(inc.n_total(), all.n_total());
        assert!(rel_err(&inc_out.assoc.beta, &all_out.assoc.beta) < 1e-12);
        assert!(rel_err(&inc_out.assoc.se, &all_out.assoc.se) < 1e-12);
        assert_eq!(inc.rounds(), 2);
    }

    #[test]
    fn shard_folds_equal_cohort_fold() {
        // folding shard-by-shard reconstructs exactly the full aggregate
        let p1 = party(70, 3, 12, 180);
        let p2 = party(55, 3, 12, 181);
        let full = IncrementalAggregate::from_parties(&[p1.clone(), p2.clone()]).unwrap();

        let (layout, f1) = flatten_for_sum(&p1);
        let (_, f2) = flatten_for_sum(&p2);
        let summed: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let base_flat = &summed[..layout.xty_off()];
        let mut sharded = IncrementalAggregate::from_base_flat(layout, base_flat).unwrap();

        let plan = ShardPlan::new(12, 5); // 3 shards, ragged tail
        for r in plan.ranges() {
            // build the shard's flat delta from the summed full vector
            let w = r.width();
            let mut flat = Vec::with_capacity(crate::scan::shard_flat_len(3, w));
            flat.extend_from_slice(&summed[layout.xty_off() + r.j0..layout.xty_off() + r.j1]);
            flat.extend_from_slice(&summed[layout.xtx_off() + r.j0..layout.xtx_off() + r.j1]);
            for kk in 0..3 {
                let off = layout.ctx_off() + kk * 12;
                flat.extend_from_slice(&summed[off + r.j0..off + r.j1]);
            }
            sharded.add_shard_flat(r, &flat).unwrap();
        }
        assert_eq!(sharded.flat, full.flat);
        let a = sharded.recombine().unwrap();
        let b = full.recombine().unwrap();
        assert_eq!(a.assoc.beta.len(), b.assoc.beta.len());
        for j in 0..12 {
            assert_eq!(a.assoc.beta[j].to_bits(), b.assoc.beta[j].to_bits());
        }
    }

    #[test]
    fn assembler_matches_single_shot() {
        let p1 = party(64, 4, 15, 182);
        let p2 = party(48, 4, 15, 183);
        let inc = IncrementalAggregate::from_parties(&[p1, p2]).unwrap();
        let agg = inc.sums().unwrap();
        let single = combine_compressed(
            &agg,
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
        )
        .unwrap();

        let mut asm = ScanAssembler::new(
            &agg.base(),
            None,
            CombineOptions { r_method: RFactorMethod::Cholesky },
            15,
        )
        .unwrap();
        let plan = ShardPlan::new(15, 4);
        for r in plan.ranges() {
            let sums = ShardSums {
                xty: agg.xty[r.j0..r.j1].to_vec(),
                xtx: agg.xtx[r.j0..r.j1].to_vec(),
                ctx: agg.ctx.col_slice(r.j0, r.j1),
            };
            let part = asm.add_shard(r, &sums).unwrap();
            assert_eq!(part.beta.len(), r.width());
        }
        let out = asm.finish().unwrap();
        for j in 0..15 {
            assert_eq!(out.assoc.beta[j].to_bits(), single.assoc.beta[j].to_bits());
            assert_eq!(out.assoc.p[j].to_bits(), single.assoc.p[j].to_bits());
        }
        assert_eq!(out.assoc.df, single.assoc.df);
    }

    #[test]
    fn assembler_rejects_out_of_order_and_incomplete() {
        let p1 = party(40, 3, 8, 184);
        let inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        let agg = inc.sums().unwrap();
        let opts = CombineOptions { r_method: RFactorMethod::Cholesky };
        let mut asm = ScanAssembler::new(&agg.base(), None, opts, 8).unwrap();
        let plan = ShardPlan::new(8, 4);
        // out of order: shard 1 first
        let r1 = plan.range(1);
        let sums = ShardSums {
            xty: agg.xty[r1.j0..r1.j1].to_vec(),
            xtx: agg.xtx[r1.j0..r1.j1].to_vec(),
            ctx: agg.ctx.col_slice(r1.j0, r1.j1),
        };
        assert!(asm.add_shard(r1, &sums).is_err());
        // incomplete: only shard 0 arrives
        let r0 = plan.range(0);
        let sums0 = ShardSums {
            xty: agg.xty[r0.j0..r0.j1].to_vec(),
            xtx: agg.xtx[r0.j0..r0.j1].to_vec(),
            ctx: agg.ctx.col_slice(r0.j0, r0.j1),
        };
        asm.add_shard(r0, &sums0).unwrap();
        assert_eq!(asm.assembled(), 4);
        assert!(asm.finish().is_err());
    }

    #[test]
    fn n_total_tracks_samples() {
        let p1 = party(60, 3, 5, 174);
        let p2 = party(40, 3, 5, 175);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert_eq!(inc.n_total(), 60);
        inc.add_parties(std::slice::from_ref(&p2)).unwrap();
        assert_eq!(inc.n_total(), 100);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let p1 = party(60, 3, 5, 176);
        let p2 = party(40, 4, 5, 177); // different K
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p1)).unwrap();
        assert!(inc.add_parties(std::slice::from_ref(&p2)).is_err());
    }

    #[test]
    fn update_cost_independent_of_history() {
        // add_round_flat touches only the O(K·M) aggregate — its cost
        // can't depend on how many samples are already folded in. Here we
        // just assert the state size is constant across rounds.
        let p = party(50, 3, 20, 178);
        let mut inc = IncrementalAggregate::from_parties(std::slice::from_ref(&p)).unwrap();
        let size0 = inc.flat.len();
        for seed in 0..5 {
            let q = party(50, 3, 20, 200 + seed);
            inc.add_parties(std::slice::from_ref(&q)).unwrap();
            assert_eq!(inc.flat.len(), size0);
        }
        assert_eq!(inc.rounds(), 6);
    }
}
