//! Leader-side checkpoint persistence: one versioned
//! [`Checkpoint`] frame per session, written atomically after every
//! combined shard and deleted on clean completion.
//!
//! The on-disk format is the wire format — a single v1 frame
//! ([`crate::net::FrameWriter`]) holding the CHECKPOINT message, so the
//! snapshot inherits the codec layer's length guards and needs no
//! separate parser. Files live at `{dir}/session-{sid}.ckpt`; writes go
//! through a `.tmp` sibling + rename so a crash mid-write leaves either
//! the previous snapshot or none, never a torn file.
//!
//! What is deliberately NOT in the snapshot (DESIGN.md §Checkpointing):
//! the base-round aggregate, the SELECT state, and any mask or share
//! material. The base round and SELECT replay deterministically on
//! resume, and the PRG mask/share streams are keyed by (seed, session,
//! round) with absolute round numbers — a resumed session re-runs only
//! rounds whose mask domains it would have used anyway, so replay can
//! never reuse randomness across different plaintexts.

use super::messages::Checkpoint;
use crate::net::{FrameReader, FrameWriter, WireMessage};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Checkpoint file path for one session.
pub fn checkpoint_path(dir: &str, session: u64) -> PathBuf {
    Path::new(dir).join(format!("session-{session}.ckpt"))
}

/// Atomically persist `ckpt` for its session (tmp + rename; creates
/// `dir` if missing).
pub fn save(dir: &str, ckpt: &Checkpoint) -> anyhow::Result<()> {
    fs::create_dir_all(dir)?;
    let path = checkpoint_path(dir, ckpt.session);
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut file = fs::File::create(&tmp)?;
        FrameWriter::new(&mut file).write(&ckpt.to_frame())?;
        file.flush()?;
        file.sync_all()?;
    }
    fs::rename(&tmp, &path)?;
    Ok(())
}

/// Load a session's checkpoint. `Ok(None)` when no snapshot exists
/// (fresh session); a present-but-malformed file is an error, not a
/// silent restart from zero.
pub fn load(dir: &str, session: u64) -> anyhow::Result<Option<Checkpoint>> {
    let path = checkpoint_path(dir, session);
    let bytes = match fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let frame = FrameReader::new(bytes.as_slice()).read()?;
    let ckpt = Checkpoint::from_frame(&frame)?;
    anyhow::ensure!(
        ckpt.session == session,
        "checkpoint {} holds session {} (want {session})",
        path.display(),
        ckpt.session
    );
    Ok(Some(ckpt))
}

/// Delete a session's checkpoint after clean completion (missing file
/// is fine — nothing was ever written, or a previous run cleaned up).
pub fn remove(dir: &str, session: u64) -> anyhow::Result<()> {
    match fs::remove_file(checkpoint_path(dir, session)) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(e.into()),
    }
}

/// Garbage-collect orphaned checkpoints: delete every
/// `session-*.ckpt` in `dir` whose session id is not in `keep`, plus
/// any `.ckpt.tmp` leftovers from interrupted writes. Cancelled,
/// failed, and crashed runs leave snapshots behind that no one will
/// ever resume — under a long-lived daemon those accumulate forever
/// unless swept at startup. Unrelated files are never touched; a
/// missing directory is nothing to sweep. Returns how many files were
/// removed.
pub fn sweep(dir: &str, keep: &[u64]) -> anyhow::Result<usize> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(e.into()),
    };
    let mut removed = 0usize;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stale_tmp = name.starts_with("session-") && name.ends_with(".ckpt.tmp");
        let session = name
            .strip_prefix("session-")
            .and_then(|rest| rest.strip_suffix(".ckpt"))
            .and_then(|rest| rest.parse::<u64>().ok());
        if stale_tmp || session.is_some_and(|s| !keep.contains(&s)) {
            match fs::remove_file(entry.path()) {
                Ok(()) => removed += 1,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e.into()),
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::CHECKPOINT_VERSION;

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mpc-ckpt-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn ckpt(session: u64) -> Checkpoint {
        let (m, t) = (4u64, 2u64);
        let mut stats = vec![f64::NAN; (4 * t * m) as usize];
        stats[1] = 2.5;
        Checkpoint {
            version: CHECKPOINT_VERSION,
            session,
            seed: 7,
            backend: 1,
            m,
            k: 3,
            t,
            shard_m: 2,
            select_k: 0,
            done: vec![0],
            df: 10.0,
            stats,
        }
    }

    #[test]
    fn save_load_remove_roundtrip() {
        let dir = tempdir("roundtrip");
        let d = dir.to_str().unwrap();
        // nothing written yet → fresh
        assert!(load(d, 3).unwrap().is_none());
        save(d, &ckpt(3)).unwrap();
        let got = load(d, 3).unwrap().unwrap();
        assert_eq!(got.session, 3);
        assert_eq!(got.done, vec![0]);
        assert_eq!(got.stats[1], 2.5);
        assert!(got.stats[0].is_nan());
        // sessions don't collide
        assert!(load(d, 4).unwrap().is_none());
        save(d, &ckpt(4)).unwrap();
        // overwrite is the common case (one snapshot per combined shard)
        let mut later = ckpt(3);
        later.done = vec![0, 1];
        save(d, &later).unwrap();
        assert_eq!(load(d, 3).unwrap().unwrap().done, vec![0, 1]);
        remove(d, 3).unwrap();
        assert!(load(d, 3).unwrap().is_none());
        // removing twice (or a never-written session) is not an error
        remove(d, 3).unwrap();
        assert_eq!(load(d, 4).unwrap().unwrap().session, 4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_removes_only_orphaned_checkpoints() {
        let dir = tempdir("sweep");
        let d = dir.to_str().unwrap();
        // a missing directory is nothing to sweep
        assert_eq!(sweep(d, &[]).unwrap(), 0);
        save(d, &ckpt(1)).unwrap();
        save(d, &ckpt(2)).unwrap();
        save(d, &ckpt(3)).unwrap();
        // a torn write leaves a stale tmp; sweep clears it too
        fs::write(Path::new(d).join("session-9.ckpt.tmp"), b"torn").unwrap();
        // unrelated files are never touched
        fs::write(Path::new(d).join("notes.txt"), b"keep me").unwrap();
        let removed = sweep(d, &[2]).unwrap();
        assert_eq!(removed, 3, "sessions 1 and 3 plus the stale tmp");
        assert!(load(d, 1).unwrap().is_none());
        assert_eq!(load(d, 2).unwrap().unwrap().session, 2);
        assert!(load(d, 3).unwrap().is_none());
        assert!(Path::new(d).join("notes.txt").exists());
        // idempotent: a second sweep finds nothing
        assert_eq!(sweep(d, &[2]).unwrap(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_file_is_a_loud_error() {
        let dir = tempdir("malformed");
        let d = dir.to_str().unwrap();
        fs::create_dir_all(d).unwrap();
        fs::write(checkpoint_path(d, 1), b"not a frame").unwrap();
        assert!(load(d, 1).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
