//! Run configuration: JSON config files + CLI overrides → typed specs.
//!
//! The launcher accepts `--config run.json` plus per-field overrides;
//! [`RunConfig`] is the single source of truth handed to the coordinator,
//! and it serializes back to JSON for reproducible experiment records
//! (every EXPERIMENTS.md row carries its config).

use crate::coordinator::Transport;
use crate::gwas::CohortSpec;
use crate::mpc::Backend;
use crate::scan::{Glm, RFactorMethod, ScanConfig, SelectPolicy};
use crate::util::json::Json;

/// Full configuration of one scan run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub cohort: CohortSpec,
    pub scan: ScanConfig,
    pub seed: u64,
    /// leader ↔ party transport: in-process channels, threaded TCP
    /// (one pump thread per connection), or the epoll reactor (one
    /// readiness thread for every connection)
    pub transport: Transport,
    /// number of multiplexed sessions to run over shared connections
    /// (1 = classic single-session deployment on dedicated connections)
    pub sessions: usize,
    /// bound on concurrently-running sessions (leader worker pool and
    /// party service pool) when `sessions > 1`
    pub max_concurrent: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            cohort: CohortSpec::default_small(),
            scan: ScanConfig::default(),
            seed: 7,
            transport: Transport::InProc,
            sessions: 1,
            max_concurrent: 4,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document (all fields optional; defaults apply).
    pub fn from_json(v: &Json) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(s) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = s as u64;
        }
        if let Some(t) = v.get("transport").and_then(Json::as_str) {
            cfg.transport = parse_transport(t)?;
        }
        if let Some(x) = v.get("sessions").and_then(Json::as_usize) {
            anyhow::ensure!(x >= 1, "sessions must be ≥ 1");
            cfg.sessions = x;
        }
        if let Some(x) = v.get("max_concurrent").and_then(Json::as_usize) {
            anyhow::ensure!(x >= 1, "max_concurrent must be ≥ 1");
            cfg.max_concurrent = x;
        }
        if let Some(c) = v.get("cohort") {
            cfg.cohort = parse_cohort(c, cfg.cohort)?;
        }
        if let Some(s) = v.get("scan") {
            cfg.scan = parse_scan(s, cfg.scan)?;
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config {path}: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Serialize for the experiment record.
    pub fn to_json(&self) -> Json {
        let mut cohort = Json::obj();
        cohort
            .set("party_sizes", self.cohort.party_sizes.clone())
            .set("m_variants", self.cohort.m_variants)
            .set("n_traits", self.cohort.n_traits)
            .set("n_causal", self.cohort.n_causal)
            .set("effect_sd", self.cohort.effect_sd)
            .set("fst", self.cohort.fst)
            .set("party_admixture", self.cohort.party_admixture.clone())
            .set("ancestry_effect", self.cohort.ancestry_effect)
            .set("batch_effect_sd", self.cohort.batch_effect_sd)
            .set("n_pcs", self.cohort.n_pcs)
            .set("noise_sd", self.cohort.noise_sd)
            .set("binary_traits", self.cohort.binary_traits);
        let mut scan = Json::obj();
        scan.set("backend", self.scan.backend.name())
            .set("frac_bits", self.scan.frac_bits as usize)
            .set("block_m", self.scan.block_m)
            .set("shard_m", self.scan.shard_m)
            .set("select_k", self.scan.select_k)
            .set("select_alpha", self.scan.select_alpha)
            .set("select_policy", self.scan.select_policy.name())
            .set("select_candidates", self.scan.select_candidates)
            .set("use_artifacts", self.scan.use_artifacts)
            .set("artifacts_dir", self.scan.artifacts_dir.as_str())
            .set("checkpoint_dir", self.scan.checkpoint_dir.as_str())
            .set("resume", self.scan.resume)
            .set("artifact_exec", self.scan.artifact_exec.name())
            .set("entry_widths", self.scan.entry_widths.clone())
            .set("entry_traits", self.scan.entry_traits.clone())
            .set("entry_k_pad", self.scan.entry_k_pad)
            .set("glm", self.scan.glm.name())
            .set("irls_max_iter", self.scan.irls_max_iter)
            .set("irls_tol", self.scan.irls_tol)
            .set(
                "r_method",
                match self.scan.r_method {
                    RFactorMethod::Auto => "auto",
                    RFactorMethod::Tsqr => "tsqr",
                    RFactorMethod::Cholesky => "cholesky",
                },
            );
        if let Some(t) = self.scan.threads {
            scan.set("threads", t);
        }
        if let Some(t) = self.scan.compress_threads {
            scan.set("compress_threads", t);
        }
        let mut o = Json::obj();
        o.set("seed", self.seed)
            .set("transport", transport_name(self.transport))
            .set("sessions", self.sessions)
            .set("max_concurrent", self.max_concurrent)
            .set("cohort", cohort)
            .set("scan", scan);
        o
    }
}

/// Parse a transport name (`--transport` / config `"transport"`).
pub fn parse_transport(t: &str) -> anyhow::Result<Transport> {
    Ok(match t {
        "inproc" => Transport::InProc,
        "tcp" => Transport::Tcp,
        "reactor" => Transport::Reactor,
        other => anyhow::bail!("unknown transport `{other}`"),
    })
}

/// Canonical name of a transport (config serialization and run reports).
pub fn transport_name(t: Transport) -> &'static str {
    match t {
        Transport::InProc => "inproc",
        Transport::Tcp => "tcp",
        Transport::Reactor => "reactor",
    }
}

fn parse_usize_vec(v: &Json, key: &str) -> anyhow::Result<Option<Vec<usize>>> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Arr(a)) => Ok(Some(
            a.iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("non-numeric element in {key}"))
                })
                .collect::<anyhow::Result<_>>()?,
        )),
        _ => anyhow::bail!("{key} must be an array"),
    }
}

fn parse_f64_vec(v: &Json, key: &str) -> anyhow::Result<Option<Vec<f64>>> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Arr(a)) => Ok(Some(
            a.iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("non-numeric element in {key}"))
                })
                .collect::<anyhow::Result<_>>()?,
        )),
        _ => anyhow::bail!("{key} must be an array"),
    }
}

fn parse_cohort(v: &Json, mut c: CohortSpec) -> anyhow::Result<CohortSpec> {
    if let Some(ps) = parse_usize_vec(v, "party_sizes")? {
        c.party_sizes = ps;
    }
    if let Some(pa) = parse_f64_vec(v, "party_admixture")? {
        c.party_admixture = pa;
    } else if c.party_admixture.len() != c.party_sizes.len() {
        // sensible default: evenly spaced admixture
        let p = c.party_sizes.len();
        c.party_admixture = (0..p)
            .map(|i| if p == 1 { 0.5 } else { i as f64 / (p - 1) as f64 })
            .collect();
    }
    for (key, slot) in [
        ("m_variants", &mut c.m_variants as &mut usize),
        ("n_traits", &mut c.n_traits),
        ("n_causal", &mut c.n_causal),
        ("n_pcs", &mut c.n_pcs),
    ] {
        if let Some(x) = v.get(key).and_then(Json::as_usize) {
            *slot = x;
        }
    }
    for (key, slot) in [
        ("effect_sd", &mut c.effect_sd as &mut f64),
        ("fst", &mut c.fst),
        ("ancestry_effect", &mut c.ancestry_effect),
        ("batch_effect_sd", &mut c.batch_effect_sd),
        ("noise_sd", &mut c.noise_sd),
    ] {
        if let Some(x) = v.get(key).and_then(Json::as_f64) {
            *slot = x;
        }
    }
    if let Some(x) = v.get("binary_traits").and_then(|j| j.as_bool()) {
        c.binary_traits = x;
    }
    Ok(c)
}

fn parse_scan(v: &Json, mut s: ScanConfig) -> anyhow::Result<ScanConfig> {
    if let Some(b) = v.get("backend").and_then(Json::as_str) {
        // parties unknown here; threshold recomputed by launcher if needed
        s.backend = Backend::parse(b, 3)?;
    }
    if let Some(x) = v.get("frac_bits").and_then(Json::as_usize) {
        s.frac_bits = x as u32;
    }
    if let Some(x) = v.get("block_m").and_then(Json::as_usize) {
        s.block_m = x;
    }
    if let Some(x) = v.get("shard_m").and_then(Json::as_usize) {
        s.shard_m = x;
    }
    if let Some(x) = v.get("select_k").and_then(Json::as_usize) {
        s.select_k = x;
    }
    if let Some(x) = v.get("select_alpha").and_then(Json::as_f64) {
        anyhow::ensure!(x > 0.0 && x <= 1.0, "select_alpha must be in (0, 1]");
        s.select_alpha = x;
    }
    if let Some(x) = v.get("select_policy").and_then(Json::as_str) {
        s.select_policy = SelectPolicy::parse(x)?;
    }
    if let Some(x) = v.get("select_candidates").and_then(Json::as_usize) {
        s.select_candidates = x;
    }
    if let Some(x) = v.get("threads").and_then(Json::as_usize) {
        s.threads = Some(x);
    }
    if let Some(x) = v.get("compress_threads").and_then(Json::as_usize) {
        s.compress_threads = Some(x);
    }
    if let Some(x) = v.get("use_artifacts").and_then(|j| j.as_bool()) {
        s.use_artifacts = x;
    }
    if let Some(x) = v.get("artifacts_dir").and_then(Json::as_str) {
        s.artifacts_dir = x.to_string();
    }
    if let Some(x) = v.get("checkpoint_dir").and_then(Json::as_str) {
        s.checkpoint_dir = x.to_string();
    }
    if let Some(x) = v.get("resume").and_then(|j| j.as_bool()) {
        s.resume = x;
    }
    if let Some(x) = v.get("artifact_exec").and_then(Json::as_str) {
        s.artifact_exec = crate::runtime::ArtifactExec::parse(x)?;
    }
    if let Some(x) = parse_usize_vec(v, "entry_widths")? {
        s.entry_widths = x;
    }
    if let Some(x) = parse_usize_vec(v, "entry_traits")? {
        s.entry_traits = x;
    }
    if let Some(x) = v.get("entry_k_pad").and_then(Json::as_usize) {
        s.entry_k_pad = x;
    }
    s.entry_policy().validate()?;
    if let Some(x) = v.get("glm").and_then(Json::as_str) {
        s.glm = Glm::parse(x)?;
    }
    if let Some(x) = v.get("irls_max_iter").and_then(Json::as_usize) {
        anyhow::ensure!(x >= 1, "irls_max_iter must be ≥ 1");
        s.irls_max_iter = x;
    }
    if let Some(x) = v.get("irls_tol").and_then(Json::as_f64) {
        anyhow::ensure!(x.is_finite() && x > 0.0, "irls_tol must be a positive number");
        s.irls_tol = x;
    }
    if let Some(x) = v.get("r_method").and_then(Json::as_str) {
        s.r_method = match x {
            "auto" => RFactorMethod::Auto,
            "tsqr" => RFactorMethod::Tsqr,
            "cholesky" => RFactorMethod::Cholesky,
            other => anyhow::bail!("unknown r_method `{other}`"),
        };
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let cfg = RunConfig::default();
        let j = cfg.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.cohort.party_sizes, cfg.cohort.party_sizes);
        assert_eq!(back.scan.backend, cfg.scan.backend);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.sessions, 1);
        assert_eq!(back.max_concurrent, 4);
    }

    #[test]
    fn session_config_roundtrips_and_validates() {
        let j = Json::parse(r#"{"sessions": 16, "max_concurrent": 8}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.sessions, 16);
        assert_eq!(cfg.max_concurrent, 8);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.sessions, 16);
        assert_eq!(back.max_concurrent, 8);
        assert!(RunConfig::from_json(&Json::parse(r#"{"sessions": 0}"#).unwrap()).is_err());
        assert!(
            RunConfig::from_json(&Json::parse(r#"{"max_concurrent": 0}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn overrides_apply() {
        let j = Json::parse(
            r#"{"seed": 42, "transport": "tcp",
                "cohort": {"party_sizes": [100, 100], "m_variants": 50, "n_traits": 8,
                           "fst": 0.2},
                "scan": {"backend": "shamir", "frac_bits": 20, "r_method": "cholesky",
                         "shard_m": 4096, "select_k": 3, "select_alpha": 0.001,
                         "select_policy": "per-trait", "select_candidates": 16}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.transport, Transport::Tcp);
        assert_eq!(cfg.cohort.party_sizes, vec![100, 100]);
        assert_eq!(cfg.cohort.party_admixture.len(), 2); // auto-filled
        assert_eq!(cfg.cohort.m_variants, 50);
        assert_eq!(cfg.cohort.n_traits, 8);
        assert_eq!(cfg.scan.frac_bits, 20);
        assert_eq!(cfg.scan.r_method, RFactorMethod::Cholesky);
        assert_eq!(cfg.scan.shard_m, 4096);
        assert_eq!(cfg.scan.select_k, 3);
        assert_eq!(cfg.scan.select_alpha, 0.001);
        assert_eq!(cfg.scan.select_policy, SelectPolicy::PerTrait);
        assert_eq!(cfg.scan.select_candidates, 16);
    }

    #[test]
    fn select_config_roundtrips_through_json() {
        let mut cfg = RunConfig::default();
        cfg.scan.select_k = 2;
        cfg.scan.select_policy = SelectPolicy::PerTrait;
        cfg.scan.select_candidates = 8;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scan.select_k, 2);
        assert_eq!(back.scan.select_policy, SelectPolicy::PerTrait);
        assert_eq!(back.scan.select_candidates, 8);
        assert_eq!(back.scan.select_alpha, cfg.scan.select_alpha);
    }

    #[test]
    fn compress_threads_roundtrips_and_falls_back() {
        // default: unset, falls back to the legacy threads knob
        let cfg = RunConfig::default();
        assert_eq!(cfg.scan.compress_threads, None);
        assert_eq!(cfg.scan.effective_compress_threads(), None);
        let j = Json::parse(r#"{"scan": {"threads": 3, "compress_threads": 5}}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scan.threads, Some(3));
        assert_eq!(cfg.scan.compress_threads, Some(5));
        assert_eq!(cfg.scan.effective_compress_threads(), Some(5));
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scan.compress_threads, Some(5));
        assert_eq!(back.scan.threads, Some(3));
        // only the legacy knob set → it is the compress budget
        let j = Json::parse(r#"{"scan": {"threads": 2}}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scan.effective_compress_threads(), Some(2));
    }

    #[test]
    fn artifact_suite_config_roundtrips() {
        let j = Json::parse(
            r#"{"scan": {"use_artifacts": true, "artifact_exec": "reference",
                         "entry_widths": [8, 32], "entry_traits": [1, 8],
                         "entry_k_pad": 8}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert!(cfg.scan.use_artifacts);
        assert_eq!(cfg.scan.artifact_exec, crate::runtime::ArtifactExec::Reference);
        assert_eq!(cfg.scan.entry_widths, vec![8, 32]);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scan.artifact_exec, cfg.scan.artifact_exec);
        assert_eq!(back.scan.entry_widths, cfg.scan.entry_widths);
        assert_eq!(back.scan.entry_traits, cfg.scan.entry_traits);
        assert_eq!(back.scan.entry_k_pad, 8);
        // malformed shape policies are rejected at parse time
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"entry_widths": [32, 32]}}"#).unwrap()
        )
        .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"artifact_exec": "gpu"}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn checkpoint_config_roundtrips() {
        // defaults: checkpointing off
        let d = RunConfig::default();
        assert!(d.scan.checkpoint_dir.is_empty());
        assert!(!d.scan.resume);
        let j = Json::parse(r#"{"scan": {"checkpoint_dir": "/tmp/ckpt", "resume": true}}"#)
            .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scan.checkpoint_dir, "/tmp/ckpt");
        assert!(cfg.scan.resume);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scan.checkpoint_dir, "/tmp/ckpt");
        assert!(back.scan.resume);
    }

    #[test]
    fn glm_config_roundtrips_and_validates() {
        // defaults: linear scan, IRLS knobs at the stats-layer defaults
        let d = RunConfig::default();
        assert_eq!(d.scan.glm, Glm::Linear);
        assert!(!d.cohort.binary_traits);
        let j = Json::parse(
            r#"{"cohort": {"binary_traits": true},
                "scan": {"glm": "logistic", "irls_max_iter": 50, "irls_tol": 1e-9}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scan.glm, Glm::Logistic);
        assert!(cfg.cohort.binary_traits);
        assert_eq!(cfg.scan.irls_max_iter, 50);
        assert_eq!(cfg.scan.irls_tol, 1e-9);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scan.glm, Glm::Logistic);
        assert!(back.cohort.binary_traits);
        assert_eq!(back.scan.irls_max_iter, 50);
        assert_eq!(back.scan.irls_tol, 1e-9);
        assert!(RunConfig::from_json(&Json::parse(r#"{"scan": {"glm": "poisson"}}"#).unwrap())
            .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"irls_max_iter": 0}}"#).unwrap()
        )
        .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"irls_tol": -1.0}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn transport_names_roundtrip() {
        for name in ["inproc", "tcp", "reactor"] {
            let j = Json::parse(&format!(r#"{{"transport": "{name}"}}"#)).unwrap();
            let cfg = RunConfig::from_json(&j).unwrap();
            assert_eq!(transport_name(cfg.transport), name);
            let back = RunConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.transport, cfg.transport);
        }
    }

    #[test]
    fn bad_values_rejected() {
        assert!(RunConfig::from_json(&Json::parse(r#"{"transport": "carrier-pigeon"}"#).unwrap())
            .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"backend": "rot13"}}"#).unwrap()
        )
        .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"r_method": "qr-ish"}}"#).unwrap()
        )
        .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"select_policy": "greedy-ish"}}"#).unwrap()
        )
        .is_err());
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"scan": {"select_alpha": 0.0}}"#).unwrap()
        )
        .is_err());
    }
}
