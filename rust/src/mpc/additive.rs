//! Additive secret sharing over Z_2^64.
//!
//! `share(v, P)` splits a ring element into `P` uniformly random shares
//! summing (mod 2^64) to `v`; any `P−1` shares are jointly uniform and
//! reveal nothing. Aggregation is share-wise wrapping addition; the sum
//! of all parties' share-sums reconstructs Σv exactly.

use crate::util::rng::Rng;

/// Split `values` into `parties` share vectors.
pub fn share_vec(values: &[u64], parties: usize, rng: &mut Rng) -> Vec<Vec<u64>> {
    assert!(parties >= 1);
    let mut shares: Vec<Vec<u64>> = (0..parties).map(|_| vec![0u64; values.len()]).collect();
    for (i, &v) in values.iter().enumerate() {
        let mut acc = 0u64;
        for p in 0..parties - 1 {
            let s = rng.next_u64();
            shares[p][i] = s;
            acc = acc.wrapping_add(s);
        }
        shares[parties - 1][i] = v.wrapping_sub(acc);
    }
    shares
}

/// Share-wise sum (in place into `acc`).
pub fn add_assign(acc: &mut [u64], share: &[u64]) {
    assert_eq!(acc.len(), share.len());
    for (a, &s) in acc.iter_mut().zip(share) {
        *a = a.wrapping_add(s);
    }
}

/// Reconstruct from per-party share vectors.
pub fn reconstruct(shares: &[Vec<u64>]) -> Vec<u64> {
    assert!(!shares.is_empty());
    let mut out = vec![0u64; shares[0].len()];
    for s in shares {
        add_assign(&mut out, s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, PropConfig};

    #[test]
    fn shares_reconstruct() {
        let mut rng = Rng::new(70);
        let vals: Vec<u64> = (0..100).map(|_| rng.next_u64()).collect();
        for parties in [1usize, 2, 3, 7] {
            let shares = share_vec(&vals, parties, &mut rng);
            assert_eq!(shares.len(), parties);
            assert_eq!(reconstruct(&shares), vals);
        }
    }

    #[test]
    fn single_share_looks_uniform() {
        // crude uniformity check: mean of top bit ≈ 0.5
        let mut rng = Rng::new(71);
        let vals = vec![42u64; 4096];
        let shares = share_vec(&vals, 3, &mut rng);
        let ones: u32 = shares[0].iter().map(|s| (s >> 63) as u32).sum();
        let frac = ones as f64 / 4096.0;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn additive_property_sum_of_partials() {
        // Party-wise partial aggregation == reconstruct of all shares.
        run_prop(
            "additive-partial-sums",
            PropConfig::default(),
            |r| {
                let n = 1 + r.below(50) as usize;
                let p = 2 + r.below(6) as usize;
                let vals: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                (vals, p, r.next_u64())
            },
            |(vals, parties, seed)| {
                let mut rng = Rng::new(*seed);
                let shares = share_vec(vals, *parties, &mut rng);
                let rec = reconstruct(&shares);
                if &rec == vals {
                    Ok(())
                } else {
                    Err("reconstruction mismatch".to_string())
                }
            },
        );
    }
}
