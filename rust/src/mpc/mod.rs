//! Secure multi-party computation substrate for the combine stage.
//!
//! The paper's protocol needs exactly one cryptographic operation:
//! **secure summation** of fixed-size compressed statistics across
//! parties ("compress in plaintext, combine with crypto", §2). Three
//! backends are provided, in increasing strength/cost:
//!
//! - [`additive`] — additive secret sharing over `Z_2^64` of fixed-point
//!   values; a share vector per party, sums reconstruct exactly.
//! - [`masking`] — Bonawitz-style pairwise-mask secure aggregation: each
//!   ordered pair of parties derives a common PRG stream; party `i` adds
//!   `+mask(i,j)` for `j > i` and `−mask(j,i)` for `j < i`. All masks
//!   cancel in the sum, so the leader sees only the aggregate. One round,
//!   no per-party share fan-out — `O(P·len)` total bytes.
//! - [`shamir`] — t-of-P Shamir sharing over the Mersenne-61 prime field
//!   with Lagrange reconstruction; tolerates up to `t−1` colluding
//!   parties, at `O(P²·len)` bytes.
//!
//! [`beaver`] adds Beaver-triple multiplication over the field, used by
//! the `full` SMC level to compute the Lemma 3.1 ratios without revealing
//! the aggregate cross-products. [`fixed`] is the deterministic
//! real ↔ ring codec shared by all backends, and [`naive`] implements the
//! strawman the paper argues against: secret-sharing the raw `N×M` data.

pub mod fixed;
pub mod field;
pub mod additive;
pub mod masking;
pub mod shamir;
pub mod beaver;
pub mod naive;

/// Which SMC backend a combine session uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// No crypto: per-party statistics sent in the clear (simulation /
    /// baseline mode; matches the paper's plaintext comparator).
    Plaintext,
    /// Pairwise-mask secure aggregation over Z_2^64 (default).
    Masked,
    /// Shamir t-of-P over Mersenne-61.
    Shamir { threshold: usize },
}

impl Backend {
    pub fn parse(s: &str, parties: usize) -> anyhow::Result<Backend> {
        match s {
            "plaintext" => Ok(Backend::Plaintext),
            "masked" => Ok(Backend::Masked),
            "shamir" => Ok(Backend::Shamir { threshold: parties.div_ceil(2) + 1 }),
            other => anyhow::bail!("unknown SMC backend `{other}` (plaintext|masked|shamir)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Plaintext => "plaintext",
            Backend::Masked => "masked",
            Backend::Shamir { .. } => "shamir",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("plaintext", 4).unwrap(), Backend::Plaintext);
        assert_eq!(Backend::parse("masked", 4).unwrap(), Backend::Masked);
        assert_eq!(Backend::parse("shamir", 4).unwrap(), Backend::Shamir { threshold: 3 });
        assert!(Backend::parse("bogus", 4).is_err());
    }
}
