//! Fixed-point codec: reals ↔ ring elements.
//!
//! All secure-sum backends operate on integers (Z_2^64 or the Mersenne-61
//! field). Statistics are encoded as two's-complement fixed point with
//! `FRAC_BITS` fractional bits. The codec must satisfy, for any party
//! values `v_p` within range: `decode(Σ encode(v_p)) = Σ round(v_p)`
//! exactly in the ring — encoding is a ring homomorphism up to rounding,
//! which is what makes share-wise addition compute the true sum.
//!
//! Range analysis: compressed statistics are sums of products of
//! standardized data, magnitude ≤ N·max²  ≈ 2^20·2^6 = 2^26 for our
//! largest workloads; with 24 fractional bits values fit comfortably in
//! i64 (2^26+24 = 2^50 ≪ 2^63). [`FixedCodec::check_range`] enforces this
//! at encode time rather than silently wrapping.
//!
//! ## Precision contract (pinned by `tests/integration_precision.rs`)
//!
//! Per encoded element the rounding error is ≤ `0.5 / 2^frac_bits`, and
//! a sum across `P` parties inherits ≤ `P` such roundings
//! ([`FixedCodec::sum_error_bound`]) — the masked ring (Z_2^64) and the
//! Shamir field (Mersenne-61) add **no** further error; both are exact
//! on the encoded integers. Downstream, with the default
//! `frac_bits = 24`, the envelope test sweeps joint trait/genotype
//! magnitudes across decades (scale 0.03 … 100, the widest band the
//! range check admits for its cohort) and pins the secure backends to
//! the plaintext scan within **1e-3 relative (floor 0.05 absolute) on
//! β̂ and σ̂** for every finite variant. Magnitudes past
//! [`FixedCodec::max_abs`] are rejected at encode time, never silently
//! wrapped.

/// Fixed-point parameters.
#[derive(Clone, Copy, Debug)]
pub struct FixedCodec {
    pub frac_bits: u32,
}

impl Default for FixedCodec {
    fn default() -> Self {
        FixedCodec { frac_bits: 24 }
    }
}

impl FixedCodec {
    pub fn new(frac_bits: u32) -> Self {
        assert!(frac_bits < 62);
        FixedCodec { frac_bits }
    }

    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Largest encodable magnitude (with headroom for summing across
    /// up to 2^10 parties without overflow).
    pub fn max_abs(&self) -> f64 {
        ((1u64 << (62 - self.frac_bits - 10)) as f64).floor()
    }

    /// Encode one value into the ring Z_2^64 (two's complement).
    #[inline]
    pub fn encode(&self, v: f64) -> anyhow::Result<u64> {
        self.check_range(v)?;
        let scaled = (v * self.scale()).round() as i64;
        Ok(scaled as u64)
    }

    /// Decode one ring element.
    #[inline]
    pub fn decode(&self, r: u64) -> f64 {
        (r as i64) as f64 / self.scale()
    }

    pub fn check_range(&self, v: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            v.is_finite() && v.abs() <= self.max_abs(),
            "value {v:e} outside fixed-point range ±{:e} (frac_bits={}); \
             consider standardizing inputs or lowering frac_bits",
            self.max_abs(),
            self.frac_bits
        );
        Ok(())
    }

    /// Encode a slice.
    pub fn encode_vec(&self, vs: &[f64]) -> anyhow::Result<Vec<u64>> {
        vs.iter().map(|&v| self.encode(v)).collect()
    }

    /// Decode a slice.
    pub fn decode_vec(&self, rs: &[u64]) -> Vec<f64> {
        rs.iter().map(|&r| self.decode(r)).collect()
    }

    /// Worst-case absolute rounding error of a sum of `terms` encodings.
    pub fn sum_error_bound(&self, terms: usize) -> f64 {
        0.5 * terms as f64 / self.scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_for_representable() {
        let c = FixedCodec::default();
        for &v in &[0.0, 1.0, -1.0, 0.5, -1234.0625, 1e6] {
            let r = c.encode(v).unwrap();
            assert_eq!(c.decode(r), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        let c = FixedCodec::default();
        let mut rng = Rng::new(50);
        for _ in 0..10_000 {
            let v = rng.normal_ms(0.0, 100.0);
            let err = (c.decode(c.encode(v).unwrap()) - v).abs();
            assert!(err <= 0.5 / c.scale() + 1e-15, "v={v} err={err:e}");
        }
    }

    #[test]
    fn homomorphic_addition_matches_sum() {
        // decode(Σ encode(v_p)) == Σ fixed(v_p) exactly
        let c = FixedCodec::default();
        let mut rng = Rng::new(51);
        for _ in 0..1000 {
            let vs: Vec<f64> = (0..8).map(|_| rng.normal_ms(0.0, 50.0)).collect();
            let ring_sum = vs
                .iter()
                .map(|&v| c.encode(v).unwrap())
                .fold(0u64, |a, b| a.wrapping_add(b));
            let sum_rounded: f64 = vs
                .iter()
                .map(|&v| (v * c.scale()).round() / c.scale())
                .sum();
            assert!((c.decode(ring_sum) - sum_rounded).abs() < 1e-12);
        }
    }

    #[test]
    fn negative_values_wrap_correctly() {
        let c = FixedCodec::default();
        let r = c.encode(-3.25).unwrap();
        assert!(r > u64::MAX / 2); // two's complement wrap
        assert_eq!(c.decode(r), -3.25);
    }

    #[test]
    fn out_of_range_rejected() {
        let c = FixedCodec::default();
        assert!(c.encode(c.max_abs() * 2.0).is_err());
        assert!(c.encode(f64::NAN).is_err());
        assert!(c.encode(f64::INFINITY).is_err());
    }

    #[test]
    fn vec_roundtrip() {
        let c = FixedCodec::new(20);
        let vs = vec![1.5, -2.25, 0.0, 1000.0];
        let enc = c.encode_vec(&vs).unwrap();
        assert_eq!(c.decode_vec(&enc), vs);
    }

    #[test]
    fn error_bound_monotone() {
        let c = FixedCodec::default();
        assert!(c.sum_error_bound(10) < c.sum_error_bound(100));
    }
}
