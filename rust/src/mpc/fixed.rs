//! Fixed-point codec: reals ↔ ring elements.
//!
//! All secure-sum backends operate on integers (Z_2^64 or the Mersenne-61
//! field). Statistics are encoded as two's-complement fixed point with
//! `FRAC_BITS` fractional bits. The codec must satisfy, for any party
//! values `v_p` within range: `decode(Σ encode(v_p)) = Σ round(v_p)`
//! exactly in the ring — encoding is a ring homomorphism up to rounding,
//! which is what makes share-wise addition compute the true sum.
//!
//! Range analysis: compressed statistics are sums of products of
//! standardized data, magnitude ≤ N·max²  ≈ 2^20·2^6 = 2^26 for our
//! largest workloads; with 24 fractional bits values fit comfortably in
//! i64 (2^26+24 = 2^50 ≪ 2^63). [`FixedCodec::check_range`] enforces this
//! at encode time rather than silently wrapping.
//!
//! ## Precision contract (pinned by `tests/integration_precision.rs`)
//!
//! Per encoded element the rounding error is ≤ `0.5 / 2^frac_bits`, and
//! a sum across `P` parties inherits ≤ `P` such roundings
//! ([`FixedCodec::sum_error_bound`]) — the masked ring (Z_2^64) and the
//! Shamir field (Mersenne-61) add **no** further error; both are exact
//! on the encoded integers. Downstream, with the default
//! `frac_bits = 24`, the envelope test sweeps joint trait/genotype
//! magnitudes across decades (scale 0.03 … 100, the widest band the
//! range check admits for its cohort) and pins the secure backends to
//! the plaintext scan within **1e-3 relative (floor 0.05 absolute) on
//! β̂ and σ̂** for every finite variant. Magnitudes past
//! [`FixedCodec::max_abs`] are rejected at encode time, never silently
//! wrapped.
//!
//! ### IRLS weighted sums (logistic scans)
//!
//! The logistic workload secure-sums *reweighted* cross-products. The
//! IRLS weights are intrinsically bounded — `w = μ(1-μ) ∈ (0, 1/4]` —
//! and although the working response `z = η + (y-μ)/w` is unbounded as
//! `w → 0`, every encoded entry carries the product `w·z = w·η + (y-μ)`
//! with `|y-μ| ≤ 1`, so the weighted sums `CᵀWC`, `CᵀWz`, `XᵀWX`,
//! `CᵀWX` and the score `Xᵀ(y-μ)` all stay within `O(N·max(|C|,|X|)² ·
//! max(1, |η|))` of the linear envelope. The one way out of the
//! envelope is **quasi-separation**: a perfectly predictive covariate
//! drives `β̂` (hence `η`) toward ±∞ iteration over iteration, the
//! leader-side divergence guard trips first in practice, and any
//! weighted sum that does outgrow [`FixedCodec::max_abs`] is rejected
//! at encode time with a range error — never silently wrapped
//! (regression-tested by the quasi-separated cohort in
//! `tests/logistic.rs`).

/// Fixed-point parameters.
#[derive(Clone, Copy, Debug)]
pub struct FixedCodec {
    pub frac_bits: u32,
}

impl Default for FixedCodec {
    fn default() -> Self {
        FixedCodec { frac_bits: 24 }
    }
}

/// Largest supported `frac_bits`: `max_abs` keeps 10 bits of party
/// headroom under the 62-bit magnitude budget, so the integer part
/// runs out at `62 - 10 = 52` fractional bits (`max_abs() == 1.0`).
/// Anything above would underflow the shift — the old `frac_bits < 62`
/// bound let `max_abs` panic in debug and wrap to a bogus huge range
/// (defeating `check_range`) in release.
pub const MAX_FRAC_BITS: u32 = 52;

impl FixedCodec {
    /// Construct with a trusted `frac_bits` (panics on an unsupported
    /// value — use [`try_new`](Self::try_new) for wire-derived input).
    pub fn new(frac_bits: u32) -> Self {
        Self::try_new(frac_bits).expect("unsupported frac_bits")
    }

    /// Non-panicking constructor for untrusted (wire/config) values.
    pub fn try_new(frac_bits: u32) -> anyhow::Result<Self> {
        anyhow::ensure!(
            frac_bits <= MAX_FRAC_BITS,
            "frac_bits {frac_bits} unsupported (max {MAX_FRAC_BITS}: \
             larger values underflow the max_abs headroom shift)"
        );
        Ok(FixedCodec { frac_bits })
    }

    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Largest encodable magnitude (with headroom for summing across
    /// up to 2^10 parties without overflow). Non-panicking over the
    /// whole constructor-admitted range `0..=MAX_FRAC_BITS`.
    pub fn max_abs(&self) -> f64 {
        (1u64 << (62 - self.frac_bits.min(MAX_FRAC_BITS) - 10)) as f64
    }

    /// Encode one value into the ring Z_2^64 (two's complement).
    #[inline]
    pub fn encode(&self, v: f64) -> anyhow::Result<u64> {
        self.check_range(v)?;
        let scaled = (v * self.scale()).round() as i64;
        Ok(scaled as u64)
    }

    /// Decode one ring element.
    #[inline]
    pub fn decode(&self, r: u64) -> f64 {
        (r as i64) as f64 / self.scale()
    }

    pub fn check_range(&self, v: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            v.is_finite() && v.abs() <= self.max_abs(),
            "value {v:e} outside fixed-point range ±{:e} (frac_bits={}); \
             consider standardizing inputs or lowering frac_bits",
            self.max_abs(),
            self.frac_bits
        );
        Ok(())
    }

    /// Encode a slice.
    pub fn encode_vec(&self, vs: &[f64]) -> anyhow::Result<Vec<u64>> {
        vs.iter().map(|&v| self.encode(v)).collect()
    }

    /// Decode a slice.
    pub fn decode_vec(&self, rs: &[u64]) -> Vec<f64> {
        rs.iter().map(|&r| self.decode(r)).collect()
    }

    /// Worst-case absolute rounding error of a sum of `terms` encodings.
    pub fn sum_error_bound(&self, terms: usize) -> f64 {
        0.5 * terms as f64 / self.scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_for_representable() {
        let c = FixedCodec::default();
        for &v in &[0.0, 1.0, -1.0, 0.5, -1234.0625, 1e6] {
            let r = c.encode(v).unwrap();
            assert_eq!(c.decode(r), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        let c = FixedCodec::default();
        let mut rng = Rng::new(50);
        for _ in 0..10_000 {
            let v = rng.normal_ms(0.0, 100.0);
            let err = (c.decode(c.encode(v).unwrap()) - v).abs();
            assert!(err <= 0.5 / c.scale() + 1e-15, "v={v} err={err:e}");
        }
    }

    #[test]
    fn homomorphic_addition_matches_sum() {
        // decode(Σ encode(v_p)) == Σ fixed(v_p) exactly
        let c = FixedCodec::default();
        let mut rng = Rng::new(51);
        for _ in 0..1000 {
            let vs: Vec<f64> = (0..8).map(|_| rng.normal_ms(0.0, 50.0)).collect();
            let ring_sum = vs
                .iter()
                .map(|&v| c.encode(v).unwrap())
                .fold(0u64, |a, b| a.wrapping_add(b));
            let sum_rounded: f64 = vs
                .iter()
                .map(|&v| (v * c.scale()).round() / c.scale())
                .sum();
            assert!((c.decode(ring_sum) - sum_rounded).abs() < 1e-12);
        }
    }

    #[test]
    fn negative_values_wrap_correctly() {
        let c = FixedCodec::default();
        let r = c.encode(-3.25).unwrap();
        assert!(r > u64::MAX / 2); // two's complement wrap
        assert_eq!(c.decode(r), -3.25);
    }

    #[test]
    fn out_of_range_rejected() {
        let c = FixedCodec::default();
        assert!(c.encode(c.max_abs() * 2.0).is_err());
        assert!(c.encode(f64::NAN).is_err());
        assert!(c.encode(f64::INFINITY).is_err());
    }

    #[test]
    fn vec_roundtrip() {
        let c = FixedCodec::new(20);
        let vs = vec![1.5, -2.25, 0.0, 1000.0];
        let enc = c.encode_vec(&vs).unwrap();
        assert_eq!(c.decode_vec(&enc), vs);
    }

    #[test]
    fn error_bound_monotone() {
        let c = FixedCodec::default();
        assert!(c.sum_error_bound(10) < c.sum_error_bound(100));
    }

    /// Boundary of the tightened constructor bound: `MAX_FRAC_BITS` is
    /// accepted with a sane (non-wrapped) `max_abs`, one past it is
    /// rejected — the shift that used to underflow for
    /// `52 < frac_bits < 62` can no longer be reached.
    #[test]
    fn frac_bits_boundary() {
        let c = FixedCodec::new(MAX_FRAC_BITS);
        assert_eq!(c.max_abs(), 1.0);
        assert_eq!(c.decode(c.encode(1.0).unwrap()), 1.0);
        assert!(c.encode(1.5).is_err(), "past max_abs must be rejected");
        assert!(FixedCodec::try_new(MAX_FRAC_BITS).is_ok());
        for bad in [MAX_FRAC_BITS + 1, 61, 62, u32::MAX] {
            assert!(FixedCodec::try_new(bad).is_err(), "frac_bits={bad}");
        }
    }

    #[test]
    #[should_panic(expected = "unsupported frac_bits")]
    fn new_panics_past_bound() {
        let _ = FixedCodec::new(MAX_FRAC_BITS + 1);
    }

    /// max_abs is monotone decreasing in frac_bits over the whole
    /// admitted range and never wraps to a bogus huge value.
    #[test]
    fn max_abs_sane_across_range() {
        let mut prev = f64::INFINITY;
        for fb in 0..=MAX_FRAC_BITS {
            let m = FixedCodec::new(fb).max_abs();
            assert!(m >= 1.0 && m < prev, "frac_bits={fb}: max_abs={m}");
            prev = m;
        }
    }
}
