//! Shamir t-of-P secret sharing over GF(2^61 − 1).
//!
//! Stronger threat model than pairwise masking: any coalition of fewer
//! than `threshold` parties learns nothing, and reconstruction succeeds
//! from any `threshold` shares (robust to P − threshold dropouts).
//! Costs `O(P)` shares per secret per party (`O(P²·len)` session bytes),
//! measured in bench_mpc.

use super::field::{random_fe, Fe};
use crate::util::rng::Rng;

/// A share: evaluation of the secret polynomial at x = party index + 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Share {
    /// evaluation point (1-based party id)
    pub x: u64,
    pub y: Fe,
}

/// Split `secret` into `parties` shares with reconstruction threshold
/// `threshold` (degree `threshold−1` polynomial).
pub fn share(secret: Fe, parties: usize, threshold: usize, rng: &mut Rng) -> Vec<Share> {
    assert!(threshold >= 1 && threshold <= parties, "1 ≤ t ≤ P");
    // coefficients: [secret, a1, ..., a_{t-1}]
    let coeffs: Vec<Fe> = std::iter::once(secret)
        .chain((1..threshold).map(|_| random_fe(rng)))
        .collect();
    (1..=parties as u64)
        .map(|x| {
            // Horner evaluation at x
            let fx = Fe::new(x);
            let mut acc = Fe(0);
            for &c in coeffs.iter().rev() {
                acc = acc.mul(fx).add(c);
            }
            Share { x, y: acc }
        })
        .collect()
}

/// Share a vector: returns `parties` share vectors.
pub fn share_vec(
    secrets: &[Fe],
    parties: usize,
    threshold: usize,
    rng: &mut Rng,
) -> Vec<Vec<Share>> {
    let mut out: Vec<Vec<Share>> = (0..parties).map(|_| Vec::with_capacity(secrets.len())).collect();
    for &s in secrets {
        for (p, sh) in share(s, parties, threshold, rng).into_iter().enumerate() {
            out[p].push(sh);
        }
    }
    out
}

/// Lagrange reconstruction at x = 0 from any ≥ threshold shares
/// (distinct evaluation points required).
pub fn reconstruct(shares: &[Share]) -> Fe {
    assert!(!shares.is_empty());
    let mut acc = Fe(0);
    for (i, si) in shares.iter().enumerate() {
        let mut num = Fe(1);
        let mut den = Fe(1);
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            assert_ne!(si.x, sj.x, "duplicate evaluation points");
            num = num.mul(Fe::new(sj.x).neg()); // (0 − x_j)
            den = den.mul(Fe::new(si.x).sub(Fe::new(sj.x)));
        }
        acc = acc.add(si.y.mul(num.mul(den.inv())));
    }
    acc
}

/// Reconstruct a vector from per-party share vectors (first `threshold`
/// parties' shares are used; pass exactly the surviving parties).
pub fn reconstruct_vec(party_shares: &[&[Share]]) -> Vec<Fe> {
    assert!(!party_shares.is_empty());
    let len = party_shares[0].len();
    (0..len)
        .map(|i| {
            let row: Vec<Share> = party_shares.iter().map(|p| p[i]).collect();
            reconstruct(&row)
        })
        .collect()
}

/// Element-wise Lagrange reconstruction from summed share vectors held
/// by an arbitrary surviving quorum. `points[q]` is party `q`'s
/// evaluation point (1-based party id) and `sums[q]` its share-sum
/// vector of raw field words — the dropout-recovery path feeds whichever
/// parties stayed alive, which need not be a prefix of the roster.
/// Reconstruction is field-exact for **any** ≥ threshold distinct
/// points, so a degraded quorum yields bit-identical secrets.
pub fn reconstruct_sums(points: &[u64], sums: &[&[u64]]) -> Vec<Fe> {
    assert_eq!(points.len(), sums.len(), "one evaluation point per sum vector");
    assert!(!sums.is_empty());
    let len = sums[0].len();
    for s in sums {
        assert_eq!(s.len(), len, "ragged share-sum vectors");
    }
    (0..len)
        .map(|i| {
            let row: Vec<Share> = points
                .iter()
                .zip(sums)
                .map(|(&x, s)| Share { x, y: Fe(s[i]) })
                .collect();
            reconstruct(&row)
        })
        .collect()
}

/// Share-wise addition: add another party's contribution share-by-share
/// (same evaluation points required).
pub fn add_share_vecs(a: &mut [Share], b: &[Share]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        assert_eq!(x.x, y.x, "mismatched evaluation points");
        x.y = x.y.add(y.y);
    }
}

/// Per-session share-randomness stream for one party: keyed by the
/// session's pairwise seeds, the party index, *and* the session id, so
/// concurrent sessions over the same setup (even with identical seeds)
/// draw their sharing polynomials from disjoint streams — the Shamir
/// analogue of the pairwise-mask domain separation
/// (`tests/mask_domains.rs`).
pub fn session_rng(seeds: &[u64], party_index: u64, session: u64) -> Rng {
    let base = seeds.iter().fold(0x5A17u64, |a, &s| a ^ s.rotate_left(17))
        ^ party_index.wrapping_mul(0x9E3779B97F4A7C15);
    Rng::new(base).derive(session)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn share_reconstruct_roundtrip() {
        let mut rng = Rng::new(90);
        for &(p, t) in &[(3usize, 2usize), (5, 3), (7, 7), (4, 1)] {
            let secret = random_fe(&mut rng);
            let shares = share(secret, p, t, &mut rng);
            assert_eq!(reconstruct(&shares[..t]), secret, "p={p} t={t} (min quorum)");
            assert_eq!(reconstruct(&shares), secret, "p={p} t={t} (all)");
        }
    }

    #[test]
    fn any_quorum_works() {
        let mut rng = Rng::new(91);
        let secret = Fe::new(123456789);
        let shares = share(secret, 5, 3, &mut rng);
        // every 3-subset
        for a in 0..5 {
            for b in a + 1..5 {
                for c in b + 1..5 {
                    let q = [shares[a], shares[b], shares[c]];
                    assert_eq!(reconstruct(&q), secret);
                }
            }
        }
    }

    #[test]
    fn below_threshold_is_random_looking() {
        // With t=3, two shares + wrong guess of third ≠ secret (sanity;
        // information-theoretic privacy is by construction).
        let mut rng = Rng::new(92);
        let s1 = share(Fe::new(1111), 4, 3, &mut rng);
        let s2 = share(Fe::new(2222), 4, 3, &mut rng);
        // identical first-two-share prefixes can encode different secrets:
        // reconstruct on 2 shares is under-determined — Lagrange on 2 pts
        // of a degree-2 polynomial gives garbage, not either secret.
        let r1 = reconstruct(&s1[..2]);
        let r2 = reconstruct(&s2[..2]);
        assert_ne!(r1, Fe::new(1111));
        assert_ne!(r2, Fe::new(2222));
    }

    #[test]
    fn homomorphic_sum() {
        let mut rng = Rng::new(93);
        let secrets = [Fe::new(100), Fe::new(250), Fe::new(7)];
        let parties = 4;
        let t = 3;
        // each party ends up with the share-sum of all secrets
        let mut acc: Option<Vec<Share>> = None;
        for &s in &secrets {
            let sh = share(s, parties, t, &mut rng);
            match &mut acc {
                None => acc = Some(sh),
                Some(a) => add_share_vecs(a, &sh),
            }
        }
        let total = reconstruct(&acc.unwrap()[..t]);
        assert_eq!(total, Fe::new(357));
    }

    #[test]
    fn vector_api_roundtrip() {
        let mut rng = Rng::new(94);
        let secrets: Vec<Fe> = (0..20).map(|_| random_fe(&mut rng)).collect();
        let party_shares = share_vec(&secrets, 5, 3, &mut rng);
        let quorum: Vec<&[Share]> = party_shares[..3].iter().map(|v| v.as_slice()).collect();
        assert_eq!(reconstruct_vec(&quorum), secrets);
    }

    #[test]
    fn reconstruct_sums_from_any_survivor_subset_is_exact() {
        // 5 parties, t = 3: sum two shared vectors share-wise, then
        // reconstruct the totals from every 3-subset of "survivors" —
        // all subsets must agree exactly (the Degraded-but-correct
        // property the dropout recovery path relies on)
        let mut rng = Rng::new(96);
        let a: Vec<Fe> = (0..9).map(|_| random_fe(&mut rng)).collect();
        let b: Vec<Fe> = (0..9).map(|_| random_fe(&mut rng)).collect();
        let want: Vec<Fe> = a.iter().zip(&b).map(|(x, y)| x.add(*y)).collect();
        let mut party_sums = share_vec(&a, 5, 3, &mut rng);
        for (acc, sh) in party_sums.iter_mut().zip(share_vec(&b, 5, 3, &mut rng)) {
            add_share_vecs(acc, &sh);
        }
        let raw: Vec<Vec<u64>> = party_sums
            .iter()
            .map(|v| v.iter().map(|s| s.y.0).collect())
            .collect();
        for i in 0..5 {
            for j in i + 1..5 {
                for k in j + 1..5 {
                    let points = [i as u64 + 1, j as u64 + 1, k as u64 + 1];
                    let sums = [raw[i].as_slice(), raw[j].as_slice(), raw[k].as_slice()];
                    assert_eq!(
                        reconstruct_sums(&points, &sums),
                        want,
                        "survivors {points:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn signed_values_through_field() {
        let mut rng = Rng::new(95);
        let v = -123456i64;
        let shares = share(Fe::from_i64(v), 3, 2, &mut rng);
        assert_eq!(reconstruct(&shares[..2]).to_i64(), v);
    }

    #[test]
    #[should_panic(expected = "duplicate evaluation points")]
    fn duplicate_points_panic() {
        let s = Share { x: 1, y: Fe(5) };
        let _ = reconstruct(&[s, s]);
    }
}
