//! Beaver-triple multiplication over GF(2^61 − 1).
//!
//! Extension beyond the paper's combine-by-summation: with multiplication
//! we can evaluate the Lemma 3.1 *ratios* under MPC so the parties reveal
//! only (β̂, σ̂) rather than the aggregate cross-products (`--smc-level
//! full`). Triples are dealt by a trusted offline dealer (standard
//! preprocessing model; in production they would come from OT/HE).
//!
//! Protocol (semi-honest, additive shares over the field): to multiply
//! secrets x, y given triple (a, b, c=ab): parties open d = x−a and
//! e = y−b, then each computes share `z_i = c_i + d·b_i + e·a_i` and one
//! designated party adds `d·e`. Σz_i = xy.

use super::field::{random_fe, Fe};
use crate::util::rng::Rng;

/// One multiplication triple, additively shared across parties.
#[derive(Clone, Debug)]
pub struct TripleShares {
    /// a_i, b_i, c_i per party; Σa·Σb = Σc
    pub a: Vec<Fe>,
    pub b: Vec<Fe>,
    pub c: Vec<Fe>,
}

/// Additive sharing of a field element across `parties`.
pub fn additive_share_fe(v: Fe, parties: usize, rng: &mut Rng) -> Vec<Fe> {
    let mut shares: Vec<Fe> = (0..parties - 1).map(|_| random_fe(rng)).collect();
    let partial = shares.iter().fold(Fe(0), |acc, s| acc.add(*s));
    shares.push(v.sub(partial));
    shares
}

/// Reconstruct an additively shared element.
pub fn additive_open(shares: &[Fe]) -> Fe {
    shares.iter().fold(Fe(0), |acc, s| acc.add(*s))
}

/// Offline dealer: produce one random triple shared across `parties`.
pub fn deal_triple(parties: usize, rng: &mut Rng) -> TripleShares {
    let a = random_fe(rng);
    let b = random_fe(rng);
    let c = a.mul(b);
    TripleShares {
        a: additive_share_fe(a, parties, rng),
        b: additive_share_fe(b, parties, rng),
        c: additive_share_fe(c, parties, rng),
    }
}

/// One party's state in a Beaver multiplication.
pub struct BeaverParty {
    pub index: usize,
    pub x: Fe,
    pub y: Fe,
    pub a: Fe,
    pub b: Fe,
    pub c: Fe,
}

impl BeaverParty {
    /// Round 1: masked openings (d_i, e_i) to broadcast.
    pub fn openings(&self) -> (Fe, Fe) {
        (self.x.sub(self.a), self.y.sub(self.b))
    }

    /// Round 2: local share of the product given opened d = Σd_i,
    /// e = Σe_i.
    pub fn product_share(&self, d: Fe, e: Fe) -> Fe {
        let mut z = self.c.add(d.mul(self.b)).add(e.mul(self.a));
        if self.index == 0 {
            z = z.add(d.mul(e));
        }
        z
    }
}

/// Run a full multiplication of two shared secrets (test/driver helper —
/// the coordinator runs the same steps over the transport).
pub fn multiply_shared(
    x_shares: &[Fe],
    y_shares: &[Fe],
    triple: &TripleShares,
) -> Vec<Fe> {
    let parties = x_shares.len();
    assert_eq!(y_shares.len(), parties);
    let ps: Vec<BeaverParty> = (0..parties)
        .map(|i| BeaverParty {
            index: i,
            x: x_shares[i],
            y: y_shares[i],
            a: triple.a[i],
            b: triple.b[i],
            c: triple.c[i],
        })
        .collect();
    let (ds, es): (Vec<Fe>, Vec<Fe>) = ps.iter().map(|p| p.openings()).unzip();
    let d = additive_open(&ds);
    let e = additive_open(&es);
    ps.iter().map(|p| p.product_share(d, e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, PropConfig};

    #[test]
    fn triple_is_consistent() {
        let mut rng = Rng::new(100);
        let t = deal_triple(4, &mut rng);
        let a = additive_open(&t.a);
        let b = additive_open(&t.b);
        let c = additive_open(&t.c);
        assert_eq!(a.mul(b), c);
    }

    #[test]
    fn multiplication_correct() {
        let mut rng = Rng::new(101);
        for parties in [2usize, 3, 5] {
            let x = random_fe(&mut rng);
            let y = random_fe(&mut rng);
            let xs = additive_share_fe(x, parties, &mut rng);
            let ys = additive_share_fe(y, parties, &mut rng);
            let t = deal_triple(parties, &mut rng);
            let zs = multiply_shared(&xs, &ys, &t);
            assert_eq!(additive_open(&zs), x.mul(y), "parties={parties}");
        }
    }

    #[test]
    fn multiplication_property() {
        run_prop(
            "beaver-mul",
            PropConfig { cases: 40, ..Default::default() },
            |r| (r.next_u64() % 1_000_000, r.next_u64() % 1_000_000, r.next_u64()),
            |&(xv, yv, seed)| {
                let mut rng = Rng::new(seed);
                let x = Fe::new(xv);
                let y = Fe::new(yv);
                let xs = additive_share_fe(x, 3, &mut rng);
                let ys = additive_share_fe(y, 3, &mut rng);
                let t = deal_triple(3, &mut rng);
                let z = additive_open(&multiply_shared(&xs, &ys, &t));
                if z == x.mul(y) {
                    Ok(())
                } else {
                    Err(format!("{}·{} gave {}", x.0, y.0, z.0))
                }
            },
        );
    }

    #[test]
    fn openings_hide_secrets() {
        // d = x − a is uniform (a uniform) → d ≠ x almost surely.
        let mut rng = Rng::new(102);
        let x = Fe::new(42);
        let xs = additive_share_fe(x, 2, &mut rng);
        let ys = additive_share_fe(Fe::new(7), 2, &mut rng);
        let t = deal_triple(2, &mut rng);
        let p0 = BeaverParty {
            index: 0,
            x: xs[0],
            y: ys[0],
            a: t.a[0],
            b: t.b[0],
            c: t.c[0],
        };
        let (d, e) = p0.openings();
        assert_ne!(d, xs[0]);
        assert_ne!(e, ys[0]);
    }

    #[test]
    fn additive_share_roundtrip() {
        let mut rng = Rng::new(103);
        for parties in [1usize, 2, 8] {
            let v = random_fe(&mut rng);
            let s = additive_share_fe(v, parties, &mut rng);
            assert_eq!(s.len(), parties);
            assert_eq!(additive_open(&s), v);
        }
    }
}
