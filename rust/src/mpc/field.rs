//! Arithmetic in the Mersenne-61 prime field GF(2^61 − 1).
//!
//! Used by the Shamir backend and Beaver-triple multiplication. The
//! Mersenne modulus makes reduction two adds and a mask — fast enough
//! that field arithmetic never appears in combine-stage profiles.

/// The prime 2^61 − 1.
pub const P: u64 = (1u64 << 61) - 1;

/// A field element (always kept in `[0, P)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fe(pub u64);

impl Fe {
    #[inline]
    pub fn new(v: u64) -> Fe {
        Fe(v % P)
    }

    /// Map a signed 64-bit integer into the field (two's complement →
    /// mod-P representative). Fixed-point values go through this.
    #[inline]
    pub fn from_i64(v: i64) -> Fe {
        if v >= 0 {
            Fe::new(v as u64)
        } else {
            Fe::new(P - ((-(v as i128)) as u64 % P))
        }
    }

    /// Back to a signed integer, interpreting values > P/2 as negative.
    #[inline]
    pub fn to_i64(self) -> i64 {
        if self.0 > P / 2 {
            -((P - self.0) as i64)
        } else {
            self.0 as i64
        }
    }

    #[inline]
    pub fn add(self, o: Fe) -> Fe {
        let s = self.0 + o.0; // ≤ 2P−2 < 2^62, no overflow
        Fe(if s >= P { s - P } else { s })
    }

    #[inline]
    pub fn sub(self, o: Fe) -> Fe {
        Fe(if self.0 >= o.0 { self.0 - o.0 } else { self.0 + P - o.0 })
    }

    #[inline]
    pub fn neg(self) -> Fe {
        if self.0 == 0 {
            self
        } else {
            Fe(P - self.0)
        }
    }

    #[inline]
    pub fn mul(self, o: Fe) -> Fe {
        let prod = self.0 as u128 * o.0 as u128;
        // Mersenne reduction: x = hi·2^61 + lo ≡ hi + lo (mod 2^61−1)
        let lo = (prod & P as u128) as u64;
        let hi = (prod >> 61) as u64;
        let s = lo + hi;
        Fe(if s >= P { s - P } else { s })
    }

    /// Modular inverse via Fermat (exponent P−2).
    pub fn inv(self) -> Fe {
        assert!(self.0 != 0, "inverse of zero");
        self.pow(P - 2)
    }

    pub fn pow(self, mut e: u64) -> Fe {
        let mut base = self;
        let mut acc = Fe(1);
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }
}

/// Sample a uniform field element.
pub fn random_fe(rng: &mut crate::util::rng::Rng) -> Fe {
    // rejection sampling from 61 random bits
    loop {
        let v = rng.next_u64() >> 3; // 61 bits
        if v < P {
            return Fe(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn add_sub_inverse() {
        let mut rng = Rng::new(60);
        for _ in 0..1000 {
            let a = random_fe(&mut rng);
            let b = random_fe(&mut rng);
            assert_eq!(a.add(b).sub(b), a);
            assert_eq!(a.sub(a), Fe(0));
            assert_eq!(a.add(a.neg()), Fe(0));
        }
    }

    #[test]
    fn mul_matches_u128_reference() {
        let mut rng = Rng::new(61);
        for _ in 0..1000 {
            let a = random_fe(&mut rng);
            let b = random_fe(&mut rng);
            let want = ((a.0 as u128 * b.0 as u128) % P as u128) as u64;
            assert_eq!(a.mul(b).0, want);
        }
    }

    #[test]
    fn mul_identity_and_zero() {
        let a = Fe::new(123456789);
        assert_eq!(a.mul(Fe(1)), a);
        assert_eq!(a.mul(Fe(0)), Fe(0));
    }

    #[test]
    fn inv_is_inverse() {
        let mut rng = Rng::new(62);
        for _ in 0..200 {
            let a = random_fe(&mut rng);
            if a.0 == 0 {
                continue;
            }
            assert_eq!(a.mul(a.inv()), Fe(1));
        }
    }

    #[test]
    fn signed_roundtrip() {
        // representable signed range is (−P/2, P/2)
        let big = (P / 4) as i64;
        for &v in &[0i64, 1, -1, 123456, -987654321, big, -big] {
            assert_eq!(Fe::from_i64(v).to_i64(), v, "v={v}");
        }
    }

    #[test]
    fn signed_addition_homomorphic() {
        let a = -5_000i64;
        let b = 12_345i64;
        let s = Fe::from_i64(a).add(Fe::from_i64(b));
        assert_eq!(s.to_i64(), a + b);
    }

    #[test]
    fn pow_small_cases() {
        assert_eq!(Fe(2).pow(10), Fe(1024));
        assert_eq!(Fe(3).pow(0), Fe(1));
    }

    #[test]
    fn boundary_values() {
        assert_eq!(Fe::new(P), Fe(0));
        assert_eq!(Fe(P - 1).add(Fe(1)), Fe(0));
        assert_eq!(Fe(0).sub(Fe(1)), Fe(P - 1));
    }
}
