//! Pairwise-mask secure aggregation (Bonawitz et al. pattern).
//!
//! Session setup distributes a symmetric seed `s_{ij}` to every unordered
//! pair of parties (in a deployment this comes from a Diffie–Hellman
//! exchange; here the leader's session setup delivers seeds over the
//! transport, which we count in the byte meter). To contribute vector
//! `v_i`, party `i` sends
//!
//! `m_i = v_i + Σ_{j>i} PRG(s_{ij}) − Σ_{j<i} PRG(s_{ij})   (mod 2^64)`
//!
//! The leader adds the `m_i`; every mask appears once with `+` and once
//! with `−`, so `Σ m_i = Σ v_i` while each individual `m_i` is uniformly
//! random to the leader. One round, `O(P·len)` total communication — the
//! cheapest backend, and the default.

use crate::util::rng::Rng;

/// Per-party masking context for one session.
#[derive(Clone, Debug)]
pub struct PairwiseMasker {
    pub party: usize,
    pub parties: usize,
    /// seeds[j] = shared seed with party j (seeds[party] unused)
    pub seeds: Vec<u64>,
    /// round counter — fresh masks per combine round
    pub round: u64,
    /// mask-domain tag (the session id in multiplexed deployments):
    /// concurrent sessions sharing a transport — or even, degenerately,
    /// identical pairwise seeds — draw from disjoint PRG streams
    pub domain: u64,
}

impl PairwiseMasker {
    pub fn new(party: usize, parties: usize, seeds: Vec<u64>) -> Self {
        Self::with_domain(party, parties, seeds, 0)
    }

    /// As [`PairwiseMasker::new`] with an explicit mask domain (session
    /// id). Two maskers over the same seeds but different domains
    /// produce disjoint mask streams for every round
    /// (`tests/mask_domains.rs`).
    pub fn with_domain(party: usize, parties: usize, seeds: Vec<u64>, domain: u64) -> Self {
        assert_eq!(seeds.len(), parties);
        assert!(party < parties);
        PairwiseMasker { party, parties, seeds, round: 0, domain }
    }

    /// Generate the symmetric seed matrix for a session (leader side).
    /// Returns `seeds[i][j]` with `seeds[i][j] == seeds[j][i]`.
    pub fn session_seeds(parties: usize, rng: &mut Rng) -> Vec<Vec<u64>> {
        let mut m = vec![vec![0u64; parties]; parties];
        for i in 0..parties {
            for j in i + 1..parties {
                let s = rng.next_u64();
                m[i][j] = s;
                m[j][i] = s;
            }
        }
        m
    }

    /// Mask `values` in place for this round and advance the round
    /// counter. The PRG stream is keyed by (pair seed, domain, round) so
    /// each round's masks are independent — across rounds within a
    /// session *and* across concurrent sessions (domains) on the same
    /// pairwise seeds.
    pub fn mask_in_place(&mut self, values: &mut [u64]) {
        for j in 0..self.parties {
            if j == self.party {
                continue;
            }
            let mut prg = Rng::new(self.seeds[j]).derive(self.domain).derive(self.round);
            if j > self.party {
                for v in values.iter_mut() {
                    *v = v.wrapping_add(prg.next_u64());
                }
            } else {
                for v in values.iter_mut() {
                    *v = v.wrapping_sub(prg.next_u64());
                }
            }
        }
        self.round += 1;
    }
}

/// Leader-side aggregation of masked contributions.
pub fn aggregate_masked(contributions: &[Vec<u64>]) -> Vec<u64> {
    assert!(!contributions.is_empty());
    let mut out = vec![0u64; contributions[0].len()];
    for c in contributions {
        assert_eq!(c.len(), out.len());
        for (o, &v) in out.iter_mut().zip(c) {
            *o = o.wrapping_add(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::fixed::FixedCodec;

    fn run_round(parties: usize, len: usize, seed: u64, rounds: u64) {
        let mut rng = Rng::new(seed);
        let seeds = PairwiseMasker::session_seeds(parties, &mut rng);
        let mut maskers: Vec<PairwiseMasker> = (0..parties)
            .map(|p| PairwiseMasker::new(p, parties, seeds[p].clone()))
            .collect();
        for _round in 0..rounds {
            let plain: Vec<Vec<u64>> = (0..parties)
                .map(|_| (0..len).map(|_| rng.next_u64() >> 8).collect())
                .collect();
            let want: Vec<u64> = (0..len)
                .map(|i| plain.iter().fold(0u64, |a, p| a.wrapping_add(p[i])))
                .collect();
            let mut masked = plain.clone();
            for (p, m) in masked.iter_mut().enumerate() {
                maskers[p].mask_in_place(m);
                if parties > 1 {
                    assert_ne!(m, &plain[p], "mask must change the vector");
                }
            }
            assert_eq!(aggregate_masked(&masked), want);
        }
    }

    #[test]
    fn masks_cancel_various_sizes() {
        for &(p, l) in &[(2usize, 1usize), (3, 10), (5, 100), (8, 1000)] {
            run_round(p, l, 80 + p as u64, 1);
        }
    }

    #[test]
    fn multi_round_masks_fresh() {
        run_round(4, 64, 81, 5);
    }

    #[test]
    fn seeds_symmetric() {
        let mut rng = Rng::new(82);
        let s = PairwiseMasker::session_seeds(6, &mut rng);
        for i in 0..6 {
            assert_eq!(s[i][i], 0);
            for j in 0..6 {
                assert_eq!(s[i][j], s[j][i]);
            }
        }
    }

    #[test]
    fn single_party_is_identity() {
        let mut m = PairwiseMasker::new(0, 1, vec![0]);
        let mut v = vec![1u64, 2, 3];
        m.mask_in_place(&mut v);
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn end_to_end_with_fixed_point() {
        // The full path the coordinator uses: encode → mask → sum → decode.
        let codec = FixedCodec::default();
        let mut rng = Rng::new(83);
        let parties = 4;
        let len = 50;
        let seeds = PairwiseMasker::session_seeds(parties, &mut rng);
        let mut maskers: Vec<PairwiseMasker> = (0..parties)
            .map(|p| PairwiseMasker::new(p, parties, seeds[p].clone()))
            .collect();
        let plain: Vec<Vec<f64>> = (0..parties)
            .map(|_| (0..len).map(|_| rng.normal_ms(0.0, 10.0)).collect())
            .collect();
        let mut masked = Vec::new();
        for (p, vals) in plain.iter().enumerate() {
            let mut enc = codec.encode_vec(vals).unwrap();
            maskers[p].mask_in_place(&mut enc);
            masked.push(enc);
        }
        let agg = codec.decode_vec(&aggregate_masked(&masked));
        for i in 0..len {
            let want: f64 = plain.iter().map(|p| p[i]).sum();
            assert!((agg[i] - want).abs() < 1e-6, "i={i}: {} vs {want}", agg[i]);
        }
    }

    #[test]
    fn domains_cancel_independently_and_disjointly() {
        // masks still cancel within each domain…
        let mut rng = Rng::new(85);
        let seeds = PairwiseMasker::session_seeds(3, &mut rng);
        for domain in [1u64, 2] {
            let mut maskers: Vec<PairwiseMasker> = (0..3)
                .map(|p| PairwiseMasker::with_domain(p, 3, seeds[p].clone(), domain))
                .collect();
            let plain: Vec<Vec<u64>> = (0..3).map(|p| vec![p as u64; 16]).collect();
            let mut masked = plain.clone();
            for (p, m) in masked.iter_mut().enumerate() {
                maskers[p].mask_in_place(m);
            }
            assert_eq!(aggregate_masked(&masked), vec![3u64; 16]);
        }
        // …and identical seeds in different domains give disjoint streams
        let mut a = PairwiseMasker::with_domain(0, 3, seeds[0].clone(), 1);
        let mut b = PairwiseMasker::with_domain(0, 3, seeds[0].clone(), 2);
        let mut va = vec![0u64; 256];
        let mut vb = vec![0u64; 256];
        a.mask_in_place(&mut va);
        b.mask_in_place(&mut vb);
        let same = va.iter().zip(&vb).filter(|(x, y)| x == y).count();
        assert!(same <= 1, "mask streams overlap in {same}/256 words");
    }

    #[test]
    fn leader_view_is_masked() {
        // A single contribution must differ from plaintext in (almost)
        // every word — the leader learns nothing from one message.
        let mut rng = Rng::new(84);
        let seeds = PairwiseMasker::session_seeds(3, &mut rng);
        let mut m0 = PairwiseMasker::new(0, 3, seeds[0].clone());
        let plain: Vec<u64> = (0..256).collect();
        let mut masked = plain.clone();
        m0.mask_in_place(&mut masked);
        let unchanged = plain.iter().zip(&masked).filter(|(a, b)| a == b).count();
        assert!(unchanged <= 1, "unchanged={unchanged}");
    }
}
