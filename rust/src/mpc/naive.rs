//! Naive baseline: secret-share the *raw* N-dimensional data and compute
//! the regression inside MPC.
//!
//! This is the comparator the paper's introduction argues against (its
//! fn. 2: raw-data SMC methods "remain many orders of magnitude slower
//! than plaintext"). We implement it faithfully enough to measure the
//! asymptotics: every sample row is additively shared, and every inner
//! product `O(N)` runs share-wise with Beaver multiplications, so both
//! communication and computation scale with `N·M` instead of the
//! compressed `K·M`. Used by E1/E4 to show the crossover.

use super::beaver::{additive_open, additive_share_fe, deal_triple, multiply_shared};
use super::field::Fe;
use super::fixed::FixedCodec;
use crate::util::rng::Rng;

/// Cost counters for one naive secure dot product.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveCost {
    /// field elements communicated (openings: 2 per multiplication)
    pub opened_elems: u64,
    /// Beaver triples consumed
    pub triples: u64,
}

/// Securely compute `x · y` where both vectors are additively shared
/// across `parties`. Every coordinate costs one Beaver multiplication
/// (two opened field elements) — `O(N)` communication per dot product,
/// versus `O(1)` aggregate words for the compressed protocol.
pub fn secure_dot(
    x_shares: &[Vec<Fe>],
    y_shares: &[Vec<Fe>],
    parties: usize,
    rng: &mut Rng,
    cost: &mut NaiveCost,
) -> Fe {
    let n = x_shares[0].len();
    assert!(x_shares.len() == parties && y_shares.len() == parties);
    let mut acc_shares = vec![Fe(0); parties];
    for i in 0..n {
        let xi: Vec<Fe> = (0..parties).map(|p| x_shares[p][i]).collect();
        let yi: Vec<Fe> = (0..parties).map(|p| y_shares[p][i]).collect();
        let t = deal_triple(parties, rng);
        let zi = multiply_shared(&xi, &yi, &t);
        for p in 0..parties {
            acc_shares[p] = acc_shares[p].add(zi[p]);
        }
        cost.opened_elems += 2 * parties as u64;
        cost.triples += 1;
    }
    additive_open(&acc_shares)
}

/// Share a real vector into per-party additive field shares
/// (fixed-point encoded).
pub fn share_real_vec(
    v: &[f64],
    parties: usize,
    codec: &FixedCodec,
    rng: &mut Rng,
) -> anyhow::Result<Vec<Vec<Fe>>> {
    let mut out: Vec<Vec<Fe>> = (0..parties).map(|_| Vec::with_capacity(v.len())).collect();
    for &x in v {
        let fe = Fe::from_i64(codec.encode(x)? as i64);
        for (p, s) in additive_share_fe(fe, parties, rng).into_iter().enumerate() {
            out[p].push(s);
        }
    }
    Ok(out)
}

/// Decode a field result of a single product of two fixed-point values
/// (scale²) back to f64.
pub fn decode_product(fe: Fe, codec: &FixedCodec) -> f64 {
    fe.to_i64() as f64 / (codec.scale() * codec.scale())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secure_dot_matches_plaintext() {
        let mut rng = Rng::new(110);
        let codec = FixedCodec::new(16); // products need 2× frac bits of headroom
        let n = 64;
        let parties = 3;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let xs = share_real_vec(&x, parties, &codec, &mut rng).unwrap();
        let ys = share_real_vec(&y, parties, &codec, &mut rng).unwrap();
        let mut cost = NaiveCost::default();
        let got = decode_product(
            secure_dot(&xs, &ys, parties, &mut rng, &mut cost),
            &codec,
        );
        let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        assert_eq!(cost.triples, n as u64);
        assert_eq!(cost.opened_elems, 2 * n as u64 * parties as u64);
    }

    #[test]
    fn cost_scales_with_n() {
        let mut rng = Rng::new(111);
        let codec = FixedCodec::new(16);
        let parties = 2;
        let mut costs = Vec::new();
        for n in [8usize, 16, 32] {
            let x: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let xs = share_real_vec(&x, parties, &codec, &mut rng).unwrap();
            let mut cost = NaiveCost::default();
            let _ = secure_dot(&xs, &xs, parties, &mut rng, &mut cost);
            costs.push(cost.opened_elems);
        }
        assert_eq!(costs[1], 2 * costs[0]);
        assert_eq!(costs[2], 2 * costs[1]);
    }
}
