//! Linear-regression statistics computed from *compressed* sufficient
//! statistics — the combine-stage math of the paper.
//!
//! §2: from `(N, yᵀy, Cᵀy, CᵀC)` recover `γ̂ = (CᵀC)⁻¹Cᵀy`,
//! `τ̂² = (yᵀy − γ̂ᵀ(CᵀC)γ̂)/(N−K)`, and standard errors from the
//! diagonal of `(CᵀC)⁻¹τ̂²`.
//!
//! §3 Lemma 3.1: from the projected quantities
//! `(X·y, X·X, Qᵀy, QᵀX, yᵀy, N, K)` recover per-variant `β̂` and `σ̂`
//! (plus t and p) without ever revisiting the N-dimensional data.

use crate::linalg::{cholesky_upper, invert_upper, solve_rt_b, solve_upper, Matrix};
use crate::stats::tdist::t_two_sided_p;

/// Full regression fit of §2 from sufficient statistics.
#[derive(Clone, Debug)]
pub struct RegressionFit {
    /// coefficient estimates γ̂ (length K)
    pub gamma: Vec<f64>,
    /// standard errors of γ̂ (length K)
    pub se: Vec<f64>,
    /// residual variance estimate τ̂²
    pub tau2: f64,
    /// t statistics γ̂ / se
    pub t: Vec<f64>,
    /// two-sided p-values (df = N − K)
    pub p: Vec<f64>,
    /// residual degrees of freedom
    pub df: f64,
}

/// §2 combine stage: statistics from `(N, yᵀy, Cᵀy, CᵀC)`.
///
/// Uses the Cholesky factor of `CᵀC` (equivalently the `R` of `QR(C)`,
/// Lemma 4.1) for all solves — `O(K³)`, independent of sample size.
pub fn fit_from_sufficient(
    n: usize,
    yty: f64,
    cty: &[f64],
    ctc: &Matrix,
) -> anyhow::Result<RegressionFit> {
    let k = cty.len();
    anyhow::ensure!(ctc.rows == k && ctc.cols == k, "CᵀC must be K×K");
    anyhow::ensure!(n > k, "need N > K for residual df (N={n}, K={k})");
    let r = cholesky_upper(ctc)?; // CᵀC = RᵀR
    // γ̂ = (CᵀC)⁻¹ Cᵀy  solved as Rᵀ(Rγ̂)=Cᵀy
    let cty_m = Matrix::from_vec(k, 1, cty.to_vec());
    let w = solve_rt_b(&r, &cty_m); // w = R⁻ᵀ Cᵀy  (= Qᵀy)
    let gamma_m = solve_upper(&r, &w); // γ̂ = R⁻¹ w
    let gamma: Vec<f64> = gamma_m.data.clone();
    // τ̂² = (yᵀy − γ̂ᵀ(CᵀC)γ̂)/(N−K); note γ̂ᵀ(CᵀC)γ̂ = |Rγ̂|² = |w|²
    let fitted: f64 = w.data.iter().map(|v| v * v).sum();
    let df = (n - k) as f64;
    let tau2 = ((yty - fitted) / df).max(0.0);
    // Var(γ̂) = (CᵀC)⁻¹ τ̂²; (CᵀC)⁻¹ = R⁻¹ R⁻ᵀ
    let rinv = invert_upper(&r);
    let mut se = Vec::with_capacity(k);
    for i in 0..k {
        // diag_i of R⁻¹R⁻ᵀ = Σ_j R⁻¹[i,j]²
        let v: f64 = (0..k).map(|j| rinv[(i, j)] * rinv[(i, j)]).sum();
        se.push((v * tau2).sqrt());
    }
    let t: Vec<f64> = gamma
        .iter()
        .zip(&se)
        .map(|(g, s)| if *s > 0.0 { g / s } else { f64::INFINITY })
        .collect();
    let p: Vec<f64> = t.iter().map(|&tv| t_two_sided_p(tv, df)).collect();
    Ok(RegressionFit { gamma, se, tau2, t, p, df })
}

/// Inputs for the Lemma 3.1 epilogue, already projected through `Qᵀ`.
/// All vectors have length `M` (one entry per transient covariate);
/// `qt_x` is `K × M`, `qt_y` has length `K`.
#[derive(Clone, Debug)]
pub struct ScanStats {
    pub n: usize,
    pub k: usize,
    pub yty: f64,
    pub xty: Vec<f64>,
    pub xtx: Vec<f64>,
    pub qt_y: Vec<f64>,
    pub qt_x: Matrix,
}

/// Result of an association scan.
#[derive(Clone, Debug)]
pub struct AssocResult {
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
    pub t: Vec<f64>,
    pub p: Vec<f64>,
    /// residual df = N − K − 1
    pub df: f64,
}

impl AssocResult {
    pub fn min_p(&self) -> Option<f64> {
        self.p.iter().copied().filter(|p| p.is_finite()).fold(None, |m, p| {
            Some(match m {
                None => p,
                Some(m) => m.min(p),
            })
        })
    }
}

/// Lemma 3.1 epilogue (pure Rust reference path; the artifact-backed path
/// computes the same expression inside the AOT HLO):
///
/// β̂ = (X·y − QᵀX·Qᵀy) / (X·X − QᵀX·QᵀX)
/// σ̂² = ((y·y − Qᵀy·Qᵀy)/(X·X − QᵀX·QᵀX) − β̂²) / (N−K−1)
pub fn scan_stats_from_projected(s: &ScanStats) -> AssocResult {
    scan_stats_from_projected_parts(s.n, s.k, s.yty, &s.xty, &s.xtx, &s.qt_y, &s.qt_x)
}

/// Borrowed-parts form of [`scan_stats_from_projected`], for callers
/// that share the projected inputs across invocations — the multi-trait
/// combine runs this once per trait against the *same* `QᵀX` without
/// cloning it.
pub fn scan_stats_from_projected_parts(
    n: usize,
    k: usize,
    yty: f64,
    xty: &[f64],
    xtx: &[f64],
    qt_y: &[f64],
    qt_x: &Matrix,
) -> AssocResult {
    let m = xty.len();
    assert_eq!(xtx.len(), m);
    assert_eq!(qt_x.rows, k);
    assert_eq!(qt_x.cols, m);
    assert_eq!(qt_y.len(), k);
    let df = (n as f64) - (k as f64) - 1.0;
    assert!(df > 0.0, "need N > K + 1");
    let yy_resid = {
        let qy2: f64 = qt_y.iter().map(|v| v * v).sum();
        yty - qy2
    };
    let mut beta = vec![0.0; m];
    let mut se = vec![0.0; m];
    let mut t = vec![0.0; m];
    let mut p = vec![1.0; m];
    for j in 0..m {
        // column j of QᵀX
        let mut qx_qy = 0.0;
        let mut qx_qx = 0.0;
        for i in 0..k {
            let q = qt_x[(i, j)];
            qx_qy += q * qt_y[i];
            qx_qx += q * q;
        }
        let denom = xtx[j] - qx_qx;
        if denom <= 1e-12 * xtx[j].abs().max(1.0) {
            // x_j is (numerically) in the span of C — no signal left.
            beta[j] = f64::NAN;
            se[j] = f64::NAN;
            t[j] = f64::NAN;
            p[j] = f64::NAN;
            continue;
        }
        let b = (xty[j] - qx_qy) / denom;
        let sigma2 = ((yy_resid / denom) - b * b) / df;
        let sd = sigma2.max(0.0).sqrt();
        beta[j] = b;
        se[j] = sd;
        t[j] = if sd > 0.0 { b / sd } else { f64::INFINITY };
        p[j] = t_two_sided_p(t[j], df);
    }
    AssocResult { beta, se, t, p, df }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder_qr;
    use crate::util::rng::Rng;

    /// Brute-force OLS of y on [x | C] returning (β̂_x, se_x).
    fn brute_force_single(x: &[f64], c: &Matrix, y: &[f64]) -> (f64, f64) {
        let n = y.len();
        let k = c.cols + 1;
        let mut design = Matrix::zeros(n, k);
        for i in 0..n {
            design[(i, 0)] = x[i];
            for j in 0..c.cols {
                design[(i, j + 1)] = c[(i, j)];
            }
        }
        let fit = fit_from_sufficient(
            n,
            y.iter().map(|v| v * v).sum(),
            &design.t_matvec(y),
            &design.gram(),
        )
        .unwrap();
        (fit.gamma[0], fit.se[0])
    }

    fn make_data(n: usize, k: usize, m: usize, seed: u64) -> (Vec<f64>, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0; // intercept
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n)
            .map(|i| 0.7 * x[(i, 0)] + 0.3 * c[(i, k - 1)] + rng.normal())
            .collect();
        (y, c, x)
    }

    #[test]
    fn fit_from_sufficient_recovers_known_coefficients() {
        // y = 2 + 3 c1 with tiny noise
        let n = 500;
        let mut rng = Rng::new(40);
        let mut c = Matrix::zeros(n, 2);
        let mut y = vec![0.0; n];
        for i in 0..n {
            c[(i, 0)] = 1.0;
            c[(i, 1)] = rng.normal();
            y[i] = 2.0 + 3.0 * c[(i, 1)] + 0.01 * rng.normal();
        }
        let fit =
            fit_from_sufficient(n, y.iter().map(|v| v * v).sum(), &c.t_matvec(&y), &c.gram())
                .unwrap();
        assert!((fit.gamma[0] - 2.0).abs() < 0.01);
        assert!((fit.gamma[1] - 3.0).abs() < 0.01);
        assert!(fit.tau2 < 2e-4);
        assert!(fit.p[1] < 1e-100);
    }

    #[test]
    fn fit_errors_on_underdetermined() {
        let c = Matrix::identity(3);
        assert!(fit_from_sufficient(3, 1.0, &[0.0; 3], &c).is_err());
    }

    #[test]
    fn scan_matches_brute_force_ols() {
        let (y, c, x) = make_data(120, 4, 6, 41);
        let n = y.len();
        let f = householder_qr(&c);
        let qt_x = f.q.t_matmul(&x);
        let qt_y = f.q.t_matvec(&y);
        let stats = ScanStats {
            n,
            k: c.cols,
            yty: y.iter().map(|v| v * v).sum(),
            xty: x.t_matvec(&y),
            xtx: (0..x.cols).map(|j| x.col(j).iter().map(|v| v * v).sum()).collect(),
            qt_y,
            qt_x,
        };
        let res = scan_stats_from_projected(&stats);
        for j in 0..x.cols {
            let (b_ref, se_ref) = brute_force_single(&x.col(j), &c, &y);
            assert!(
                (res.beta[j] - b_ref).abs() < 1e-9 * b_ref.abs().max(1.0),
                "beta[{j}]: {} vs {}",
                res.beta[j],
                b_ref
            );
            assert!(
                (res.se[j] - se_ref).abs() < 1e-9 * se_ref.abs().max(1.0),
                "se[{j}]: {} vs {}",
                res.se[j],
                se_ref
            );
        }
    }

    #[test]
    fn scan_flags_collinear_variant() {
        let (y, c, _) = make_data(60, 3, 1, 42);
        let n = y.len();
        // x = copy of covariate column 1 → fully explained by C
        let x = Matrix::from_vec(n, 1, c.col(1));
        let f = householder_qr(&c);
        let stats = ScanStats {
            n,
            k: c.cols,
            yty: y.iter().map(|v| v * v).sum(),
            xty: x.t_matvec(&y),
            xtx: vec![x.col(0).iter().map(|v| v * v).sum()],
            qt_y: f.q.t_matvec(&y),
            qt_x: f.q.t_matmul(&x),
        };
        let res = scan_stats_from_projected(&stats);
        assert!(res.beta[0].is_nan());
        assert!(res.p[0].is_nan());
    }

    #[test]
    fn null_variants_have_uniform_ish_p() {
        // no signal → p-values should not pile up near 0
        let n = 300;
        let mut rng = Rng::new(43);
        let mut c = Matrix::zeros(n, 2);
        for i in 0..n {
            c[(i, 0)] = 1.0;
            c[(i, 1)] = rng.normal();
        }
        let m = 200;
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let f = householder_qr(&c);
        let stats = ScanStats {
            n,
            k: 2,
            yty: y.iter().map(|v| v * v).sum(),
            xty: x.t_matvec(&y),
            xtx: (0..m).map(|j| x.col(j).iter().map(|v| v * v).sum()).collect(),
            qt_y: f.q.t_matvec(&y),
            qt_x: f.q.t_matmul(&x),
        };
        let res = scan_stats_from_projected(&stats);
        let frac_sig = res.p.iter().filter(|&&p| p < 0.05).count() as f64 / m as f64;
        assert!(frac_sig < 0.12, "frac={frac_sig}"); // ≈0.05 expected
        assert!(res.min_p().unwrap() > 1e-8);
    }

    #[test]
    fn min_p_ignores_nan() {
        let r = AssocResult {
            beta: vec![1.0, f64::NAN],
            se: vec![1.0, f64::NAN],
            t: vec![1.0, f64::NAN],
            p: vec![0.2, f64::NAN],
            df: 10.0,
        };
        assert_eq!(r.min_p(), Some(0.2));
    }
}
