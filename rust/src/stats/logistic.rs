//! Logistic-regression math on *compressed* weighted sufficient
//! statistics — the IRLS analogue of [`super::regression`].
//!
//! Each Newton/IRLS iteration of a logistic fit is a weighted
//! least-squares solve: with `μ_i = σ(c_iᵀβ)`, `w_i = μ_i(1-μ_i)` and
//! the *scaled* working response `w_i z_i = w_i η_i + (y_i - μ_i)`, the
//! update solves `(CᵀWC) β⁺ = CᵀWz`. Both sides are sums of per-sample
//! products — exactly the shape the secure-sum pipeline aggregates —
//! so parties only ever reveal the aggregated `CᵀWC`, `CᵀWz` and the
//! deviance per iteration, never per-sample weights.
//!
//! After the null model `y ~ C` converges, per-variant association uses
//! the **score test** with a one-step coefficient estimate: from the
//! aggregated `U_j = x_jᵀ(y - μ̂)`, `x_jᵀWx_j` and `CᵀWx_j`,
//!
//! ```text
//! V_j = x_jᵀWx_j − u_jᵀu_j,   u_j = R⁻ᵀ (CᵀWx_j),  RᵀR = CᵀWC
//! β̂_j = U_j / V_j,  se_j = 1/√V_j,  z_j = U_j/√V_j,  p = 2Φ̄(|z_j|)
//! ```
//!
//! — one weighted pass over the variant shards, per-variant traffic
//! `O(K)` like the linear scan, no per-variant iteration. The same
//! epilogue ([`score_assoc_from_sums`]) serves the secure leader and
//! the pooled-plaintext oracle, so the two differ only by fixed-point
//! rounding of the aggregated sums.

use crate::linalg::{cholesky_upper, invert_upper, solve_rt_b, solve_upper, Matrix};
use crate::stats::tdist::normal_two_sided_p;
use crate::stats::{AssocResult, RegressionFit};

/// μ clamp: keeps `ln μ`, `ln(1-μ)` finite and the weights strictly
/// positive. Applied identically by every compute path (Rust kernels,
/// reference executor, pooled oracle) — part of the bit-identity
/// contract for logistic scans.
pub const MU_EPS: f64 = 1e-12;

/// Default IRLS iteration cap.
pub const IRLS_DEFAULT_MAX_ITER: usize = 25;

/// Default deviance-based stop tolerance:
/// `|dev_i − dev_{i−1}| < tol · (|dev_i| + 0.1)`.
pub const IRLS_DEFAULT_TOL: f64 = 1e-8;

/// Divergence guard: a null-model coefficient past this magnitude means
/// the deviance is still falling because a covariate (quasi-)separates
/// the cases — the fit has no finite optimum and the weighted sums
/// would eventually outgrow the fixed-point envelope.
pub const IRLS_BETA_GUARD: f64 = 30.0;

/// The logistic mean function, clamped away from {0, 1}.
#[inline]
pub fn clamped_mu(eta: f64) -> f64 {
    let mu = 1.0 / (1.0 + (-eta).exp());
    mu.clamp(MU_EPS, 1.0 - MU_EPS)
}

/// One sample's contribution to the binomial deviance
/// `−2 Σ [y ln μ + (1−y) ln(1−μ)]` for y ∈ {0, 1}.
#[inline]
pub fn deviance_term(y: f64, mu: f64) -> f64 {
    -2.0 * if y > 0.5 { mu.ln() } else { (1.0 - mu).ln() }
}

/// Shared IRLS starting point (used by the secure leader and the pooled
/// oracle so both walk the same iterate sequence): intercept at
/// `logit(p̄)` with the prevalence clamped to `[1/n, 1−1/n]`, all other
/// coefficients zero. Assumes column 0 of `C` is the intercept (as
/// every cohort in this codebase is built); for a general design this
/// is still a valid — just less centered — starting point.
pub fn irls_beta_init(k: usize, n: f64, sum_y: f64) -> Vec<f64> {
    let p = (sum_y / n).clamp(1.0 / n, 1.0 - 1.0 / n);
    let mut beta = vec![0.0; k];
    beta[0] = (p / (1.0 - p)).ln();
    beta
}

/// Whether the deviance sequence has converged at iteration `i ≥ 2`.
#[inline]
pub fn deviance_converged(dev: f64, prev: f64, tol: f64) -> bool {
    (dev - prev).abs() < tol * (dev.abs() + 0.1)
}

/// One IRLS update on aggregated sums: solve `(CᵀWC) β⁺ = CᵀWz` via the
/// Cholesky factor of `CᵀWC`. Errors when the weighted Gram matrix is
/// not positive definite (collinear covariates, or weights collapsed to
/// zero under separation).
pub fn irls_solve(ctwc: &Matrix, ctwz: &[f64]) -> anyhow::Result<Vec<f64>> {
    let k = ctwz.len();
    anyhow::ensure!(ctwc.rows == k && ctwc.cols == k, "CᵀWC must be K×K");
    let r = cholesky_upper(ctwc)?;
    let b = Matrix::from_vec(k, 1, ctwz.to_vec());
    let w = solve_rt_b(&r, &b);
    Ok(solve_upper(&r, &w).data)
}

/// Null-model fit summary: the converged (or capped) coefficients plus
/// Wald statistics from the final weighted Gram matrix.
#[derive(Clone, Debug)]
pub struct LogisticFit {
    pub beta: Vec<f64>,
    pub se: Vec<f64>,
    pub z: Vec<f64>,
    pub p: Vec<f64>,
    pub deviance: f64,
    /// IRLS iterations actually evaluated (≥ 1)
    pub iters: usize,
    /// false when the max-iteration cap stopped the fit
    pub converged: bool,
    /// upper Cholesky factor of the final `CᵀWC` (evaluated at `beta`)
    pub r: Matrix,
}

/// Build the Wald summary from a final iterate: `Var(β̂) = (CᵀWC)⁻¹`,
/// z = β̂/se, p from the normal tail (IRLS standard asymptotics).
pub fn logistic_fit_from_final(
    beta: Vec<f64>,
    r: Matrix,
    deviance: f64,
    iters: usize,
    converged: bool,
) -> LogisticFit {
    let k = beta.len();
    let rinv = invert_upper(&r);
    let mut se = Vec::with_capacity(k);
    for i in 0..k {
        let v: f64 = (0..k).map(|j| rinv[(i, j)] * rinv[(i, j)]).sum();
        se.push(v.sqrt());
    }
    let z: Vec<f64> = beta
        .iter()
        .zip(&se)
        .map(|(b, s)| if *s > 0.0 { b / s } else { f64::INFINITY })
        .collect();
    let p: Vec<f64> = z.iter().map(|&zv| normal_two_sided_p(zv)).collect();
    LogisticFit { beta, se, z, p, deviance, iters, converged, r }
}

impl LogisticFit {
    /// Repackage as the [`RegressionFit`] slot of a
    /// [`crate::scan::ScanOutput`] covariate fit. `tau2` carries the
    /// null deviance (logistic fits have no residual variance), `df` is
    /// the usual `N − K`.
    pub fn to_regression_fit(&self, n: usize) -> RegressionFit {
        RegressionFit {
            gamma: self.beta.clone(),
            se: self.se.clone(),
            tau2: self.deviance,
            t: self.z.clone(),
            p: self.p.clone(),
            df: (n - self.beta.len()) as f64,
        }
    }
}

/// Score-test epilogue on aggregated weighted sums for one shard of
/// variants: `score[j] = x_jᵀ(y − μ̂)`, `xwx[j] = x_jᵀWx_j`, column `j`
/// of `cwx` is `CᵀWx_j`, and `r` is the upper Cholesky factor of the
/// final `CᵀWC`. Variants whose effective information `V_j` vanishes
/// (numerically in the span of C, or carrying no weight) get NaN
/// statistics, exactly like the collinear guard of the linear scan.
pub fn score_assoc_from_sums(
    n: usize,
    k: usize,
    r: &Matrix,
    score: &[f64],
    xwx: &[f64],
    cwx: &Matrix,
) -> AssocResult {
    let w = score.len();
    assert_eq!(xwx.len(), w);
    assert_eq!(cwx.rows, k);
    assert_eq!(cwx.cols, w);
    let df = (n as f64) - (k as f64) - 1.0;
    let u = solve_rt_b(r, cwx); // K × w, u_j = R⁻ᵀ CᵀWx_j
    let mut beta = vec![0.0; w];
    let mut se = vec![0.0; w];
    let mut z = vec![0.0; w];
    let mut p = vec![1.0; w];
    for j in 0..w {
        let mut uu = 0.0;
        for i in 0..k {
            let v = u[(i, j)];
            uu += v * v;
        }
        let vj = xwx[j] - uu;
        if vj <= 1e-12 * xwx[j].abs().max(1.0) {
            beta[j] = f64::NAN;
            se[j] = f64::NAN;
            z[j] = f64::NAN;
            p[j] = f64::NAN;
            continue;
        }
        let sv = vj.sqrt();
        beta[j] = score[j] / vj;
        se[j] = 1.0 / sv;
        z[j] = score[j] / sv;
        p[j] = normal_two_sided_p(z[j]);
    }
    AssocResult { beta, se, t: z, p, df }
}

/// Pooled plaintext Newton–Raphson oracle for the null model `y ~ C`,
/// walking the *same* iterate sequence as the secure protocol: evaluate
/// the weighted sums at the broadcast β, stop (without a further
/// update) once the deviance stabilizes or the cap is hit, so the final
/// `CᵀWC` is exactly the one the score epilogue uses.
pub fn logistic_fit_pooled(
    y: &[f64],
    c: &Matrix,
    max_iter: usize,
    tol: f64,
) -> anyhow::Result<LogisticFit> {
    let n = y.len();
    let k = c.cols;
    anyhow::ensure!(c.rows == n, "C rows != N");
    anyhow::ensure!(n > k, "need N > K");
    anyhow::ensure!(max_iter >= 1, "need at least one IRLS iteration");
    for &v in y {
        anyhow::ensure!(v == 0.0 || v == 1.0, "logistic traits must be 0/1 (got {v})");
    }
    let sum_y: f64 = y.iter().sum();
    let mut beta = irls_beta_init(k, n as f64, sum_y);
    let mut prev_dev: Option<f64> = None;
    for iter in 1..=max_iter {
        // weighted sums at the current iterate
        let mut ctwc = Matrix::zeros(k, k);
        let mut ctwz = vec![0.0; k];
        let mut dev = 0.0;
        for i in 0..n {
            let row = c.row(i);
            let eta: f64 = row.iter().zip(&beta).map(|(a, b)| a * b).sum();
            let mu = clamped_mu(eta);
            let wgt = mu * (1.0 - mu);
            let wz = wgt * eta + (y[i] - mu);
            dev += deviance_term(y[i], mu);
            for a in 0..k {
                ctwz[a] += row[a] * wz;
                for b in a..k {
                    ctwc[(a, b)] += wgt * row[a] * row[b];
                }
            }
        }
        for a in 0..k {
            for b in 0..a {
                ctwc[(a, b)] = ctwc[(b, a)];
            }
        }
        anyhow::ensure!(dev.is_finite(), "IRLS deviance diverged");
        let stop = prev_dev.is_some_and(|p| deviance_converged(dev, p, tol));
        if stop || iter == max_iter {
            let r = cholesky_upper(&ctwc)?;
            return Ok(logistic_fit_from_final(beta, r, dev, iter, stop));
        }
        prev_dev = Some(dev);
        beta = irls_solve(&ctwc, &ctwz)?;
        anyhow::ensure!(
            beta.iter().all(|b| b.abs() <= IRLS_BETA_GUARD),
            "IRLS diverged (quasi-separation?): |beta| exceeded {IRLS_BETA_GUARD}"
        );
    }
    unreachable!("loop returns at iter == max_iter");
}

/// Pooled plaintext score scan oracle: per-variant score statistics at
/// the fitted null model, via the same epilogue as the secure leader.
pub fn logistic_score_scan_pooled(
    y: &[f64],
    c: &Matrix,
    x: &Matrix,
    fit: &LogisticFit,
) -> AssocResult {
    let n = y.len();
    let k = c.cols;
    let m = x.cols;
    assert_eq!(c.rows, n);
    assert_eq!(x.rows, n);
    // per-sample weights at the converged null
    let mut resid = vec![0.0; n];
    let mut wgt = vec![0.0; n];
    for i in 0..n {
        let eta: f64 = c.row(i).iter().zip(&fit.beta).map(|(a, b)| a * b).sum();
        let mu = clamped_mu(eta);
        resid[i] = y[i] - mu;
        wgt[i] = mu * (1.0 - mu);
    }
    let mut score = vec![0.0; m];
    let mut xwx = vec![0.0; m];
    let mut cwx = Matrix::zeros(k, m);
    for i in 0..n {
        let xr = x.row(i);
        let cr = c.row(i);
        for j in 0..m {
            let xv = xr[j];
            if xv == 0.0 {
                continue;
            }
            score[j] += xv * resid[i];
            xwx[j] += wgt[i] * xv * xv;
            for a in 0..k {
                cwx[(a, j)] += wgt[i] * cr[a] * xv;
            }
        }
    }
    score_assoc_from_sums(n, k, &fit.r, &score, &xwx, &cwx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth(n: usize, k: usize, seed: u64) -> (Vec<f64>, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        let true_beta: Vec<f64> = (0..k).map(|j| 0.4 * (j as f64 - 1.0)).collect();
        let mut y = vec![0.0; n];
        for i in 0..n {
            c[(i, 0)] = 1.0;
            let eta: f64 = c.row(i).iter().zip(&true_beta).map(|(a, b)| a * b).sum();
            let p = 1.0 / (1.0 + (-eta).exp());
            y[i] = if rng.uniform() < p { 1.0 } else { 0.0 };
        }
        (y, c)
    }

    #[test]
    fn pooled_fit_recovers_known_coefficients() {
        // strong signal, large n: β̂ close to truth, Wald p tiny
        let n = 4000;
        let (y, c) = synth(n, 3, 7001);
        let fit = logistic_fit_pooled(&y, &c, 25, 1e-10).unwrap();
        assert!(fit.converged, "should converge in 25 iterations");
        // truth: [-0.4, 0.0, 0.4]
        assert!((fit.beta[0] + 0.4).abs() < 0.15, "beta0={}", fit.beta[0]);
        assert!(fit.beta[1].abs() < 0.15, "beta1={}", fit.beta[1]);
        assert!((fit.beta[2] - 0.4).abs() < 0.15, "beta2={}", fit.beta[2]);
        assert!(fit.p[2] < 1e-10);
        assert!(fit.deviance > 0.0 && fit.deviance < 2.0 * n as f64);
    }

    #[test]
    fn perfect_separation_trips_the_divergence_guard() {
        // y = 1 exactly when c1 > 0: no finite optimum
        let n = 200;
        let mut rng = Rng::new(7002);
        let mut c = Matrix::zeros(n, 2);
        let mut y = vec![0.0; n];
        for i in 0..n {
            c[(i, 0)] = 1.0;
            c[(i, 1)] = rng.normal();
            y[i] = if c[(i, 1)] > 0.0 { 1.0 } else { 0.0 };
        }
        let err = logistic_fit_pooled(&y, &c, 500, 1e-12).unwrap_err();
        assert!(
            format!("{err:#}").contains("quasi-separation"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn max_iter_cap_stops_without_convergence_flag() {
        let (y, c) = synth(300, 3, 7003);
        let fit = logistic_fit_pooled(&y, &c, 2, 1e-14).unwrap();
        assert_eq!(fit.iters, 2);
        assert!(!fit.converged);
    }

    #[test]
    fn score_scan_matches_wald_refit_direction() {
        // the score z and a full per-variant refit must agree in sign
        // and roughly in magnitude for a causal variant
        let n = 1500;
        let mut rng = Rng::new(7004);
        let mut c = Matrix::zeros(n, 2);
        let mut x = Matrix::zeros(n, 2);
        let mut y = vec![0.0; n];
        for i in 0..n {
            c[(i, 0)] = 1.0;
            c[(i, 1)] = rng.normal();
            x[(i, 0)] = rng.normal(); // causal
            x[(i, 1)] = rng.normal(); // null
            let eta = 0.2 * c[(i, 1)] + 0.8 * x[(i, 0)];
            let p = 1.0 / (1.0 + (-eta).exp());
            y[i] = if rng.uniform() < p { 1.0 } else { 0.0 };
        }
        let fit = logistic_fit_pooled(&y, &c, 25, 1e-10).unwrap();
        let scan = logistic_score_scan_pooled(&y, &c, &x, &fit);
        assert!(scan.beta[0] > 0.3, "causal beta={}", scan.beta[0]);
        assert!(scan.p[0] < 1e-8, "causal p={}", scan.p[0]);
        assert!(scan.p[1] > 1e-4, "null p={}", scan.p[1]);
        assert!(scan.t.iter().all(|z| z.is_finite()));
    }

    #[test]
    fn collinear_variant_gets_nan_score_stats() {
        let (y, c) = synth(400, 3, 7005);
        // x col 0 = covariate col 1 → zero effective information
        let x = Matrix::from_vec(y.len(), 1, c.col(1));
        let fit = logistic_fit_pooled(&y, &c, 25, 1e-10).unwrap();
        let scan = logistic_score_scan_pooled(&y, &c, &x, &fit);
        assert!(scan.beta[0].is_nan());
        assert!(scan.p[0].is_nan());
    }

    #[test]
    fn beta_init_is_clamped_and_centered() {
        let b = irls_beta_init(3, 100.0, 50.0);
        assert_eq!(b, vec![0.0, 0.0, 0.0]);
        // all-cases cohort: clamped to 1 − 1/n, finite logit
        let b = irls_beta_init(2, 100.0, 100.0);
        assert!(b[0].is_finite() && b[0] > 4.0);
        assert_eq!(b[1], 0.0);
    }

    #[test]
    fn clamped_mu_stays_inside_unit_interval() {
        for eta in [-800.0, -40.0, 0.0, 40.0, 800.0] {
            let mu = clamped_mu(eta);
            assert!(mu >= MU_EPS && mu <= 1.0 - MU_EPS, "eta={eta} mu={mu}");
            assert!(deviance_term(1.0, mu).is_finite());
            assert!(deviance_term(0.0, mu).is_finite());
        }
    }
}
