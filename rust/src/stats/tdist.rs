//! Special functions for p-values: log-gamma, regularized incomplete
//! beta, and Student-t tail probabilities.
//!
//! Implementations follow the classic Lanczos / Lentz continued-fraction
//! formulations (Numerical Recipes style), accurate to ~1e-12 over the
//! ranges a GWAS needs (df ≥ 1, |t| up to ~40 → p down to ~1e-300).

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma requires x > 0, got {x}");
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta function I_x(a, b) via Lentz's continued
/// fraction with the symmetry transformation for convergence.
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "betainc requires a,b > 0");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - front * betacf(b, a, 1.0 - x) / b
    }
}

/// Continued fraction for betainc (Lentz's algorithm).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Survival function P(T > t) for Student-t with `df` degrees of freedom.
///
/// A NaN statistic (zero-variance / collinear variant) propagates to a
/// NaN probability — it must never masquerade as a tail value.
pub fn t_sf(t: f64, df: f64) -> f64 {
    assert!(df > 0.0);
    if t.is_nan() {
        return f64::NAN;
    }
    if !t.is_finite() {
        return if t > 0.0 { 0.0 } else { 1.0 };
    }
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t >= 0.0 {
        p
    } else {
        1.0 - p
    }
}

/// Two-sided p-value for a t statistic: P(|T| > |t|).
///
/// NaN t → NaN p (a NaN statistic previously fell through a dead
/// `t == 0.0` arm and returned p = 0.0, i.e. *maximally significant* —
/// it would rank first in SELECT); ±∞ → 0.0.
pub fn t_two_sided_p(t: f64, df: f64) -> f64 {
    if t.is_nan() {
        return f64::NAN;
    }
    if !t.is_finite() {
        return 0.0;
    }
    let x = df / (df + t * t);
    betainc(0.5 * df, 0.5, x).clamp(0.0, 1.0)
}

/// Complementary error function via the regularized upper incomplete
/// gamma function: `erfc(x) = Q(1/2, x²)` for `x ≥ 0`, with the
/// reflection `erfc(-x) = 2 - erfc(x)`. Accurate to ~1e-12 over the
/// Wald-z range a GWAS needs.
pub fn erfc(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x >= 0.0 {
        gamma_q(0.5, x * x)
    } else {
        2.0 - gamma_q(0.5, x * x)
    }
}

/// Standard-normal survival function P(Z > z) (Wald tests). NaN → NaN.
pub fn normal_sf(z: f64) -> f64 {
    0.5 * erfc(z / std::f64::consts::SQRT_2)
}

/// Two-sided standard-normal p-value: P(|Z| > |z|). NaN z → NaN p,
/// ±∞ → 0.0 — same contract as [`t_two_sided_p`].
pub fn normal_two_sided_p(z: f64) -> f64 {
    if z.is_nan() {
        return f64::NAN;
    }
    if !z.is_finite() {
        return 0.0;
    }
    erfc(z.abs() / std::f64::consts::SQRT_2).clamp(0.0, 1.0)
}

/// Regularized upper incomplete gamma function Q(a, x), series /
/// continued-fraction split (Numerical Recipes style).
fn gamma_q(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == f64::INFINITY {
        return 0.0;
    }
    if x < a + 1.0 {
        1.0 - gamma_p_series(a, x)
    } else {
        gamma_q_cf(a, x)
    }
}

/// Series representation of P(a, x), convergent for x < a + 1.
fn gamma_p_series(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let gln = ln_gamma(a);
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..300 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 3e-16 {
            break;
        }
    }
    sum * (-x + a * x.ln() - gln).exp()
}

/// Lentz continued fraction for Q(a, x), convergent for x ≥ a + 1.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let gln = ln_gamma(a);
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..=300 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 3e-16 {
            break;
        }
    }
    (-x + a * x.ln() - gln).exp() * h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-11);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-11);
    }

    #[test]
    fn ln_gamma_recurrence() {
        // Γ(x+1) = x Γ(x)
        for &x in &[0.3, 1.7, 4.2, 9.9, 25.0] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = x.ln() + ln_gamma(x);
            assert!((lhs - rhs).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn betainc_bounds_and_symmetry() {
        assert_eq!(betainc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.4), (0.5, 0.5, 0.1), (10.0, 2.0, 0.8)] {
            let lhs = betainc(a, b, x);
            let rhs = 1.0 - betainc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-12);
        }
    }

    #[test]
    fn betainc_uniform_case() {
        // I_x(1,1) = x
        for &x in &[0.1, 0.5, 0.9] {
            assert!((betainc(1.0, 1.0, x) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn t_sf_reference_values() {
        // scipy.stats.t.sf reference values
        let cases = [
            // (t, df, sf)
            (0.0, 5.0, 0.5),
            (1.0, 1.0, 0.25),             // Cauchy: 1/2 - atan(1)/pi = 0.25
            (2.0, 10.0, 0.03669401738537018),  // scipy.stats.t.sf
            (2.5, 30.0, 0.009057824534033344),
            (5.0, 100.0, 1.225086706751901e-6),
        ];
        for &(t, df, want) in &cases {
            let got = t_sf(t, df);
            assert!(
                (got - want).abs() / want.max(1e-12) < 1e-3,
                "t={t} df={df}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn t_symmetry() {
        for &(t, df) in &[(1.3, 7.0), (2.2, 3.0), (0.4, 50.0)] {
            assert!((t_sf(t, df) + t_sf(-t, df) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn two_sided_p() {
        let p = t_two_sided_p(2.0, 10.0);
        assert!((p - 2.0 * t_sf(2.0, 10.0)).abs() < 1e-12);
        assert_eq!(t_two_sided_p(0.0, 5.0), 1.0);
    }

    #[test]
    fn extreme_tails_no_underflow_to_garbage() {
        let p = t_two_sided_p(40.0, 1000.0);
        assert!(p > 0.0 && p < 1e-100, "p={p:e}");
        assert!(t_sf(f64::INFINITY, 5.0) == 0.0);
    }

    #[test]
    fn large_df_approaches_normal() {
        // t with huge df ≈ standard normal: P(T>1.96) ≈ 0.025
        let p = t_sf(1.959964, 1e7);
        assert!((p - 0.025).abs() < 1e-4, "p={p}");
    }

    /// Regression: a NaN t statistic must propagate to NaN — the old
    /// `!t.is_finite()` branch tested `t == 0.0` (dead: 0.0 is finite)
    /// and `t > 0.0` (false for NaN), so NaN returned p = 0.0 from
    /// `t_two_sided_p` (maximally significant) and 1.0 from `t_sf`.
    #[test]
    fn nan_t_propagates_to_nan_p() {
        for df in [1.0, 5.0, 1000.0] {
            assert!(t_two_sided_p(f64::NAN, df).is_nan(), "df={df}");
            assert!(t_sf(f64::NAN, df).is_nan(), "df={df}");
        }
        assert!(normal_two_sided_p(f64::NAN).is_nan());
        assert!(normal_sf(f64::NAN).is_nan());
    }

    /// ±∞ keep their exact-tail semantics after the NaN fix.
    #[test]
    fn infinite_and_zero_t_edges() {
        for df in [1.0, 10.0] {
            assert_eq!(t_two_sided_p(f64::INFINITY, df), 0.0, "df={df}");
            assert_eq!(t_two_sided_p(f64::NEG_INFINITY, df), 0.0, "df={df}");
            assert_eq!(t_sf(f64::INFINITY, df), 0.0, "df={df}");
            assert_eq!(t_sf(f64::NEG_INFINITY, df), 1.0, "df={df}");
            assert_eq!(t_two_sided_p(0.0, df), 1.0, "df={df}");
            assert!((t_sf(0.0, df) - 0.5).abs() < 1e-12, "df={df}");
        }
        assert_eq!(normal_two_sided_p(f64::INFINITY), 0.0);
        assert_eq!(normal_two_sided_p(f64::NEG_INFINITY), 0.0);
        assert_eq!(normal_two_sided_p(0.0), 1.0);
        assert_eq!(normal_sf(f64::INFINITY), 0.0);
        assert_eq!(normal_sf(f64::NEG_INFINITY), 1.0);
    }

    #[test]
    fn erfc_reference_values() {
        // scipy.special.erfc reference values
        let cases = [
            (0.0, 1.0),
            (0.5, 0.4795001221869535),
            (1.0, 0.15729920705028513),
            (2.0, 0.004677734981063127),
            (-1.0, 1.842700792949715),
            (3.5, 7.430983723414128e-7),
        ];
        for &(x, want) in &cases {
            let got = erfc(x);
            assert!(
                (got - want).abs() < 1e-12 * want.abs().max(1.0),
                "erfc({x}): got {got:e}, want {want:e}"
            );
        }
    }

    #[test]
    fn normal_sf_reference_values() {
        // scipy.stats.norm.sf reference values
        let cases = [
            (0.0, 0.5),
            (1.959963984540054, 0.025000000000000022),
            (5.0, 2.866515719235352e-7),
            (-1.0, 0.8413447460685429),
        ];
        for &(z, want) in &cases {
            let got = normal_sf(z);
            assert!(
                (got - want).abs() / want.max(1e-12) < 1e-10,
                "normal_sf({z}): got {got:e}, want {want:e}"
            );
        }
        // two-sided consistency
        let p = normal_two_sided_p(1.959963984540054);
        assert!((p - 0.05).abs() < 1e-12, "p={p}");
        // extreme Wald z still yields a nonzero, tiny p
        let p = normal_two_sided_p(12.0);
        assert!(p > 0.0 && p < 1e-30, "p={p:e}");
    }
}
