//! Statistics substrate: special functions, t-distribution tails, and
//! the regression formulas of §2 / §3 evaluated on *compressed*
//! sufficient statistics.

mod tdist;
mod regression;
mod logistic;

pub use tdist::{
    ln_gamma, betainc, t_sf, t_two_sided_p, erfc, normal_sf, normal_two_sided_p,
};
pub use regression::{
    RegressionFit, fit_from_sufficient, ScanStats, scan_stats_from_projected,
    scan_stats_from_projected_parts, AssocResult,
};
pub use logistic::{
    clamped_mu, deviance_converged, deviance_term, irls_beta_init, irls_solve,
    logistic_fit_from_final, logistic_fit_pooled, logistic_score_scan_pooled,
    score_assoc_from_sums, LogisticFit, IRLS_BETA_GUARD, IRLS_DEFAULT_MAX_ITER,
    IRLS_DEFAULT_TOL, MU_EPS,
};
