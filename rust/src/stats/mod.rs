//! Statistics substrate: special functions, t-distribution tails, and
//! the regression formulas of §2 / §3 evaluated on *compressed*
//! sufficient statistics.

mod tdist;
mod regression;

pub use tdist::{ln_gamma, betainc, t_sf, t_two_sided_p};
pub use regression::{
    RegressionFit, fit_from_sufficient, ScanStats, scan_stats_from_projected,
    scan_stats_from_projected_parts, AssocResult,
};
