//! Minimal HTTP/1.1 server and client for the daemon control plane.
//!
//! Hand-rolled over [`std::net::TcpListener`] under the same
//! no-new-deps discipline as the rest of the transport layer: the
//! control plane needs exactly five routes and JSON bodies, not a web
//! framework. The server is deliberately simple — every connection
//! carries one request and is closed after the response
//! (`Connection: close`), each accepted connection is handled on its
//! own short-lived thread, and bodies are bounded (`413` past the cap)
//! with a socket read timeout so a stalled client cannot pin a handler
//! thread forever. That is the right shape for a job-control API where
//! requests are small, infrequent, and latency-insensitive relative to
//! the multi-second scans they launch.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Largest accepted request body (the biggest legitimate payload is a
/// RunConfig JSON document, a few KiB).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted request head (request line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Per-socket read timeout: bounds how long a slow or stalled peer can
/// hold a handler thread.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path only (any `?query` suffix is kept verbatim in `path`; the
    /// control plane doesn't use query strings)
    pub path: String,
    /// header names lowercased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }
}

/// One HTTP response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with the right content type.
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "application/json".into())],
            body: body.to_string().into_bytes(),
        }
    }

    /// Plain-text response.
    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "text/plain".into())],
            body: body.as_bytes().to_vec(),
        }
    }

    /// Add a header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    /// Case-insensitive header lookup (client side).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// Body parsed as JSON (client side).
    pub fn json_body(&self) -> anyhow::Result<crate::util::json::Json> {
        crate::util::json::Json::parse(std::str::from_utf8(&self.body)?)
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "",
    }
}

/// Read one request head + body off a stream. `Err` means the request
/// was malformed or over limits; the enclosed response should be sent
/// back before closing.
fn read_request(stream: &mut BufReader<TcpStream>) -> Result<Request, Response> {
    let mut head = String::new();
    // request line
    let mut line = String::new();
    stream
        .read_line(&mut line)
        .map_err(|_| Response::text(400, "unreadable request line"))?;
    if line.is_empty() {
        return Err(Response::text(400, "empty request"));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(Response::text(400, "malformed request line"));
    }
    // headers
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        stream
            .read_line(&mut line)
            .map_err(|_| Response::text(400, "unreadable header"))?;
        head.push_str(&line);
        if head.len() > MAX_HEAD_BYTES {
            return Err(Response::text(400, "request head too large"));
        }
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        let Some((name, value)) = trimmed.split_once(':') else {
            return Err(Response::text(400, "malformed header"));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    // body (Content-Length framing only; the control plane never chunks)
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse())
        .transpose()
        .map_err(|_| Response::text(400, "malformed content-length"))?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(Response::text(413, "request body too large"));
    }
    let mut body = vec![0u8; len];
    stream
        .read_exact(&mut body)
        .map_err(|_| Response::text(400, "truncated request body"))?;
    Ok(Request { method, path, headers, body })
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let mut out = format!("HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    for (k, v) in &resp.headers {
        out.push_str(&format!("{k}: {v}\r\n"));
    }
    out.push_str(&format!("content-length: {}\r\nconnection: close\r\n\r\n", resp.body.len()));
    stream.write_all(out.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Request handler: pure function from request to response. Panics are
/// contained per connection and answered with a 500.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A tiny threaded HTTP server: one accept loop, one short-lived thread
/// per connection, one request per connection.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl HttpServer {
    /// Bind and start serving. `addr` may use port 0 (ephemeral); the
    /// actual address is [`HttpServer::local_addr`].
    pub fn bind(addr: &str, handler: Handler) -> anyhow::Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::spawn(move || {
            // finished-connection reaping keeps the handle list bounded
            // on a long-lived daemon
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let handler = Arc::clone(&handler);
                conns.push(std::thread::spawn(move || serve_conn(stream, handler)));
                conns.retain(|h| !h.is_finished());
            }
            for h in conns {
                let _ = h.join();
            }
        });
        Ok(HttpServer {
            addr: local,
            stop,
            accept_thread: Mutex::new(Some(accept)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and wait for in-flight connections to finish.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        let handle = crate::util::lock_unpoisoned(&self.accept_thread).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(mut stream: TcpStream, handler: Handler) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let resp = match read_request(&mut reader) {
        Ok(req) => {
            // a panicking handler answers 500 and the daemon lives on
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(&req)))
                .unwrap_or_else(|_| Response::text(500, "handler panicked"))
        }
        Err(resp) => resp,
    };
    let _ = write_response(&mut stream, &resp);
}

/// Blocking one-shot HTTP client: open, send one request, read the full
/// response. Enough for the `dash jobs` CLI, the tests, and the bench.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> anyhow::Result<Response> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("cannot connect to {addr}: {e}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let body = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\n\
         connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    anyhow::ensure!(
        parts.next().is_some_and(|v| v.starts_with("HTTP/1.")),
        "malformed status line {line:?}"
    );
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line {line:?}"))?;
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let len: Option<usize> = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok());
    let mut body = Vec::new();
    match len {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok(Response { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn echo_server() -> HttpServer {
        HttpServer::bind(
            "127.0.0.1:0",
            Arc::new(|req: &Request| match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/healthz") => {
                    let mut o = Json::obj();
                    o.set("ok", true);
                    Response::json(200, &o)
                }
                ("POST", "/echo") => Response {
                    status: 200,
                    headers: vec![("content-type".into(), "application/json".into())],
                    body: req.body.clone(),
                },
                ("GET", "/boom") => panic!("handler panic"),
                ("GET", "/busy") => {
                    Response::text(429, "try later").with_header("retry-after", "1")
                }
                _ => Response::text(404, "no such route"),
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_and_post_roundtrip() {
        let srv = echo_server();
        let addr = srv.local_addr().to_string();
        let r = http_request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json_body().unwrap().get("ok").and_then(|j| j.as_bool()), Some(true));
        let body = br#"{"x": 3}"#;
        let r = http_request(&addr, "POST", "/echo", Some(body)).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, body);
        let r = http_request(&addr, "GET", "/nowhere", None).unwrap();
        assert_eq!(r.status, 404);
        srv.shutdown();
    }

    #[test]
    fn custom_headers_survive_the_wire() {
        let srv = echo_server();
        let addr = srv.local_addr().to_string();
        let r = http_request(&addr, "GET", "/busy", None).unwrap();
        assert_eq!(r.status, 429);
        assert_eq!(r.header("Retry-After"), Some("1"));
        srv.shutdown();
    }

    #[test]
    fn handler_panic_is_a_500_and_the_server_keeps_serving() {
        let srv = echo_server();
        let addr = srv.local_addr().to_string();
        let r = http_request(&addr, "GET", "/boom", None).unwrap();
        assert_eq!(r.status, 500);
        // the accept loop survived the panicked handler
        let r = http_request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        srv.shutdown();
    }

    #[test]
    fn oversized_body_is_rejected_with_413() {
        let srv = echo_server();
        let addr = srv.local_addr().to_string();
        // claim an over-cap body without paying to send it
        let mut stream = TcpStream::connect(&addr).unwrap();
        let head = format!(
            "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        stream.write_all(head.as_bytes()).unwrap();
        let mut resp = String::new();
        BufReader::new(stream).read_line(&mut resp).unwrap();
        assert!(resp.contains("413"), "{resp}");
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_prompt_and_idempotent() {
        let srv = echo_server();
        let addr = srv.local_addr().to_string();
        srv.shutdown();
        srv.shutdown();
        assert!(http_request(&addr, "GET", "/healthz", None).is_err());
    }
}
