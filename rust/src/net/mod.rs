//! Byte-metered transports between parties and leader.
//!
//! The paper's E4 claim — `O(M)` inter-party communication — is verified
//! on real serialized bytes, not an analytic count. Messages are
//! length-prefixed frames of a tagged binary encoding ([`frame`]);
//! transports are in-process channels (default, used by benches for
//! deterministic timing) and localhost TCP (`--transport tcp`, proving
//! the protocol is genuinely message-passing). Every send is counted by
//! a shared [`ByteMeter`]. Protocol messages describe their payload once
//! through the codec layer ([`WireMessage`] / [`Codec`]), which renders
//! to the binary wire format (or a lossless JSON-debug form for
//! transcripts).

mod codec;
mod frame;
mod transport;
mod meter;

pub use codec::{Codec, FieldSink, FieldSource, WireMessage};
pub use frame::{Frame, FrameReader, FrameWriter, PayloadReader};
pub use meter::ByteMeter;
pub use transport::{duplex_pair, tcp_pair, Endpoint};
