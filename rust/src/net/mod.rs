//! Byte-metered transports between parties and leader.
//!
//! The paper's E4 claim — `O(M)` inter-party communication — is verified
//! on real serialized bytes, not an analytic count. Messages are
//! length-prefixed frames of a tagged binary encoding ([`frame`]);
//! transports are in-process channels (default, used by benches for
//! deterministic timing) and localhost TCP (`--transport tcp`, proving
//! the protocol is genuinely message-passing). Every send is counted by
//! a shared [`ByteMeter`]. Protocol messages describe their payload once
//! through the codec layer ([`WireMessage`] / [`Codec`]), which renders
//! to the binary wire format (or a lossless JSON-debug form for
//! transcripts).

//!
//! Multiplexed deployments layer a session demultiplexer ([`mux`]) over
//! one shared connection per party: every frame gains a `session_id`
//! (codec v2, with v1 fallback for dedicated connections) and a
//! [`SessionChannel`] exposes each session as an ordered [`Channel`] —
//! the interface both deployment shapes share. The chaos battery drives
//! the same stack through a fault-injecting transport ([`chaos`]).

pub mod chaos;
mod codec;
mod frame;
pub mod http;
pub mod mux;
pub mod reactor;
mod transport;
mod meter;

pub use codec::{Codec, FieldSink, FieldSource, WireMessage};
pub use frame::{Frame, FrameDecoder, FrameReader, FrameWriter, PayloadReader,
    FRAME_V2_MAGIC, FRAME_V2_OVERHEAD};
pub use meter::ByteMeter;
pub use mux::{MuxOptions, MuxSink, SessionChannel, SessionMux, SessionTransport,
    TransportDead, SESSION_CTRL, TAG_MUX_SHUTDOWN};
pub use reactor::{ConnHandle, FrameSink, Reactor, SinkVerdict};
pub use transport::{duplex_pair, tcp_pair, tcp_stream_pair, Channel, Endpoint};

use std::sync::atomic::{AtomicU64, Ordering};

/// Transport driver threads spawned so far in this process: one per
/// pump-mode [`SessionMux`], one per [`Reactor`]. Monotonic — benches
/// and tests read deltas to prove the reactor drives any number of
/// connections with O(1) threads where the threaded pump needs one
/// each.
static DRIVER_THREADS: AtomicU64 = AtomicU64::new(0);

pub(crate) fn note_driver_thread() {
    DRIVER_THREADS.fetch_add(1, Ordering::Relaxed);
}

/// Cumulative count of transport driver threads spawned by this
/// process.
pub fn transport_driver_threads() -> u64 {
    DRIVER_THREADS.load(Ordering::Relaxed)
}
