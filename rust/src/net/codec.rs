//! Message codec: one field-level description per protocol message,
//! many encodings.
//!
//! Protocol messages used to hand-roll `Frame::put_*` writers and
//! `reader()` parsers in pairs; every new message doubled the ad-hoc
//! serialization surface. Here a message implements [`WireMessage`] once
//! — a tag plus a flat field walk through a [`FieldSink`]/[`FieldSource`]
//! — and a [`Codec`] turns that description into bytes:
//!
//! - [`Codec::Binary`] — the production wire format: fields in walk
//!   order through the [`Frame`] payload helpers, bit-exact and byte-
//!   metered (identical across in-proc and TCP transports).
//! - [`Codec::JsonDebug`] — a lossless JSON rendering (scalars as
//!   decimal strings, floats via Rust's shortest-round-trip formatting,
//!   bytes as hex) for protocol debugging and transcript inspection.
//!   Never used on the hot path; round-trips exactly.
//!
//! Field names only exist in the JSON encoding; the binary codec ignores
//! them, so naming costs nothing on the wire.

use super::frame::{Frame, PayloadReader};
use crate::util::json::Json;

/// Write-side field walk: a message describes its payload as a sequence
/// of named primitive fields.
pub trait FieldSink {
    fn u64(&mut self, name: &'static str, v: u64);
    fn f64(&mut self, name: &'static str, v: f64);
    fn u64s(&mut self, name: &'static str, v: &[u64]);
    fn f64s(&mut self, name: &'static str, v: &[f64]);
    fn bytes(&mut self, name: &'static str, v: &[u8]);
}

/// Read-side field walk, mirroring [`FieldSink`] in the same order.
pub trait FieldSource {
    fn u64(&mut self, name: &'static str) -> anyhow::Result<u64>;
    fn f64(&mut self, name: &'static str) -> anyhow::Result<f64>;
    fn u64s(&mut self, name: &'static str) -> anyhow::Result<Vec<u64>>;
    fn f64s(&mut self, name: &'static str) -> anyhow::Result<Vec<f64>>;
    fn bytes(&mut self, name: &'static str) -> anyhow::Result<Vec<u8>>;
}

/// A protocol message: a frame tag plus a symmetric field walk.
/// `write_fields` and `read_fields` must visit the same fields in the
/// same order — the round-trip tests in `coordinator::messages` hold
/// every implementation to that.
pub trait WireMessage: Sized {
    const TAG: u32;
    /// Human-readable name (error messages, JSON debug encoding).
    const NAME: &'static str;

    fn write_fields<S: FieldSink>(&self, sink: &mut S);
    fn read_fields<S: FieldSource>(source: &mut S) -> anyhow::Result<Self>;

    /// Encode with the production binary codec.
    fn to_frame(&self) -> Frame {
        Codec::Binary.encode(self)
    }

    /// Decode from a frame (binary codec), checking the tag.
    fn from_frame(f: &Frame) -> anyhow::Result<Self> {
        Codec::Binary.decode(f)
    }
}

/// Available frame encodings.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Codec {
    /// Tagged little-endian binary (the wire format).
    #[default]
    Binary,
    /// Lossless JSON text payload, for debugging only.
    JsonDebug,
}

impl Codec {
    /// Encode a message into a frame with this codec.
    pub fn encode<M: WireMessage>(&self, m: &M) -> Frame {
        match self {
            Codec::Binary => {
                let mut sink = BinarySink { f: Frame::new(M::TAG) };
                m.write_fields(&mut sink);
                sink.f
            }
            Codec::JsonDebug => {
                let mut sink = JsonSink { fields: Vec::new() };
                m.write_fields(&mut sink);
                let mut o = Json::obj();
                o.set("msg", M::NAME).set("fields", Json::Arr(sink.fields));
                let mut f = Frame::new(M::TAG);
                f.put_bytes(o.to_string().as_bytes());
                f
            }
        }
    }

    /// Decode a message from a frame with this codec, checking the tag.
    pub fn decode<M: WireMessage>(&self, f: &Frame) -> anyhow::Result<M> {
        anyhow::ensure!(
            f.tag == M::TAG,
            "expected {} (tag {}), got tag {}",
            M::NAME,
            M::TAG,
            f.tag
        );
        match self {
            Codec::Binary => {
                let mut src = BinarySource { r: f.reader() };
                M::read_fields(&mut src)
            }
            Codec::JsonDebug => {
                let text = String::from_utf8(f.reader().bytes()?)
                    .map_err(|_| anyhow::anyhow!("JSON debug payload not utf-8"))?;
                let v = Json::parse(&text)?;
                let name = v.req_str("msg")?;
                anyhow::ensure!(name == M::NAME, "expected {} message, got {name}", M::NAME);
                let fields = v.req_arr("fields")?;
                let mut src = JsonSource { fields, pos: 0 };
                M::read_fields(&mut src)
            }
        }
    }

    /// Render a message as its JSON debug text (for logs).
    pub fn debug_string<M: WireMessage>(m: &M) -> String {
        let f = Codec::JsonDebug.encode(m);
        let mut r = f.reader();
        String::from_utf8(r.bytes().unwrap_or_default()).unwrap_or_default()
    }
}

// ---- binary codec ----

struct BinarySink {
    f: Frame,
}

impl FieldSink for BinarySink {
    fn u64(&mut self, _name: &'static str, v: u64) {
        self.f.put_u64(v);
    }
    fn f64(&mut self, _name: &'static str, v: f64) {
        self.f.put_f64(v);
    }
    fn u64s(&mut self, _name: &'static str, v: &[u64]) {
        self.f.put_u64_slice(v);
    }
    fn f64s(&mut self, _name: &'static str, v: &[f64]) {
        self.f.put_f64_slice(v);
    }
    fn bytes(&mut self, _name: &'static str, v: &[u8]) {
        self.f.put_bytes(v);
    }
}

struct BinarySource<'a> {
    r: PayloadReader<'a>,
}

impl FieldSource for BinarySource<'_> {
    fn u64(&mut self, name: &'static str) -> anyhow::Result<u64> {
        self.r.u64().map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
    fn f64(&mut self, name: &'static str) -> anyhow::Result<f64> {
        self.r.f64().map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
    fn u64s(&mut self, name: &'static str) -> anyhow::Result<Vec<u64>> {
        self.r.u64_vec().map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
    fn f64s(&mut self, name: &'static str) -> anyhow::Result<Vec<f64>> {
        self.r.f64_vec().map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
    fn bytes(&mut self, name: &'static str) -> anyhow::Result<Vec<u8>> {
        self.r.bytes().map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
}

// ---- JSON debug codec ----
//
// Lossless by construction: u64 as decimal strings (JSON numbers are
// f64 and would truncate), f64 via Rust's shortest round-trip `{:?}`
// formatting, bytes as lowercase hex.

fn f64_to_json(v: f64) -> Json {
    Json::Str(format!("{v:?}"))
}

fn f64_from_json(j: &Json) -> anyhow::Result<f64> {
    let s = j.as_str().ok_or_else(|| anyhow::anyhow!("expected float string"))?;
    s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad float `{s}`"))
}

struct JsonSink {
    /// `[name, value]` pairs in walk order (an array, not an object —
    /// repeated field names are legal in the walk).
    fields: Vec<Json>,
}

impl JsonSink {
    fn push(&mut self, name: &'static str, value: Json) {
        self.fields.push(Json::Arr(vec![Json::Str(name.to_string()), value]));
    }
}

impl FieldSink for JsonSink {
    fn u64(&mut self, name: &'static str, v: u64) {
        self.push(name, Json::Str(v.to_string()));
    }
    fn f64(&mut self, name: &'static str, v: f64) {
        self.push(name, f64_to_json(v));
    }
    fn u64s(&mut self, name: &'static str, v: &[u64]) {
        self.push(name, Json::Arr(v.iter().map(|x| Json::Str(x.to_string())).collect()));
    }
    fn f64s(&mut self, name: &'static str, v: &[f64]) {
        self.push(name, Json::Arr(v.iter().map(|&x| f64_to_json(x)).collect()));
    }
    fn bytes(&mut self, name: &'static str, v: &[u8]) {
        let hex: String = v.iter().map(|b| format!("{b:02x}")).collect();
        self.push(name, Json::Str(hex));
    }
}

struct JsonSource<'a> {
    fields: &'a [Json],
    pos: usize,
}

impl JsonSource<'_> {
    fn next(&mut self, name: &'static str) -> anyhow::Result<&Json> {
        let entry = self
            .fields
            .get(self.pos)
            .ok_or_else(|| anyhow::anyhow!("missing field {name}"))?;
        self.pos += 1;
        let pair = entry
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field entry for {name} not a pair"))?;
        anyhow::ensure!(pair.len() == 2, "field entry for {name} not a pair");
        let got = pair[0]
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field name for {name} not a string"))?;
        anyhow::ensure!(got == name, "expected field {name}, found {got}");
        Ok(&pair[1])
    }
}

impl FieldSource for JsonSource<'_> {
    fn u64(&mut self, name: &'static str) -> anyhow::Result<u64> {
        let v = self.next(name)?;
        let s = v.as_str().ok_or_else(|| anyhow::anyhow!("field {name} not a string"))?;
        s.parse::<u64>().map_err(|_| anyhow::anyhow!("field {name}: bad u64 `{s}`"))
    }
    fn f64(&mut self, name: &'static str) -> anyhow::Result<f64> {
        let v = self.next(name)?;
        f64_from_json(v).map_err(|e| anyhow::anyhow!("field {name}: {e}"))
    }
    fn u64s(&mut self, name: &'static str) -> anyhow::Result<Vec<u64>> {
        let v = self.next(name)?;
        let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("field {name} not an array"))?;
        arr.iter()
            .map(|j| {
                let s = j.as_str().ok_or_else(|| anyhow::anyhow!("field {name}: non-string"))?;
                s.parse::<u64>().map_err(|_| anyhow::anyhow!("field {name}: bad u64 `{s}`"))
            })
            .collect()
    }
    fn f64s(&mut self, name: &'static str) -> anyhow::Result<Vec<f64>> {
        let v = self.next(name)?;
        let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("field {name} not an array"))?;
        arr.iter().map(f64_from_json).collect()
    }
    fn bytes(&mut self, name: &'static str) -> anyhow::Result<Vec<u8>> {
        let v = self.next(name)?;
        let s = v.as_str().ok_or_else(|| anyhow::anyhow!("field {name} not a string"))?;
        anyhow::ensure!(s.len() % 2 == 0, "field {name}: odd hex length");
        // byte-wise (not char-wise) so malformed multi-byte input errors
        // instead of panicking on a char boundary
        fn nibble(b: u8) -> Option<u8> {
            match b {
                b'0'..=b'9' => Some(b - b'0'),
                b'a'..=b'f' => Some(b - b'a' + 10),
                b'A'..=b'F' => Some(b - b'A' + 10),
                _ => None,
            }
        }
        s.as_bytes()
            .chunks_exact(2)
            .map(|c| {
                match (nibble(c[0]), nibble(c[1])) {
                    (Some(hi), Some(lo)) => Ok(hi << 4 | lo),
                    _ => Err(anyhow::anyhow!("field {name}: bad hex")),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    struct Probe {
        a: u64,
        scalar: f64,
        xs: Vec<u64>,
        fs: Vec<f64>,
        blob: Vec<u8>,
    }

    impl WireMessage for Probe {
        const TAG: u32 = 900;
        const NAME: &'static str = "PROBE";

        fn write_fields<S: FieldSink>(&self, s: &mut S) {
            s.u64("a", self.a);
            s.f64("scalar", self.scalar);
            s.u64s("xs", &self.xs);
            s.f64s("fs", &self.fs);
            s.bytes("blob", &self.blob);
        }

        fn read_fields<S: FieldSource>(s: &mut S) -> anyhow::Result<Self> {
            Ok(Probe {
                a: s.u64("a")?,
                scalar: s.f64("scalar")?,
                xs: s.u64s("xs")?,
                fs: s.f64s("fs")?,
                blob: s.bytes("blob")?,
            })
        }
    }

    fn probe() -> Probe {
        Probe {
            a: u64::MAX,
            scalar: -2.5e-308,
            xs: vec![0, 1, u64::MAX - 1],
            fs: vec![0.1, -1.5e300, f64::NAN, f64::INFINITY, -0.0],
            blob: vec![0x00, 0xff, 0x7f],
        }
    }

    fn probes_equal(a: &Probe, b: &Probe) -> bool {
        a.a == b.a
            && a.scalar.to_bits() == b.scalar.to_bits()
            && a.xs == b.xs
            && a.blob == b.blob
            && a.fs.len() == b.fs.len()
            && a.fs.iter().zip(&b.fs).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn binary_roundtrip() {
        let p = probe();
        let f = Codec::Binary.encode(&p);
        assert_eq!(f.tag, 900);
        let q: Probe = Codec::Binary.decode(&f).unwrap();
        assert!(probes_equal(&p, &q));
    }

    #[test]
    fn binary_matches_hand_rolled_frame() {
        // The codec must produce exactly the bytes the old put_* code
        // produced — byte counts are part of the E4 measurements.
        let p = probe();
        let via_codec = Codec::Binary.encode(&p);
        let mut by_hand = Frame::new(900);
        by_hand
            .put_u64(p.a)
            .put_f64(p.scalar)
            .put_u64_slice(&p.xs)
            .put_f64_slice(&p.fs)
            .put_bytes(&p.blob);
        assert_eq!(via_codec, by_hand);
    }

    #[test]
    fn json_debug_roundtrip_is_lossless() {
        let p = probe();
        let f = Codec::JsonDebug.encode(&p);
        let q: Probe = Codec::JsonDebug.decode(&f).unwrap();
        assert!(probes_equal(&p, &q), "{:?} vs {:?}", p, q);
        let text = Codec::debug_string(&p);
        assert!(text.contains("\"PROBE\""));
        assert!(text.contains("blob"));
    }

    #[test]
    fn wrong_tag_rejected() {
        let mut f = Codec::Binary.encode(&probe());
        f.tag = 901;
        assert!(Codec::Binary.decode::<Probe>(&f).is_err());
        assert!(Probe::from_frame(&f).is_err());
    }

    #[test]
    fn codecs_do_not_cross_decode() {
        let p = probe();
        let bin = Codec::Binary.encode(&p);
        assert!(Codec::JsonDebug.decode::<Probe>(&bin).is_err());
        let js = Codec::JsonDebug.encode(&p);
        assert!(probes_equal(&p, &Codec::JsonDebug.decode::<Probe>(&js).unwrap()));
    }

    #[test]
    fn truncated_binary_names_the_field() {
        let p = probe();
        let mut f = Codec::Binary.encode(&p);
        f.payload.truncate(4);
        let err = format!("{:#}", Codec::Binary.decode::<Probe>(&f).unwrap_err());
        assert!(err.contains("field a") || err.contains("field xs"), "{err}");
    }
}
