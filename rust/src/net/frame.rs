//! Binary message framing.
//!
//! Wire format v1: `[u32 tag][u64 payload_len][payload bytes]`, all
//! little-endian. Payload helpers encode vectors of `u64`/`f64` and
//! matrices with shape headers — enough structure for the protocol
//! messages without a serde dependency.
//!
//! Wire format v2 (multiplexed sessions): `[u32 FRAME_V2_MAGIC]
//! [u64 session_id][u32 tag][u64 payload_len][payload bytes]`. The magic
//! word occupies the tag position of a v1 frame, so a reader that
//! understands both ([`FrameReader::read_any`]) sniffs the first word:
//! magic ⇒ v2 with an explicit session id, anything else ⇒ a v1 frame
//! belonging to the implicit session 0. v1 writers and readers are
//! unchanged; only session-multiplexed transports emit v2 frames.

use std::io::{Read, Write};

/// First word of a v2 (session-multiplexed) frame. Deliberately far
/// outside the protocol tag range so a v1 frame can never alias it.
pub const FRAME_V2_MAGIC: u32 = 0xD5A2_F2AA;

/// Extra wire bytes a v2 frame carries over v1: the magic word plus the
/// session id.
pub const FRAME_V2_OVERHEAD: u64 = 4 + 8;

/// Largest payload length accepted from the wire, in either framing
/// version and by both the blocking reader and the incremental decoder.
/// Checked against the peer's length word **as a u64, before any cast
/// or allocation** — a corrupted or hostile length must surface as a
/// clean error, never a huge allocation or a lossy `as usize` truncation
/// on 32-bit targets.
pub const MAX_FRAME_LEN: u64 = 1 << 32;

/// A tagged frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub tag: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(tag: u32) -> Frame {
        Frame { tag, payload: Vec::new() }
    }

    /// Total bytes on the wire for this frame (v1 framing).
    pub fn wire_len(&self) -> u64 {
        4 + 8 + self.payload.len() as u64
    }

    /// Total bytes on the wire for this frame under v2 (session) framing.
    pub fn wire_len_v2(&self) -> u64 {
        self.wire_len() + FRAME_V2_OVERHEAD
    }

    // ---- payload writers ----

    pub fn put_u64(&mut self, v: u64) -> &mut Self {
        self.payload.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn put_f64(&mut self, v: f64) -> &mut Self {
        self.payload.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn put_u64_slice(&mut self, vs: &[u64]) -> &mut Self {
        self.put_u64(vs.len() as u64);
        self.payload.reserve(vs.len() * 8);
        for &v in vs {
            self.payload.extend_from_slice(&v.to_le_bytes());
        }
        self
    }

    pub fn put_f64_slice(&mut self, vs: &[f64]) -> &mut Self {
        self.put_u64(vs.len() as u64);
        self.payload.reserve(vs.len() * 8);
        for &v in vs {
            self.payload.extend_from_slice(&v.to_le_bytes());
        }
        self
    }

    pub fn put_bytes(&mut self, bs: &[u8]) -> &mut Self {
        self.put_u64(bs.len() as u64);
        self.payload.extend_from_slice(bs);
        self
    }

    /// Cursor-based payload reader.
    pub fn reader(&self) -> PayloadReader<'_> {
        PayloadReader { buf: &self.payload, pos: 0 }
    }
}

/// Sequential reader over a frame payload.
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        // checked: a corrupted length can put pos + n past usize::MAX
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("payload underrun"))?;
        anyhow::ensure!(end <= self.buf.len(), "payload underrun");
        let s = &self.buf[self.pos..end];
        self.pos += n;
        Ok(s)
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length prefix of a vector, overflow-checked: a corrupted prefix
    /// near `u64::MAX` must surface as a clean error, not an arithmetic
    /// panic (debug) or a silently-wrapped short read (release).
    fn vec_bytes(&mut self) -> anyhow::Result<usize> {
        let n = self.u64()? as usize;
        n.checked_mul(8).ok_or_else(|| anyhow::anyhow!("implausible vector length {n}"))
    }

    pub fn u64_vec(&mut self) -> anyhow::Result<Vec<u64>> {
        let bytes = self.vec_bytes()?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f64_vec(&mut self) -> anyhow::Result<Vec<f64>> {
        let bytes = self.vec_bytes()?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Write frames to any `Write`.
pub struct FrameWriter<W: Write> {
    w: W,
}

impl<W: Write> FrameWriter<W> {
    pub fn new(w: W) -> Self {
        FrameWriter { w }
    }

    pub fn write(&mut self, f: &Frame) -> anyhow::Result<u64> {
        anyhow::ensure!(f.tag != FRAME_V2_MAGIC, "tag collides with the v2 magic word");
        self.w.write_all(&f.tag.to_le_bytes())?;
        self.w.write_all(&(f.payload.len() as u64).to_le_bytes())?;
        self.w.write_all(&f.payload)?;
        self.w.flush()?;
        Ok(f.wire_len())
    }

    /// Write a v2 (session-multiplexed) frame.
    pub fn write_v2(&mut self, session: u64, f: &Frame) -> anyhow::Result<u64> {
        self.w.write_all(&FRAME_V2_MAGIC.to_le_bytes())?;
        self.w.write_all(&session.to_le_bytes())?;
        self.w.write_all(&f.tag.to_le_bytes())?;
        self.w.write_all(&(f.payload.len() as u64).to_le_bytes())?;
        self.w.write_all(&f.payload)?;
        self.w.flush()?;
        Ok(f.wire_len_v2())
    }
}

/// Read frames from any `Read`.
pub struct FrameReader<R: Read> {
    r: R,
}

impl<R: Read> FrameReader<R> {
    pub fn new(r: R) -> Self {
        FrameReader { r }
    }

    pub fn read(&mut self) -> anyhow::Result<Frame> {
        let mut tag = [0u8; 4];
        self.r.read_exact(&mut tag)?;
        self.read_body(u32::from_le_bytes(tag))
    }

    /// Read a frame in either framing version: a v2 frame yields its
    /// explicit session id, a v1 frame falls back to session 0.
    pub fn read_any(&mut self) -> anyhow::Result<(u64, Frame)> {
        let mut head = [0u8; 4];
        self.r.read_exact(&mut head)?;
        let first = u32::from_le_bytes(head);
        if first == FRAME_V2_MAGIC {
            let mut sid = [0u8; 8];
            self.r.read_exact(&mut sid)?;
            let mut tag = [0u8; 4];
            self.r.read_exact(&mut tag)?;
            let f = self.read_body(u32::from_le_bytes(tag))?;
            Ok((u64::from_le_bytes(sid), f))
        } else {
            Ok((0, self.read_body(first)?))
        }
    }

    fn read_body(&mut self, tag: u32) -> anyhow::Result<Frame> {
        let mut len = [0u8; 8];
        self.r.read_exact(&mut len)?;
        let len = u64::from_le_bytes(len);
        anyhow::ensure!(len <= MAX_FRAME_LEN, "frame too large: {len} bytes");
        let mut payload = vec![0u8; len as usize];
        self.r.read_exact(&mut payload)?;
        Ok(Frame { tag, payload })
    }
}

/// Incremental, non-blocking frame decoder: feed it byte chunks as they
/// arrive ([`FrameDecoder::push`]) and pull complete frames out
/// ([`FrameDecoder::next_frame`]). Decoding mirrors
/// [`FrameReader::read_any`] exactly — v2 magic sniff, v1 fallback to
/// session 0, the same frame-length cap — but never blocks: a partial
/// frame yields `Ok(None)` until more bytes arrive, so a readiness-driven
/// reactor can hand it whatever the socket had and move on. The internal
/// reassembly buffer is owned per connection and reused across frames.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder { buf: Vec::new() }
    }

    /// Append freshly-read bytes to the reassembly buffer.
    pub fn push(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes buffered but not yet decoded. Nonzero at EOF means the
    /// stream was cut mid-frame.
    pub fn buffered_len(&self) -> usize {
        self.buf.len()
    }

    fn word(&self, off: usize) -> u32 {
        u32::from_le_bytes(self.buf[off..off + 4].try_into().unwrap())
    }

    fn long(&self, off: usize) -> u64 {
        u64::from_le_bytes(self.buf[off..off + 8].try_into().unwrap())
    }

    /// Try to decode the next complete frame. `Ok(None)` means "need
    /// more bytes"; `Err` means the stream is corrupt (implausible frame
    /// length) and the connection must be failed.
    pub fn next_frame(&mut self) -> anyhow::Result<Option<(u64, Frame)>> {
        let avail = self.buf.len();
        if avail < 4 {
            return Ok(None);
        }
        // header layout after the sniffed first word: v2 is
        // [magic][sid u64][tag u32][len u64], v1 is [tag u32][len u64]
        let (hdr, sid, tag) = if self.word(0) == FRAME_V2_MAGIC {
            if avail < 24 {
                return Ok(None);
            }
            (24usize, self.long(4), self.word(12))
        } else {
            if avail < 12 {
                return Ok(None);
            }
            (12usize, 0u64, self.word(0))
        };
        let len = if hdr == 24 { self.long(16) } else { self.long(4) };
        anyhow::ensure!(len <= MAX_FRAME_LEN, "frame too large: {len} bytes");
        let total = hdr + len as usize;
        if avail < total {
            return Ok(None);
        }
        let payload = self.buf[hdr..total].to_vec();
        let f = Frame { tag, payload };
        self.buf.drain(..total);
        Ok(Some((sid, f)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_roundtrip() {
        let mut f = Frame::new(7);
        f.put_u64(42)
            .put_f64(-1.5)
            .put_u64_slice(&[1, 2, 3])
            .put_f64_slice(&[0.5, 2.5])
            .put_bytes(b"hello");
        let mut r = f.reader();
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(r.f64().unwrap(), -1.5);
        assert_eq!(r.u64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f64_vec().unwrap(), vec![0.5, 2.5]);
        assert_eq!(r.bytes().unwrap(), b"hello");
        assert!(r.done());
    }

    #[test]
    fn stream_roundtrip() {
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            let mut f1 = Frame::new(1);
            f1.put_u64(10);
            let mut f2 = Frame::new(2);
            f2.put_f64_slice(&[1.0, 2.0, 3.0]);
            w.write(&f1).unwrap();
            w.write(&f2).unwrap();
        }
        let mut r = FrameReader::new(buf.as_slice());
        let g1 = r.read().unwrap();
        assert_eq!(g1.tag, 1);
        assert_eq!(g1.reader().u64().unwrap(), 10);
        let g2 = r.read().unwrap();
        assert_eq!(g2.tag, 2);
        assert_eq!(g2.reader().f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn wire_len_counts_header() {
        let mut f = Frame::new(0);
        f.put_u64(1);
        assert_eq!(f.wire_len(), 4 + 8 + 8);
    }

    #[test]
    fn underrun_is_error_not_panic() {
        let f = Frame::new(1);
        assert!(f.reader().u64().is_err());
    }

    #[test]
    fn corrupted_length_prefix_is_error_not_panic() {
        // a vector length prefix near u64::MAX must not overflow the
        // byte-count arithmetic (debug panic / release wraparound)
        let mut f = Frame::new(1);
        f.put_u64(u64::MAX).put_u64(42);
        assert!(f.reader().u64_vec().is_err());
        assert!(f.reader().f64_vec().is_err());
        assert!(f.reader().bytes().is_err());
        // length prefixes that wrap pos + n
        let mut g = Frame::new(1);
        g.put_u64(u64::MAX / 8);
        assert!(g.reader().u64_vec().is_err());
        assert!(g.reader().bytes().is_err());
    }

    #[test]
    fn implausible_length_word_is_error_in_both_read_paths() {
        // a v1 header whose length word exceeds MAX_FRAME_LEN must fail
        // before allocating, through read(), read_any(), and the
        // incremental decoder alike
        let mut v1 = 3u32.to_le_bytes().to_vec();
        v1.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = FrameReader::new(v1.as_slice()).read().unwrap_err();
        assert!(format!("{err:#}").contains("frame too large"), "{err:#}");
        let err = FrameReader::new(v1.as_slice()).read_any().unwrap_err();
        assert!(format!("{err:#}").contains("frame too large"), "{err:#}");
        // boundary: exactly MAX_FRAME_LEN + 1 (would truncate to 1 under
        // a 32-bit `as usize` cast) is rejected too
        let mut edge = 3u32.to_le_bytes().to_vec();
        edge.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(FrameReader::new(edge.as_slice()).read_any().is_err());
    }

    #[test]
    fn v2_roundtrip_with_session_id() {
        let mut buf = Vec::new();
        let mut f = Frame::new(7);
        f.put_u64(99).put_f64_slice(&[1.5, -2.5]);
        let n = FrameWriter::new(&mut buf).write_v2(0xDEAD_BEEF, &f).unwrap();
        assert_eq!(n, f.wire_len() + FRAME_V2_OVERHEAD);
        assert_eq!(n as usize, buf.len());
        let (sid, g) = FrameReader::new(buf.as_slice()).read_any().unwrap();
        assert_eq!(sid, 0xDEAD_BEEF);
        assert_eq!(g, f);
    }

    #[test]
    fn read_any_falls_back_to_v1() {
        // interleaved v1 and v2 frames on one stream, read with read_any
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            let mut f1 = Frame::new(3);
            f1.put_u64(1);
            w.write(&f1).unwrap();
            let mut f2 = Frame::new(4);
            f2.put_u64(2);
            w.write_v2(42, &f2).unwrap();
            let mut f3 = Frame::new(5);
            f3.put_u64(3);
            w.write(&f3).unwrap();
        }
        let mut r = FrameReader::new(buf.as_slice());
        let (s1, g1) = r.read_any().unwrap();
        assert_eq!((s1, g1.tag), (0, 3));
        let (s2, g2) = r.read_any().unwrap();
        assert_eq!((s2, g2.tag), (42, 4));
        let (s3, g3) = r.read_any().unwrap();
        assert_eq!((s3, g3.tag), (0, 5));
    }

    #[test]
    fn v1_writer_rejects_magic_tag() {
        let mut buf = Vec::new();
        let f = Frame::new(FRAME_V2_MAGIC);
        assert!(FrameWriter::new(&mut buf).write(&f).is_err());
        // v2 framing carries any tag, including one equal to the magic
        let n = FrameWriter::new(&mut buf).write_v2(1, &f).unwrap();
        assert_eq!(n as usize, buf.len());
        let (sid, g) = FrameReader::new(buf.as_slice()).read_any().unwrap();
        assert_eq!(sid, 1);
        assert_eq!(g.tag, FRAME_V2_MAGIC);
    }

    #[test]
    fn truncated_v2_stream_errors() {
        let mut buf = Vec::new();
        let mut f = Frame::new(1);
        f.put_u64_slice(&[1, 2, 3]);
        FrameWriter::new(&mut buf).write_v2(9, &f).unwrap();
        for cut in [2usize, 6, 13, buf.len() - 1] {
            let mut t = buf.clone();
            t.truncate(cut);
            assert!(FrameReader::new(t.as_slice()).read_any().is_err(), "cut {cut}");
        }
    }

    #[test]
    fn truncated_stream_errors() {
        let mut buf = Vec::new();
        let mut w = FrameWriter::new(&mut buf);
        let mut f = Frame::new(1);
        f.put_u64_slice(&[1, 2, 3, 4]);
        w.write(&f).unwrap();
        buf.truncate(buf.len() - 3);
        let mut r = FrameReader::new(buf.as_slice());
        assert!(r.read().is_err());
    }

    #[test]
    fn incremental_decoder_matches_read_any_byte_at_a_time() {
        // a mixed v1/v2 stream fed one byte at a time must decode to the
        // exact frames read_any sees on the whole buffer
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            let mut f1 = Frame::new(3);
            f1.put_u64(1);
            w.write(&f1).unwrap();
            let mut f2 = Frame::new(4);
            f2.put_f64_slice(&[1.5, -2.5]);
            w.write_v2(42, &f2).unwrap();
            w.write(&Frame::new(5)).unwrap();
        }
        let mut want = Vec::new();
        let mut r = FrameReader::new(buf.as_slice());
        for _ in 0..3 {
            want.push(r.read_any().unwrap());
        }
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for b in &buf {
            dec.push(std::slice::from_ref(b));
            while let Some(sf) = dec.next_frame().unwrap() {
                got.push(sf);
            }
        }
        assert_eq!(got, want);
        assert_eq!(dec.buffered_len(), 0);
    }

    #[test]
    fn incremental_decoder_reports_partial_frames() {
        let mut buf = Vec::new();
        let mut f = Frame::new(9);
        f.put_u64_slice(&[7, 8]);
        FrameWriter::new(&mut buf).write_v2(5, &f).unwrap();
        let mut dec = FrameDecoder::new();
        dec.push(&buf[..buf.len() - 1]);
        assert!(dec.next_frame().unwrap().is_none());
        assert_eq!(dec.buffered_len(), buf.len() - 1);
        dec.push(&buf[buf.len() - 1..]);
        let (sid, g) = dec.next_frame().unwrap().unwrap();
        assert_eq!((sid, g), (5, f));
    }

    #[test]
    fn incremental_decoder_rejects_implausible_length() {
        // corrupt length word in both framings → clean Err, not an
        // unbounded allocation or a hang
        let mut v1 = 1u32.to_le_bytes().to_vec();
        v1.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&v1);
        assert!(dec.next_frame().is_err());
        let mut v2 = FRAME_V2_MAGIC.to_le_bytes().to_vec();
        v2.extend_from_slice(&7u64.to_le_bytes());
        v2.extend_from_slice(&1u32.to_le_bytes());
        v2.extend_from_slice(&((1u64 << 32) + 1).to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&v2);
        assert!(dec.next_frame().is_err());
    }
}
