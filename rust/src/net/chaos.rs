//! Fault-injecting session transport for the chaos battery.
//!
//! [`FaultyTransport`] wraps any [`SessionTransport`] and perturbs
//! exactly one targeted frame — the `nth` frame of a chosen session in a
//! chosen direction — by dropping, duplicating, reordering, or
//! misrouting it to another session. The perturbation is deterministic
//! (a counter, not a coin flip) so every chaos test pins down precisely
//! which protocol step was hit and can assert the exact failure surface:
//! the affected session fails with a clean `ErrorMsg`/timeout, and every
//! untouched session completes bit-identically to its serial run
//! (`tests/chaos_sessions.rs`).
//!
//! Mux control frames ([`crate::net::SESSION_CTRL`]) are never targeted,
//! so connection teardown stays orderly even under fault injection.

use super::frame::Frame;
use super::meter::ByteMeter;
use super::mux::{SessionTransport, TransportDead, SESSION_CTRL};
use super::reactor::{FrameSink, SinkVerdict};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What happens to the targeted frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// the frame vanishes
    Drop,
    /// the frame is delivered twice
    Duplicate,
    /// the frame is swapped with the targeted session's *next* frame
    /// (swapping with another session's frame would be undone by the
    /// demux, which only guarantees per-session FIFO order); if no later
    /// frame of that session ever passes, the held frame is lost
    /// (degrades to a drop — still bounded by the receive timeout)
    Reorder,
    /// the frame is delivered to a different session
    Misroute { to: u64 },
    /// the frame **and every subsequent frame** of the targeted session
    /// in this direction vanish — a persistent one-directional
    /// connection death, the party-dropout axis of the chaos battery
    /// (the recovery path in `coordinator::leader` must turn this into
    /// a resumed or typed-degraded result, never a restart or a hang)
    Hangup,
}

/// Which direction of the wrapped transport is perturbed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultDir {
    /// outgoing frames (`send_s`)
    Send,
    /// incoming frames (`recv_s`)
    Recv,
}

/// One deterministic fault: the `nth` (0-based) frame of `session` in
/// direction `dir` on the wrapped connection of party `party`.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// which party's shared connection is wrapped (used by the session
    /// service wiring; the transport itself doesn't read it)
    pub party: usize,
    pub dir: FaultDir,
    pub mode: FaultMode,
    /// targeted session id
    pub session: u64,
    /// 0-based index among that session's frames in that direction
    pub nth: u64,
}

/// Does this frame hit the fault trigger? `seen` counts that
/// direction's frames of the targeted session.
fn hits(spec: &FaultSpec, seen: &AtomicU64, sid: u64) -> bool {
    if sid != spec.session || sid == SESSION_CTRL {
        return false;
    }
    let n = seen.fetch_add(1, Ordering::SeqCst);
    match spec.mode {
        // a hangup is permanent: the nth and every later frame die
        FaultMode::Hangup => n >= spec.nth,
        _ => n == spec.nth,
    }
}

/// Receive-direction fault logic, factored out of the pull-mode
/// transport so the push-mode reactor path ([`FaultSink`]) applies the
/// exact same perturbation: one incoming frame expands to zero, one, or
/// two deliveries.
pub struct RecvFilter {
    spec: FaultSpec,
    seen: AtomicU64,
    /// held frame awaiting the targeted session's next frame (reorder)
    held: Mutex<Option<(u64, Frame)>>,
}

impl RecvFilter {
    pub fn new(spec: FaultSpec) -> RecvFilter {
        RecvFilter { spec, seen: AtomicU64::new(0), held: Mutex::new(None) }
    }

    /// Perturb one incoming frame into its deliveries, in order.
    pub fn apply(&self, sid: u64, f: Frame) -> Vec<(u64, Frame)> {
        if hits(&self.spec, &self.seen, sid) {
            return match self.spec.mode {
                FaultMode::Drop | FaultMode::Hangup => Vec::new(),
                FaultMode::Duplicate => vec![(sid, f.clone()), (sid, f)],
                FaultMode::Misroute { to } => vec![(to, f)],
                FaultMode::Reorder => {
                    *self.held.lock().unwrap() = Some((sid, f));
                    Vec::new()
                }
            };
        }
        if sid == self.spec.session {
            if let Some(h) = self.held.lock().unwrap().take() {
                // deliver the later frame now, the held one next
                return vec![(sid, f), h];
            }
        }
        vec![(sid, f)]
    }
}

/// A [`SessionTransport`] that injects exactly one fault.
pub struct FaultyTransport {
    inner: Box<dyn SessionTransport>,
    spec: FaultSpec,
    seen: AtomicU64,
    /// held frame awaiting the next send (send-side reorder)
    held: Mutex<Option<(u64, Frame)>>,
    /// recv-side perturbation (consulted only for `FaultDir::Recv`)
    filter: RecvFilter,
    /// deliveries queued by the recv filter, drained in order
    pending: Mutex<VecDeque<(u64, Frame)>>,
}

impl FaultyTransport {
    pub fn new(inner: Box<dyn SessionTransport>, spec: FaultSpec) -> FaultyTransport {
        FaultyTransport {
            inner,
            spec,
            seen: AtomicU64::new(0),
            held: Mutex::new(None),
            filter: RecvFilter::new(spec),
            pending: Mutex::new(VecDeque::new()),
        }
    }

    /// Wrap an endpoint-like transport only if the spec targets this
    /// party; otherwise pass it through untouched.
    pub fn wrap_if(
        inner: Box<dyn SessionTransport>,
        party: usize,
        spec: Option<FaultSpec>,
    ) -> Box<dyn SessionTransport> {
        match spec {
            Some(s) if s.party == party => Box::new(FaultyTransport::new(inner, s)),
            _ => inner,
        }
    }

}

impl SessionTransport for FaultyTransport {
    fn send_s(&self, sid: u64, f: &Frame) -> anyhow::Result<u64> {
        if self.spec.dir != FaultDir::Send {
            return self.inner.send_s(sid, f);
        }
        if hits(&self.spec, &self.seen, sid) {
            return match self.spec.mode {
                FaultMode::Drop | FaultMode::Hangup => Ok(0),
                FaultMode::Duplicate => {
                    let a = self.inner.send_s(sid, f)?;
                    let b = self.inner.send_s(sid, f)?;
                    Ok(a + b)
                }
                FaultMode::Misroute { to } => self.inner.send_s(to, f),
                FaultMode::Reorder => {
                    *self.held.lock().unwrap() = Some((sid, f.clone()));
                    Ok(0)
                }
            };
        }
        let n = self.inner.send_s(sid, f)?;
        // a held (reordered) frame goes out right after the targeted
        // session's next frame
        if sid == self.spec.session {
            let held = self.held.lock().unwrap().take();
            if let Some((hs, hf)) = held {
                self.inner.send_s(hs, &hf)?;
            }
        }
        Ok(n)
    }

    fn recv_s(&self) -> anyhow::Result<(u64, Frame)> {
        if self.spec.dir != FaultDir::Recv {
            return self.inner.recv_s();
        }
        loop {
            if let Some(x) = self.pending.lock().unwrap().pop_front() {
                return Ok(x);
            }
            let (sid, f) = self.inner.recv_s()?;
            let out = self.filter.apply(sid, f);
            self.pending.lock().unwrap().extend(out);
        }
    }

    fn meter(&self) -> &ByteMeter {
        self.inner.meter()
    }
}

/// Receive-side fault injection for the reactor drive mode: sits
/// between the reactor and a [`crate::net::MuxSink`], expanding each
/// pushed frame through the same [`RecvFilter`] the pull-mode
/// [`FaultyTransport`] uses — both drive modes perturb identically.
/// Inbox-full backpressure composes: refused deliveries queue here and
/// replay (in order) when the reactor retries after resume.
pub struct FaultSink {
    filter: RecvFilter,
    inner: Arc<dyn FrameSink>,
    pending: Mutex<VecDeque<(u64, Frame)>>,
}

impl FaultSink {
    pub fn new(spec: FaultSpec, inner: Arc<dyn FrameSink>) -> FaultSink {
        FaultSink {
            filter: RecvFilter::new(spec),
            inner,
            pending: Mutex::new(VecDeque::new()),
        }
    }

    /// Wrap a sink only if the spec targets this party's receive
    /// direction; otherwise pass it through untouched.
    pub fn wrap_if(
        inner: Arc<dyn FrameSink>,
        party: usize,
        spec: Option<FaultSpec>,
    ) -> Arc<dyn FrameSink> {
        match spec {
            Some(s) if s.party == party && s.dir == FaultDir::Recv => {
                Arc::new(FaultSink::new(s, inner))
            }
            _ => inner,
        }
    }
}

impl FrameSink for FaultSink {
    fn on_frame(&self, sid: u64, f: Frame) -> SinkVerdict {
        let mut pend = self.pending.lock().unwrap();
        if pend.is_empty() {
            pend.extend(self.filter.apply(sid, f));
        }
        // non-empty pending means this call is the reactor retrying a
        // refused delivery: the argument is the placeholder returned
        // below and the real frames replay from the queue
        while let Some((s, g)) = pend.pop_front() {
            match self.inner.on_frame(s, g) {
                SinkVerdict::Accepted => {}
                SinkVerdict::Full(back) => {
                    pend.push_front((s, back));
                    return SinkVerdict::Full(Frame::new(0));
                }
            }
        }
        SinkVerdict::Accepted
    }

    fn on_dead(&self, dead: TransportDead) {
        self.inner.on_dead(dead);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::duplex_pair;

    fn frame(v: u64) -> Frame {
        let mut f = Frame::new(1);
        f.put_u64(v);
        f
    }

    fn faulty_pair(spec: FaultSpec) -> (FaultyTransport, crate::net::Endpoint) {
        let (a, b) = duplex_pair(ByteMeter::new());
        (FaultyTransport::new(Box::new(a), spec), b)
    }

    #[test]
    fn drop_swallows_only_the_targeted_frame() {
        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Drop,
            session: 7,
            nth: 1,
        });
        t.send_s(7, &frame(0)).unwrap();
        t.send_s(9, &frame(100)).unwrap(); // other session untouched
        assert_eq!(t.send_s(7, &frame(1)).unwrap(), 0); // dropped
        t.send_s(7, &frame(2)).unwrap();
        let got: Vec<(u64, u64)> = (0..3)
            .map(|_| {
                let (sid, f) = peer.recv_s().unwrap();
                (sid, f.reader().u64().unwrap())
            })
            .collect();
        assert_eq!(got, vec![(7, 0), (9, 100), (7, 2)]);
    }

    #[test]
    fn duplicate_and_misroute_on_send() {
        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Duplicate,
            session: 3,
            nth: 0,
        });
        t.send_s(3, &frame(5)).unwrap();
        for _ in 0..2 {
            let (sid, f) = peer.recv_s().unwrap();
            assert_eq!((sid, f.reader().u64().unwrap()), (3, 5));
        }

        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Misroute { to: 8 },
            session: 3,
            nth: 0,
        });
        t.send_s(3, &frame(6)).unwrap();
        let (sid, _) = peer.recv_s().unwrap();
        assert_eq!(sid, 8);
    }

    #[test]
    fn reorder_swaps_with_next_frame() {
        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Reorder,
            session: 2,
            nth: 0,
        });
        t.send_s(2, &frame(1)).unwrap(); // held
        t.send_s(2, &frame(2)).unwrap(); // goes first, then flushes held
        let a = peer.recv_s().unwrap().1.reader().u64().unwrap();
        let b = peer.recv_s().unwrap().1.reader().u64().unwrap();
        assert_eq!((a, b), (2, 1));
    }

    #[test]
    fn recv_side_faults() {
        // drop on receive: the frame is read off the wire and discarded
        let (a, b) = duplex_pair(ByteMeter::new());
        let t = FaultyTransport::new(
            Box::new(a),
            FaultSpec {
                party: 0,
                dir: FaultDir::Recv,
                mode: FaultMode::Drop,
                session: 4,
                nth: 0,
            },
        );
        b.send_s(4, &frame(1)).unwrap();
        b.send_s(4, &frame(2)).unwrap();
        let (sid, f) = t.recv_s().unwrap();
        assert_eq!((sid, f.reader().u64().unwrap()), (4, 2));

        // duplicate on receive: delivered twice
        let (a, b) = duplex_pair(ByteMeter::new());
        let t = FaultyTransport::new(
            Box::new(a),
            FaultSpec {
                party: 0,
                dir: FaultDir::Recv,
                mode: FaultMode::Duplicate,
                session: 4,
                nth: 0,
            },
        );
        b.send_s(4, &frame(9)).unwrap();
        assert_eq!(t.recv_s().unwrap().1.reader().u64().unwrap(), 9);
        assert_eq!(t.recv_s().unwrap().1.reader().u64().unwrap(), 9);
    }

    #[test]
    fn hangup_kills_the_session_from_nth_onward() {
        // receive side: frames 0..nth flow, nth and everything after die,
        // other sessions keep flowing
        let (a, b) = duplex_pair(ByteMeter::new());
        let t = FaultyTransport::new(
            Box::new(a),
            FaultSpec {
                party: 0,
                dir: FaultDir::Recv,
                mode: FaultMode::Hangup,
                session: 4,
                nth: 2,
            },
        );
        for v in 0..5u64 {
            b.send_s(4, &frame(v)).unwrap();
        }
        b.send_s(9, &frame(100)).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            let (sid, f) = t.recv_s().unwrap();
            got.push((sid, f.reader().u64().unwrap()));
        }
        assert_eq!(got, vec![(4, 0), (4, 1), (9, 100)]);

        // send side: same persistence
        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Hangup,
            session: 2,
            nth: 1,
        });
        t.send_s(2, &frame(0)).unwrap();
        assert_eq!(t.send_s(2, &frame(1)).unwrap(), 0);
        assert_eq!(t.send_s(2, &frame(2)).unwrap(), 0);
        t.send_s(3, &frame(30)).unwrap();
        assert_eq!(peer.recv_s().unwrap().1.reader().u64().unwrap(), 0);
        assert_eq!(peer.recv_s().unwrap().0, 3);
    }

    #[test]
    fn ctrl_session_is_never_targeted() {
        let (t, peer) = faulty_pair(FaultSpec {
            party: 0,
            dir: FaultDir::Send,
            mode: FaultMode::Drop,
            session: SESSION_CTRL,
            nth: 0,
        });
        t.send_s(SESSION_CTRL, &frame(1)).unwrap();
        assert_eq!(peer.recv_s().unwrap().0, SESSION_CTRL);
    }
}
