//! Shared byte/message counters for E4.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Thread-safe counter of bytes and messages crossing a party boundary.
#[derive(Clone, Debug, Default)]
pub struct ByteMeter {
    inner: Arc<MeterInner>,
}

#[derive(Debug, Default)]
struct MeterInner {
    bytes: AtomicU64,
    messages: AtomicU64,
}

impl ByteMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, bytes: u64) {
        self.inner.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.inner.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bytes(&self) -> u64 {
        self.inner.bytes.load(Ordering::Relaxed)
    }

    pub fn messages(&self) -> u64 {
        self.inner.messages.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.inner.bytes.store(0, Ordering::Relaxed);
        self.inner.messages.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_resets() {
        let m = ByteMeter::new();
        m.record(100);
        m.record(24);
        assert_eq!(m.bytes(), 124);
        assert_eq!(m.messages(), 2);
        m.reset();
        assert_eq!(m.bytes(), 0);
    }

    #[test]
    fn clones_share_state() {
        let m = ByteMeter::new();
        let m2 = m.clone();
        m2.record(8);
        assert_eq!(m.bytes(), 8);
    }

    #[test]
    fn concurrent_records() {
        let m = ByteMeter::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record(1);
                    }
                });
            }
        });
        assert_eq!(m.bytes(), 8000);
        assert_eq!(m.messages(), 8000);
    }
}
