//! Readiness-driven reactor transport: one thread, thousands of
//! connections.
//!
//! The threaded mux burns one blocking pump thread per shared connection
//! ([`crate::net::mux::SessionMux::new`]); a party service built on it
//! tops out at hundreds of peers. The [`Reactor`] replaces the pumps
//! with a single epoll-backed readiness loop: every registered
//! connection is non-blocking, incoming bytes feed a per-connection
//! incremental [`FrameDecoder`], and each decoded frame is pushed into a
//! [`FrameSink`] (a reactor-driven [`crate::net::mux::SessionMux`], or a
//! fault-injecting wrapper from `net::chaos`). The epoll interface is
//! hand-rolled over the libc syscall surface — no new dependency,
//! matching the repo's hermetic-build stance.
//!
//! ## Flow control
//!
//! A sink may refuse a frame ([`SinkVerdict::Full`]) when its bounded
//! per-session inbox is at capacity. The reactor then parks the frame,
//! disarms read interest for that connection (so TCP backpressure
//! reaches the peer) and leaves any undecoded bytes in the decoder;
//! when the consumer drains the inbox, the mux's resume hook calls
//! [`ConnHandle::resume`] and the reactor retries the parked frame
//! before re-arming reads. A full session therefore stalls only its own
//! connection — never the readiness loop.
//!
//! ## Write coalescing
//!
//! Senders never touch the socket: [`ConnHandle::send_s`] encodes the
//! v2 frame straight into a shared per-connection outbound buffer and
//! wakes the reactor only on the empty→non-empty edge. The reactor
//! flushes the whole buffer with single large `write` calls, so bursts
//! of tiny frames (SELECT rounds are O(lanes·H) small frames) coalesce
//! into a handful of syscalls instead of one per frame. `EPOLLOUT` is
//! armed only while the socket pushes back.

use super::frame::{Frame, FrameWriter};
use super::meter::ByteMeter;
use super::mux::{SessionTransport, TransportDead};
use std::sync::{Arc, Mutex};

/// Verdict a [`FrameSink`] returns for one delivered frame.
pub enum SinkVerdict {
    /// Frame consumed (routed, dropped-and-counted, or control-handled).
    Accepted,
    /// The consumer's bounded queue is full: the frame comes back to the
    /// reactor, which parks it and pauses reads until `resume`.
    Full(Frame),
}

/// Consumer side of a reactor connection: decoded frames are pushed in
/// on the reactor thread.
pub trait FrameSink: Send + Sync {
    /// Deliver one decoded frame (session id from the v2 envelope; v1
    /// frames fall back to session 0).
    fn on_frame(&self, sid: u64, f: Frame) -> SinkVerdict;
    /// The connection stopped delivering: clean EOF surfaces as
    /// [`TransportDead::PeerHangup`], a mid-frame cut as
    /// [`TransportDead::TruncatedFrame`]. A sink that already saw the
    /// orderly shutdown handshake ignores this.
    fn on_dead(&self, dead: TransportDead);
}

#[cfg(target_os = "linux")]
pub use linux::{ConnHandle, Reactor};

#[cfg(target_os = "linux")]
mod linux {
    use super::*;
    use std::collections::HashMap;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    mod sys {
        pub const EPOLL_CTL_ADD: i32 = 1;
        pub const EPOLL_CTL_DEL: i32 = 2;
        pub const EPOLL_CTL_MOD: i32 = 3;
        pub const EPOLLIN: u32 = 0x001;
        pub const EPOLLOUT: u32 = 0x004;
        pub const EPOLLERR: u32 = 0x008;
        pub const EPOLLHUP: u32 = 0x010;
        pub const EPOLLRDHUP: u32 = 0x2000;
        pub const EPOLL_CLOEXEC: i32 = 0o2000000;
        pub const EFD_CLOEXEC: i32 = 0o2000000;

        /// Kernel epoll_event layout; packed on x86 so the 64-bit data
        /// word sits directly after the 32-bit event mask.
        #[repr(C)]
        #[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
        #[derive(Clone, Copy)]
        pub struct EpollEvent {
            pub events: u32,
            pub data: u64,
        }

        extern "C" {
            pub fn epoll_create1(flags: i32) -> i32;
            pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, ev: *mut EpollEvent) -> i32;
            pub fn epoll_wait(
                epfd: i32,
                evs: *mut EpollEvent,
                maxevents: i32,
                timeout_ms: i32,
            ) -> i32;
            pub fn eventfd(initval: u32, flags: i32) -> i32;
            pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
            pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
            pub fn close(fd: i32) -> i32;
        }
    }

    /// Token the wakeup eventfd carries in `epoll_event.data`.
    const WAKE: u64 = u64::MAX;

    /// Per-connection shared outbound buffer (coalesced writes).
    struct OutBuf {
        bytes: Mutex<Vec<u8>>,
    }

    enum Cmd {
        Register(u64, TcpStream, Arc<dyn FrameSink>, Arc<OutBuf>, ByteMeter),
        Flush(u64),
        Resume(u64),
    }

    struct Inner {
        epfd: i32,
        wakefd: i32,
        cmds: Mutex<Vec<Cmd>>,
        next_token: AtomicU64,
        stop: AtomicBool,
    }

    impl Inner {
        fn push(&self, cmd: Cmd) {
            self.cmds.lock().unwrap().push(cmd);
            self.wake();
        }

        fn wake(&self) {
            let one = 1u64.to_ne_bytes();
            // best-effort: a full eventfd counter still wakes the loop
            unsafe { sys::write(self.wakefd, one.as_ptr(), one.len()) };
        }
    }

    impl Drop for Inner {
        fn drop(&mut self) {
            unsafe {
                sys::close(self.epfd);
                sys::close(self.wakefd);
            }
        }
    }

    /// Reactor-thread-local state of one registered connection.
    struct Conn {
        stream: TcpStream,
        decoder: crate::net::FrameDecoder,
        sink: Arc<dyn FrameSink>,
        out: Arc<OutBuf>,
        meter: ByteMeter,
        /// frame the sink refused; retried on resume before re-arming reads
        parked: Option<(u64, Frame)>,
        paused: bool,
        want_write: bool,
    }

    enum Fate {
        Keep,
        Dead,
    }

    /// One readiness loop driving every registered connection.
    pub struct Reactor {
        inner: Arc<Inner>,
        thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    }

    impl Reactor {
        /// Create the epoll instance and spawn the (single) driver
        /// thread.
        pub fn new() -> anyhow::Result<Reactor> {
            let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
            anyhow::ensure!(epfd >= 0, "epoll_create1 failed: {}", errno());
            let wakefd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC) };
            if wakefd < 0 {
                let e = errno();
                unsafe { sys::close(epfd) };
                anyhow::bail!("eventfd failed: {e}");
            }
            let mut ev = sys::EpollEvent { events: sys::EPOLLIN, data: WAKE };
            let rc = unsafe { sys::epoll_ctl(epfd, sys::EPOLL_CTL_ADD, wakefd, &mut ev) };
            if rc != 0 {
                let e = errno();
                unsafe {
                    sys::close(epfd);
                    sys::close(wakefd);
                }
                anyhow::bail!("epoll_ctl(wakefd) failed: {e}");
            }
            let inner = Arc::new(Inner {
                epfd,
                wakefd,
                cmds: Mutex::new(Vec::new()),
                next_token: AtomicU64::new(0),
                stop: AtomicBool::new(false),
            });
            let loop_inner = Arc::clone(&inner);
            crate::net::note_driver_thread();
            let thread = std::thread::spawn(move || run_loop(&loop_inner));
            Ok(Reactor { inner, thread: Mutex::new(Some(thread)) })
        }

        /// Stage a connection: the returned handle sends immediately
        /// (bytes buffer until the reactor picks the connection up), but
        /// reads are armed only once [`ConnHandle::activate`] attaches
        /// the frame sink — the sink usually needs the handle first.
        pub fn connect(&self, stream: TcpStream, meter: ByteMeter) -> anyhow::Result<ConnHandle> {
            stream.set_nonblocking(true)?;
            stream.set_nodelay(true)?;
            let token = self.inner.next_token.fetch_add(1, Ordering::Relaxed);
            Ok(ConnHandle {
                token,
                inner: Arc::clone(&self.inner),
                out: Arc::new(OutBuf { bytes: Mutex::new(Vec::new()) }),
                meter,
                staged: Arc::new(Mutex::new(Some(stream))),
            })
        }

        /// Stop the readiness loop and close every registered
        /// connection. Idempotent.
        pub fn shutdown(&self) {
            self.inner.stop.store(true, Ordering::SeqCst);
            self.inner.wake();
            let handle = self.thread.lock().unwrap().take();
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }

    impl Drop for Reactor {
        fn drop(&mut self) {
            self.shutdown();
        }
    }

    /// Send side of one reactor connection. Implements
    /// [`SessionTransport`] so the existing mux / fault-injection
    /// plumbing wraps it unchanged; receiving happens via the
    /// [`FrameSink`], never by pulling.
    #[derive(Clone)]
    pub struct ConnHandle {
        token: u64,
        inner: Arc<Inner>,
        out: Arc<OutBuf>,
        meter: ByteMeter,
        staged: Arc<Mutex<Option<TcpStream>>>,
    }

    impl ConnHandle {
        /// Attach the frame sink and arm the read side.
        pub fn activate(&self, sink: Arc<dyn FrameSink>) -> anyhow::Result<()> {
            let stream = self.staged.lock().unwrap().take();
            let stream = stream.ok_or_else(|| anyhow::anyhow!("connection already active"))?;
            self.inner.push(Cmd::Register(
                self.token,
                stream,
                sink,
                Arc::clone(&self.out),
                self.meter.clone(),
            ));
            Ok(())
        }

        /// Retry the parked frame and re-arm reads (called by the
        /// consumer after draining a full inbox).
        pub fn resume(&self) {
            self.inner.push(Cmd::Resume(self.token));
        }
    }

    impl SessionTransport for ConnHandle {
        fn send_s(&self, session: u64, f: &Frame) -> anyhow::Result<u64> {
            let mut b = self.out.bytes.lock().unwrap();
            let was_empty = b.is_empty();
            let n = FrameWriter::new(&mut *b).write_v2(session, f)?;
            drop(b);
            self.meter.record(n);
            if was_empty {
                self.inner.push(Cmd::Flush(self.token));
            }
            Ok(n)
        }

        fn recv_s(&self) -> anyhow::Result<(u64, Frame)> {
            anyhow::bail!("reactor connections deliver frames through their sink")
        }

        fn meter(&self) -> &ByteMeter {
            &self.meter
        }
    }

    fn errno() -> std::io::Error {
        std::io::Error::last_os_error()
    }

    fn ctl(epfd: i32, op: i32, fd: i32, events: u32, data: u64) {
        let mut ev = sys::EpollEvent { events, data };
        unsafe { sys::epoll_ctl(epfd, op, fd, &mut ev) };
    }

    fn rearm(epfd: i32, conn: &Conn, token: u64) {
        let mut events = sys::EPOLLRDHUP;
        if !conn.paused {
            events |= sys::EPOLLIN;
        }
        if conn.want_write {
            events |= sys::EPOLLOUT;
        }
        ctl(epfd, sys::EPOLL_CTL_MOD, conn.stream.as_raw_fd(), events, token);
    }

    fn run_loop(inner: &Inner) {
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 64];
        let mut scratch = vec![0u8; 64 * 1024];
        loop {
            let n = unsafe {
                sys::epoll_wait(inner.epfd, events.as_mut_ptr(), events.len() as i32, -1)
            };
            if n < 0 {
                if errno().kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                // an unusable epoll fd: fail every connection and exit
                for (_, conn) in conns.drain() {
                    conn.sink.on_dead(TransportDead::Io("epoll_wait failed".into()));
                }
                return;
            }
            let fired: Vec<sys::EpollEvent> = events[..n as usize].to_vec();
            if fired.iter().any(|ev| ev.data == WAKE) {
                let mut buf = [0u8; 8];
                unsafe { sys::read(inner.wakefd, buf.as_mut_ptr(), buf.len()) };
            }
            let cmds = std::mem::take(&mut *inner.cmds.lock().unwrap());
            for cmd in cmds {
                match cmd {
                    Cmd::Register(token, stream, sink, out, meter) => {
                        ctl(
                            inner.epfd,
                            sys::EPOLL_CTL_ADD,
                            stream.as_raw_fd(),
                            sys::EPOLLIN | sys::EPOLLRDHUP,
                            token,
                        );
                        let mut conn = Conn {
                            stream,
                            decoder: crate::net::FrameDecoder::new(),
                            sink,
                            out,
                            meter,
                            parked: None,
                            paused: false,
                            want_write: false,
                        };
                        // bytes sent before registration flush now
                        if let Fate::Dead = flush_conn(inner.epfd, &mut conn, token) {
                            drop_conn(inner.epfd, conn);
                        } else {
                            conns.insert(token, conn);
                        }
                    }
                    Cmd::Flush(token) => {
                        if let Some(conn) = conns.get_mut(&token) {
                            if let Fate::Dead = flush_conn(inner.epfd, conn, token) {
                                let conn = conns.remove(&token).unwrap();
                                drop_conn(inner.epfd, conn);
                            }
                        }
                    }
                    Cmd::Resume(token) => {
                        if let Some(conn) = conns.get_mut(&token) {
                            if let Fate::Dead = resume_conn(inner.epfd, conn, token) {
                                let conn = conns.remove(&token).unwrap();
                                drop_conn(inner.epfd, conn);
                            }
                        }
                    }
                }
            }
            if inner.stop.load(Ordering::SeqCst) {
                for (token, mut conn) in conns.drain() {
                    // best-effort final flush of coalesced writes
                    let _ = flush_conn(inner.epfd, &mut conn, token);
                    drop_conn(inner.epfd, conn);
                }
                return;
            }
            for ev in &fired {
                let (data, mask) = (ev.data, ev.events);
                if data == WAKE || !conns.contains_key(&data) {
                    continue;
                }
                let mut fate = Fate::Keep;
                if mask & sys::EPOLLOUT != 0 {
                    let conn = conns.get_mut(&data).unwrap();
                    fate = flush_conn(inner.epfd, conn, data);
                }
                let readable = mask & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP
                    | sys::EPOLLRDHUP)
                    != 0;
                if let (Fate::Keep, true) = (&fate, readable) {
                    let conn = conns.get_mut(&data).unwrap();
                    if !conn.paused {
                        fate = read_conn(inner.epfd, conn, data, &mut scratch);
                    }
                }
                if let Fate::Dead = fate {
                    let conn = conns.remove(&data).unwrap();
                    drop_conn(inner.epfd, conn);
                }
            }
        }
    }

    fn drop_conn(epfd: i32, conn: Conn) {
        ctl(epfd, sys::EPOLL_CTL_DEL, conn.stream.as_raw_fd(), 0, 0);
        // conn.stream drops here, closing the socket
    }

    /// Write the coalesced outbound buffer until empty or the socket
    /// pushes back (then arm `EPOLLOUT`).
    fn flush_conn(epfd: i32, conn: &mut Conn, token: u64) -> Fate {
        loop {
            let mut b = conn.out.bytes.lock().unwrap();
            if b.is_empty() {
                if conn.want_write {
                    conn.want_write = false;
                    rearm(epfd, conn, token);
                }
                return Fate::Keep;
            }
            match conn.stream.write(&b) {
                Ok(n) => {
                    b.drain(..n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    drop(b);
                    if !conn.want_write {
                        conn.want_write = true;
                        rearm(epfd, conn, token);
                    }
                    return Fate::Keep;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    drop(b);
                    conn.sink.on_dead(TransportDead::Io(format!("write failed: {e}")));
                    return Fate::Dead;
                }
            }
        }
    }

    /// Read until the socket would block, pushing bytes through the
    /// incremental decoder and decoded frames into the sink.
    fn read_conn(epfd: i32, conn: &mut Conn, token: u64, scratch: &mut [u8]) -> Fate {
        loop {
            match conn.stream.read(scratch) {
                Ok(0) => {
                    let dead = if conn.decoder.buffered_len() > 0 || conn.parked.is_some() {
                        TransportDead::TruncatedFrame
                    } else {
                        TransportDead::PeerHangup
                    };
                    conn.sink.on_dead(dead);
                    return Fate::Dead;
                }
                Ok(n) => {
                    conn.decoder.push(&scratch[..n]);
                    if let Fate::Dead = drain_frames(epfd, conn, token) {
                        return Fate::Dead;
                    }
                    if conn.paused {
                        return Fate::Keep;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Fate::Keep,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    conn.sink.on_dead(TransportDead::Io(format!("read failed: {e}")));
                    return Fate::Dead;
                }
            }
        }
    }

    /// Deliver every complete frame in the decoder; on a refusal, park
    /// the frame and pause reads (TCP backpressure toward the peer).
    fn drain_frames(epfd: i32, conn: &mut Conn, token: u64) -> Fate {
        loop {
            let before = conn.decoder.buffered_len();
            match conn.decoder.next_frame() {
                Ok(Some((sid, f))) => {
                    conn.meter.record((before - conn.decoder.buffered_len()) as u64);
                    match conn.sink.on_frame(sid, f) {
                        SinkVerdict::Accepted => {}
                        SinkVerdict::Full(back) => {
                            conn.parked = Some((sid, back));
                            conn.paused = true;
                            rearm(epfd, conn, token);
                            return Fate::Keep;
                        }
                    }
                }
                Ok(None) => return Fate::Keep,
                Err(e) => {
                    conn.sink.on_dead(TransportDead::Io(format!("{e:#}")));
                    return Fate::Dead;
                }
            }
        }
    }

    /// Retry the parked frame; on acceptance re-arm reads and drain any
    /// frames that were already buffered while paused.
    fn resume_conn(epfd: i32, conn: &mut Conn, token: u64) -> Fate {
        if let Some((sid, f)) = conn.parked.take() {
            match conn.sink.on_frame(sid, f) {
                SinkVerdict::Accepted => {}
                SinkVerdict::Full(back) => {
                    conn.parked = Some((sid, back));
                    return Fate::Keep;
                }
            }
        }
        conn.paused = false;
        rearm(epfd, conn, token);
        drain_frames(epfd, conn, token)
    }
}

#[cfg(not(target_os = "linux"))]
pub use fallback::{ConnHandle, Reactor};

#[cfg(not(target_os = "linux"))]
mod fallback {
    use super::*;
    use std::net::TcpStream;

    /// Stub on platforms without epoll: construction fails cleanly and
    /// callers fall back to the threaded pump transport.
    pub struct Reactor;

    impl Reactor {
        pub fn new() -> anyhow::Result<Reactor> {
            anyhow::bail!("the reactor transport requires linux epoll; use --transport threaded")
        }

        pub fn connect(&self, _: TcpStream, _: ByteMeter) -> anyhow::Result<ConnHandle> {
            anyhow::bail!("the reactor transport requires linux epoll")
        }

        pub fn shutdown(&self) {}
    }

    #[derive(Clone)]
    pub struct ConnHandle;

    impl ConnHandle {
        pub fn activate(&self, _: Arc<dyn FrameSink>) -> anyhow::Result<()> {
            anyhow::bail!("the reactor transport requires linux epoll")
        }

        pub fn resume(&self) {}
    }

    impl SessionTransport for ConnHandle {
        fn send_s(&self, _: u64, _: &Frame) -> anyhow::Result<u64> {
            anyhow::bail!("the reactor transport requires linux epoll")
        }

        fn recv_s(&self) -> anyhow::Result<(u64, Frame)> {
            anyhow::bail!("the reactor transport requires linux epoll")
        }

        fn meter(&self) -> &ByteMeter {
            unreachable!("fallback reactor connections cannot be constructed")
        }
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use crate::net::mux::{MuxOptions, SessionMux};
    use crate::net::transport::{tcp_stream_pair, Channel};

    fn driven_pair(
        reactor: &Reactor,
        l_opts: MuxOptions,
        p_opts: MuxOptions,
    ) -> (SessionMux, SessionMux) {
        let (ls, ps) = tcp_stream_pair().unwrap();
        let lh = reactor.connect(ls, ByteMeter::new()).unwrap();
        let ph = reactor.connect(ps, ByteMeter::new()).unwrap();
        let (lmux, lsink) = SessionMux::driven(Box::new(lh.clone()), l_opts);
        let (pmux, psink) = SessionMux::driven(Box::new(ph.clone()), p_opts);
        let (lr, pr) = (lh.clone(), ph.clone());
        lmux.set_resume_hook(Box::new(move || lr.resume()));
        pmux.set_resume_hook(Box::new(move || pr.resume()));
        lh.activate(lsink).unwrap();
        ph.activate(psink).unwrap();
        (lmux, pmux)
    }

    fn frame(tag: u32, v: u64) -> Frame {
        let mut f = Frame::new(tag);
        f.put_u64(v);
        f
    }

    #[test]
    fn driven_mux_roundtrips_sessions_over_one_reactor() {
        let reactor = Reactor::new().unwrap();
        let (leader, party) = driven_pair(
            &reactor,
            MuxOptions { accept: false, ..Default::default() },
            MuxOptions { accept: true, ..Default::default() },
        );
        let a = leader.open(1).unwrap();
        let b = leader.open(2).unwrap();
        b.send(&frame(10, 20)).unwrap();
        a.send(&frame(10, 10)).unwrap();
        let pa = party.accept().unwrap().unwrap();
        let pb = party.accept().unwrap().unwrap();
        assert_eq!(pa.session(), 2);
        assert_eq!(pb.session(), 1);
        assert_eq!(pb.recv().unwrap().reader().u64().unwrap(), 10);
        assert_eq!(pa.recv().unwrap().reader().u64().unwrap(), 20);
        pa.send(&frame(12, 200)).unwrap();
        pb.send(&frame(12, 100)).unwrap();
        assert_eq!(a.recv().unwrap().reader().u64().unwrap(), 100);
        assert_eq!(b.recv().unwrap().reader().u64().unwrap(), 200);
        // per-session byte meters hold under reactor delivery
        let f = frame(10, 10);
        assert_eq!(a.meter().bytes(), 2 * f.wire_len_v2());
        leader.shutdown();
        assert!(party.accept().unwrap().is_none());
        party.shutdown();
        leader.join();
        party.join();
        reactor.shutdown();
    }

    #[test]
    fn full_inbox_pauses_one_connection_not_the_loop() {
        let reactor = Reactor::new().unwrap();
        let (leader, party) = driven_pair(
            &reactor,
            MuxOptions { accept: false, ..Default::default() },
            MuxOptions { accept: true, queue_cap: 1, ..Default::default() },
        );
        let a = leader.open(1).unwrap();
        // burst far past the inbox bound: backpressure must park, not
        // drop or deadlock
        for i in 0..16u64 {
            a.send(&frame(7, i)).unwrap();
        }
        let pa = party.accept().unwrap().unwrap();
        for i in 0..16u64 {
            assert_eq!(pa.recv().unwrap().reader().u64().unwrap(), i);
        }
        // the paused connection never stalled the loop: a second
        // connection on the same reactor keeps flowing while session 1
        // is saturated
        let (l2, p2) = driven_pair(
            &reactor,
            MuxOptions { accept: false, ..Default::default() },
            MuxOptions { accept: true, ..Default::default() },
        );
        let c = l2.open(9).unwrap();
        c.send(&frame(1, 42)).unwrap();
        let pc = p2.accept().unwrap().unwrap();
        assert_eq!(pc.recv().unwrap().reader().u64().unwrap(), 42);
        for (l, p) in [(&leader, &party), (&l2, &p2)] {
            l.shutdown();
            assert!(p.accept().unwrap().is_none());
            p.shutdown();
            l.join();
            p.join();
        }
        reactor.shutdown();
    }
}
