//! Session demultiplexer: many interleaved protocol sessions over one
//! shared connection.
//!
//! A [`SessionMux`] wraps one raw frame transport (an [`Endpoint`] or a
//! fault-injecting [`crate::net::chaos::FaultyTransport`]) and carries
//! any number of concurrent sessions over it using v2 (session-tagged)
//! frames. A background pump thread reads incoming frames and routes
//! them into per-session queues; [`SessionChannel`] handles expose one
//! session as an ordered, byte-metered [`Channel`] — exactly what the
//! leader and party state machines already speak — so the entire
//! scan+SELECT protocol multiplexes without touching a single protocol
//! message.
//!
//! ## Session lifecycle
//!
//! The initiating side (the leader) calls [`SessionMux::open`] before
//! sending a session's first frame; the accepting side (a party) calls
//! [`SessionMux::accept`], which yields a channel when the first frame
//! of an unknown session id arrives. [`SessionMux::close`] frees a
//! session's queue (asserted by the soak test — per-session state must
//! not accumulate). Connection teardown is an explicit two-way
//! handshake: each side sends a control-session shutdown frame
//! ([`SessionMux::shutdown`]), and a pump exits when it *receives* one,
//! so every in-flight frame is routed before either pump stops.
//!
//! ## Fault containment
//!
//! Frames for unknown or already-closed sessions are counted and
//! dropped — a misrouted frame can at worst fail its target session's
//! protocol state machine (every contribution carries its round/shard
//! ordinal, so cross-session leakage is detected), never stall the
//! connection. A configurable receive timeout bounds how long a session
//! waits on a frame that a faulty transport swallowed: the waiting
//! session fails with a clean error and every other session keeps
//! running (the chaos battery in `tests/chaos_sessions.rs`).

use super::frame::Frame;
use super::meter::ByteMeter;
use super::reactor::{FrameSink, SinkVerdict};
use super::transport::{Channel, Endpoint};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Why a connection's frame driver (pump thread or reactor) stopped
/// routing — the typed replacement for the old free-form poison string,
/// so callers can distinguish a peer vanishing from local I/O failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportDead {
    /// The peer hung up without the orderly shutdown handshake.
    PeerHangup,
    /// The byte stream ended in the middle of a frame.
    TruncatedFrame,
    /// The raw transport failed with an I/O or decode error.
    Io(String),
}

impl std::fmt::Display for TransportDead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportDead::PeerHangup => write!(f, "peer hung up"),
            TransportDead::TruncatedFrame => write!(f, "stream truncated mid-frame"),
            TransportDead::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for TransportDead {}

/// Reserved session id for mux control frames (never a protocol
/// session).
pub const SESSION_CTRL: u64 = u64::MAX;

/// Control frame tag: orderly connection shutdown.
pub const TAG_MUX_SHUTDOWN: u32 = 0xF00F;

/// Raw frame transport a [`SessionMux`] multiplexes over: session-tagged
/// send and receive plus the shared-connection byte meter.
pub trait SessionTransport: Send + Sync {
    /// Send one session-tagged frame; returns its wire bytes.
    fn send_s(&self, session: u64, f: &Frame) -> anyhow::Result<u64>;
    /// Receive the next frame with its session id (v1 frames fall back
    /// to session 0).
    fn recv_s(&self) -> anyhow::Result<(u64, Frame)>;
    /// Whole-connection meter (all sessions, both framing versions).
    fn meter(&self) -> &ByteMeter;
}

impl SessionTransport for Endpoint {
    fn send_s(&self, session: u64, f: &Frame) -> anyhow::Result<u64> {
        Endpoint::send_s(self, session, f)
    }
    fn recv_s(&self) -> anyhow::Result<(u64, Frame)> {
        Endpoint::recv_s(self)
    }
    fn meter(&self) -> &ByteMeter {
        Endpoint::meter(self)
    }
}

/// Mux configuration.
#[derive(Clone, Debug)]
pub struct MuxOptions {
    /// Accept sessions initiated by the peer (party side). When false,
    /// frames for sessions not opened locally are dropped (leader side).
    pub accept: bool,
    /// How long a session waits for a frame before failing cleanly.
    /// `None` blocks indefinitely (only safe when the peer is trusted to
    /// always answer or shut down).
    pub recv_timeout: Option<Duration>,
    /// Bound on each session's inbox (frames). A full inbox exerts
    /// backpressure on the shared connection: the pump blocks, the
    /// reactor parks the frame and pauses that connection's reads.
    pub queue_cap: usize,
}

impl Default for MuxOptions {
    fn default() -> Self {
        MuxOptions {
            accept: false,
            recv_timeout: Some(Duration::from_secs(30)),
            queue_cap: 256,
        }
    }
}

struct MuxState {
    /// per-session inbox, keyed by session id
    queues: BTreeMap<u64, VecDeque<Frame>>,
    /// sessions created by incoming frames, not yet accepted locally
    pending: VecDeque<u64>,
    /// peer sent its shutdown control frame
    closed: bool,
    /// frame driver (pump or reactor) died on a transport error
    poisoned: Option<TransportDead>,
    /// frames for unknown/closed sessions, counted and dropped
    dropped: u64,
    /// session whose full inbox is holding a frame back at the driver
    stalled: Option<u64>,
}

/// Outcome of offering one incoming frame to the routing core.
enum Routed {
    /// Consumed: queued, dropped-and-counted, or accepted-session setup.
    Done,
    /// The peer's orderly shutdown control frame arrived.
    Shutdown,
    /// The target session's inbox is at capacity; the frame comes back.
    Full(Frame),
}

struct MuxCore {
    raw: Box<dyn SessionTransport>,
    state: Mutex<MuxState>,
    cv: Condvar,
    opts: MuxOptions,
    /// reactor-mode hook: called (lock released) when a stalled
    /// session's inbox drains so the reactor retries the parked frame
    resume: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
}

impl MuxCore {
    /// Offer one incoming frame to the per-session queues. Shared by
    /// the pump thread and the reactor sink so both drive modes route
    /// identically.
    fn try_route(&self, sid: u64, f: Frame) -> Routed {
        let mut st = self.state.lock().unwrap();
        if sid == SESSION_CTRL {
            if f.tag == TAG_MUX_SHUTDOWN {
                st.closed = true;
                self.cv.notify_all();
                return Routed::Shutdown;
            }
            st.dropped += 1;
        } else if let Some(q) = st.queues.get_mut(&sid) {
            if q.len() >= self.opts.queue_cap {
                st.stalled = Some(sid);
                return Routed::Full(f);
            }
            q.push_back(f);
            self.cv.notify_all();
        } else if self.opts.accept {
            let mut q = VecDeque::new();
            q.push_back(f);
            st.queues.insert(sid, q);
            st.pending.push_back(sid);
            self.cv.notify_all();
        } else {
            st.dropped += 1;
        }
        Routed::Done
    }

    fn fail(&self, dead: TransportDead) {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.poisoned.is_some() {
            return;
        }
        st.poisoned = Some(dead);
        self.cv.notify_all();
    }

    /// A pop made room in `sid`'s inbox: wake a blocked pump and, if
    /// the frame driver parked a frame for this session, fire the
    /// reactor resume hook (outside the state lock).
    fn unstall(&self, sid: u64, st: std::sync::MutexGuard<'_, MuxState>) {
        let mut st = st;
        if st.stalled != Some(sid) {
            return;
        }
        st.stalled = None;
        self.cv.notify_all();
        drop(st);
        if let Some(hook) = self.resume.lock().unwrap().as_ref() {
            hook();
        }
    }

    /// Pump loop: route every incoming frame to its session queue,
    /// blocking (TCP backpressure) while a target inbox is full.
    fn pump(&self) {
        loop {
            match self.raw.recv_s() {
                Ok((sid, f)) => {
                    let mut f = f;
                    loop {
                        match self.try_route(sid, f) {
                            Routed::Done => break,
                            Routed::Shutdown => return,
                            Routed::Full(back) => {
                                f = back;
                                let mut st = self.state.lock().unwrap();
                                loop {
                                    if st.closed {
                                        return;
                                    }
                                    match st.queues.get(&sid) {
                                        None => break,
                                        Some(q) if q.len() < self.opts.queue_cap => break,
                                        Some(_) => st = self.cv.wait(st).unwrap(),
                                    }
                                }
                            }
                        }
                    }
                }
                Err(e) => {
                    self.fail(TransportDead::Io(format!("{e:#}")));
                    return;
                }
            }
        }
    }

    fn recv_on(&self, sid: u64) -> anyhow::Result<Frame> {
        // one deadline per receive — other sessions' traffic waking the
        // condvar must not extend this session's wait (the liveness
        // bound the chaos battery relies on). `recv_timeout: None` means
        // wait forever on a plain (zero-CPU) condvar wait, never a
        // zero-duration `wait_timeout` spin.
        let deadline = self
            .opts
            .recv_timeout
            .map(|d| (std::time::Instant::now() + d, d));
        let mut st = self.state.lock().unwrap();
        loop {
            match st.queues.get_mut(&sid) {
                Some(q) => {
                    if let Some(f) = q.pop_front() {
                        self.unstall(sid, st);
                        return Ok(f);
                    }
                }
                None => anyhow::bail!("session {sid} is not open on this connection"),
            }
            if let Some(p) = &st.poisoned {
                anyhow::bail!("session {sid}: connection failed: {p}");
            }
            if st.closed {
                anyhow::bail!("session {sid}: connection shut down by peer");
            }
            st = match deadline {
                None => self.cv.wait(st).unwrap(),
                Some((deadline, timeout)) => {
                    let now = std::time::Instant::now();
                    let Some(left) = deadline.checked_duration_since(now).filter(|d| {
                        !d.is_zero()
                    }) else {
                        anyhow::bail!(
                            "session {sid}: timed out after {timeout:?} waiting for a frame"
                        );
                    };
                    self.cv.wait_timeout(st, left).unwrap().0
                }
            };
        }
    }
}

/// Push side of a reactor-driven mux: the reactor (or a fault-injecting
/// wrapper) delivers decoded frames here instead of a pump pulling them.
pub struct MuxSink {
    core: Arc<MuxCore>,
}

impl FrameSink for MuxSink {
    fn on_frame(&self, sid: u64, f: Frame) -> SinkVerdict {
        match self.core.try_route(sid, f) {
            // shutdown just marks the mux closed; the reactor keeps the
            // connection until the whole loop stops
            Routed::Done | Routed::Shutdown => SinkVerdict::Accepted,
            Routed::Full(back) => SinkVerdict::Full(back),
        }
    }

    fn on_dead(&self, dead: TransportDead) {
        self.core.fail(dead);
    }
}

/// One shared connection carrying many interleaved sessions.
pub struct SessionMux {
    core: Arc<MuxCore>,
    pump: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SessionMux {
    fn core_for(raw: Box<dyn SessionTransport>, opts: MuxOptions) -> Arc<MuxCore> {
        Arc::new(MuxCore {
            raw,
            state: Mutex::new(MuxState {
                queues: BTreeMap::new(),
                pending: VecDeque::new(),
                closed: false,
                poisoned: None,
                dropped: 0,
                stalled: None,
            }),
            cv: Condvar::new(),
            opts,
            resume: Mutex::new(None),
        })
    }

    /// Wrap a raw transport and start the routing pump (threaded drive
    /// mode: one blocking thread per shared connection).
    pub fn new(raw: Box<dyn SessionTransport>, opts: MuxOptions) -> SessionMux {
        let core = SessionMux::core_for(raw, opts);
        let pump_core = Arc::clone(&core);
        crate::net::note_driver_thread();
        let pump = std::thread::spawn(move || pump_core.pump());
        SessionMux { core, pump: Mutex::new(Some(pump)) }
    }

    /// Reactor drive mode: no pump thread — the returned [`MuxSink`] is
    /// handed to the reactor, which pushes decoded frames in. `send` is
    /// the send-only half (a reactor connection handle, optionally
    /// fault-wrapped); its `recv_s` is never called.
    pub fn driven(send: Box<dyn SessionTransport>, opts: MuxOptions) -> (SessionMux, Arc<MuxSink>) {
        let core = SessionMux::core_for(send, opts);
        let sink = Arc::new(MuxSink { core: Arc::clone(&core) });
        (SessionMux { core, pump: Mutex::new(None) }, sink)
    }

    /// Wire the reactor's backpressure-release callback (reactor drive
    /// mode only): invoked when a stalled session's inbox drains.
    pub fn set_resume_hook(&self, hook: Box<dyn Fn() + Send + Sync>) {
        *self.core.resume.lock().unwrap() = Some(hook);
    }

    /// Convenience for the common case: mux over an [`Endpoint`].
    pub fn over(ep: Endpoint, opts: MuxOptions) -> SessionMux {
        SessionMux::new(Box::new(ep), opts)
    }

    /// Open a locally-initiated session (leader side). Must be called
    /// before the first frame of that session can arrive back.
    pub fn open(&self, sid: u64) -> anyhow::Result<SessionChannel> {
        anyhow::ensure!(sid != SESSION_CTRL, "session id {sid} is reserved");
        let mut st = self.core.state.lock().unwrap();
        if let Some(p) = &st.poisoned {
            anyhow::bail!("connection failed: {p}");
        }
        anyhow::ensure!(!st.closed, "connection shut down by peer");
        anyhow::ensure!(
            st.queues.insert(sid, VecDeque::new()).is_none(),
            "session {sid} already open"
        );
        drop(st);
        Ok(self.channel(sid))
    }

    /// Wait for the peer to initiate a session (party side). Returns
    /// `Ok(None)` after the peer's orderly shutdown; `Err` if the
    /// connection died. Safe to call from many worker threads — each
    /// pending session is handed to exactly one caller.
    pub fn accept(&self) -> anyhow::Result<Option<SessionChannel>> {
        anyhow::ensure!(self.core.opts.accept, "mux is not in accepting mode");
        let mut st = self.core.state.lock().unwrap();
        loop {
            if let Some(sid) = st.pending.pop_front() {
                drop(st);
                return Ok(Some(self.channel(sid)));
            }
            if let Some(p) = &st.poisoned {
                anyhow::bail!("connection failed: {p}");
            }
            if st.closed {
                return Ok(None);
            }
            st = self.core.cv.wait(st).unwrap();
        }
    }

    /// Close a session: frees its queue. Late frames for it are dropped.
    /// A receiver blocked in `recv` on this session is woken and fails
    /// with a clean "not open" error immediately — close is the
    /// cancellation path, and a cancelled session must not sit out the
    /// full receive timeout first.
    pub fn close(&self, sid: u64) {
        let mut st = self.core.state.lock().unwrap();
        st.queues.remove(&sid);
        self.core.cv.notify_all();
        // a frame driver stalled on this session's full inbox must not
        // wait forever for a consumer that just left
        self.core.unstall(sid, st);
    }

    /// Announce orderly shutdown to the peer (its pump exits once every
    /// earlier frame has been routed). Best-effort: a dead connection is
    /// already shut down.
    pub fn shutdown(&self) {
        let _ = self.core.raw.send_s(SESSION_CTRL, &Frame::new(TAG_MUX_SHUTDOWN));
    }

    /// Wait for frame delivery to stop: the pump thread to exit
    /// (threaded mode) or the peer's shutdown / connection death to be
    /// routed (reactor mode — the reactor thread itself lives on,
    /// driving other connections).
    pub fn join(&self) {
        let handle = self.pump.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
            return;
        }
        let mut st = self.core.state.lock().unwrap();
        while !st.closed && st.poisoned.is_none() {
            st = self.core.cv.wait(st).unwrap();
        }
    }

    /// Sessions currently open (soak-test handle: must return to 0).
    pub fn open_sessions(&self) -> usize {
        self.core.state.lock().unwrap().queues.len()
    }

    /// Frames dropped for unknown/closed sessions.
    pub fn dropped_frames(&self) -> u64 {
        self.core.state.lock().unwrap().dropped
    }

    /// Whole-connection byte meter.
    pub fn conn_meter(&self) -> &ByteMeter {
        self.core.raw.meter()
    }

    fn channel(&self, sid: u64) -> SessionChannel {
        SessionChannel { sid, core: Arc::clone(&self.core), meter: ByteMeter::new() }
    }
}

/// One session of a multiplexed connection, as an ordered frame
/// [`Channel`]. The per-channel meter counts this session's wire bytes
/// in both directions (sends locally, receives as routed by the pump),
/// so per-session accounting survives multiplexing.
pub struct SessionChannel {
    sid: u64,
    core: Arc<MuxCore>,
    meter: ByteMeter,
}

impl SessionChannel {
    pub fn session(&self) -> u64 {
        self.sid
    }
}

impl Channel for SessionChannel {
    fn send(&self, f: &Frame) -> anyhow::Result<()> {
        let n = self.core.raw.send_s(self.sid, f)?;
        self.meter.record(n);
        Ok(())
    }

    fn recv(&self) -> anyhow::Result<Frame> {
        let f = self.core.recv_on(self.sid)?;
        self.meter.record(f.wire_len_v2());
        Ok(f)
    }

    fn meter(&self) -> &ByteMeter {
        &self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::duplex_pair;

    fn muxed_pair() -> (SessionMux, SessionMux) {
        let (l, p) = duplex_pair(ByteMeter::new());
        (
            SessionMux::over(l, MuxOptions { accept: false, ..Default::default() }),
            SessionMux::over(p, MuxOptions { accept: true, ..Default::default() }),
        )
    }

    fn frame(tag: u32, v: u64) -> Frame {
        let mut f = Frame::new(tag);
        f.put_u64(v);
        f
    }

    fn finish(leader: &SessionMux, party: &SessionMux) {
        leader.shutdown();
        assert!(party.accept().unwrap().is_none());
        party.shutdown();
        leader.join();
        party.join();
    }

    #[test]
    fn two_sessions_interleave_without_crosstalk() {
        let (leader, party) = muxed_pair();
        let a = leader.open(1).unwrap();
        let b = leader.open(2).unwrap();
        // interleave sends across the two sessions
        b.send(&frame(10, 20)).unwrap();
        a.send(&frame(10, 10)).unwrap();
        b.send(&frame(11, 21)).unwrap();
        let pa = party.accept().unwrap().unwrap();
        let pb = party.accept().unwrap().unwrap();
        // accept order follows first-frame arrival order
        assert_eq!(pa.session(), 2);
        assert_eq!(pb.session(), 1);
        assert_eq!(pb.recv().unwrap().reader().u64().unwrap(), 10);
        assert_eq!(pa.recv().unwrap().reader().u64().unwrap(), 20);
        assert_eq!(pa.recv().unwrap().reader().u64().unwrap(), 21);
        // answers route back by session id
        pa.send(&frame(12, 200)).unwrap();
        pb.send(&frame(12, 100)).unwrap();
        assert_eq!(a.recv().unwrap().reader().u64().unwrap(), 100);
        assert_eq!(b.recv().unwrap().reader().u64().unwrap(), 200);
        finish(&leader, &party);
    }

    #[test]
    fn per_session_meters_count_both_directions() {
        let (leader, party) = muxed_pair();
        let a = leader.open(5).unwrap();
        let f = frame(1, 7);
        a.send(&f).unwrap();
        let pa = party.accept().unwrap().unwrap();
        let g = pa.recv().unwrap();
        pa.send(&g).unwrap();
        a.recv().unwrap();
        assert_eq!(a.meter().bytes(), 2 * f.wire_len_v2());
        assert_eq!(pa.meter().bytes(), 2 * f.wire_len_v2());
        assert_eq!(leader.conn_meter().bytes(), 2 * f.wire_len_v2());
        finish(&leader, &party);
    }

    #[test]
    fn close_frees_queue_and_drops_late_frames() {
        let (leader, party) = muxed_pair();
        let a = leader.open(1).unwrap();
        a.send(&frame(1, 1)).unwrap();
        let pa = party.accept().unwrap().unwrap();
        pa.recv().unwrap();
        assert_eq!(leader.open_sessions(), 1);
        leader.close(1);
        assert_eq!(leader.open_sessions(), 0);
        // a frame arriving for the closed session is dropped, not routed
        pa.send(&frame(2, 2)).unwrap();
        // synchronize: open a fresh session and round-trip through it so
        // the pump has definitely processed the stale frame first
        let b = leader.open(2).unwrap();
        b.send(&frame(3, 3)).unwrap();
        let pb = party.accept().unwrap().unwrap();
        pb.recv().unwrap();
        pb.send(&frame(4, 4)).unwrap();
        b.recv().unwrap();
        assert_eq!(leader.dropped_frames(), 1);
        finish(&leader, &party);
    }

    #[test]
    fn recv_timeout_fails_cleanly() {
        let (l, p) = duplex_pair(ByteMeter::new());
        let leader = SessionMux::over(
            l,
            MuxOptions {
                accept: false,
                recv_timeout: Some(Duration::from_millis(50)),
                ..Default::default()
            },
        );
        let party = SessionMux::over(p, MuxOptions { accept: true, ..Default::default() });
        let a = leader.open(1).unwrap();
        let err = a.recv().unwrap_err();
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
        finish(&leader, &party);
    }

    #[test]
    fn none_timeout_recv_blocks_until_a_frame_arrives() {
        // recv_timeout: None must wait indefinitely (no spurious timeout
        // error) and wake when a frame finally lands
        let (l, p) = duplex_pair(ByteMeter::new());
        let leader = SessionMux::over(
            l,
            MuxOptions { accept: false, recv_timeout: None, ..Default::default() },
        );
        let party = SessionMux::over(p, MuxOptions { accept: true, ..Default::default() });
        let a = leader.open(1).unwrap();
        a.send(&frame(1, 1)).unwrap();
        let pa = party.accept().unwrap().unwrap();
        pa.recv().unwrap();
        let t = std::thread::spawn(move || a.recv());
        std::thread::sleep(Duration::from_millis(120));
        pa.send(&frame(2, 7)).unwrap();
        let got = t.join().unwrap().unwrap();
        assert_eq!(got.reader().u64().unwrap(), 7);
        finish(&leader, &party);
    }

    /// Thread CPU ticks (utime + stime) of the calling thread, from
    /// procfs — the busy-spin detector for the None-timeout wait.
    #[cfg(target_os = "linux")]
    fn own_thread_cpu_ticks() -> u64 {
        let stat = std::fs::read_to_string("/proc/thread-self/stat").unwrap();
        // fields after the parenthesized comm: state is field 3, so
        // utime (field 14) and stime (field 15) are offsets 11 and 12
        let rest = stat.rsplit(')').next().unwrap();
        let fs: Vec<&str> = rest.split_whitespace().collect();
        fs[11].parse::<u64>().unwrap() + fs[12].parse::<u64>().unwrap()
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn none_timeout_recv_blocks_without_burning_cpu() {
        // a session configured to wait forever must park on the condvar:
        // a zero-duration wait_timeout fallback would spin the thread
        // and show up as hundreds of ms of CPU here
        let (l, p) = duplex_pair(ByteMeter::new());
        let leader = SessionMux::over(
            l,
            MuxOptions { accept: false, recv_timeout: None, ..Default::default() },
        );
        let party = SessionMux::over(p, MuxOptions { accept: true, ..Default::default() });
        let a = leader.open(1).unwrap();
        a.send(&frame(1, 1)).unwrap();
        let pa = party.accept().unwrap().unwrap();
        pa.recv().unwrap();
        let t = std::thread::spawn(move || {
            let before = own_thread_cpu_ticks();
            let got = a.recv();
            (before, own_thread_cpu_ticks(), got)
        });
        // let the receiver block for a measurable window, then release it
        std::thread::sleep(Duration::from_millis(400));
        pa.send(&frame(2, 9)).unwrap();
        let (before, after, got) = t.join().unwrap();
        assert_eq!(got.unwrap().reader().u64().unwrap(), 9);
        // a spinning wait burns ~40 ticks (at the usual 100 Hz) over
        // 400 ms; a parked wait burns ~0. Allow generous scheduler noise.
        assert!(
            after - before < 10,
            "blocked recv burned {} CPU ticks — busy spin",
            after - before
        );
        finish(&leader, &party);
    }

    #[test]
    fn close_wakes_a_blocked_receiver_promptly() {
        // a session blocked in recv (30 s default timeout) must fail the
        // moment its queue is closed out from under it — the liveness
        // bound the daemon's cancellation path relies on
        let (leader, party) = muxed_pair();
        let a = leader.open(1).unwrap();
        let t = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            (a.recv(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50));
        leader.close(1);
        let (res, waited) = t.join().unwrap();
        let err = res.unwrap_err();
        assert!(format!("{err:#}").contains("not open"), "{err:#}");
        assert!(waited < Duration::from_secs(2), "recv waited {waited:?} after close");
        finish(&leader, &party);
    }

    #[test]
    fn unopened_session_recv_is_error() {
        let (leader, party) = muxed_pair();
        let a = leader.open(1).unwrap();
        leader.close(1);
        assert!(a.recv().is_err());
        assert!(leader.open(u64::MAX).is_err());
        finish(&leader, &party);
    }

    #[test]
    fn driver_death_is_a_typed_error() {
        // drop the party side entirely: the leader's pump dies on the
        // broken transport and waiting sessions get the typed poison,
        // not a hang or a generic string
        let (l, p) = duplex_pair(ByteMeter::new());
        let leader = SessionMux::over(l, MuxOptions { accept: false, ..Default::default() });
        let a = leader.open(1).unwrap();
        drop(p);
        let err = a.recv().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("connection failed"), "{msg}");
        // the driver has stopped: join returns promptly
        leader.join();
    }

    #[test]
    fn bounded_inbox_blocks_pump_without_losing_frames() {
        let (l, p) = duplex_pair(ByteMeter::new());
        let leader = SessionMux::over(l, MuxOptions { accept: false, ..Default::default() });
        let party = SessionMux::over(
            p,
            MuxOptions { accept: true, queue_cap: 2, ..Default::default() },
        );
        let a = leader.open(1).unwrap();
        // 12 frames against a 2-frame inbox: the pump must backpressure
        // (block on the raw transport), never drop or reorder
        for i in 0..12u64 {
            a.send(&frame(1, i)).unwrap();
        }
        let pa = party.accept().unwrap().unwrap();
        for i in 0..12u64 {
            assert_eq!(pa.recv().unwrap().reader().u64().unwrap(), i);
        }
        assert_eq!(party.dropped_frames(), 0);
        finish(&leader, &party);
    }

    #[test]
    fn shutdown_unblocks_waiting_session() {
        let (leader, party) = muxed_pair();
        let a = leader.open(1).unwrap();
        let t = std::thread::spawn(move || a.recv());
        // party announces shutdown: the waiting leader session must fail
        // cleanly rather than hang
        party.shutdown();
        let err = t.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("shut down"), "{err:#}");
        leader.shutdown();
        assert!(party.accept().unwrap().is_none());
        leader.join();
        party.join();
    }
}
