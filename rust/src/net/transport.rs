//! Party ↔ leader endpoints: in-process channels and localhost TCP.
//!
//! Both directions of an [`Endpoint`] are byte-metered. The in-proc
//! variant serializes frames through the same wire format as TCP so the
//! measured bytes are identical across transports (verified in tests).

use super::frame::{Frame, FrameReader, FrameWriter};
use super::meter::ByteMeter;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender, channel};
use std::sync::Mutex;

/// A bidirectional ordered frame channel with byte metering — the
/// interface the protocol state machines (leader and party) run over.
/// Implemented by a dedicated [`Endpoint`] (one connection per session,
/// the classic deployment) and by [`crate::net::SessionChannel`] (one
/// session of a multiplexed shared connection).
pub trait Channel: Send + Sync {
    fn send(&self, f: &Frame) -> anyhow::Result<()>;
    fn recv(&self) -> anyhow::Result<Frame>;
    fn meter(&self) -> &ByteMeter;
}

/// A bidirectional frame endpoint.
pub enum Endpoint {
    InProc {
        /// locked so a shared endpoint (session demux) stays `Sync`
        /// on every toolchain
        tx: Mutex<Sender<Vec<u8>>>,
        rx: Mutex<Receiver<Vec<u8>>>,
        meter: ByteMeter,
    },
    Tcp {
        /// separately-locked halves (`try_clone`d handles of one
        /// socket): a demux pump can block in a read while session
        /// workers keep writing — full-duplex, no lock coupling
        read: Mutex<TcpStream>,
        write: Mutex<TcpStream>,
        /// reused encode scratch: each frame is serialized here once and
        /// hits the socket as a single `write_all` — no per-send `Vec`
        /// allocation (steady state) and no four-syscall header dribble
        /// on a nodelay socket
        wbuf: Mutex<Vec<u8>>,
        meter: ByteMeter,
    },
}

impl Endpoint {
    pub fn send(&self, f: &Frame) -> anyhow::Result<()> {
        match self {
            Endpoint::InProc { tx, meter, .. } => {
                // Serialize through the real wire format so byte counts
                // match TCP exactly.
                let mut buf = Vec::with_capacity(f.payload.len() + 12);
                FrameWriter::new(&mut buf).write(f)?;
                meter.record(buf.len() as u64);
                tx.lock()
                    .unwrap()
                    .send(buf)
                    .map_err(|_| anyhow::anyhow!("peer hung up"))?;
                Ok(())
            }
            Endpoint::Tcp { write, wbuf, meter, .. } => {
                let mut b = wbuf.lock().unwrap();
                b.clear();
                let n = FrameWriter::new(&mut *b).write(f)?;
                write.lock().unwrap().write_all(&b)?;
                meter.record(n);
                Ok(())
            }
        }
    }

    pub fn recv(&self) -> anyhow::Result<Frame> {
        match self {
            Endpoint::InProc { rx, .. } => {
                let buf = rx
                    .lock()
                    .unwrap()
                    .recv()
                    .map_err(|_| anyhow::anyhow!("peer hung up"))?;
                FrameReader::new(buf.as_slice()).read()
            }
            Endpoint::Tcp { read, .. } => {
                let mut s = read.lock().unwrap();
                FrameReader::new(ReadAdapter(&mut s)).read()
            }
        }
    }

    /// Send one session-tagged (v2) frame. Returns its wire bytes.
    pub fn send_s(&self, session: u64, f: &Frame) -> anyhow::Result<u64> {
        match self {
            Endpoint::InProc { tx, meter, .. } => {
                let mut buf = Vec::with_capacity(f.payload.len() + 24);
                FrameWriter::new(&mut buf).write_v2(session, f)?;
                let n = buf.len() as u64;
                meter.record(n);
                tx.lock()
                    .unwrap()
                    .send(buf)
                    .map_err(|_| anyhow::anyhow!("peer hung up"))?;
                Ok(n)
            }
            Endpoint::Tcp { write, wbuf, meter, .. } => {
                let mut b = wbuf.lock().unwrap();
                b.clear();
                let n = FrameWriter::new(&mut *b).write_v2(session, f)?;
                write.lock().unwrap().write_all(&b)?;
                meter.record(n);
                Ok(n)
            }
        }
    }

    /// Receive one frame in either framing version: `(session_id,
    /// frame)`, with v1 frames falling back to session 0.
    pub fn recv_s(&self) -> anyhow::Result<(u64, Frame)> {
        match self {
            Endpoint::InProc { rx, .. } => {
                let buf = rx
                    .lock()
                    .unwrap()
                    .recv()
                    .map_err(|_| anyhow::anyhow!("peer hung up"))?;
                FrameReader::new(buf.as_slice()).read_any()
            }
            Endpoint::Tcp { read, .. } => {
                let mut s = read.lock().unwrap();
                FrameReader::new(ReadAdapter(&mut s)).read_any()
            }
        }
    }

    pub fn meter(&self) -> &ByteMeter {
        match self {
            Endpoint::InProc { meter, .. } => meter,
            Endpoint::Tcp { meter, .. } => meter,
        }
    }
}

impl Channel for Endpoint {
    fn send(&self, f: &Frame) -> anyhow::Result<()> {
        Endpoint::send(self, f)
    }
    fn recv(&self) -> anyhow::Result<Frame> {
        Endpoint::recv(self)
    }
    fn meter(&self) -> &ByteMeter {
        Endpoint::meter(self)
    }
}

struct ReadAdapter<'a>(&'a mut TcpStream);
impl Read for ReadAdapter<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.0.read(buf)
    }
}

/// Create a connected in-process endpoint pair (leader side, party side)
/// sharing one meter (total bytes both directions).
pub fn duplex_pair(meter: ByteMeter) -> (Endpoint, Endpoint) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (
        Endpoint::InProc {
            tx: Mutex::new(tx_a),
            rx: Mutex::new(rx_a),
            meter: meter.clone(),
        },
        Endpoint::InProc { tx: Mutex::new(tx_b), rx: Mutex::new(rx_b), meter },
    )
}

/// Create a connected localhost-TCP raw stream pair. Reactor-managed
/// connections own their sockets directly (no endpoint wrapper).
pub fn tcp_stream_pair() -> anyhow::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let client = TcpStream::connect(addr)?;
    let (server, _) = listener.accept()?;
    client.set_nodelay(true)?;
    server.set_nodelay(true)?;
    Ok((server, client))
}

/// Create a connected localhost-TCP endpoint pair.
pub fn tcp_pair(meter: ByteMeter) -> anyhow::Result<(Endpoint, Endpoint)> {
    let (server, client) = tcp_stream_pair()?;
    Ok((
        Endpoint::Tcp {
            read: Mutex::new(server.try_clone()?),
            write: Mutex::new(server),
            wbuf: Mutex::new(Vec::new()),
            meter: meter.clone(),
        },
        Endpoint::Tcp {
            read: Mutex::new(client.try_clone()?),
            write: Mutex::new(client),
            wbuf: Mutex::new(Vec::new()),
            meter,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ping_pong(a: &Endpoint, b: &Endpoint) {
        let mut f = Frame::new(1);
        f.put_f64_slice(&[1.0, 2.0, 3.0]);
        a.send(&f).unwrap();
        let g = b.recv().unwrap();
        assert_eq!(g, f);
        let mut h = Frame::new(2);
        h.put_u64(99);
        b.send(&h).unwrap();
        assert_eq!(a.recv().unwrap().reader().u64().unwrap(), 99);
    }

    #[test]
    fn inproc_roundtrip() {
        let (a, b) = duplex_pair(ByteMeter::new());
        ping_pong(&a, &b);
    }

    #[test]
    fn tcp_roundtrip() {
        let (a, b) = tcp_pair(ByteMeter::new()).unwrap();
        ping_pong(&a, &b);
    }

    #[test]
    fn byte_counts_match_across_transports() {
        let m1 = ByteMeter::new();
        let (a1, b1) = duplex_pair(m1.clone());
        ping_pong(&a1, &b1);

        let m2 = ByteMeter::new();
        let (a2, b2) = tcp_pair(m2.clone()).unwrap();
        ping_pong(&a2, &b2);

        assert_eq!(m1.bytes(), m2.bytes());
        assert_eq!(m1.messages(), m2.messages());
    }

    #[test]
    fn threaded_request_response() {
        let (leader, party) = duplex_pair(ByteMeter::new());
        let t = std::thread::spawn(move || {
            let req = party.recv().unwrap();
            let x = req.reader().u64().unwrap();
            let mut resp = Frame::new(1);
            resp.put_u64(x * 2);
            party.send(&resp).unwrap();
        });
        let mut req = Frame::new(0);
        req.put_u64(21);
        leader.send(&req).unwrap();
        assert_eq!(leader.recv().unwrap().reader().u64().unwrap(), 42);
        t.join().unwrap();
    }

    #[test]
    fn session_frames_roundtrip_both_transports() {
        for pair in [
            duplex_pair(ByteMeter::new()),
            tcp_pair(ByteMeter::new()).unwrap(),
        ] {
            let (a, b) = pair;
            let mut f = Frame::new(3);
            f.put_u64(17);
            let n = a.send_s(0xA11CE, &f).unwrap();
            assert_eq!(n, f.wire_len_v2());
            let (sid, g) = b.recv_s().unwrap();
            assert_eq!(sid, 0xA11CE);
            assert_eq!(g, f);
            // v1 frames on the same stream fall back to session 0
            a.send(&f).unwrap();
            let (sid, g) = b.recv_s().unwrap();
            assert_eq!(sid, 0);
            assert_eq!(g, f);
        }
    }

    #[test]
    fn session_frame_bytes_match_across_transports() {
        let m1 = ByteMeter::new();
        let (a1, b1) = duplex_pair(m1.clone());
        let m2 = ByteMeter::new();
        let (a2, b2) = tcp_pair(m2.clone()).unwrap();
        let mut f = Frame::new(9);
        f.put_f64_slice(&[1.0, 2.0]);
        a1.send_s(7, &f).unwrap();
        b1.recv_s().unwrap();
        a2.send_s(7, &f).unwrap();
        b2.recv_s().unwrap();
        assert_eq!(m1.bytes(), m2.bytes());
        assert_eq!(m1.bytes(), f.wire_len_v2());
    }

    #[test]
    fn hangup_is_error() {
        let (a, b) = duplex_pair(ByteMeter::new());
        drop(b);
        let mut f = Frame::new(0);
        f.put_u64(1);
        assert!(a.send(&f).is_err());
    }
}
