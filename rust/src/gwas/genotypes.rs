//! Balding–Nichols allele-frequency model and genotype sampling.

use crate::util::rng::Rng;

/// Per-variant allele frequencies in two diverged populations.
#[derive(Clone, Debug)]
pub struct VariantFreqs {
    /// ancestral minor-allele frequency
    pub ancestral: f64,
    /// population-specific frequencies [pop0, pop1]
    pub pop: [f64; 2],
}

/// Sample `m` variants: ancestral MAF ~ U(maf_min, 0.5), population
/// frequencies from the Balding–Nichols Beta with divergence `fst`.
pub fn sample_allele_freqs(m: usize, fst: f64, maf_min: f64, rng: &mut Rng) -> Vec<VariantFreqs> {
    assert!((0.0..1.0).contains(&fst));
    assert!(maf_min > 0.0 && maf_min < 0.5);
    (0..m)
        .map(|_| {
            let p = rng.uniform_range(maf_min, 0.5);
            let pop = if fst == 0.0 {
                [p, p]
            } else {
                let a = p * (1.0 - fst) / fst;
                let b = (1.0 - p) * (1.0 - fst) / fst;
                // clamp away from {0,1} so genotypes stay polymorphic
                [
                    rng.beta(a, b).clamp(0.01, 0.99),
                    rng.beta(a, b).clamp(0.01, 0.99),
                ]
            };
            VariantFreqs { ancestral: p, pop }
        })
        .collect()
}

impl VariantFreqs {
    /// Allele frequency for an individual with admixture proportion
    /// `theta` of population 1.
    #[inline]
    pub fn freq_for(&self, theta: f64) -> f64 {
        (1.0 - theta) * self.pop[0] + theta * self.pop[1]
    }

    /// Draw a diploid genotype (0/1/2) for admixture `theta`.
    #[inline]
    pub fn genotype(&self, theta: f64, rng: &mut Rng) -> f64 {
        rng.binomial(2, self.freq_for(theta)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freqs_in_range() {
        let mut rng = Rng::new(120);
        let fs = sample_allele_freqs(500, 0.1, 0.05, &mut rng);
        assert_eq!(fs.len(), 500);
        for f in &fs {
            assert!((0.05..=0.5).contains(&f.ancestral));
            for &p in &f.pop {
                assert!((0.01..=0.99).contains(&p));
            }
        }
    }

    #[test]
    fn zero_fst_means_identical_pops() {
        let mut rng = Rng::new(121);
        let fs = sample_allele_freqs(100, 0.0, 0.05, &mut rng);
        for f in &fs {
            assert_eq!(f.pop[0], f.pop[1]);
        }
    }

    #[test]
    fn higher_fst_more_divergence() {
        let mut rng = Rng::new(122);
        let div = |fst: f64, rng: &mut Rng| -> f64 {
            sample_allele_freqs(2000, fst, 0.05, rng)
                .iter()
                .map(|f| (f.pop[0] - f.pop[1]).abs())
                .sum::<f64>()
                / 2000.0
        };
        let low = div(0.01, &mut rng);
        let high = div(0.3, &mut rng);
        assert!(high > 2.0 * low, "low={low} high={high}");
    }

    #[test]
    fn genotype_mean_tracks_frequency() {
        let mut rng = Rng::new(123);
        let f = VariantFreqs { ancestral: 0.3, pop: [0.2, 0.6] };
        let n = 20_000;
        for &theta in &[0.0, 0.5, 1.0] {
            let want = 2.0 * f.freq_for(theta);
            let got: f64 =
                (0..n).map(|_| f.genotype(theta, &mut rng)).sum::<f64>() / n as f64;
            assert!((got - want).abs() < 0.02, "theta={theta}: {got} vs {want}");
        }
    }

    #[test]
    fn admixture_interpolates() {
        let f = VariantFreqs { ancestral: 0.3, pop: [0.1, 0.9] };
        assert!((f.freq_for(0.0) - 0.1).abs() < 1e-15);
        assert!((f.freq_for(1.0) - 0.9).abs() < 1e-15);
        assert!((f.freq_for(0.5) - 0.5).abs() < 1e-15);
    }
}
