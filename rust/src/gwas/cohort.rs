//! Multi-party cohort assembly: genotypes, covariates, traits, truth.

use super::genotypes::{sample_allele_freqs, VariantFreqs};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Specification of a synthetic multi-center cohort.
#[derive(Clone, Debug)]
pub struct CohortSpec {
    /// samples per party
    pub party_sizes: Vec<usize>,
    /// number of variants to scan (M)
    pub m_variants: usize,
    /// number of traits scanned jointly (T; 1 = classic single-trait
    /// GWAS, ~4K = biobank PheWAS, ~20K = eQTL)
    pub n_traits: usize,
    /// number of causal variants
    pub n_causal: usize,
    /// effect-size scale of causal variants (per standardized genotype)
    pub effect_sd: f64,
    /// population divergence
    pub fst: f64,
    /// per-party mean admixture of population 1 (length = parties);
    /// heterogeneous values make ancestry a cross-party confounder
    pub party_admixture: Vec<f64>,
    /// strength of the ancestry → trait confounding path
    pub ancestry_effect: f64,
    /// per-party additive batch effect scale on the trait
    pub batch_effect_sd: f64,
    /// number of "PC score" covariates (noisy admixture projections)
    pub n_pcs: usize,
    /// residual noise sd
    pub noise_sd: f64,
    /// threshold each liability-scale trait at 0 into a 0/1 case-control
    /// label (`--binary-traits`, logistic scans). The threshold consumes
    /// no RNG draws, so the underlying liabilities, covariates, and
    /// genotypes are bit-identical to the quantitative cohort from the
    /// same seed.
    pub binary_traits: bool,
}

impl CohortSpec {
    /// Small default (unit tests, quickstart): 3 parties, ~600 samples.
    pub fn default_small() -> CohortSpec {
        CohortSpec {
            party_sizes: vec![250, 200, 150],
            m_variants: 300,
            n_traits: 1,
            n_causal: 5,
            effect_sd: 0.35,
            fst: 0.05,
            party_admixture: vec![0.2, 0.5, 0.8],
            ancestry_effect: 0.5,
            batch_effect_sd: 0.2,
            n_pcs: 2,
            noise_sd: 1.0,
            binary_traits: false,
        }
    }

    /// Number of permanent covariates K = intercept + age + sex + PCs.
    pub fn k_covariates(&self) -> usize {
        3 + self.n_pcs
    }

    pub fn n_total(&self) -> usize {
        self.party_sizes.iter().sum()
    }

    pub fn parties(&self) -> usize {
        self.party_sizes.len()
    }

    fn validate(&self) {
        assert!(!self.party_sizes.is_empty(), "need ≥1 party");
        assert!(self.n_traits >= 1, "need ≥1 trait");
        assert_eq!(
            self.party_admixture.len(),
            self.party_sizes.len(),
            "party_admixture length must match party_sizes"
        );
        assert!(self.n_causal <= self.m_variants);
        for &n in &self.party_sizes {
            assert!(
                n > self.k_covariates() + 1,
                "party size {n} too small for K={} covariates",
                self.k_covariates()
            );
        }
    }
}

/// One party's local data (never leaves the party in secure modes).
#[derive(Clone, Debug)]
pub struct PartyData {
    /// trait matrix, N_p × T (column 0 = the primary trait; T = 1 for a
    /// classic single-trait scan)
    pub ys: Matrix,
    /// permanent covariates, N_p × K (column 0 = intercept)
    pub c: Matrix,
    /// transient covariates (genotypes), N_p × M
    pub x: Matrix,
}

impl PartyData {
    pub fn n(&self) -> usize {
        self.ys.rows
    }

    /// Number of traits carried by this party's data.
    pub fn t(&self) -> usize {
        self.ys.cols
    }
}

/// Ground truth of the simulation (for power/FDR evaluation only — not
/// visible to the protocol).
#[derive(Clone, Debug)]
pub struct Truth {
    pub causal_idx: Vec<usize>,
    /// per-trait causal effects, `n_traits × n_causal` (row 0 = the
    /// primary trait)
    pub causal_beta: Matrix,
    pub freqs: Vec<VariantFreqs>,
}

/// A full multi-party cohort.
#[derive(Clone, Debug)]
pub struct Cohort {
    pub spec: CohortSpec,
    pub parties: Vec<PartyData>,
    pub truth: Truth,
}

impl Cohort {
    pub fn k(&self) -> usize {
        self.spec.k_covariates()
    }

    pub fn m(&self) -> usize {
        self.spec.m_variants
    }

    pub fn t(&self) -> usize {
        self.spec.n_traits
    }

    pub fn n_total(&self) -> usize {
        self.parties.iter().map(|p| p.n()).sum()
    }
}

/// Generate a cohort from a spec, deterministically in `seed`.
///
/// Trait 0 reproduces the historical single-trait generator draw-for-draw
/// (a `n_traits = 1` cohort is bit-identical to what the pre-trait-major
/// generator produced). Extra traits share the causal variant set with
/// per-trait effect sizes and their own noise, all drawn from *derived*
/// RNG streams so they never perturb trait 0, the covariates, or the
/// genotypes.
pub fn generate_cohort(spec: &CohortSpec, seed: u64) -> Cohort {
    spec.validate();
    let mut rng = Rng::new(seed);
    let m = spec.m_variants;
    let k = spec.k_covariates();
    let t = spec.n_traits;
    let freqs = sample_allele_freqs(m, spec.fst, 0.05, &mut rng);

    // causal architecture
    let mut idx: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut idx);
    let causal_idx: Vec<usize> = idx[..spec.n_causal].to_vec();
    let mut causal_beta = Matrix::zeros(t, spec.n_causal);
    for ci in 0..spec.n_causal {
        causal_beta[(0, ci)] = rng.normal_ms(0.0, spec.effect_sd);
    }
    // extra-trait effects from a derived stream (leaves `rng` untouched)
    let mut beta_rng = rng.derive(0xBE7A);
    for tt in 1..t {
        for ci in 0..spec.n_causal {
            causal_beta[(tt, ci)] = beta_rng.normal_ms(0.0, spec.effect_sd);
        }
    }

    let mut parties = Vec::with_capacity(spec.parties());
    for (p, &np) in spec.party_sizes.iter().enumerate() {
        let mut prng = rng.derive(1000 + p as u64);
        // extra-trait noise/batch stream, derived so trait 0 stays on the
        // historical draw sequence
        let mut trng = prng.derive(0x712A17);
        let batch = prng.normal_ms(0.0, spec.batch_effect_sd);
        let extra_batch: Vec<f64> =
            (1..t).map(|_| trng.normal_ms(0.0, spec.batch_effect_sd)).collect();
        let mut c = Matrix::zeros(np, k);
        let mut x = Matrix::zeros(np, m);
        let mut ys = Matrix::zeros(np, t);
        for i in 0..np {
            // individual admixture around the party mean
            let theta = (spec.party_admixture[p] + prng.normal_ms(0.0, 0.1)).clamp(0.0, 1.0);
            // covariates: intercept, age (standardized), sex ∈ {0,1}
            c[(i, 0)] = 1.0;
            c[(i, 1)] = prng.normal();
            c[(i, 2)] = if prng.uniform() < 0.5 { 0.0 } else { 1.0 };
            // "PC scores": noisy projections of ancestry, as produced by a
            // public reference-panel projection (paper §1)
            for pc in 0..spec.n_pcs {
                let signal = if pc == 0 { theta } else { theta * theta };
                c[(i, 3 + pc)] = signal + prng.normal_ms(0.0, 0.05);
            }
            // genotypes
            for j in 0..m {
                x[(i, j)] = freqs[j].genotype(theta, &mut prng);
            }
            // trait 0: causal effects on standardized genotypes +
            // covariate effects + ancestry confounding + batch + noise
            let fixed = 0.2 * c[(i, 1)] - 0.1 * c[(i, 2)] + spec.ancestry_effect * theta;
            let mut v = fixed + batch + prng.normal_ms(0.0, spec.noise_sd);
            for (ci, &j) in causal_idx.iter().enumerate() {
                let f = freqs[j].ancestral;
                let sd = (2.0 * f * (1.0 - f)).sqrt();
                v += causal_beta[(0, ci)] * (x[(i, j)] - 2.0 * f) / sd;
            }
            ys[(i, 0)] = v;
            // extra traits: same structural model, per-trait effects and
            // noise from the derived stream
            for tt in 1..t {
                let mut vt =
                    fixed + extra_batch[tt - 1] + trng.normal_ms(0.0, spec.noise_sd);
                for (ci, &j) in causal_idx.iter().enumerate() {
                    let f = freqs[j].ancestral;
                    let sd = (2.0 * f * (1.0 - f)).sqrt();
                    vt += causal_beta[(tt, ci)] * (x[(i, j)] - 2.0 * f) / sd;
                }
                ys[(i, tt)] = vt;
            }
        }
        if spec.binary_traits {
            // case = positive liability; draw-free, so the generator
            // stream stays on the quantitative-cohort sequence
            for v in ys.data.iter_mut() {
                *v = if *v > 0.0 { 1.0 } else { 0.0 };
            }
        }
        parties.push(PartyData { ys, c, x });
    }

    Cohort { spec: spec.clone(), parties, truth: Truth { causal_idx, causal_beta, freqs } }
}

/// Pool a cohort into single-party matrices (oracle / baseline path).
pub fn pool_cohort(cohort: &Cohort) -> PartyData {
    let ys: Vec<&Matrix> = cohort.parties.iter().map(|p| &p.ys).collect();
    let cs: Vec<&Matrix> = cohort.parties.iter().map(|p| &p.c).collect();
    let xs: Vec<&Matrix> = cohort.parties.iter().map(|p| &p.x).collect();
    PartyData { ys: Matrix::vstack(&ys), c: Matrix::vstack(&cs), x: Matrix::vstack(&xs) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_spec() {
        let spec = CohortSpec::default_small();
        let cohort = generate_cohort(&spec, 7);
        assert_eq!(cohort.parties.len(), 3);
        for (p, party) in cohort.parties.iter().enumerate() {
            assert_eq!(party.n(), spec.party_sizes[p]);
            assert_eq!(party.t(), 1);
            assert_eq!(party.c.cols, spec.k_covariates());
            assert_eq!(party.x.cols, spec.m_variants);
        }
        assert_eq!(cohort.truth.causal_idx.len(), spec.n_causal);
    }

    #[test]
    fn multi_trait_shapes_and_trait0_invariance() {
        let mut spec = CohortSpec::default_small();
        let single = generate_cohort(&spec, 21);
        spec.n_traits = 4;
        let multi = generate_cohort(&spec, 21);
        for (a, b) in single.parties.iter().zip(&multi.parties) {
            assert_eq!(b.t(), 4);
            // trait 0, covariates, and genotypes are bit-identical to the
            // single-trait cohort from the same seed
            assert_eq!(a.ys.col(0), b.ys.col(0));
            assert_eq!(a.c.data, b.c.data);
            assert_eq!(a.x.data, b.x.data);
            // extra traits actually differ from trait 0
            assert_ne!(b.ys.col(0), b.ys.col(1));
        }
        assert_eq!(multi.truth.causal_beta.rows, 4);
        assert_eq!(
            single.truth.causal_beta.data,
            multi.truth.causal_beta.row_slice(0, 1).data
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let mut spec = CohortSpec::default_small();
        spec.n_traits = 3;
        let a = generate_cohort(&spec, 9);
        let b = generate_cohort(&spec, 9);
        assert_eq!(a.parties[0].ys.data, b.parties[0].ys.data);
        assert_eq!(a.parties[2].x.data, b.parties[2].x.data);
        let c = generate_cohort(&spec, 10);
        assert_ne!(a.parties[0].ys.data, c.parties[0].ys.data);
    }

    #[test]
    fn genotypes_are_dosages() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 11);
        for p in &cohort.parties {
            for v in &p.x.data {
                assert!(*v == 0.0 || *v == 1.0 || *v == 2.0);
            }
        }
    }

    #[test]
    fn intercept_column_is_ones() {
        let cohort = generate_cohort(&CohortSpec::default_small(), 12);
        for p in &cohort.parties {
            for i in 0..p.n() {
                assert_eq!(p.c[(i, 0)], 1.0);
            }
        }
    }

    #[test]
    fn pool_preserves_order_and_counts() {
        let mut spec = CohortSpec::default_small();
        spec.n_traits = 2;
        let cohort = generate_cohort(&spec, 13);
        let pooled = pool_cohort(&cohort);
        assert_eq!(pooled.n(), cohort.n_total());
        assert_eq!(pooled.t(), 2);
        assert_eq!(pooled.ys[(0, 0)], cohort.parties[0].ys[(0, 0)]);
        let n0 = cohort.parties[0].n();
        assert_eq!(pooled.ys[(n0, 1)], cohort.parties[1].ys[(0, 1)]);
        assert_eq!(pooled.x.rows, cohort.n_total());
    }

    #[test]
    fn admixture_differs_across_parties() {
        // party 0 (theta≈0.2) should have different pop-1-allele load than
        // party 2 (theta≈0.8) at highly diverged variants
        let mut spec = CohortSpec::default_small();
        spec.fst = 0.3;
        let cohort = generate_cohort(&spec, 14);
        let f = &cohort.truth.freqs;
        // pick the most diverged variant
        let j = (0..spec.m_variants)
            .max_by(|&a, &b| {
                let da = (f[a].pop[0] - f[a].pop[1]).abs();
                let db = (f[b].pop[0] - f[b].pop[1]).abs();
                da.partial_cmp(&db).unwrap()
            })
            .unwrap();
        let mean = |p: &PartyData| p.x.col(j).iter().sum::<f64>() / p.n() as f64;
        let m0 = mean(&cohort.parties[0]);
        let m2 = mean(&cohort.parties[2]);
        assert!((m0 - m2).abs() > 0.1, "m0={m0} m2={m2}");
    }

    #[test]
    #[should_panic(expected = "party_admixture")]
    fn mismatched_admixture_panics() {
        let mut spec = CohortSpec::default_small();
        spec.party_admixture = vec![0.5];
        let _ = generate_cohort(&spec, 1);
    }

    #[test]
    #[should_panic(expected = "≥1 trait")]
    fn zero_traits_panics() {
        let mut spec = CohortSpec::default_small();
        spec.n_traits = 0;
        let _ = generate_cohort(&spec, 1);
    }
}
