//! Synthetic multi-center GWAS workload generator.
//!
//! Substitute for the private institutional data the paper's setting
//! assumes (see DESIGN.md §Substitutions): genotypes follow a
//! Balding–Nichols two-population model with configurable F_ST, parties
//! differ in sample size and admixture (so ancestry is a real confounder,
//! exactly the situation where the paper's pooled covariate-adjusted scan
//! beats per-party meta-analysis), covariates include intercept, age,
//! sex, and "reference-panel PC scores" (noisy individual admixture, as
//! computed securely by each center in the paper's §1), and traits are
//! linear in a sparse causal set plus ancestry and party batch effects.

mod genotypes;
mod cohort;

pub use cohort::{generate_cohort, pool_cohort, Cohort, CohortSpec, PartyData, Truth};
pub use genotypes::{sample_allele_freqs, VariantFreqs};
