//! Triangular solves — the `R⁻ᵀ(CᵀX)` projection of the combine stage.

use super::dense::Matrix;

/// Solve `L x = b` for lower-triangular `L` (forward substitution),
/// column-wise over the `K × m` right-hand side.
pub fn solve_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows;
    assert_eq!(l.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in 0..n {
            let mut sum = x[(i, c)];
            for k in 0..i {
                sum -= l[(i, k)] * x[(k, c)];
            }
            let d = l[(i, i)];
            assert!(d != 0.0, "singular triangular system at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Solve `U x = b` for upper-triangular `U` (back substitution).
pub fn solve_upper(u: &Matrix, b: &Matrix) -> Matrix {
    let n = u.rows;
    assert_eq!(u.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in (0..n).rev() {
            let mut sum = x[(i, c)];
            for k in i + 1..n {
                sum -= u[(i, k)] * x[(k, c)];
            }
            let d = u[(i, i)];
            assert!(d != 0.0, "singular triangular system at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Solve `Rᵀ x = b` for upper-triangular `R` — i.e. compute `R⁻ᵀ b`,
/// the paper's `Qᵀy = R⁻ᵀ(Cᵀy)` / `QᵀX = R⁻ᵀ(CᵀX)` step. `Rᵀ` is lower
/// triangular, so this is a forward substitution that reads `R` transposed
/// in place (no copy).
pub fn solve_rt_b(r: &Matrix, b: &Matrix) -> Matrix {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in 0..n {
            let mut sum = x[(i, c)];
            for k in 0..i {
                // (Rᵀ)[i,k] = R[k,i]
                sum -= r[(k, i)] * x[(k, c)];
            }
            let d = r[(i, i)];
            assert!(d != 0.0, "singular R at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Invert an upper-triangular matrix (for `(CᵀC)⁻¹ = R⁻¹R⁻ᵀ` in the
/// plain multi-party regression of §2).
pub fn invert_upper(u: &Matrix) -> Matrix {
    solve_upper(u, &Matrix::identity(u.rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, rel_err};
    use crate::util::rng::Rng;

    fn random_upper(n: usize, rng: &mut Rng) -> Matrix {
        let mut u = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                u[(i, j)] = rng.normal();
            }
            u[(i, i)] = 1.0 + rng.uniform(); // well-conditioned diagonal
        }
        u
    }

    #[test]
    fn solve_upper_roundtrip() {
        let mut rng = Rng::new(30);
        let u = random_upper(7, &mut rng);
        let b = Matrix::randn(7, 3, &mut rng);
        let x = solve_upper(&u, &b);
        assert!(rel_err(&u.matmul(&x).data, &b.data) < 1e-12);
    }

    #[test]
    fn solve_lower_roundtrip() {
        let mut rng = Rng::new(31);
        let l = random_upper(6, &mut rng).transpose();
        let b = Matrix::randn(6, 2, &mut rng);
        let x = solve_lower(&l, &b);
        assert!(rel_err(&l.matmul(&x).data, &b.data) < 1e-12);
    }

    #[test]
    fn solve_rt_b_matches_transpose_solve() {
        let mut rng = Rng::new(32);
        let r = random_upper(5, &mut rng);
        let b = Matrix::randn(5, 4, &mut rng);
        let fast = solve_rt_b(&r, &b);
        let slow = solve_lower(&r.transpose(), &b);
        assert!(rel_err(&fast.data, &slow.data) < 1e-13);
    }

    #[test]
    fn invert_upper_gives_inverse() {
        let mut rng = Rng::new(33);
        let u = random_upper(8, &mut rng);
        let inv = invert_upper(&u);
        let eye = u.matmul(&inv);
        assert!(rel_err(&eye.data, &Matrix::identity(8).data) < 1e-11);
    }

    #[test]
    fn projection_identity_qr() {
        // QᵀX == R⁻ᵀ CᵀX end-to-end with real QR factors.
        let mut rng = Rng::new(34);
        let c = Matrix::randn(50, 4, &mut rng);
        let x = Matrix::randn(50, 9, &mut rng);
        let f = householder_qr(&c);
        let lhs = f.q.t_matmul(&x);
        let rhs = solve_rt_b(&f.r, &c.t_matmul(&x));
        assert!(rel_err(&rhs.data, &lhs.data) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn singular_panics() {
        let mut u = Matrix::identity(3);
        u[(1, 1)] = 0.0;
        let _ = solve_upper(&u, &Matrix::identity(3));
    }
}
