//! Triangular solves — the `R⁻ᵀ(CᵀX)` projection of the combine stage.

use super::dense::Matrix;

/// Solve `L x = b` for lower-triangular `L` (forward substitution),
/// column-wise over the `K × m` right-hand side.
pub fn solve_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows;
    assert_eq!(l.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in 0..n {
            let mut sum = x[(i, c)];
            for k in 0..i {
                sum -= l[(i, k)] * x[(k, c)];
            }
            let d = l[(i, i)];
            assert!(d != 0.0, "singular triangular system at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Solve `U x = b` for upper-triangular `U` (back substitution).
pub fn solve_upper(u: &Matrix, b: &Matrix) -> Matrix {
    let n = u.rows;
    assert_eq!(u.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in (0..n).rev() {
            let mut sum = x[(i, c)];
            for k in i + 1..n {
                sum -= u[(i, k)] * x[(k, c)];
            }
            let d = u[(i, i)];
            assert!(d != 0.0, "singular triangular system at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Solve `Rᵀ x = b` for upper-triangular `R` — i.e. compute `R⁻ᵀ b`,
/// the paper's `Qᵀy = R⁻ᵀ(Cᵀy)` / `QᵀX = R⁻ᵀ(CᵀX)` step. `Rᵀ` is lower
/// triangular, so this is a forward substitution that reads `R` transposed
/// in place (no copy).
pub fn solve_rt_b(r: &Matrix, b: &Matrix) -> Matrix {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for c in 0..b.cols {
        for i in 0..n {
            let mut sum = x[(i, c)];
            for k in 0..i {
                // (Rᵀ)[i,k] = R[k,i]
                sum -= r[(k, i)] * x[(k, c)];
            }
            let d = r[(i, i)];
            assert!(d != 0.0, "singular R at {i}");
            x[(i, c)] = sum / d;
        }
    }
    x
}

/// Invert an upper-triangular matrix (for `(CᵀC)⁻¹ = R⁻¹R⁻ᵀ` in the
/// plain multi-party regression of §2).
pub fn invert_upper(u: &Matrix) -> Matrix {
    solve_upper(u, &Matrix::identity(u.rows))
}

/// Extend a projection through a rank-1 QR append without re-solving.
///
/// When the basis `B` grows by a column `b` (`linalg::qr_append`), the
/// new orthonormal direction is `q = (b − QQᵀb)/ρ` with `ρ = √(b·b −
/// ‖Qᵀb‖²)`. For any vector `x` whose projection `u_x = Qᵀx` against the
/// *old* basis is already known, the augmented projection is `[u_x; e]`
/// with the single new entry
///
/// `e = qᵀx = (b·x − (Qᵀb)·(Qᵀx)) / ρ`
///
/// — `O(K)` per vector instead of an `O(K²)` fresh triangular solve, and
/// needing only the raw cross-product `b·x`. This is what lets the
/// SELECT phase re-project every cached statistic against the grown
/// basis from `O(K+T+H)` numbers per round.
pub fn project_append(u_b: &[f64], rho: f64, u_x: &[f64], btx: f64) -> f64 {
    assert_eq!(u_b.len(), u_x.len(), "projection length mismatch");
    assert!(rho > 0.0, "non-positive residual norm {rho}");
    let dot: f64 = u_b.iter().zip(u_x).map(|(a, b)| a * b).sum();
    (btx - dot) / rho
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, rel_err};
    use crate::util::rng::Rng;

    fn random_upper(n: usize, rng: &mut Rng) -> Matrix {
        let mut u = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                u[(i, j)] = rng.normal();
            }
            u[(i, i)] = 1.0 + rng.uniform(); // well-conditioned diagonal
        }
        u
    }

    #[test]
    fn solve_upper_roundtrip() {
        let mut rng = Rng::new(30);
        let u = random_upper(7, &mut rng);
        let b = Matrix::randn(7, 3, &mut rng);
        let x = solve_upper(&u, &b);
        assert!(rel_err(&u.matmul(&x).data, &b.data) < 1e-12);
    }

    #[test]
    fn solve_lower_roundtrip() {
        let mut rng = Rng::new(31);
        let l = random_upper(6, &mut rng).transpose();
        let b = Matrix::randn(6, 2, &mut rng);
        let x = solve_lower(&l, &b);
        assert!(rel_err(&l.matmul(&x).data, &b.data) < 1e-12);
    }

    #[test]
    fn solve_rt_b_matches_transpose_solve() {
        let mut rng = Rng::new(32);
        let r = random_upper(5, &mut rng);
        let b = Matrix::randn(5, 4, &mut rng);
        let fast = solve_rt_b(&r, &b);
        let slow = solve_lower(&r.transpose(), &b);
        assert!(rel_err(&fast.data, &slow.data) < 1e-13);
    }

    #[test]
    fn invert_upper_gives_inverse() {
        let mut rng = Rng::new(33);
        let u = random_upper(8, &mut rng);
        let inv = invert_upper(&u);
        let eye = u.matmul(&inv);
        assert!(rel_err(&eye.data, &Matrix::identity(8).data) < 1e-11);
    }

    #[test]
    fn projection_identity_qr() {
        // QᵀX == R⁻ᵀ CᵀX end-to-end with real QR factors.
        let mut rng = Rng::new(34);
        let c = Matrix::randn(50, 4, &mut rng);
        let x = Matrix::randn(50, 9, &mut rng);
        let f = householder_qr(&c);
        let lhs = f.q.t_matmul(&x);
        let rhs = solve_rt_b(&f.r, &c.t_matmul(&x));
        assert!(rel_err(&rhs.data, &lhs.data) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn singular_panics() {
        let mut u = Matrix::identity(3);
        u[(1, 1)] = 0.0;
        let _ = solve_upper(&u, &Matrix::identity(3));
    }

    #[test]
    fn project_append_matches_fresh_solve() {
        // Appending b to the basis and re-projecting x from scratch must
        // agree with the O(K) incremental entry.
        let mut rng = Rng::new(35);
        let c = Matrix::randn(50, 4, &mut rng);
        let b = Matrix::randn(50, 1, &mut rng).col(0);
        let x = Matrix::randn(50, 1, &mut rng).col(0);
        let f = householder_qr(&c);
        let u_b = f.q.t_matvec(&b);
        let u_x = f.q.t_matvec(&x);
        let d: f64 = b.iter().map(|v| v * v).sum();
        let rho = (d - u_b.iter().map(|v| v * v).sum::<f64>()).sqrt();
        let btx: f64 = b.iter().zip(&x).map(|(a, c)| a * c).sum();
        let e = project_append(&u_b, rho, &u_x, btx);

        // fresh solve against the augmented basis
        let aug = Matrix::vstack(&[&c.transpose(), &Matrix::from_col(b.clone()).transpose()])
            .transpose();
        let qa = householder_qr(&aug).q;
        let full = qa.t_matvec(&x);
        assert!((full[4] - e).abs() < 1e-9, "{} vs {e}", full[4]);
        for i in 0..4 {
            assert!((full[i] - u_x[i]).abs() < 1e-9);
        }
    }
}
