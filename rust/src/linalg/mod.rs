//! Dense linear algebra substrate for the combine stage.
//!
//! The combine stage of the paper is `O(PK² + K³)` work on small `K×K`
//! matrices: stacking per-party `R_p` factors (TSQR, Lemma 4.1), QR /
//! Cholesky factorizations, triangular solves, and the `R⁻ᵀ(CᵀX)`
//! projection. These run on the Rust request path (no artifact round-trip
//! is worth it at K ≤ 64), so they are implemented here and verified
//! against the JAX oracle in the python tests and against analytic cases
//! in unit tests.

mod dense;
mod qr;
mod chol;
mod tri;

pub use dense::Matrix;
pub use qr::{
    householder_qr, qr_append, qt_from_compressed, tsqr_stack_r, QrFactors, QR_APPEND_TOL,
};
pub use chol::cholesky_upper;
pub use tri::{invert_upper, project_append, solve_lower, solve_rt_b, solve_upper};

/// Frobenius norm of a slice.
pub fn fro_norm(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Relative Frobenius error ‖a − b‖ / max(‖b‖, eps).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let diff: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    diff / fro_norm(b).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fro_and_rel() {
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!(rel_err(&[1.0, 2.0], &[1.0, 2.0]) < 1e-15);
        assert!(rel_err(&[1.1, 2.0], &[1.0, 2.0]) > 0.01);
    }
}
