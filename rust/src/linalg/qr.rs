//! Householder QR and the TSQR stacking step (paper Lemma 4.1).
//!
//! The multi-party combine stage needs the `R` factor of the stacked
//! covariate matrix `C = [C_1; …; C_P]`. Lemma 4.1: QR of the stack of
//! per-party `R_p` factors has the same `R` as QR of `C` itself (with the
//! positive-diagonal convention that makes QR unique for full-column-rank
//! input). [`householder_qr`] computes thin QR with that convention;
//! [`tsqr_stack_r`] applies it to the `PK × K` stack.

use super::dense::Matrix;
use super::tri::solve_rt_b;

/// Thin QR factors: `a = q · r`, `q` is `n × k` with orthonormal columns,
/// `r` is `k × k` upper triangular with non-negative diagonal.
#[derive(Clone, Debug)]
pub struct QrFactors {
    pub q: Matrix,
    pub r: Matrix,
}

/// Householder thin QR with positive-diagonal normalization.
///
/// Complexity `O(n k²)` — this is the per-party compress-stage cost the
/// paper counts as `O(N_p K²)`.
pub fn householder_qr(a: &Matrix) -> QrFactors {
    let n = a.rows;
    let k = a.cols;
    assert!(n >= k, "householder_qr requires n >= k (tall matrix), got {n}x{k}");
    let mut r = a.clone(); // will be reduced in place
    // Store Householder vectors to build thin Q afterwards.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Householder vector for column j below (and including) row j.
        let mut norm2 = 0.0;
        for i in j..n {
            let x = r[(i, j)];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        let x0 = r[(j, j)];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0; n - j];
        if norm > 0.0 {
            v[0] = x0 - alpha;
            for i in j + 1..n {
                v[i - j] = r[(i, j)];
            }
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 > 0.0 {
                // Apply H = I - 2 v vᵀ / (vᵀv) to the trailing block of R.
                for c in j..k {
                    let mut dot = 0.0;
                    for i in j..n {
                        dot += v[i - j] * r[(i, c)];
                    }
                    let f = 2.0 * dot / vnorm2;
                    for i in j..n {
                        r[(i, c)] -= f * v[i - j];
                    }
                }
            }
        }
        vs.push(v);
    }

    // Build thin Q by applying the Householder reflectors to I(:, 0..k).
    let mut q = Matrix::zeros(n, k);
    for j in 0..k {
        q[(j, j)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut dot = 0.0;
            for i in j..n {
                dot += v[i - j] * q[(i, c)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in j..n {
                q[(i, c)] -= f * v[i - j];
            }
        }
    }

    // Normalize to positive diagonal (uniqueness convention from the
    // paper: "requiring that R have positive diagonal entries").
    let mut r_thin = Matrix::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            r_thin[(i, j)] = r[(i, j)];
        }
    }
    for i in 0..k {
        if r_thin[(i, i)] < 0.0 {
            for j in i..k {
                r_thin[(i, j)] = -r_thin[(i, j)];
            }
            for rr in 0..n {
                q[(rr, i)] = -q[(rr, i)];
            }
        }
    }
    QrFactors { q, r: r_thin }
}

/// TSQR combine: given per-party `R_p` factors (each `K × K`), stack them
/// vertically and return the `R` of the stack — by Lemma 4.1 this equals
/// the `R` of the full stacked covariate matrix. `O(P K³)` work,
/// independent of sample size.
pub fn tsqr_stack_r(rs: &[Matrix]) -> Matrix {
    assert!(!rs.is_empty());
    let k = rs[0].cols;
    for r in rs {
        assert_eq!(r.rows, k, "R_p must be K×K");
        assert_eq!(r.cols, k, "R_p must be K×K");
    }
    let refs: Vec<&Matrix> = rs.iter().collect();
    let stack = Matrix::vstack(&refs);
    householder_qr(&stack).r
}

/// Compute `Qᵀ b` from compressed statistics without materializing `Q`:
/// `Qᵀ b = R⁻ᵀ (Cᵀ b)` (since `C = QR` ⇒ `Cᵀ = RᵀQᵀ`). This is the
/// combine-stage projection of §4; `ctb` is `K × m`.
pub fn qt_from_compressed(r: &Matrix, ctb: &Matrix) -> Matrix {
    solve_rt_b(r, ctb)
}

/// Relative residual-norm threshold below which an appended column is
/// treated as lying in the span of the existing basis (matches the
/// collinearity guard of the Lemma 3.1 epilogue in `stats::regression`).
pub const QR_APPEND_TOL: f64 = 1e-12;

/// Rank-1 QR extension (the SELECT-phase "promote a variant into the
/// covariate basis" step): given the `K × K` factor `R` of `QR(B)`, the
/// projection `u = Qᵀb` of a new column `b`, and `d = b·b`, return the
/// `(K+1) × (K+1)` factor of `QR([B | b])`:
///
/// ```text
/// R' = [ R  u ]      ρ = ‖(I − QQᵀ)b‖ = √(d − ‖u‖²)
///      [ 0  ρ ]
/// ```
///
/// No pass over the `N`-row data and no re-factorization — `O(K²)` to
/// copy plus `O(K)` new entries. Errors (rather than producing a
/// numerically-singular factor) when the residual `d − ‖u‖²` is below
/// [`QR_APPEND_TOL`] relative to `d`, i.e. the column is already in the
/// span of the basis.
pub fn qr_append(r: &Matrix, u: &[f64], d: f64) -> anyhow::Result<Matrix> {
    let k = r.rows;
    anyhow::ensure!(r.cols == k, "qr_append needs a square R, got {}x{}", r.rows, r.cols);
    anyhow::ensure!(u.len() == k, "projection length {} != K={k}", u.len());
    let unorm2: f64 = u.iter().map(|x| x * x).sum();
    let resid = d - unorm2;
    anyhow::ensure!(
        resid > QR_APPEND_TOL * d.abs().max(1.0),
        "appended column is (numerically) in the span of the basis \
         (residual {resid:e} vs ‖b‖² {d:e})"
    );
    let rho = resid.sqrt();
    let mut out = Matrix::zeros(k + 1, k + 1);
    for i in 0..k {
        for j in i..k {
            out[(i, j)] = r[(i, j)];
        }
        out[(i, k)] = u[i];
    }
    out[(k, k)] = rho;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::util::rng::Rng;

    fn check_qr(a: &Matrix, tol: f64) {
        let QrFactors { q, r } = householder_qr(a);
        // Reconstruction
        let qr = q.matmul(&r);
        assert!(rel_err(&qr.data, &a.data) < tol, "reconstruction");
        // Orthonormal columns
        let qtq = q.gram();
        let eye = Matrix::identity(a.cols);
        assert!(rel_err(&qtq.data, &eye.data) < tol, "orthonormality");
        // Upper triangular with positive diagonal
        for i in 0..r.rows {
            assert!(r[(i, i)] >= 0.0, "diag sign");
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0, "lower triangle");
            }
        }
    }

    #[test]
    fn qr_random_tall() {
        let mut rng = Rng::new(10);
        for &(n, k) in &[(4usize, 4usize), (10, 3), (50, 8), (200, 12)] {
            let a = Matrix::randn(n, k, &mut rng);
            check_qr(&a, 1e-12);
        }
    }

    #[test]
    fn qr_with_constant_column() {
        // intercept column of ones — the GWAS default
        let mut rng = Rng::new(11);
        let mut a = Matrix::randn(30, 4, &mut rng);
        for i in 0..30 {
            a[(i, 0)] = 1.0;
        }
        check_qr(&a, 1e-12);
    }

    #[test]
    fn qr_square_identity() {
        let a = Matrix::identity(5);
        let QrFactors { q, r } = householder_qr(&a);
        assert!(rel_err(&q.data, &a.data) < 1e-14);
        assert!(rel_err(&r.data, &a.data) < 1e-14);
    }

    #[test]
    fn tsqr_matches_full_qr() {
        // Lemma 4.1: R of stacked R_p equals R of stacked data.
        let mut rng = Rng::new(12);
        let k = 6;
        let parts: Vec<Matrix> = [20usize, 35, 11]
            .iter()
            .map(|&n| Matrix::randn(n, k, &mut rng))
            .collect();
        let rs: Vec<Matrix> = parts.iter().map(|c| householder_qr(c).r).collect();
        let r_tsqr = tsqr_stack_r(&rs);
        let refs: Vec<&Matrix> = parts.iter().collect();
        let full = Matrix::vstack(&refs);
        let r_full = householder_qr(&full).r;
        assert!(
            rel_err(&r_tsqr.data, &r_full.data) < 1e-11,
            "err={}",
            rel_err(&r_tsqr.data, &r_full.data)
        );
    }

    #[test]
    fn tsqr_single_party_is_identity_op() {
        let mut rng = Rng::new(13);
        let c = Matrix::randn(40, 5, &mut rng);
        let r = householder_qr(&c).r;
        let r2 = tsqr_stack_r(std::slice::from_ref(&r));
        assert!(rel_err(&r2.data, &r.data) < 1e-12);
    }

    #[test]
    fn qt_from_compressed_matches_direct() {
        let mut rng = Rng::new(14);
        let c = Matrix::randn(60, 5, &mut rng);
        let x = Matrix::randn(60, 7, &mut rng);
        let QrFactors { q, r } = householder_qr(&c);
        let direct = q.t_matmul(&x);
        let via_r = qt_from_compressed(&r, &c.t_matmul(&x));
        assert!(rel_err(&via_r.data, &direct.data) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "n >= k")]
    fn qr_wide_panics() {
        let a = Matrix::zeros(2, 5);
        let _ = householder_qr(&a);
    }

    #[test]
    fn qr_append_matches_full_refactorization() {
        // R' of [C | b] from the rank-1 append equals the R of a fresh QR
        // of the augmented matrix (positive-diagonal convention on both).
        let mut rng = Rng::new(15);
        let c = Matrix::randn(60, 5, &mut rng);
        let b = Matrix::randn(60, 1, &mut rng);
        let QrFactors { q, r } = householder_qr(&c);
        let u = q.t_matvec(&b.col(0));
        let d: f64 = b.col(0).iter().map(|x| x * x).sum();
        let r_app = qr_append(&r, &u, d).unwrap();

        let full = Matrix::vstack(&[&c.transpose(), &b.transpose()]).transpose();
        assert_eq!((full.rows, full.cols), (60, 6));
        let r_full = householder_qr(&full).r;
        assert!(
            rel_err(&r_app.data, &r_full.data) < 1e-10,
            "err={}",
            rel_err(&r_app.data, &r_full.data)
        );
        // chained appends keep agreeing with the full factorization
        let b2 = Matrix::randn(60, 1, &mut rng);
        let q2 = householder_qr(&full).q;
        let u2 = q2.t_matvec(&b2.col(0));
        let d2: f64 = b2.col(0).iter().map(|x| x * x).sum();
        let r_app2 = qr_append(&r_app, &u2, d2).unwrap();
        let full2 = Matrix::vstack(&[&full.transpose(), &b2.transpose()]).transpose();
        let r_full2 = householder_qr(&full2).r;
        assert!(rel_err(&r_app2.data, &r_full2.data) < 1e-9);
    }

    #[test]
    fn qr_append_rejects_collinear_column() {
        // appending a column already in the span must error, not produce
        // a singular factor
        let mut rng = Rng::new(16);
        let c = Matrix::randn(40, 4, &mut rng);
        let QrFactors { q, r } = householder_qr(&c);
        // b = C · w lies exactly in the span
        let w = vec![1.0, -2.0, 0.5, 3.0];
        let b = c.matvec(&w);
        let u = q.t_matvec(&b);
        let d: f64 = b.iter().map(|x| x * x).sum();
        assert!(qr_append(&r, &u, d).is_err());
        // and shape mismatches error too
        assert!(qr_append(&r, &u[..3], d).is_err());
    }
}
