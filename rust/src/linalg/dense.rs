//! Row-major dense matrix with the operations the combine stage needs.

use crate::util::rng::Rng;

/// Row-major dense `rows × cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Single-column matrix from a vector — the `T = 1` trait matrix.
    pub fn from_col(data: Vec<f64>) -> Matrix {
        Matrix { rows: data.len(), cols: 1, data }
    }

    /// i.i.d. standard normal entries (workload + test generator).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Column `j` as an owned vector. Reads the backing storage with a
    /// single row stride instead of per-element `Index` calls (bounds
    /// checks once, vectorizable gather).
    pub fn col(&self, j: usize) -> Vec<f64> {
        assert!(j < self.cols, "col {j} out of range ({} cols)", self.cols);
        if self.rows == 0 {
            return Vec::new();
        }
        self.data[j..].iter().step_by(self.cols).copied().collect()
    }

    /// Iterate columns `range` in order as owned vectors — the trait-dim
    /// slicing used to peel per-trait columns out of `Y`, `CᵀY`, `XᵀY`.
    pub fn cols(
        &self,
        range: std::ops::Range<usize>,
    ) -> impl Iterator<Item = Vec<f64>> + '_ {
        assert!(range.end <= self.cols, "cols range beyond {} cols", self.cols);
        range.map(move |j| self.col(j))
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self · other` — naive triple loop with row-major inner kernel.
    /// Fine for combine-stage sizes (K ≤ 64); the data-sized matmuls run
    /// through the AOT-compiled XLA path, not here.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let mut out = Matrix::zeros(self.cols, other.cols);
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Gram matrix `selfᵀ · self` (syrk), symmetric by construction.
    pub fn gram(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let a = row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(i);
                for j in i..self.cols {
                    out_row[j] += a * row[j];
                }
            }
        }
        // mirror upper → lower
        for i in 0..self.cols {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// `selfᵀ x`.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * xi;
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// Vertical stack.
    pub fn vstack(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            assert_eq!(m.cols, cols, "vstack col mismatch");
            data.extend_from_slice(&m.data);
        }
        Matrix { rows, cols, data }
    }

    /// Horizontal slice of columns `[j0, j1)`.
    pub fn col_slice(&self, j0: usize, j1: usize) -> Matrix {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[j0..j1]);
        }
        out
    }

    /// Gather an arbitrary (possibly non-contiguous) set of columns into
    /// a new `rows × idx.len()` matrix — the SELECT-phase candidate
    /// shortlist extraction. Indices may repeat; order is preserved.
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        for &j in idx {
            assert!(j < self.cols, "gather col {j} out of range ({} cols)", self.cols);
        }
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            for (o, &j) in out.row_mut(i).iter_mut().zip(idx) {
                *o = src[j];
            }
        }
        out
    }

    /// Row slice `[i0, i1)`.
    pub fn row_slice(&self, i0: usize, i1: usize) -> Matrix {
        assert!(i0 <= i1 && i1 <= self.rows);
        Matrix {
            rows: i1 - i0,
            cols: self.cols,
            data: self.data[i0 * self.cols..i1 * self.cols].to_vec(),
        }
    }

    /// Split off rows `[r, rows)` into a new matrix, keeping `[0, r)` in
    /// place — the retained prefix is never copied, so peeling a
    /// row-major block apart tail-first is allocation-moving, not
    /// duplicating (used to shard the cached `M × T` trait block).
    pub fn split_off_rows(&mut self, r: usize) -> Matrix {
        assert!(r <= self.rows, "split row {r} beyond {} rows", self.rows);
        let tail = Matrix {
            rows: self.rows - r,
            cols: self.cols,
            data: self.data.split_off(r * self.cols),
        };
        self.rows = r;
        tail
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = Matrix::randn(5, 7, &mut r);
        let i = Matrix::identity(7);
        assert!(rel_err(&a.matmul(&i).data, &a.data) < 1e-15);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut r = Rng::new(2);
        let a = Matrix::randn(9, 4, &mut r);
        let b = Matrix::randn(9, 6, &mut r);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(rel_err(&fast.data, &slow.data) < 1e-13);
    }

    #[test]
    fn gram_matches_t_matmul_self() {
        let mut r = Rng::new(3);
        let a = Matrix::randn(20, 5, &mut r);
        let g = a.gram();
        let g2 = a.t_matmul(&a);
        assert!(rel_err(&g.data, &g2.data) < 1e-13);
        // symmetry
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn matvec_t_matvec() {
        let mut r = Rng::new(4);
        let a = Matrix::randn(6, 3, &mut r);
        let x = vec![1.0, -2.0, 0.5];
        let y = a.matvec(&x);
        let y2 = a.matmul(&Matrix::from_vec(3, 1, x.clone())).data;
        assert!(rel_err(&y, &y2) < 1e-14);
        let z = vec![0.1; 6];
        let w = a.t_matvec(&z);
        let w2 = a.transpose().matvec(&z);
        assert!(rel_err(&w, &w2) < 1e-14);
    }

    #[test]
    fn vstack_and_slices() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[3.0, 4.0], &[5.0, 6.0]]);
        let s = Matrix::vstack(&[&a, &b]);
        assert_eq!(s.rows, 3);
        assert_eq!(s.row(2), &[5.0, 6.0]);
        assert_eq!(s.row_slice(1, 3).data, b.data);
        assert_eq!(s.col_slice(1, 2).col(0), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn col_and_cols_range() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(m.col(0), vec![1.0, 4.0]);
        assert_eq!(m.col(2), vec![3.0, 6.0]);
        let mid: Vec<Vec<f64>> = m.cols(1..3).collect();
        assert_eq!(mid, vec![vec![2.0, 5.0], vec![3.0, 6.0]]);
        assert_eq!(m.cols(0..0).count(), 0);
        // empty matrix edge
        let e = Matrix::zeros(0, 2);
        assert_eq!(e.col(1), Vec::<f64>::new());
        // single-column view round-trips through from_col
        assert_eq!(Matrix::from_col(m.col(1)).data, vec![2.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn col_out_of_range_panics() {
        let _ = Matrix::zeros(2, 2).col(2);
    }

    #[test]
    fn gather_cols_selects_in_order() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let g = m.gather_cols(&[2, 0, 2]);
        assert_eq!((g.rows, g.cols), (2, 3));
        assert_eq!(g.data, vec![3.0, 1.0, 3.0, 6.0, 4.0, 6.0]);
        assert_eq!(m.gather_cols(&[]).cols, 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn gather_cols_out_of_range_panics() {
        let _ = Matrix::zeros(2, 2).gather_cols(&[0, 2]);
    }

    #[test]
    fn split_off_rows_partitions_without_copying_prefix() {
        let full = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let mut m = full.clone();
        let tail = m.split_off_rows(1);
        assert_eq!((m.rows, m.cols), (1, 2));
        assert_eq!(m.data, vec![1.0, 2.0]);
        assert_eq!((tail.rows, tail.cols), (2, 2));
        assert_eq!(tail.data, full.row_slice(1, 3).data);
        // degenerate splits
        let mut m2 = full.clone();
        assert_eq!(m2.split_off_rows(3).rows, 0);
        assert_eq!(m2.rows, 3);
        let mut m3 = full.clone();
        let all = m3.split_off_rows(0);
        assert_eq!(all.data, full.data);
        assert_eq!(m3.rows, 0);
    }

    #[test]
    fn add_sub_scale() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[3.0, 5.0]]);
        assert_eq!(a.add(&b).data, vec![4.0, 7.0]);
        assert_eq!(b.sub(&a).data, vec![2.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
