//! Cholesky factorization.
//!
//! Used by the *secure* combine path: under SMC the parties reveal only
//! the aggregate Gram matrix `CᵀC = Σ_p C_pᵀC_p`, and `R = cholᵀ(CᵀC)`
//! is mathematically the same `R` as Lemma 4.1's TSQR (both are the
//! unique positive-diagonal Cholesky factor of `CᵀC`), at the cost of a
//! squared condition number. The E9 ablation quantifies the gap.

use super::dense::Matrix;

/// Upper-triangular Cholesky factor `U` with `a = Uᵀ U`.
/// Errors if `a` is not (numerically) symmetric positive definite.
pub fn cholesky_upper(a: &Matrix) -> anyhow::Result<Matrix> {
    let n = a.rows;
    anyhow::ensure!(a.cols == n, "cholesky requires square input");
    let mut u = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut sum = a[(i, j)];
            for k in 0..i {
                sum -= u[(k, i)] * u[(k, j)];
            }
            if i == j {
                anyhow::ensure!(
                    sum > 0.0,
                    "matrix not positive definite at pivot {i} (got {sum:e}); \
                     covariates are likely collinear"
                );
                u[(i, j)] = sum.sqrt();
            } else {
                u[(i, j)] = sum / u[(i, i)];
            }
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, rel_err};
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs() {
        let mut rng = Rng::new(20);
        let b = Matrix::randn(30, 6, &mut rng);
        let g = b.gram();
        let u = cholesky_upper(&g).unwrap();
        let back = u.t_matmul(&u);
        assert!(rel_err(&back.data, &g.data) < 1e-12);
        // upper triangular, positive diagonal
        for i in 0..6 {
            assert!(u[(i, i)] > 0.0);
            for j in 0..i {
                assert_eq!(u[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn matches_qr_r_factor() {
        // chol(CᵀC) == R from QR(C) — the identity the secure path uses.
        let mut rng = Rng::new(21);
        let c = Matrix::randn(80, 5, &mut rng);
        let r_qr = householder_qr(&c).r;
        let r_chol = cholesky_upper(&c.gram()).unwrap();
        assert!(rel_err(&r_chol.data, &r_qr.data) < 1e-10);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky_upper(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(cholesky_upper(&Matrix::zeros(2, 3)).is_err());
    }
}
