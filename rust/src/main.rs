//! `dash` — launcher for the DASH multi-party association scan.
//!
//! Subcommands:
//!   scan         run a full multi-party scan on a synthetic cohort
//!   regress      multi-party linear regression only (§2)
//!   bench-comm   communication scaling rows (E4)
//!   artifacts    report on the compiled artifact set
//!   serve        scan-as-a-service leader daemon (HTTP/JSON control plane)
//!   jobs         client for a running daemon (submit/status/result/cancel)
//!
//! Examples:
//!   dash scan --parties 4 --n 8000 --m 20000 --backend masked
//!   dash scan --config run.json --transport tcp
//!   dash regress --parties 3 --n 3000
//!   dash serve --listen 127.0.0.1:8787 --max-jobs 2
//!   dash jobs submit --addr 127.0.0.1:8787 --config run.json --wait

use dash::config::RunConfig;
use dash::coordinator::{
    result_fingerprint, run_multi_party_scan_t, Daemon, DaemonOptions, Transport,
};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::net::http::http_request;
use dash::scan::combine_regression;
use dash::util::cli::Command;
use dash::util::json::Json;
use dash::util::{human_bytes, human_secs};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let (sub, rest) = match args.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => {
            print_usage();
            return Ok(());
        }
    };
    match sub {
        "scan" => cmd_scan(&rest),
        "regress" => cmd_regress(&rest),
        "bench-comm" => cmd_bench_comm(&rest),
        "artifacts" => cmd_artifacts(&rest),
        "serve" => cmd_serve(&rest),
        "jobs" => cmd_jobs(&rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand `{other}`\n{}", usage_text()),
    }
}

fn usage_text() -> String {
    "usage: dash <scan|regress|bench-comm|artifacts|serve|jobs> [options]\n\
     run `dash <subcommand> --help` for options"
        .to_string()
}

fn print_usage() {
    println!("{}", usage_text());
}

fn scan_command() -> Command {
    Command::new("scan", "run a multi-party association scan")
        .opt("config", "", "JSON config file (CLI flags override it)")
        .opt("parties", "4", "number of parties")
        .opt("n", "2000", "total samples (split across parties)")
        .opt("m", "2000", "number of variants")
        .opt("traits", "1", "number of traits scanned jointly (T; the genotype-side cost is shared across traits)")
        .opt("backend", "masked", "SMC backend: plaintext|masked|shamir")
        .opt("seed", "7", "rng seed")
        .opt("block-m", "256", "variant block width")
        .opt("shard-m", "0", "variant shard width for the streaming protocol (0 = single shot)")
        .opt("compress-threads", "0", "worker-thread budget for the tiled compress kernels, shared across concurrent sessions (0 = auto; bit-identical at any count)")
        .opt("transport", "inproc", "inproc|tcp|reactor (reactor: one epoll readiness thread drives every connection)")
        .opt("sessions", "1", "multiplexed scan+SELECT sessions over shared per-party connections (1 = classic dedicated-connection run)")
        .opt("max-concurrent", "4", "bound on concurrently-running sessions (leader scheduler and party service pools)")
        .opt("report", "", "write a JSON report to this path")
        .flag("artifacts", "use the artifact kernel suite for compression")
        .opt("artifacts-dir", "artifacts", "artifact directory")
        .opt("artifact-exec", "auto", "artifact executor: auto|pjrt|reference")
        .opt("entry-widths", "64,256,1024,4096", "canonical shard widths of the artifact entry-shape policy (CSV ladder)")
        .opt("entry-traits", "1,4,16,64", "canonical trait batches of the artifact entry-shape policy (CSV ladder)")
        .opt("entry-k-pad", "16", "covariate padding of the artifact entries")
        .opt("alpha", "5e-8", "significance threshold for reported hits")
        .opt("select-k", "0", "forward-stepwise SELECT rounds after the scan (0 = scan only)")
        .opt("select-alpha", "1e-4", "SELECT stop rule: entry p-value threshold")
        .opt("select-policy", "union", "SELECT lane policy: union|per-trait")
        .opt("select-candidates", "32", "SELECT candidate-shortlist cap per trait")
        .opt("glm", "linear", "model: linear|logistic (logistic = secure IRLS null model + weighted score-test pass; requires 0/1 traits)")
        .opt("irls-max-iter", "25", "IRLS iteration cap for --glm logistic")
        .opt("irls-tol", "1e-8", "IRLS relative deviance stop tolerance for --glm logistic")
        .flag("binary-traits", "threshold simulated liabilities into 0/1 case-control traits (for --glm logistic)")
        .opt(
            "checkpoint-dir",
            "",
            "leader-side checkpoint directory: snapshot after every combined shard \
             (empty = checkpointing off)",
        )
        .flag("resume", "resume from an existing checkpoint in --checkpoint-dir")
}

fn cmd_scan(raw: &[String]) -> anyhow::Result<()> {
    let a = scan_command().parse(raw)?;
    let mut cfg = match a.get("config") {
        Some("") | None => RunConfig::default(),
        Some(path) => RunConfig::load(path)?,
    };
    // CLI overrides
    let parties = a.get_usize("parties")?;
    let n = a.get_usize("n")?;
    let m = a.get_usize("m")?;
    cfg.cohort.party_sizes = split_sizes(n, parties);
    cfg.cohort.party_admixture = (0..parties)
        .map(|i| if parties == 1 { 0.5 } else { i as f64 / (parties - 1) as f64 })
        .collect();
    cfg.cohort.m_variants = m;
    let traits = a.get_usize("traits")?;
    anyhow::ensure!(traits >= 1, "--traits must be ≥ 1");
    cfg.cohort.n_traits = traits;
    cfg.cohort.n_causal = cfg.cohort.n_causal.min(m);
    cfg.scan.backend = Backend::parse(a.get("backend").unwrap(), parties)?;
    cfg.seed = a.get_u64("seed")?;
    cfg.scan.block_m = a.get_usize("block-m")?;
    cfg.scan.shard_m = a.get_usize("shard-m")?;
    let compress_threads = a.get_usize("compress-threads")?;
    if compress_threads > 0 {
        cfg.scan.compress_threads = Some(compress_threads);
    }
    cfg.transport = dash::config::parse_transport(a.get("transport").unwrap())?;
    if a.flag("artifacts") {
        cfg.scan.use_artifacts = true;
        cfg.scan.artifacts_dir = a.get("artifacts-dir").unwrap().to_string();
    }
    cfg.scan.artifact_exec =
        dash::runtime::ArtifactExec::parse(a.get("artifact-exec").unwrap())?;
    cfg.scan.entry_widths =
        dash::runtime::ShapePolicy::parse_ladder(a.get("entry-widths").unwrap(), "--entry-widths")?;
    cfg.scan.entry_traits =
        dash::runtime::ShapePolicy::parse_ladder(a.get("entry-traits").unwrap(), "--entry-traits")?;
    cfg.scan.entry_k_pad = a.get_usize("entry-k-pad")?;
    cfg.scan.entry_policy().validate()?;
    cfg.scan.select_k = a.get_usize("select-k")?;
    cfg.scan.select_alpha = a.get_f64("select-alpha")?;
    anyhow::ensure!(
        cfg.scan.select_alpha > 0.0 && cfg.scan.select_alpha <= 1.0,
        "--select-alpha must be in (0, 1]"
    );
    cfg.scan.select_policy = dash::scan::SelectPolicy::parse(a.get("select-policy").unwrap())?;
    cfg.scan.select_candidates = a.get_usize("select-candidates")?;
    cfg.scan.glm = dash::scan::Glm::parse(a.get("glm").unwrap())?;
    cfg.scan.irls_max_iter = a.get_usize("irls-max-iter")?;
    anyhow::ensure!(cfg.scan.irls_max_iter >= 1, "--irls-max-iter must be ≥ 1");
    cfg.scan.irls_tol = a.get_f64("irls-tol")?;
    anyhow::ensure!(
        cfg.scan.irls_tol.is_finite() && cfg.scan.irls_tol > 0.0,
        "--irls-tol must be a positive number"
    );
    if a.flag("binary-traits") {
        cfg.cohort.binary_traits = true;
    }
    anyhow::ensure!(
        cfg.scan.glm != dash::scan::Glm::Logistic || cfg.scan.select_k == 0,
        "--glm logistic does not support the SELECT phase (drop --select-k)"
    );
    if let Some(dir) = a.get("checkpoint-dir") {
        if !dir.is_empty() {
            cfg.scan.checkpoint_dir = dir.to_string();
        }
    }
    if a.flag("resume") {
        cfg.scan.resume = true;
    }
    anyhow::ensure!(
        !cfg.scan.resume || !cfg.scan.checkpoint_dir.is_empty(),
        "--resume requires --checkpoint-dir"
    );
    let alpha = a.get_f64("alpha")?;
    cfg.sessions = a.get_usize("sessions")?;
    anyhow::ensure!(cfg.sessions >= 1, "--sessions must be ≥ 1");
    cfg.max_concurrent = a.get_usize("max-concurrent")?;
    anyhow::ensure!(cfg.max_concurrent >= 1, "--max-concurrent must be ≥ 1");

    if cfg.sessions > 1 {
        return run_scan_sessions(&cfg, a.get("report").filter(|p| !p.is_empty()));
    }

    eprintln!(
        "generating cohort: P={} N={} M={} T={} K={} ...",
        parties,
        n,
        m,
        cfg.cohort.n_traits,
        cfg.cohort.k_covariates()
    );
    let cohort = generate_cohort(&cfg.cohort, cfg.seed);
    let transport = cfg.transport;
    eprintln!(
        "running scan: backend={} transport={:?} artifacts={}",
        cfg.scan.backend.name(),
        transport,
        cfg.scan.use_artifacts
    );
    let res = run_multi_party_scan_t(&cohort, &cfg.scan, transport, cfg.seed)?;

    println!("== dash scan ==");
    println!("parties           {parties}");
    println!("samples (N)       {}", cohort.n_total());
    println!("variants (M)      {m}");
    println!("traits (T)        {}", cohort.t());
    println!("covariates (K)    {}", cohort.k());
    println!("backend           {}", cfg.scan.backend.name());
    println!(
        "shards            {} (width {})",
        res.metrics.shards,
        if cfg.scan.shard_m == 0 { m } else { cfg.scan.shard_m }
    );
    println!("compress wall     {}", human_secs(res.metrics.compress_wall_s));
    println!("combine           {}", human_secs(res.metrics.combine_s));
    if cfg.scan.glm == dash::scan::Glm::Logistic {
        println!(
            "irls              {} iters, {} total, peak round {}",
            res.metrics.irls_iters,
            human_bytes(res.metrics.bytes_irls),
            human_bytes(res.metrics.bytes_max_irls_round)
        );
    }
    println!("total             {}", human_secs(res.metrics.total_s));
    println!(
        "variant·traits/s  {:.0}",
        (m * cohort.t()) as f64 / res.metrics.total_s
    );
    println!("inter-party bytes {}", human_bytes(res.metrics.bytes_total));
    println!("peak round bytes  {}", human_bytes(res.metrics.bytes_max_round));
    if cfg.scan.use_artifacts {
        let lowered: u64 = res.party_kernels.iter().map(|k| k.lowered_entries()).sum();
        let cache_hits: u64 = res.party_kernels.iter().map(|k| k.cache_hits()).sum();
        let xside: u64 = res.party_kernels.iter().map(|k| k.xside_passes()).sum();
        let peak = res.party_kernels.iter().map(|k| k.peak_block_bytes()).max().unwrap_or(0);
        println!(
            "artifact suite    exec={} entries={lowered} cache-hits={cache_hits} \
             x-passes={xside} peak block {}",
            cfg.scan.artifact_exec.name(),
            human_bytes(peak)
        );
    }
    println!(
        "bytes/(variant·trait) {:.1}",
        res.metrics.bytes_total as f64 / (m * cohort.t()) as f64
    );
    let hits = res.output.hits(alpha);
    println!("hits, trait 0 (p < {alpha:.1e}): {}", hits.len());
    for &j in hits.iter().take(10) {
        let is_causal = cohort.truth.causal_idx.contains(&j);
        println!(
            "  variant {:>6}  beta={:+.4}  se={:.4}  p={:.3e}{}",
            j,
            res.output.assoc[0].beta[j],
            res.output.assoc[0].se[j],
            res.output.assoc[0].p[j],
            if is_causal { "  [causal]" } else { "" }
        );
    }
    if cohort.t() > 1 {
        let total_hits: usize =
            (0..cohort.t()).map(|tt| res.output.hits_for(tt, alpha).len()).sum();
        println!("hits, all {} traits: {}", cohort.t(), total_hits);
    }

    if cfg.scan.select_k > 0 {
        println!(
            "select            policy={} k={} alpha={:.1e} rounds={} peak round {}",
            cfg.scan.select_policy.name(),
            cfg.scan.select_k,
            cfg.scan.select_alpha,
            res.metrics.select_rounds,
            human_bytes(res.metrics.bytes_max_select_round)
        );
        match &res.select {
            Some(sel) => {
                for round in &sel.rounds {
                    for (lane, pick) in round.picks.iter().enumerate() {
                        let Some(p) = pick else { continue };
                        let is_causal = cohort.truth.causal_idx.contains(&p.variant);
                        println!(
                            "  round {} lane {lane}: variant {:>6} (trait {}) beta={:+.4} p={:.3e}{}",
                            round.round,
                            p.variant,
                            p.trait_idx,
                            p.beta,
                            p.p,
                            if is_causal { "  [causal]" } else { "" }
                        );
                    }
                }
                if sel.rounds.is_empty() {
                    println!("  (no variant passed the entry threshold)");
                }
            }
            None => println!("  (empty candidate shortlist — nothing to select)"),
        }
    }

    // parity oracle: exact bit-pattern fingerprint of the full output,
    // compared against the daemon path by the e2e smoke
    let result_fp = format!("{:016x}", result_fingerprint(&res.output, res.select.as_ref()));
    println!("result_fp         {result_fp}");

    if let Some(path) = a.get("report") {
        if !path.is_empty() {
            let mut rep = dash::util::json::Json::obj();
            rep.set("config", cfg.to_json())
                .set("result_fp", result_fp.as_str())
                .set("bytes_total", res.metrics.bytes_total)
                .set("bytes_result", res.metrics.bytes_result)
                .set("compress_wall_s", res.metrics.compress_wall_s)
                .set("combine_s", res.metrics.combine_s)
                .set("total_s", res.metrics.total_s)
                .set("shards", res.metrics.shards)
                .set("traits", cohort.t())
                .set("bytes_max_round", res.metrics.bytes_max_round)
                .set("n_hits", hits.len())
                .set("min_p", res.output.min_p_value().unwrap_or(f64::NAN));
            if cfg.scan.select_k > 0 {
                rep.set("select_rounds", res.metrics.select_rounds)
                    .set("bytes_select", res.metrics.bytes_select)
                    .set("bytes_max_select_round", res.metrics.bytes_max_select_round);
                if let Some(sel) = &res.select {
                    // one list per lane, so per-trait selections stay
                    // attributable (lanes may pick the same variant)
                    let selected: Vec<Vec<usize>> =
                        (0..sel.lanes()).map(|lane| sel.selected(lane)).collect();
                    rep.set("selected", selected);
                }
            }
            std::fs::write(path, rep.to_pretty())?;
            eprintln!("report written to {path}");
        }
    }
    Ok(())
}

/// `scan --sessions N`: run N multiplexed sessions over one shared
/// connection pair per party through the SessionManager.
fn run_scan_sessions(cfg: &RunConfig, report: Option<&str>) -> anyhow::Result<()> {
    use dash::coordinator::{run_session_batch, BatchOptions, SessionSpec};

    let cohort = generate_cohort(&cfg.cohort, cfg.seed);
    let transport = cfg.transport;
    eprintln!(
        "running {} multiplexed sessions (max {} concurrent): backend={} transport={:?} \
         artifacts={}",
        cfg.sessions,
        cfg.max_concurrent,
        cfg.scan.backend.name(),
        transport,
        cfg.scan.use_artifacts
    );
    let specs: Vec<SessionSpec> = (0..cfg.sessions)
        .map(|i| SessionSpec { cfg: cfg.scan.clone(), seed: cfg.seed.wrapping_add(i as u64) })
        .collect();
    let threads_before = dash::net::transport_driver_threads();
    let batch = run_session_batch(
        &cohort,
        &specs,
        &BatchOptions {
            transport,
            max_concurrent: cfg.max_concurrent,
            ..Default::default()
        },
    )?;
    let driver_threads = dash::net::transport_driver_threads() - threads_before;

    println!("== dash scan --sessions ==");
    println!("parties           {}", cohort.parties.len());
    println!("samples (N)       {}", cohort.n_total());
    println!("variants (M)      {}", cohort.m());
    println!("traits (T)        {}", cohort.t());
    println!("backend           {}", cfg.scan.backend.name());
    println!("sessions          {} (max {} concurrent)", cfg.sessions, cfg.max_concurrent);
    println!(
        "{:>8} {:>8} {:>10} {:>14} {:>8} {:>8}",
        "session", "status", "total_s", "bytes", "shards", "select"
    );
    let mut failures = 0usize;
    for (i, run) in batch.runs.iter().enumerate() {
        match run {
            Ok(r) => println!(
                "{:>8} {:>8} {:>10.4} {:>14} {:>8} {:>8}",
                i + 1,
                "ok",
                r.metrics.total_s,
                human_bytes(r.metrics.bytes_total),
                r.metrics.shards,
                r.metrics.select_rounds
            ),
            Err(e) => {
                failures += 1;
                println!("{:>8} {:>8}  {e:#}", i + 1, "FAILED");
            }
        }
    }
    let conn_total: u64 = batch.conn_bytes.iter().sum();
    println!("wall time         {}", human_secs(batch.wall_s));
    println!("throughput        {:.2} sessions/s", cfg.sessions as f64 / batch.wall_s);
    println!("shared-conn bytes {}", human_bytes(conn_total));
    println!(
        "transport threads {driver_threads} ({})",
        dash::config::transport_name(transport)
    );
    println!("party serve ok/err {} / {}", batch.served, batch.failed);
    if cfg.scan.use_artifacts {
        let lowered: u64 = batch.party_kernels.iter().map(|k| k.lowered_entries()).sum();
        let hits: u64 = batch.party_kernels.iter().map(|k| k.cache_hits()).sum();
        println!(
            "artifact suite    entries={lowered} cache-hits={hits} (one engine per party, \
             shared across sessions)"
        );
    }
    if let Some(path) = report {
        let mut rep = dash::util::json::Json::obj();
        rep.set("config", cfg.to_json())
            .set("sessions", cfg.sessions)
            .set("max_concurrent", cfg.max_concurrent)
            .set("wall_s", batch.wall_s)
            .set("sessions_per_s", cfg.sessions as f64 / batch.wall_s)
            .set("conn_bytes_total", conn_total)
            .set("driver_threads", driver_threads)
            .set("served", batch.served)
            .set("failed", batch.failed);
        let rows: Vec<dash::util::json::Json> = batch
            .runs
            .iter()
            .enumerate()
            .map(|(i, run)| {
                let mut row = dash::util::json::Json::obj();
                row.set("session", i + 1);
                match run {
                    Ok(r) => {
                        let fp = result_fingerprint(&r.output, r.select.as_ref());
                        row.set("ok", true)
                            .set("total_s", r.metrics.total_s)
                            .set("bytes_total", r.metrics.bytes_total)
                            .set("shards", r.metrics.shards)
                            .set("select_rounds", r.metrics.select_rounds)
                            .set("result_fp", format!("{fp:016x}"));
                    }
                    Err(e) => {
                        row.set("ok", false).set("error", format!("{e:#}"));
                    }
                }
                row
            })
            .collect();
        rep.set("runs", dash::util::json::Json::Arr(rows));
        std::fs::write(path, rep.to_pretty())?;
        eprintln!("report written to {path}");
    }
    anyhow::ensure!(failures == 0, "{failures} session(s) failed");
    Ok(())
}

fn cmd_regress(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("regress", "multi-party linear regression (§2)")
        .opt("parties", "3", "number of parties")
        .opt("n", "3000", "total samples")
        .opt("seed", "7", "rng seed");
    let a = cmd.parse(raw)?;
    let parties = a.get_usize("parties")?;
    let n = a.get_usize("n")?;
    let mut spec = CohortSpec::default_small();
    spec.party_sizes = split_sizes(n, parties);
    spec.party_admixture = vec![0.5; parties];
    spec.m_variants = 1;
    spec.n_causal = 0;
    let cohort = generate_cohort(&spec, a.get_u64("seed")?);
    let cps: Vec<_> = cohort
        .parties
        .iter()
        .map(|p| dash::scan::compress_party(&p.ys, &p.c, &p.x, 1, None))
        .collect();
    let fits = combine_regression(&cps)?;
    let fit = &fits[0];
    println!("== dash regress ==  N={} K={}", cohort.n_total(), cohort.k());
    println!("{:>4} {:>12} {:>12} {:>10} {:>12}", "k", "gamma", "se", "t", "p");
    for i in 0..fit.gamma.len() {
        println!(
            "{:>4} {:>12.5} {:>12.5} {:>10.3} {:>12.3e}",
            i, fit.gamma[i], fit.se[i], fit.t[i], fit.p[i]
        );
    }
    println!("tau^2 = {:.5}   df = {}", fit.tau2, fit.df);
    Ok(())
}

fn cmd_bench_comm(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("bench-comm", "communication scaling rows (E4)")
        .opt("parties", "3", "number of parties")
        .opt("n", "600", "total samples")
        .opt("ms", "250,500,1000,2000", "comma-separated variant counts")
        .opt("backend", "masked", "SMC backend")
        .opt("seed", "7", "rng seed");
    let a = cmd.parse(raw)?;
    let parties = a.get_usize("parties")?;
    let n = a.get_usize("n")?;
    let ms: Vec<usize> = a
        .get("ms")
        .unwrap()
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()?;
    println!(
        "{:>8} {:>14} {:>14} {:>12}",
        "M", "bytes_total", "bytes/variant", "result_bytes"
    );
    for &m in &ms {
        let mut spec = CohortSpec::default_small();
        spec.party_sizes = split_sizes(n, parties);
        spec.party_admixture = vec![0.5; parties];
        spec.m_variants = m;
        spec.n_causal = spec.n_causal.min(m);
        let cohort = generate_cohort(&spec, a.get_u64("seed")?);
        let mut scan_cfg = dash::scan::ScanConfig::default();
        scan_cfg.backend = Backend::parse(a.get("backend").unwrap(), parties)?;
        let res = run_multi_party_scan_t(&cohort, &scan_cfg, Transport::InProc, 11)?;
        println!(
            "{:>8} {:>14} {:>14.1} {:>12}",
            m,
            res.metrics.bytes_total,
            res.metrics.bytes_total as f64 / m as f64,
            res.metrics.bytes_result
        );
    }
    Ok(())
}

fn cmd_artifacts(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("artifacts", "inspect the artifact kernel suite")
        .opt("dir", "artifacts", "artifact directory")
        .opt("exec", "auto", "artifact executor: auto|pjrt|reference");
    let a = cmd.parse(raw)?;
    let opts = dash::runtime::EngineOptions {
        dir: a.get("dir").unwrap().to_string(),
        exec: dash::runtime::ArtifactExec::parse(a.get("exec").unwrap())?,
        ..Default::default()
    };
    let engine = dash::runtime::Engine::open(&opts)?;
    println!("platform    {}", engine.platform());
    let policy = engine.policy();
    println!("widths      {:?}", policy.widths);
    println!("traits      {:?}", policy.trait_batches);
    println!("k_pad       {}", policy.k_pad);
    match &engine.manifest {
        Some(m) => {
            println!("n_block     {}", m.n_block);
            println!("m_block     {}", m.m_block);
            println!("compiled artifact entries:");
            for (name, file) in &m.entries {
                println!("  {name:<22} {file}");
            }
        }
        None => {
            println!("no compiled artifact set — reference executor suite:");
            for key in policy.suite() {
                println!("  {}", key.entry_name());
            }
        }
    }
    Ok(())
}

fn cmd_serve(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "run the scan-as-a-service leader daemon")
        .opt("listen", "127.0.0.1:8787", "listen address (host:port; port 0 = ephemeral)")
        .opt("max-jobs", "2", "worker pool size — jobs running concurrently")
        .opt("queue", "4", "jobs allowed to wait behind the pool before submits get 429")
        .opt("max-jobs-per-tenant", "2", "active (queued + running) jobs per tenant")
        .opt("retry-after", "1", "Retry-After seconds attached to 429 rejections")
        .opt(
            "checkpoint-dir",
            "",
            "per-job checkpoint root: job i snapshots under job-{i}/, removed when the \
             job settles; orphans are swept at startup (empty = checkpointing off)",
        );
    let a = cmd.parse(raw)?;
    let opts = DaemonOptions {
        listen: a.get("listen").unwrap().to_string(),
        max_jobs: a.get_usize("max-jobs")?,
        queue_cap: a.get_usize("queue")?,
        max_jobs_per_tenant: a.get_usize("max-jobs-per-tenant")?,
        retry_after_s: a.get_u64("retry-after")?,
        checkpoint_root: a.get("checkpoint-dir").unwrap().to_string(),
    };
    let daemon = Daemon::start(opts)?;
    // the e2e smoke parses this line to learn the ephemeral port
    println!("dash daemon listening on {}", daemon.addr());
    use std::io::Write as _;
    std::io::stdout().flush()?;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_jobs(raw: &[String]) -> anyhow::Result<()> {
    let (action, rest) = match raw.split_first() {
        Some((s, r)) if !s.starts_with('-') => (s.as_str(), r.to_vec()),
        _ => anyhow::bail!("usage: dash jobs <submit|status|result|cancel|health> [options]"),
    };
    let cmd = Command::new("jobs", "client for a running dash daemon")
        .opt("addr", "127.0.0.1:8787", "daemon address")
        .opt("config", "", "run-config JSON file to submit (defaults apply when empty)")
        .opt("tenant", "anon", "tenant name for admission quotas")
        .opt("id", "0", "job id (status|result|cancel)")
        .opt("poll-ms", "100", "poll interval for --wait")
        .flag("wait", "submit: poll until the job settles, then fetch and print the result");
    let a = cmd.parse(&rest)?;
    let addr = a.get("addr").unwrap().to_string();
    match action {
        "health" => {
            let r = http_request(&addr, "GET", "/healthz", None)?;
            anyhow::ensure!(r.status == 200, "daemon unhealthy: HTTP {}", r.status);
            println!("{}", r.json_body()?.to_pretty());
            Ok(())
        }
        "submit" => {
            let mut body = Json::obj();
            body.set("tenant", a.get("tenant").unwrap());
            if let Some(path) = a.get("config").filter(|p| !p.is_empty()) {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("cannot read config {path}: {e}"))?;
                body.set("config", Json::parse(&text)?);
            }
            let r = http_request(&addr, "POST", "/jobs", Some(body.to_string().as_bytes()))?;
            let v = r.json_body()?;
            anyhow::ensure!(
                r.status == 201,
                "submit rejected: HTTP {} {}",
                r.status,
                v.to_string()
            );
            let id = v
                .get("job")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("daemon response carries no job id"))?;
            println!("job {id}");
            if a.flag("wait") {
                let poll = a.get_u64("poll-ms")?.max(10);
                loop {
                    let r = http_request(&addr, "GET", &format!("/jobs/{id}"), None)?;
                    anyhow::ensure!(r.status == 200, "status poll failed: HTTP {}", r.status);
                    let v = r.json_body()?;
                    let st = v.get("status").and_then(Json::as_str).unwrap_or("?").to_string();
                    if st != "queued" && st != "running" {
                        anyhow::ensure!(
                            st == "done",
                            "job {id} settled as {st}: {}",
                            v.get("error").and_then(Json::as_str).unwrap_or("(no detail)")
                        );
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(poll));
                }
                let r = http_request(&addr, "GET", &format!("/jobs/{id}/result"), None)?;
                anyhow::ensure!(r.status == 200, "result fetch failed: HTTP {}", r.status);
                print_job_result(&r.json_body()?);
            }
            Ok(())
        }
        "status" => {
            let id = a.get_u64("id")?;
            let r = http_request(&addr, "GET", &format!("/jobs/{id}"), None)?;
            println!("{}", r.json_body()?.to_pretty());
            anyhow::ensure!(r.status == 200, "HTTP {}", r.status);
            Ok(())
        }
        "result" => {
            let id = a.get_u64("id")?;
            let r = http_request(&addr, "GET", &format!("/jobs/{id}/result"), None)?;
            let v = r.json_body()?;
            anyhow::ensure!(r.status == 200, "no result: HTTP {} {}", r.status, v.to_string());
            print_job_result(&v);
            Ok(())
        }
        "cancel" => {
            let id = a.get_u64("id")?;
            let r = http_request(&addr, "DELETE", &format!("/jobs/{id}"), None)?;
            println!("{}", r.json_body()?.to_string());
            anyhow::ensure!(r.status < 300, "HTTP {}", r.status);
            Ok(())
        }
        other => {
            anyhow::bail!("unknown jobs action `{other}` (submit|status|result|cancel|health)")
        }
    }
}

/// Shape summary plus the parity fingerprint. The `result_fp` line is
/// what the e2e smoke compares against a one-shot `dash scan`.
fn print_job_result(v: &Json) {
    let g = |k: &str| v.get(k).and_then(Json::as_usize).unwrap_or(0);
    println!(
        "job {} session {}: N={} K={} M={} T={}",
        g("job"),
        g("session"),
        g("n"),
        g("k"),
        g("m"),
        g("traits")
    );
    if let Some(sel) = v.get("select") {
        println!(
            "select lanes {} selected {}",
            sel.get("lanes").and_then(Json::as_usize).unwrap_or(0),
            sel.get("selected").map(|s| s.to_string()).unwrap_or_default()
        );
    }
    println!("result_fp {}", v.get("result_fp").and_then(Json::as_str).unwrap_or("?"));
}

fn split_sizes(n: usize, parties: usize) -> Vec<usize> {
    assert!(parties > 0);
    let base = n / parties;
    let extra = n % parties;
    (0..parties).map(|i| base + usize::from(i < extra)).collect()
}
