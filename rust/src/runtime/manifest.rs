//! Artifact manifest: block geometry + entry-point file map.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_block: usize,
    pub m_block: usize,
    pub k_pad: usize,
    pub dtype: String,
    /// entry name → HLO text file (relative to `dir`)
    pub entries: BTreeMap<String, String>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let v = Json::parse(&text)?;
        let n_block = v.req_usize("n_block")?;
        let m_block = v.req_usize("m_block")?;
        let k_pad = v.req_usize("k_pad")?;
        let dtype = v.req_str("dtype")?.to_string();
        anyhow::ensure!(dtype == "f64", "runtime expects f64 artifacts, got {dtype}");
        let mut entries = BTreeMap::new();
        match v.get("entries") {
            Some(Json::Obj(m)) => {
                for (k, val) in m {
                    let fname = val
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("entry `{k}` not a string"))?;
                    let fpath = dir.join(fname);
                    anyhow::ensure!(fpath.exists(), "missing artifact {}", fpath.display());
                    entries.insert(k.clone(), fname.to_string());
                }
            }
            _ => anyhow::bail!("manifest missing `entries` object"),
        }
        for required in ["compress_x", "compress_yc", "scan_stats"] {
            anyhow::ensure!(entries.contains_key(required), "manifest missing entry `{required}`");
        }
        Ok(Manifest { dir, n_block, m_block, k_pad, dtype, entries })
    }

    pub fn entry_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        self.entries
            .get(name)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no artifact entry `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path, manifest: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dash-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"c.txt"}}"#,
            &["a.txt", "b.txt", "c.txt"],
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.n_block, 512);
        assert_eq!(m.m_block, 256);
        assert_eq!(m.k_pad, 16);
        assert!(m.entry_path("compress_x").unwrap().ends_with("a.txt"));
        assert!(m.entry_path("nope").is_err());
    }

    #[test]
    fn rejects_missing_file() {
        let d = tmpdir("missing");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"gone.txt"}}"#,
            &["a.txt", "b.txt"],
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let d = tmpdir("dtype");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f32","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"c.txt"}}"#,
            &["a.txt", "b.txt", "c.txt"],
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(tmpdir("nodir")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
