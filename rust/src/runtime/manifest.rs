//! Artifact manifest: block geometry + entry-point file map.
//!
//! Two generations of artifact sets are accepted:
//!
//! - **suite manifests** (current): parameterized entries named by their
//!   canonical shape — `compress_xy.t{T}`, `compress_x.w{W}.t{T}`,
//!   `select_gather.h{H}` — plus optional `widths`/`trait_batches`
//!   arrays recording the shape-policy ladder they were lowered for;
//! - **legacy manifests**: the fixed `compress_x`/`compress_yc`/
//!   `scan_stats` trio. The engine serves suite dispatches that a legacy
//!   set lacks from the reference executor.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_block: usize,
    pub m_block: usize,
    pub k_pad: usize,
    pub dtype: String,
    /// canonical shard widths the suite was lowered for (suite manifests)
    pub widths: Option<Vec<usize>>,
    /// canonical trait batches the suite was lowered for (suite manifests)
    pub trait_batches: Option<Vec<usize>>,
    /// entry name → HLO text file (relative to `dir`)
    pub entries: BTreeMap<String, String>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let v = Json::parse(&text)?;
        let n_block = v.req_usize("n_block")?;
        let m_block = v.req_usize("m_block")?;
        let k_pad = v.req_usize("k_pad")?;
        let dtype = v.req_str("dtype")?.to_string();
        anyhow::ensure!(dtype == "f64", "runtime expects f64 artifacts, got {dtype}");
        let widths = parse_ladder(&v, "widths")?;
        let trait_batches = parse_ladder(&v, "trait_batches")?;
        let mut entries = BTreeMap::new();
        match v.get("entries") {
            Some(Json::Obj(m)) => {
                for (k, val) in m {
                    let fname = val
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("entry `{k}` not a string"))?;
                    let fpath = dir.join(fname);
                    anyhow::ensure!(fpath.exists(), "missing artifact {}", fpath.display());
                    entries.insert(k.clone(), fname.to_string());
                }
            }
            _ => anyhow::bail!("manifest missing `entries` object"),
        }
        let legacy = ["compress_x", "compress_yc", "scan_stats"]
            .iter()
            .all(|r| entries.contains_key(*r));
        let suite = entries
            .keys()
            .any(|k| k.starts_with("compress_xy.") || k.starts_with("compress_x.w"));
        anyhow::ensure!(
            legacy || suite,
            "manifest carries neither the legacy entry trio nor any \
             parameterized suite entry (re-run `make artifacts`)"
        );
        Ok(Manifest { dir, n_block, m_block, k_pad, dtype, widths, trait_batches, entries })
    }

    pub fn entry_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        self.entry_path_opt(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact entry `{name}`"))
    }

    /// Path of an entry, `None` when the artifact set does not carry it
    /// (the engine falls back to the reference executor).
    pub fn entry_path_opt(&self, name: &str) -> Option<PathBuf> {
        self.entries.get(name).map(|f| self.dir.join(f))
    }
}

fn parse_ladder(v: &Json, key: &str) -> anyhow::Result<Option<Vec<usize>>> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Arr(a)) => {
            let ladder: Vec<usize> = a
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("non-numeric element in {key}"))
                })
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(!ladder.is_empty(), "{key} must be non-empty");
            Ok(Some(ladder))
        }
        _ => anyhow::bail!("{key} must be an array"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake(dir: &Path, manifest: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dash-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_legacy_manifest() {
        let d = tmpdir("ok");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"c.txt"}}"#,
            &["a.txt", "b.txt", "c.txt"],
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.n_block, 512);
        assert_eq!(m.m_block, 256);
        assert_eq!(m.k_pad, 16);
        assert!(m.widths.is_none());
        assert!(m.entry_path("compress_x").unwrap().ends_with("a.txt"));
        assert!(m.entry_path("nope").is_err());
        assert!(m.entry_path_opt("compress_x.w64.t1").is_none());
    }

    #[test]
    fn loads_suite_manifest() {
        let d = tmpdir("suite");
        write_fake(
            &d,
            r#"{"version":2,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "widths":[64,256],"trait_batches":[1,16],
                "entries":{"compress_xy.t1":"xy1.txt","compress_x.w64.t1":"x641.txt",
                           "select_gather.h64":"sg64.txt"}}"#,
            &["xy1.txt", "x641.txt", "sg64.txt"],
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.widths.as_deref(), Some(&[64, 256][..]));
        assert_eq!(m.trait_batches.as_deref(), Some(&[1, 16][..]));
        assert!(m.entry_path_opt("compress_x.w64.t1").is_some());
        assert!(m.entry_path_opt("compress_x.w256.t16").is_none());
    }

    #[test]
    fn rejects_entryless_manifest() {
        let d = tmpdir("noentries");
        write_fake(
            &d,
            r#"{"version":2,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"something_else":"a.txt"}}"#,
            &["a.txt"],
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_missing_file() {
        let d = tmpdir("missing");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f64","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"gone.txt"}}"#,
            &["a.txt", "b.txt"],
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let d = tmpdir("dtype");
        write_fake(
            &d,
            r#"{"version":1,"dtype":"f32","n_block":512,"m_block":256,"k_pad":16,
                "entries":{"compress_x":"a.txt","compress_yc":"b.txt","scan_stats":"c.txt"}}"#,
            &["a.txt", "b.txt", "c.txt"],
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(tmpdir("nodir")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
