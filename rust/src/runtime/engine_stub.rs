//! Stub artifact engine, compiled when the `xla-runtime` feature is off.
//!
//! Presents the same API as the real PJRT-backed engine so callers,
//! benches, and tests compile unchanged; `load` always fails with an
//! explanatory error, and every caller already treats a failed load as
//! "artifacts unavailable — use the pure-Rust compute path". SELECT
//! rounds never dispatch here at all: their `O(H)` gathered-column and
//! cross-product kernels run pure-Rust in both compute backends (see
//! `runtime/engine.rs`).

use super::manifest::Manifest;
use crate::linalg::Matrix;
use crate::scan::CompressedParty;
use crate::stats::AssocResult;
use std::path::Path;

/// Artifact engine stub (build lacks the `xla-runtime` feature).
pub struct Engine {
    pub manifest: Manifest,
}

impl Engine {
    /// Always fails: this build has no PJRT client. The manifest is
    /// still validated first so configuration errors surface the same
    /// way in both builds.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let _manifest = Manifest::load(dir)?;
        anyhow::bail!(
            "artifact runtime unavailable: dash was built without the \
             `xla-runtime` feature (rebuild with `--features xla-runtime` \
             after adding the `xla` crate to rust/Cargo.toml)"
        )
    }

    pub fn entry_count(&self) -> usize {
        0
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Unreachable in practice — `load` never returns an `Engine`.
    /// `ys` is the `N × T` trait matrix, matching the real engine.
    pub fn compress_party(
        &self,
        _ys: &Matrix,
        _c: &Matrix,
        _x: &Matrix,
    ) -> anyhow::Result<CompressedParty> {
        anyhow::bail!("artifact runtime unavailable (xla-runtime feature off)")
    }

    /// Unreachable in practice — `load` never returns an `Engine`.
    #[allow(clippy::too_many_arguments)]
    pub fn scan_stats(
        &self,
        _n: usize,
        _k: usize,
        _yty: f64,
        _xty: &[f64],
        _xtx: &[f64],
        _qty: &[f64],
        _qtx: &Matrix,
    ) -> anyhow::Result<AssocResult> {
        anyhow::bail!("artifact runtime unavailable (xla-runtime feature off)")
    }
}
