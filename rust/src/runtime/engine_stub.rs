//! Artifact engine for builds without the `xla-runtime` feature.
//!
//! Signature-parallel with the PJRT-backed engine so callers, benches,
//! and tests compile unchanged. [`Engine::load`] (the PJRT entry point)
//! still fails with an explanatory error — callers treating a failed
//! load as "compiled artifacts unavailable" keep working — but
//! [`Engine::open`] with [`ArtifactExec::Auto`]/[`ArtifactExec::Reference`]
//! returns a fully functional engine driven by the pure-Rust reference
//! executor ([`RefExec`]), which executes the parameterized kernel suite
//! under the identical padding/canonical-shape contract and is
//! bit-identical to the streaming Rust kernels (the conformance-matrix
//! anchor).

use super::kernels::{ArtifactExec, EngineOptions, KernelMeter, PassKind, RefExec, ShapePolicy};
use super::manifest::Manifest;
use crate::linalg::{householder_qr, Matrix};
use crate::scan::{BaseStats, CompressedParty, VariantBlockStats};
use crate::stats::{scan_stats_from_projected_parts, AssocResult};
use std::path::Path;

/// Artifact engine (reference executor only in this build).
pub struct Engine {
    /// manifest of a compiled artifact set, when one was present
    pub manifest: Option<Manifest>,
    exec: RefExec,
}

impl Engine {
    /// PJRT entry point — always fails in this build. The manifest is
    /// still validated first so configuration errors surface the same
    /// way in both builds.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let _manifest = Manifest::load(dir)?;
        anyhow::bail!(
            "artifact runtime unavailable: dash was built without the \
             `xla-runtime` feature (rebuild with `--features xla-runtime` \
             after adding the `xla` crate to rust/Cargo.toml, or use \
             `--artifact-exec reference`)"
        )
    }

    /// Open an engine per the requested executor. `Pjrt` fails in this
    /// build; `Auto` and `Reference` return the reference engine.
    pub fn open(opts: &EngineOptions) -> anyhow::Result<Engine> {
        match opts.exec {
            ArtifactExec::Pjrt => Self::load(&opts.dir),
            ArtifactExec::Auto | ArtifactExec::Reference => {
                // a manifest is optional for the reference executor; use
                // its geometry when present so both executors agree
                let manifest = Manifest::load(&opts.dir).ok();
                let mut policy = opts.policy.clone();
                if let Some(m) = &manifest {
                    policy.k_pad = policy.k_pad.max(m.k_pad);
                }
                Ok(Engine {
                    manifest,
                    exec: RefExec::new(policy, opts.meter.clone(), opts.threads)?,
                })
            }
        }
    }

    /// Reference engine with an explicit policy (tests/benches).
    pub fn reference(policy: ShapePolicy, meter: KernelMeter) -> anyhow::Result<Engine> {
        Ok(Engine { manifest: None, exec: RefExec::new(policy, meter, None)? })
    }

    /// Entries lowered (planned) so far.
    pub fn entry_count(&self) -> usize {
        self.exec.lowered_count()
    }

    pub fn platform(&self) -> String {
        "reference".to_string()
    }

    /// Shared kernel-suite telemetry.
    pub fn meter(&self) -> KernelMeter {
        self.exec.meter()
    }

    pub fn policy(&self) -> &ShapePolicy {
        self.exec.policy()
    }

    /// Variant-independent statistics through the trait-batched
    /// `compress_xy` entry. `R_p` (plaintext-mode TSQR input only) is a
    /// host-side `O(N_p K²)` factorization, not part of the lowered
    /// suite.
    pub fn compress_base(&self, ys: &Matrix, c: &Matrix) -> anyhow::Result<BaseStats> {
        let (yty, cty, ctc) = self.exec.compress_xy(ys, c)?;
        Ok(BaseStats { n: ys.rows, yty, cty, ctc, r: householder_qr(c).r })
    }

    /// One shard's variant statistics through the shard-width-
    /// parameterized `compress_x` entry — a single X-side pass covering
    /// all `T` traits, `O(shard_m·N_p)` resident block memory.
    pub fn compress_shard(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<VariantBlockStats> {
        self.exec.compress_x(ys, c, x, j0, j1, PassKind::Scan)
    }

    /// SELECT candidate round: gathered-shortlist statistics through the
    /// same `compress_x` entry family (accounted as a SELECT pass).
    pub fn compress_gathered(
        &self,
        ys: &Matrix,
        c: &Matrix,
        xs: &Matrix,
    ) -> anyhow::Result<VariantBlockStats> {
        self.exec.compress_x(ys, c, xs, 0, xs.cols, PassKind::Select)
    }

    /// IRLS base entry (logistic scans): one weighted covariate-side
    /// pass per secure IRLS round. The IRLS kernels have no lowered
    /// artifact — the logistic protocol requires **bit-identical**
    /// accumulation across compute modes, so both builds always serve
    /// them from the reference executor.
    pub fn compress_irls_base(
        &self,
        ys: &Matrix,
        c: &Matrix,
        beta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        self.exec.compress_irls_base(ys, c, beta)
    }

    /// IRLS weighted shard pass at the final β̂ (reference executor in
    /// both builds; see [`Self::compress_irls_base`]).
    pub fn compress_irls_shard(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        beta: &[f64],
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<Vec<f64>> {
        self.exec.compress_irls_shard(ys, c, x, beta, j0, j1)
    }

    /// SELECT promote round: the gathered-columns cross-product entry.
    pub fn cross_products(
        &self,
        x: &Matrix,
        j: usize,
        xs: &Matrix,
    ) -> anyhow::Result<Vec<f64>> {
        self.exec.select_gather(x, j, xs)
    }

    /// Whole-block compress (benches / single-shot callers): the base
    /// entry plus one full-width shard entry.
    pub fn compress_party(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
    ) -> anyhow::Result<CompressedParty> {
        let base = self.compress_base(ys, c)?;
        let vb = self.compress_shard(ys, c, x, 0, x.cols)?;
        Ok(CompressedParty {
            n: base.n,
            yty: base.yty,
            cty: base.cty,
            ctc: base.ctc,
            r: base.r,
            xty: vb.xty,
            xtx: vb.xtx,
            ctx: vb.ctx,
        })
    }

    /// Lemma 3.1 epilogue on aggregates (reference implementation — the
    /// PJRT build serves this from the `scan_stats` artifact).
    #[allow(clippy::too_many_arguments)]
    pub fn scan_stats(
        &self,
        n: usize,
        k: usize,
        yty: f64,
        xty: &[f64],
        xtx: &[f64],
        qty: &[f64],
        qtx: &Matrix,
    ) -> anyhow::Result<AssocResult> {
        let m = xty.len();
        anyhow::ensure!(
            xtx.len() == m && qtx.cols == m && qtx.rows == k && qty.len() == k,
            "scan_stats shape mismatch"
        );
        Ok(scan_stats_from_projected_parts(n, k, yty, xty, xtx, qty, qtx))
    }
}
