//! The parameterized artifact kernel suite: entry keys, shape policy,
//! lowering-cache telemetry, and the reference executor.
//!
//! The AOT engine no longer serves two fixed whole-`M` entries
//! (`compress_yc`/`compress_x`). Instead every artifact dispatch is keyed
//! by an [`EntryKey`] `(kind, shard_w, n_traits)`:
//!
//! - [`KernelKind::CompressXy`] — the trait-batched covariate-side entry:
//!   takes the whole `N × T` trait matrix and produces
//!   `YᵀY (T), CᵀY (K×T), CᵀC` in one pass, instead of looping `T`
//!   single-trait runs;
//! - [`KernelKind::CompressX`] — the shard-width-parameterized
//!   variant-side entry: takes one `N × w` column shard and produces
//!   `XᵀY (w×T), X·X (w), CᵀX (K×w)`, so artifact-mode parties lower and
//!   execute **per shard** with no transient whole-`M` materialization
//!   (peak resident block memory is `O(shard_m·N_p)`, matching the
//!   pure-Rust streaming path);
//! - [`KernelKind::SelectGather`] — the gathered-columns SELECT entry:
//!   one promoted column's cross-products against the `H` shortlisted
//!   columns, the `O(N_p·H)` kernel of a stepwise promote round;
//! - [`KernelKind::CompressIrls`] — the secure-logistic entries: the
//!   width-free weighted covariate-side pass re-executed every IRLS
//!   round (`CᵀWC, CᵀWz, dev` per trait at the broadcast `β`), and the
//!   shard-width-parameterized weighted score pass at the final `β̂`.
//!   These are served by the reference executor in every build (no
//!   lowered PJRT entry): the logistic protocol leans on bit-identical
//!   accumulation across compute modes.
//!
//! ## Shape policy
//!
//! Lowered entries have static shapes, so a [`ShapePolicy`] rounds every
//! requested `(shard_w, n_traits)` up to a small ladder of canonical
//! shapes (`--entry-widths` / `--entry-traits`): ragged tail shards and
//! odd trait counts are zero-padded into the nearest canonical entry and
//! the padded lanes sliced away — exact, because every statistic is a sum
//! of per-sample products and zero rows/columns contribute nothing. The
//! ladder bounds the lowering cache at a handful of compiled entries per
//! session no matter how ragged the shard plan is.
//!
//! ## Executors
//!
//! Two executors serve the suite. The PJRT executor (feature
//! `xla-runtime`) compiles HLO artifacts and matches the Rust kernels to
//! fp tolerance. The **reference executor** (this module, always
//! available) executes the identical padding/blocking contract in pure
//! Rust with the *same per-element accumulation order* as the streaming
//! kernels in [`crate::scan::compressed`] — so artifact-mode sessions
//! driven by it are **bit-identical** to Rust-mode sessions, which is the
//! anchor the cross-backend conformance matrix (`tests/conformance.rs`)
//! asserts. Telemetry ([`KernelMeter`]) records lowering-cache behavior,
//! per-kind pass counts, and peak resident padded-block bytes, shared
//! with the session plumbing the way [`crate::net::ByteMeter`] is.

use crate::linalg::Matrix;
use crate::scan::{
    canonical_tile_rows, compress_irls_base as irls_base_kernel,
    compress_irls_shard as irls_shard_kernel, compress_variant_block_opts, compress_yside,
    cross_products, VariantBlockStats,
};
use crate::util::threadpool::effective_threads;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which kernel an artifact entry implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelKind {
    /// Trait-batched covariate-side compress: `Y, C → YᵀY, CᵀY, CᵀC`.
    CompressXy,
    /// Shard-width-parameterized variant-side compress:
    /// `Y, C, X_shard → XᵀY, X·X, CᵀX`.
    CompressX,
    /// Gathered-columns SELECT cross-products: `x_j, X_S → x_jᵀX_S`.
    SelectGather,
    /// Secure-IRLS weighted compress (logistic scans): the width-free
    /// base entry `Y, C, β → CᵀWC, CᵀWz, dev` re-executed every IRLS
    /// round, and the shard-width-parameterized weighted pass
    /// `Y, C, X_shard, β̂ → Xᵀ(y−μ̂), diag XᵀWX, CᵀWX` at the final β.
    CompressIrls,
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::CompressXy => "compress_xy",
            KernelKind::CompressX => "compress_x",
            KernelKind::SelectGather => "select_gather",
            KernelKind::CompressIrls => "compress_irls",
        }
    }
}

/// Cache key of one lowered artifact entry. `shard_w` is the canonical
/// variant-column width (0 for the width-free `CompressXy`); `n_traits`
/// the canonical trait-batch width (1 for `SelectGather`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct EntryKey {
    pub kind: KernelKind,
    pub shard_w: usize,
    pub n_traits: usize,
}

impl EntryKey {
    /// Manifest/file name of this entry (`compress_x.w64.t16`,
    /// `compress_xy.t4`, `select_gather.h256`).
    pub fn entry_name(&self) -> String {
        match self.kind {
            KernelKind::CompressXy => format!("compress_xy.t{}", self.n_traits),
            KernelKind::CompressX => {
                format!("compress_x.w{}.t{}", self.shard_w, self.n_traits)
            }
            KernelKind::SelectGather => format!("select_gather.h{}", self.shard_w),
            // width-free base entry when shard_w == 0 (the per-round
            // IRLS pass), width-parameterized weighted shard pass else
            KernelKind::CompressIrls if self.shard_w == 0 => {
                format!("compress_irls.t{}", self.n_traits)
            }
            KernelKind::CompressIrls => {
                format!("compress_irls.w{}.t{}", self.shard_w, self.n_traits)
            }
        }
    }
}

/// Canonical entry shapes: requested widths/trait counts are rounded up
/// the ladder; requests beyond the top rung round up to a multiple of it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapePolicy {
    /// canonical shard widths (strictly ascending)
    pub widths: Vec<usize>,
    /// canonical trait-batch widths (strictly ascending)
    pub trait_batches: Vec<usize>,
    /// covariate padding (entries are lowered at `K = k_pad`)
    pub k_pad: usize,
}

impl Default for ShapePolicy {
    fn default() -> Self {
        ShapePolicy {
            widths: vec![64, 256, 1024, 4096],
            trait_batches: vec![1, 4, 16, 64],
            k_pad: 16,
        }
    }
}

impl ShapePolicy {
    pub fn validate(&self) -> anyhow::Result<()> {
        for (what, ladder) in
            [("entry widths", &self.widths), ("entry trait batches", &self.trait_batches)]
        {
            anyhow::ensure!(!ladder.is_empty(), "{what}: empty ladder");
            anyhow::ensure!(ladder[0] > 0, "{what}: zero rung");
            for w in ladder.windows(2) {
                anyhow::ensure!(w[0] < w[1], "{what}: ladder must be strictly ascending");
            }
        }
        anyhow::ensure!(self.k_pad >= 1, "k_pad must be ≥ 1");
        Ok(())
    }

    fn canon(v: usize, ladder: &[usize]) -> usize {
        match ladder.iter().find(|&&r| r >= v) {
            Some(&r) => r,
            // beyond the top rung: round up to a multiple of it, so e.g.
            // a whole-M single-shot still lowers exactly one entry
            None => {
                let top = *ladder.last().expect("validated non-empty");
                v.div_ceil(top) * top
            }
        }
    }

    /// Canonical shard width covering `w` columns.
    pub fn canon_width(&self, w: usize) -> usize {
        Self::canon(w, &self.widths)
    }

    /// Canonical trait batch covering `t` traits.
    pub fn canon_traits(&self, t: usize) -> usize {
        Self::canon(t, &self.trait_batches)
    }

    /// Canonical key for a requested dispatch shape.
    pub fn canon_key(&self, kind: KernelKind, w: usize, t: usize) -> EntryKey {
        match kind {
            KernelKind::CompressXy => {
                EntryKey { kind, shard_w: 0, n_traits: self.canon_traits(t) }
            }
            KernelKind::CompressX => EntryKey {
                kind,
                shard_w: self.canon_width(w),
                n_traits: self.canon_traits(t),
            },
            KernelKind::SelectGather => {
                EntryKey { kind, shard_w: self.canon_width(w), n_traits: 1 }
            }
            KernelKind::CompressIrls => EntryKey {
                kind,
                // w == 0 is the width-free base entry, not a zero-width
                // shard — keep it distinct from the width ladder
                shard_w: if w == 0 { 0 } else { self.canon_width(w) },
                n_traits: self.canon_traits(t),
            },
        }
    }

    /// The full pre-lowerable suite for this policy (what `make
    /// artifacts` exports; on-ladder shapes only — beyond-ladder shapes
    /// are lowered on demand).
    pub fn suite(&self) -> Vec<EntryKey> {
        let mut keys = Vec::new();
        for &t in &self.trait_batches {
            keys.push(EntryKey { kind: KernelKind::CompressXy, shard_w: 0, n_traits: t });
            keys.push(EntryKey { kind: KernelKind::CompressIrls, shard_w: 0, n_traits: t });
            for &w in &self.widths {
                keys.push(EntryKey { kind: KernelKind::CompressX, shard_w: w, n_traits: t });
                keys.push(EntryKey {
                    kind: KernelKind::CompressIrls,
                    shard_w: w,
                    n_traits: t,
                });
            }
        }
        for &w in &self.widths {
            keys.push(EntryKey { kind: KernelKind::SelectGather, shard_w: w, n_traits: 1 });
        }
        keys
    }

    /// Parse a `64,256,1024` CSV ladder (CLI/config).
    pub fn parse_ladder(s: &str, what: &str) -> anyhow::Result<Vec<usize>> {
        let v: Vec<usize> = s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("{what}: bad rung `{x}`: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!v.is_empty(), "{what}: empty ladder");
        Ok(v)
    }
}

/// Which executor serves the artifact suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ArtifactExec {
    /// PJRT when the build + artifact set allow it, reference otherwise.
    #[default]
    Auto,
    /// PJRT only — error when the `xla-runtime` feature/artifacts are
    /// unavailable.
    Pjrt,
    /// The pure-Rust reference executor (bit-identical to the streaming
    /// kernels; always available).
    Reference,
}

impl ArtifactExec {
    pub fn name(&self) -> &'static str {
        match self {
            ArtifactExec::Auto => "auto",
            ArtifactExec::Pjrt => "pjrt",
            ArtifactExec::Reference => "reference",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ArtifactExec> {
        match s {
            "auto" => Ok(ArtifactExec::Auto),
            "pjrt" => Ok(ArtifactExec::Pjrt),
            "reference" => Ok(ArtifactExec::Reference),
            other => anyhow::bail!("unknown artifact exec `{other}` (auto|pjrt|reference)"),
        }
    }
}

/// How to open an artifact [`crate::runtime::Engine`].
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// directory holding `manifest.json` (PJRT executor; optional for
    /// the reference executor)
    pub dir: String,
    pub exec: ArtifactExec,
    pub policy: ShapePolicy,
    /// shared telemetry sink (clone of the session's per-party meter)
    pub meter: KernelMeter,
    /// worker-thread budget for the executor's tiled compress kernels
    /// (None = auto). Purely a scheduling knob: the canonical tiled
    /// accumulation is bit-identical at any worker count.
    pub threads: Option<usize>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            dir: "artifacts".to_string(),
            exec: ArtifactExec::Auto,
            policy: ShapePolicy::default(),
            meter: KernelMeter::new(),
            threads: None,
        }
    }
}

/// Which pass a `CompressX` execution is accounted to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PassKind {
    /// scan-phase shard compress
    Scan,
    /// SELECT candidate-round gathered compress
    Select,
}

/// Thread-safe kernel-suite telemetry, shared with the session plumbing
/// the way [`crate::net::ByteMeter`] is: lowering-cache behavior, pass
/// counts per kernel kind, and peak resident padded-block bytes. The
/// peak is the memory-regression handle: in a sharded artifact session
/// it must track `O(shard_m·N_p)`, not `O(M·N_p)`.
#[derive(Clone, Debug, Default)]
pub struct KernelMeter {
    inner: Arc<MeterInner>,
}

#[derive(Debug, Default)]
struct MeterInner {
    lowered: AtomicU64,
    cache_hits: AtomicU64,
    xside_passes: AtomicU64,
    yside_passes: AtomicU64,
    select_passes: AtomicU64,
    irls_base_passes: AtomicU64,
    irls_shard_passes: AtomicU64,
    cur_block_bytes: AtomicU64,
    peak_block_bytes: AtomicU64,
    tile_passes: AtomicU64,
    peak_tile_threads: AtomicU64,
}

impl KernelMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn record_lower(&self) {
        self.inner.lowered.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_hit(&self) {
        self.inner.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_pass(&self, kind: KernelKind, pass: PassKind) {
        let slot = match (kind, pass) {
            (KernelKind::CompressX, PassKind::Scan) => &self.inner.xside_passes,
            (KernelKind::CompressXy, _) => &self.inner.yside_passes,
            _ => &self.inner.select_passes,
        };
        slot.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_irls_base(&self) {
        self.inner.irls_base_passes.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_irls_shard(&self) {
        self.inner.irls_shard_passes.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn enter_block(&self, bytes: u64) {
        let cur = self.inner.cur_block_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.peak_block_bytes.fetch_max(cur, Ordering::Relaxed);
    }

    pub(crate) fn exit_block(&self, bytes: u64) {
        self.inner.cur_block_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub(crate) fn record_tiles(&self, tiles: u64, threads: u64) {
        self.inner.tile_passes.fetch_add(tiles, Ordering::Relaxed);
        self.inner.peak_tile_threads.fetch_max(threads, Ordering::Relaxed);
    }

    /// Distinct entries lowered (compiled / planned) so far.
    pub fn lowered_entries(&self) -> u64 {
        self.inner.lowered.load(Ordering::Relaxed)
    }

    /// Dispatches served from the lowering cache.
    pub fn cache_hits(&self) -> u64 {
        self.inner.cache_hits.load(Ordering::Relaxed)
    }

    /// Scan-phase `CompressX` executions — one per shard, **independent
    /// of T** (the trait-batching claim asserted by the conformance
    /// matrix).
    pub fn xside_passes(&self) -> u64 {
        self.inner.xside_passes.load(Ordering::Relaxed)
    }

    /// `CompressXy` executions — one per session.
    pub fn yside_passes(&self) -> u64 {
        self.inner.yside_passes.load(Ordering::Relaxed)
    }

    /// SELECT-phase executions (candidate gather + promote rounds).
    pub fn select_passes(&self) -> u64 {
        self.inner.select_passes.load(Ordering::Relaxed)
    }

    /// IRLS base-entry executions — one per secure IRLS round.
    pub fn irls_base_passes(&self) -> u64 {
        self.inner.irls_base_passes.load(Ordering::Relaxed)
    }

    /// IRLS weighted-shard executions — one per shard of the single
    /// weighted pass at the final β, **independent of T**.
    pub fn irls_shard_passes(&self) -> u64 {
        self.inner.irls_shard_passes.load(Ordering::Relaxed)
    }

    /// Peak bytes of padded kernel blocks resident at once.
    pub fn peak_block_bytes(&self) -> u64 {
        self.inner.peak_block_bytes.load(Ordering::Relaxed)
    }

    /// Canonical sample-tile partials accumulated across all compress
    /// dispatches (a deterministic function of `(N, K)` per pass —
    /// never of thread count).
    pub fn tile_passes(&self) -> u64 {
        self.inner.tile_passes.load(Ordering::Relaxed)
    }

    /// Widest worker-thread budget any compress dispatch ran with.
    pub fn peak_tile_threads(&self) -> u64 {
        self.inner.peak_tile_threads.load(Ordering::Relaxed)
    }
}

/// The reference executor: pure-Rust execution of the parameterized
/// suite under the exact padding contract of the lowered artifacts, with
/// per-element accumulation order identical to the streaming kernels in
/// [`crate::scan::compressed`] — bit-identical outputs by construction
/// (padded rows/columns contribute exact zeros; see module docs).
#[derive(Debug)]
pub struct RefExec {
    policy: ShapePolicy,
    meter: KernelMeter,
    lowered: Mutex<BTreeSet<EntryKey>>,
    /// worker budget for the tiled compress kernels (None = auto);
    /// result-neutral by the canonical-fold contract
    threads: Option<usize>,
}

impl RefExec {
    pub fn new(
        policy: ShapePolicy,
        meter: KernelMeter,
        threads: Option<usize>,
    ) -> anyhow::Result<RefExec> {
        policy.validate()?;
        Ok(RefExec { policy, meter, lowered: Mutex::new(BTreeSet::new()), threads })
    }

    pub fn policy(&self) -> &ShapePolicy {
        &self.policy
    }

    pub fn meter(&self) -> KernelMeter {
        self.meter.clone()
    }

    /// Entries lowered (planned) so far.
    pub fn lowered_count(&self) -> usize {
        self.lowered.lock().expect("lowering cache poisoned").len()
    }

    /// Lowering-cache touch: first dispatch of a key "lowers" it (for
    /// the reference executor, planning the padded loop; for PJRT,
    /// compiling the artifact), later dispatches hit the cache.
    fn touch(&self, key: EntryKey) {
        let mut cache = self.lowered.lock().expect("lowering cache poisoned");
        if cache.insert(key) {
            self.meter.record_lower();
        } else {
            self.meter.record_hit();
        }
    }

    fn ensure_k(&self, k: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            k <= self.policy.k_pad,
            "K={k} exceeds entry k_pad={} (raise --entry-k-pad / re-run `make artifacts`)",
            self.policy.k_pad
        );
        Ok(())
    }

    /// Trait-batched covariate-side entry: `(YᵀY, CᵀY, CᵀC)` with the
    /// trait axis padded to the canonical batch and sliced back.
    pub fn compress_xy(
        &self,
        ys: &Matrix,
        c: &Matrix,
    ) -> anyhow::Result<(Vec<f64>, Matrix, Matrix)> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n, "C rows != N");
        anyhow::ensure!(ys.cols >= 1, "need at least one trait column");
        let (k, t) = (c.cols, ys.cols);
        self.ensure_k(k)?;
        let kp = self.policy.k_pad;
        let tc = self.policy.canon_traits(t);
        let key = self.policy.canon_key(KernelKind::CompressXy, 0, t);
        self.touch(key);
        self.meter.record_pass(KernelKind::CompressXy, PassKind::Scan);

        // Modeled working set of the lowered entry: one canonical sample
        // tile of the padded inputs plus the padded outputs. Tile height
        // is the deterministic `canonical_tile_rows(K)` — never the
        // thread count — so metering is machine-independent.
        let th = n.min(canonical_tile_rows(k));
        let ntiles = n.div_ceil(canonical_tile_rows(k)).max(1);
        let block_bytes = 8 * (th * (tc + kp) + tc + kp * tc + kp * kp) as u64;
        self.meter.enter_block(block_bytes);
        self.meter.record_tiles(ntiles as u64, effective_threads(self.threads) as u64);
        // The shared canonical tiled kernel on the *unpadded* inputs:
        // bit-identity with `compress_base` by construction, and no
        // padded N×·· slabs are ever materialized (padded lanes would
        // only feed the sliced-away outputs — the padding is a lowering
        // contract, not a numeric one).
        let (yty, cty) = compress_yside(ys, c, None, self.threads);
        let ctc = c.gram();
        self.meter.exit_block(block_bytes);
        Ok((yty, cty, ctc))
    }

    /// Shard-width-parameterized variant-side entry over columns
    /// `[j0, j1)` of `x`, all `T` traits in one pass.
    pub fn compress_x(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        j0: usize,
        j1: usize,
        pass: PassKind,
    ) -> anyhow::Result<VariantBlockStats> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n && x.rows == n, "row mismatch");
        anyhow::ensure!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
        anyhow::ensure!(ys.cols >= 1, "need at least one trait column");
        let (k, t, w) = (c.cols, ys.cols, j1 - j0);
        self.ensure_k(k)?;
        if w == 0 {
            // zero-width shard of an empty plan: nothing to lower
            return Ok(VariantBlockStats {
                j0,
                xty: Matrix::zeros(0, t),
                xtx: vec![],
                ctx: Matrix::zeros(k, 0),
            });
        }
        let kp = self.policy.k_pad;
        let wc = self.policy.canon_width(w);
        let tc = self.policy.canon_traits(t);
        let key = self.policy.canon_key(KernelKind::CompressX, w, t);
        self.touch(key);
        self.meter.record_pass(KernelKind::CompressX, pass);

        // Modeled working set: one canonical sample tile of the padded
        // inputs plus the padded outputs — `O(tile·wc)`, freed at exit.
        let th = n.min(canonical_tile_rows(k));
        let ntiles = n.div_ceil(canonical_tile_rows(k)).max(1);
        let block_bytes = 8 * (th * (wc + tc + kp) + wc * tc + wc + kp * wc) as u64;
        self.meter.enter_block(block_bytes);
        self.meter.record_tiles(ntiles as u64, effective_threads(self.threads) as u64);
        // The shared canonical tiled kernel on the *unpadded* inputs —
        // the exact per-element fold of `compress_variant_block`
        // (ascending canonical tiles, samples ascending within a tile),
        // so artifact-mode outputs are bit-identical to the Rust path by
        // construction at any thread count. One column chunk of the
        // canonical width keeps the scratch layout of the lowered entry.
        let vb = compress_variant_block_opts(ys, c, x, j0, j1, wc, None, self.threads);
        self.meter.exit_block(block_bytes);
        Ok(vb)
    }

    /// IRLS base entry: the per-round weighted covariate-side compress
    /// `(CᵀWC | CᵀWz | dev)` per trait at the broadcast `β`. Served by
    /// the same canonical tiled fold as the streaming kernel —
    /// bit-identical to the Rust compute path at any worker count, which
    /// the logistic conformance cells pin down.
    pub fn compress_irls_base(
        &self,
        ys: &Matrix,
        c: &Matrix,
        beta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n, "C rows != N");
        anyhow::ensure!(ys.cols >= 1, "need at least one trait column");
        let (k, t) = (c.cols, ys.cols);
        self.ensure_k(k)?;
        anyhow::ensure!(
            beta.len() == t * k,
            "beta length {} != T·K = {}",
            beta.len(),
            t * k
        );
        let kp = self.policy.k_pad;
        let tc = self.policy.canon_traits(t);
        let key = self.policy.canon_key(KernelKind::CompressIrls, 0, t);
        self.touch(key);
        self.meter.record_irls_base();

        // Modeled working set: one canonical sample tile of the padded
        // inputs plus the padded per-trait outputs (K²+K+1 lanes each).
        let th = n.min(canonical_tile_rows(k));
        let ntiles = n.div_ceil(canonical_tile_rows(k)).max(1);
        let block_bytes = 8 * (th * (tc + kp) + tc * (kp * kp + kp + 1)) as u64;
        self.meter.enter_block(block_bytes);
        self.meter.record_tiles(ntiles as u64, effective_threads(self.threads) as u64);
        let flat = irls_base_kernel(ys, c, beta, None, self.threads);
        self.meter.exit_block(block_bytes);
        Ok(flat)
    }

    /// IRLS weighted shard entry over columns `[j0, j1)` of `x` at the
    /// final `β̂`: per trait and variant `(score | diag XᵀWX | CᵀWX)`.
    pub fn compress_irls_shard(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        beta: &[f64],
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<Vec<f64>> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n && x.rows == n, "row mismatch");
        anyhow::ensure!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
        anyhow::ensure!(ys.cols >= 1, "need at least one trait column");
        let (k, t, w) = (c.cols, ys.cols, j1 - j0);
        self.ensure_k(k)?;
        anyhow::ensure!(
            beta.len() == t * k,
            "beta length {} != T·K = {}",
            beta.len(),
            t * k
        );
        if w == 0 {
            // zero-width shard of an empty plan: nothing to lower
            return Ok(Vec::new());
        }
        let kp = self.policy.k_pad;
        let wc = self.policy.canon_width(w);
        let tc = self.policy.canon_traits(t);
        let key = self.policy.canon_key(KernelKind::CompressIrls, w, t);
        self.touch(key);
        self.meter.record_irls_shard();

        let th = n.min(canonical_tile_rows(k));
        let ntiles = n.div_ceil(canonical_tile_rows(k)).max(1);
        let block_bytes = 8 * (th * (wc + tc + kp) + tc * wc * (2 + kp)) as u64;
        self.meter.enter_block(block_bytes);
        self.meter.record_tiles(ntiles as u64, effective_threads(self.threads) as u64);
        let flat = irls_shard_kernel(ys, c, x, beta, j0, j1, None, self.threads);
        self.meter.exit_block(block_bytes);
        Ok(flat)
    }

    /// Gathered-columns SELECT entry: cross-products of column `j` of
    /// `x` against the gathered shortlist `xs`, padded to the canonical
    /// width and sliced back.
    pub fn select_gather(&self, x: &Matrix, j: usize, xs: &Matrix) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(j < x.cols, "variant {j} out of range");
        anyhow::ensure!(x.rows == xs.rows, "row mismatch");
        anyhow::ensure!(xs.cols >= 1, "empty shortlist");
        let h = xs.cols;
        let hc = self.policy.canon_width(h);
        let key = self.policy.canon_key(KernelKind::SelectGather, h, 1);
        self.touch(key);
        self.meter.record_pass(KernelKind::SelectGather, PassKind::Select);

        let block_bytes = 8 * (xs.rows * hc + hc) as u64;
        self.meter.enter_block(block_bytes);
        let xs_p = pad_cols(xs, hc);
        // same accumulation (and zero-skip) as the pure-Rust kernel
        let mut v = cross_products(x, j, &xs_p);
        self.meter.exit_block(block_bytes);
        v.truncate(h);
        Ok(v)
    }
}

/// Zero-pad a matrix on the right to `cols` columns.
fn pad_cols(a: &Matrix, cols: usize) -> Matrix {
    debug_assert!(cols >= a.cols);
    let mut out = Matrix::zeros(a.rows, cols);
    for i in 0..a.rows {
        out.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::{compress_base, compress_variant_block};
    use crate::util::rng::Rng;

    fn make(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        (Matrix::randn(n, t, &mut rng), c, Matrix::randn(n, m, &mut rng))
    }

    fn exec() -> RefExec {
        RefExec::new(ShapePolicy::default(), KernelMeter::new(), None).unwrap()
    }

    #[test]
    fn canonical_rounding() {
        let p = ShapePolicy::default();
        assert_eq!(p.canon_width(1), 64);
        assert_eq!(p.canon_width(64), 64);
        assert_eq!(p.canon_width(65), 256);
        assert_eq!(p.canon_width(4096), 4096);
        // beyond the ladder: round up to a multiple of the top rung
        assert_eq!(p.canon_width(5000), 8192);
        assert_eq!(p.canon_traits(1), 1);
        assert_eq!(p.canon_traits(5), 16);
        assert_eq!(p.canon_traits(200), 256);
    }

    #[test]
    fn policy_validation() {
        assert!(ShapePolicy::default().validate().is_ok());
        let bad = ShapePolicy { widths: vec![64, 64], ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ShapePolicy { trait_batches: vec![], ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ShapePolicy { widths: vec![0, 4], ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn entry_names_and_suite() {
        let p = ShapePolicy {
            widths: vec![8, 32],
            trait_batches: vec![1, 4],
            k_pad: 8,
        };
        let key = p.canon_key(KernelKind::CompressX, 7, 3);
        assert_eq!(key.entry_name(), "compress_x.w8.t4");
        assert_eq!(
            p.canon_key(KernelKind::CompressXy, 99, 1).entry_name(),
            "compress_xy.t1"
        );
        assert_eq!(
            p.canon_key(KernelKind::SelectGather, 9, 7).entry_name(),
            "select_gather.h32"
        );
        assert_eq!(
            p.canon_key(KernelKind::CompressIrls, 0, 3).entry_name(),
            "compress_irls.t4"
        );
        assert_eq!(
            p.canon_key(KernelKind::CompressIrls, 7, 3).entry_name(),
            "compress_irls.w8.t4"
        );
        // suite: |T|·(2 + 2·|W|) compress entries (xy + irls base, and
        // per width an x + irls shard entry) + |W| select entries
        assert_eq!(p.suite().len(), 2 * (2 + 2 * 2) + 2);
    }

    #[test]
    fn compress_xy_bit_identical_to_rust_base() {
        let (ys, c, _) = make(83, 5, 3, 7, 9001);
        let (yty, cty, ctc) = exec().compress_xy(&ys, &c).unwrap();
        let base = compress_base(&ys, &c);
        assert_eq!(yty.len(), 7);
        for tt in 0..7 {
            assert_eq!(yty[tt].to_bits(), base.yty[tt].to_bits(), "yty {tt}");
        }
        assert_eq!(cty.data, base.cty.data);
        assert_eq!(ctc.data, base.ctc.data);
    }

    #[test]
    fn compress_x_bit_identical_to_rust_shard() {
        let (ys, c, x) = make(70, 4, 41, 3, 9002);
        let e = exec();
        for (j0, j1) in [(0usize, 41usize), (0, 7), (7, 40), (40, 41)] {
            let fast = e.compress_x(&ys, &c, &x, j0, j1, PassKind::Scan).unwrap();
            let slow = compress_variant_block(&ys, &c, &x, j0, j1, 16, Some(2));
            assert_eq!(fast.xty.data, slow.xty.data, "xty {j0}..{j1}");
            assert_eq!(fast.xtx, slow.xtx, "xtx {j0}..{j1}");
            assert_eq!(fast.ctx.data, slow.ctx.data, "ctx {j0}..{j1}");
        }
    }

    #[test]
    fn select_gather_bit_identical_to_rust_kernel() {
        let (_, _, x) = make(60, 2, 12, 1, 9003);
        let xs = x.gather_cols(&[1, 4, 9]);
        let fast = exec().select_gather(&x, 4, &xs).unwrap();
        let slow = cross_products(&x, 4, &xs);
        assert_eq!(fast.len(), 3);
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn lowering_cache_dedups_ragged_shapes() {
        let (ys, c, x) = make(50, 3, 30, 2, 9004);
        let e = exec();
        // three ragged shards, all canonicalized to the w=64 entry
        for (j0, j1) in [(0usize, 10usize), (10, 23), (23, 30)] {
            e.compress_x(&ys, &c, &x, j0, j1, PassKind::Scan).unwrap();
        }
        assert_eq!(e.lowered_count(), 1);
        let m = e.meter();
        assert_eq!(m.lowered_entries(), 1);
        assert_eq!(m.cache_hits(), 2);
        assert_eq!(m.xside_passes(), 3);
    }

    #[test]
    fn meter_tracks_peak_block_bytes() {
        let (ys, c, x) = make(40, 3, 100, 1, 9005);
        let e = exec();
        e.compress_x(&ys, &c, &x, 0, 10, PassKind::Scan).unwrap();
        let narrow = e.meter().peak_block_bytes();
        assert!(narrow > 0);
        e.compress_x(&ys, &c, &x, 0, 100, PassKind::Scan).unwrap();
        let wide = e.meter().peak_block_bytes();
        // canon(10)=64 vs canon(100)=256 input blocks
        assert!(wide > narrow, "peak should grow with shard width: {narrow} vs {wide}");
    }

    /// The executor's worker budget is result-neutral: a 4-thread
    /// executor reproduces the single-thread executor bit-for-bit, while
    /// the meter's tile telemetry stays a deterministic function of
    /// `(N, K)` alone.
    #[test]
    fn executor_thread_count_is_result_neutral_and_tiles_metered() {
        let (ys, c, x) = make(900, 3, 40, 2, 9008);
        let serial = RefExec::new(ShapePolicy::default(), KernelMeter::new(), Some(1)).unwrap();
        let par = RefExec::new(ShapePolicy::default(), KernelMeter::new(), Some(4)).unwrap();
        let a = serial.compress_x(&ys, &c, &x, 0, 40, PassKind::Scan).unwrap();
        let b = par.compress_x(&ys, &c, &x, 0, 40, PassKind::Scan).unwrap();
        assert_eq!(a.xty.data, b.xty.data);
        assert_eq!(a.xtx, b.xtx);
        assert_eq!(a.ctx.data, b.ctx.data);
        let (yty_a, cty_a, _) = serial.compress_xy(&ys, &c).unwrap();
        let (yty_b, cty_b, _) = par.compress_xy(&ys, &c).unwrap();
        assert_eq!(yty_a, yty_b);
        assert_eq!(cty_a.data, cty_b.data);
        // tile accounting: both executors ran the same canonical tiles
        // (900 rows / canonical_tile_rows(3) per pass, two passes), and
        // each reports its own worker budget
        let tiles_per_pass = 900u64.div_ceil(canonical_tile_rows(3) as u64);
        assert_eq!(serial.meter().tile_passes(), 2 * tiles_per_pass);
        assert_eq!(par.meter().tile_passes(), 2 * tiles_per_pass);
        assert_eq!(serial.meter().peak_tile_threads(), 1);
        assert_eq!(par.meter().peak_tile_threads(), 4);
    }

    #[test]
    fn k_pad_overflow_rejected() {
        let (ys, c, x) = make(20, 5, 4, 1, 9006);
        let policy = ShapePolicy { k_pad: 4, ..Default::default() };
        let e = RefExec::new(policy, KernelMeter::new(), None).unwrap();
        assert!(e.compress_xy(&ys, &c).is_err());
        assert!(e.compress_x(&ys, &c, &x, 0, 4, PassKind::Scan).is_err());
    }

    #[test]
    fn compress_irls_entries_bit_identical_to_rust_kernels() {
        let (mut ys, c, x) = make(91, 4, 23, 2, 9009);
        for v in ys.data.iter_mut() {
            *v = if *v > 0.0 { 1.0 } else { 0.0 };
        }
        let beta: Vec<f64> = (0..8).map(|i| 0.05 * (i as f64) - 0.1).collect();
        let e = exec();
        let fast = e.compress_irls_base(&ys, &c, &beta).unwrap();
        let slow = irls_base_kernel(&ys, &c, &beta, None, Some(3));
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (j0, j1) in [(0usize, 23usize), (0, 7), (7, 23)] {
            let fast = e.compress_irls_shard(&ys, &c, &x, &beta, j0, j1).unwrap();
            let slow = irls_shard_kernel(&ys, &c, &x, &beta, j0, j1, None, Some(2));
            assert_eq!(fast.len(), slow.len(), "{j0}..{j1}");
            for (a, b) in fast.iter().zip(&slow) {
                assert_eq!(a.to_bits(), b.to_bits(), "{j0}..{j1}");
            }
        }
        let m = e.meter();
        assert_eq!(m.irls_base_passes(), 1);
        assert_eq!(m.irls_shard_passes(), 3);
        assert_eq!(m.xside_passes(), 0);
        // one base entry + one w=64 shard entry; ragged shards dedup
        assert_eq!(e.lowered_count(), 2);
    }

    #[test]
    fn compress_irls_rejects_bad_shapes() {
        let (mut ys, c, x) = make(30, 3, 5, 2, 9010);
        for v in ys.data.iter_mut() {
            *v = if *v > 0.0 { 1.0 } else { 0.0 };
        }
        let e = exec();
        assert!(e.compress_irls_base(&ys, &c, &[0.0; 5]).is_err(), "bad beta len");
        assert!(e.compress_irls_shard(&ys, &c, &x, &[0.0; 6], 3, 2).is_err(), "bad range");
        let empty = e.compress_irls_shard(&ys, &c, &x, &[0.0; 6], 2, 2).unwrap();
        assert!(empty.is_empty(), "zero-width shard is a no-op");
        assert_eq!(e.meter().irls_shard_passes(), 0);
    }

    #[test]
    fn zero_width_shard_is_noop() {
        let (ys, c, x) = make(20, 3, 4, 2, 9007);
        let e = exec();
        let vb = e.compress_x(&ys, &c, &x, 2, 2, PassKind::Scan).unwrap();
        assert_eq!(vb.width(), 0);
        assert_eq!(vb.t(), 2);
        assert_eq!(e.lowered_count(), 0, "no entry lowered for empty shard");
    }
}
