//! The PJRT-backed artifact engine: compile-once, execute-many wrappers
//! over the CPU client, dispatching the parameterized kernel suite.
//!
//! Every dispatch canonicalizes its requested shape through the
//! [`ShapePolicy`] and looks the entry up in the lowering cache; entries
//! present in the artifact manifest are compiled on first use, and any
//! entry the artifact set lacks falls back to the reference executor
//! ([`RefExec`]) for that call — so partially-lowered artifact sets (or
//! legacy two-entry sets predating the suite) degrade gracefully instead
//! of erroring. All padding/slicing follows the same contract as the
//! reference executor; PJRT results match the Rust kernels to fp
//! tolerance (block-level accumulation), while the reference executor is
//! bit-identical.

use super::kernels::{
    ArtifactExec, EngineOptions, KernelKind, KernelMeter, PassKind, RefExec,
    ShapePolicy,
};
use super::manifest::Manifest;
use crate::linalg::{householder_qr, Matrix};
use crate::scan::{BaseStats, CompressedParty, VariantBlockStats};
use crate::stats::{t_two_sided_p, AssocResult};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

/// PJRT state: client plus the entry lowering cache. `!Send` by
/// construction (PJRT raw pointers); create one per party thread.
struct Pjrt {
    client: xla::PjRtClient,
    /// entry name → compiled executable, compiled on first dispatch
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
}

/// Compiled artifact engine.
pub struct Engine {
    pub manifest: Option<Manifest>,
    pjrt: Option<Pjrt>,
    exec: RefExec,
}

impl Engine {
    /// Load `<dir>/manifest.json` and bring up the PJRT CPU client.
    /// Entries compile lazily on first dispatch (the parameterized suite
    /// can hold dozens of shapes; a session touches a handful).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        Self::open_pjrt(dir.as_ref(), &ShapePolicy::default(), KernelMeter::new())
    }

    /// Open an engine per the requested executor.
    pub fn open(opts: &EngineOptions) -> anyhow::Result<Engine> {
        match opts.exec {
            ArtifactExec::Pjrt => {
                Self::open_pjrt(Path::new(&opts.dir), &opts.policy, opts.meter.clone())
            }
            ArtifactExec::Auto => {
                match Self::open_pjrt(Path::new(&opts.dir), &opts.policy, opts.meter.clone())
                {
                    Ok(e) => Ok(e),
                    Err(_) => Self::reference(opts.policy.clone(), opts.meter.clone()),
                }
            }
            ArtifactExec::Reference => {
                Self::reference(opts.policy.clone(), opts.meter.clone())
            }
        }
    }

    fn open_pjrt(dir: &Path, policy: &ShapePolicy, meter: KernelMeter) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut policy = policy.clone();
        // Compiled entries are fixed-shape: the artifact set's geometry
        // is authoritative, never the requested policy (a K too large
        // for it fails at dispatch with a re-run-make-artifacts error).
        policy.k_pad = manifest.k_pad;
        if let Some(w) = &manifest.widths {
            policy.widths = w.clone();
        }
        if let Some(t) = &manifest.trait_batches {
            policy.trait_batches = t.clone();
        }
        Ok(Engine {
            manifest: Some(manifest),
            pjrt: Some(Pjrt { client, executables: RefCell::new(BTreeMap::new()) }),
            exec: RefExec::new(policy, meter, None)?,
        })
    }

    /// Reference engine with an explicit policy (tests/benches).
    pub fn reference(policy: ShapePolicy, meter: KernelMeter) -> anyhow::Result<Engine> {
        Ok(Engine { manifest: None, pjrt: None, exec: RefExec::new(policy, meter, None)? })
    }

    /// Entries lowered (compiled / planned) so far.
    pub fn entry_count(&self) -> usize {
        match &self.pjrt {
            Some(p) => p.executables.borrow().len() + self.exec.lowered_count(),
            None => self.exec.lowered_count(),
        }
    }

    pub fn platform(&self) -> String {
        match &self.pjrt {
            Some(p) => p.client.platform_name(),
            None => "reference".to_string(),
        }
    }

    /// Shared kernel-suite telemetry.
    pub fn meter(&self) -> KernelMeter {
        self.exec.meter()
    }

    pub fn policy(&self) -> &ShapePolicy {
        self.exec.policy()
    }

    /// Compile (or fetch) the executable for an entry name; `None` when
    /// the artifact set does not carry it (→ reference fallback). First
    /// compilation counts as a lowering, later dispatches as cache hits
    /// — the same accounting the reference executor keeps.
    fn entry(&self, name: &str) -> anyhow::Result<Option<()>> {
        let (Some(pjrt), Some(manifest)) = (&self.pjrt, &self.manifest) else {
            return Ok(None);
        };
        if pjrt.executables.borrow().contains_key(name) {
            self.exec.meter().record_hit();
            return Ok(Some(()));
        }
        let Some(path) = manifest.entry_path_opt(name) else {
            return Ok(None);
        };
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {}", path.display()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = pjrt.client.compile(&comp)?;
        pjrt.executables.borrow_mut().insert(name.to_string(), exe);
        self.exec.meter().record_lower();
        Ok(Some(()))
    }

    /// Execute a compiled entry returning the decomposed output tuple.
    fn run(&self, name: &str, args: &[&xla::Literal]) -> anyhow::Result<Vec<Vec<f64>>> {
        let pjrt = self.pjrt.as_ref().expect("run without pjrt");
        let cache = pjrt.executables.borrow();
        let exe = cache
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry `{name}` not compiled"))?;
        let result = exe.execute::<&xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.into_iter().map(|p| Ok(p.to_vec::<f64>()?)).collect()
    }

    /// Variant-independent statistics through the trait-batched
    /// `compress_xy` entry (one Y-side pass for all `T` traits). `R_p`
    /// (plaintext-mode TSQR input only) is computed host-side.
    pub fn compress_base(&self, ys: &Matrix, c: &Matrix) -> anyhow::Result<BaseStats> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n, "row mismatch");
        let (k, t) = (c.cols, ys.cols);
        let policy = self.exec.policy().clone();
        let kp = policy.k_pad;
        let tc = policy.canon_traits(t);
        let key = policy.canon_key(KernelKind::CompressXy, 0, t);
        if self.entry(&key.entry_name())?.is_none() {
            return {
                let (yty, cty, ctc) = self.exec.compress_xy(ys, c)?;
                Ok(BaseStats { n, yty, cty, ctc, r: householder_qr(c).r })
            };
        }
        anyhow::ensure!(
            k <= kp,
            "K={k} exceeds artifact k_pad={kp}; re-run `make artifacts` with --k-pad ≥ {k}"
        );
        let meter = self.exec.meter();
        meter.record_pass(KernelKind::CompressXy, PassKind::Scan);
        let nb = self.manifest.as_ref().map_or(n.max(1), |m| m.n_block);
        let n_blocks = n.div_ceil(nb).max(1);
        let block_bytes = 8 * (nb * (tc + kp) + tc + kp * tc + kp * kp) as u64;
        meter.enter_block(block_bytes);

        let mut yty = vec![0.0; tc];
        let mut cty_pad = vec![0.0; kp * tc];
        let mut ctc = vec![0.0; kp * kp];
        let mut y_buf = vec![0.0f64; nb * tc];
        let mut c_buf = vec![0.0f64; nb * kp];
        for bi in 0..n_blocks {
            let r0 = bi * nb;
            let r1 = (r0 + nb).min(n);
            pack_rows(ys, r0, r1, tc, &mut y_buf);
            pack_rows(c, r0, r1, kp, &mut c_buf);
            let y_lit = xla::Literal::vec1(&y_buf).reshape(&[nb as i64, tc as i64])?;
            let c_lit = xla::Literal::vec1(&c_buf).reshape(&[nb as i64, kp as i64])?;
            let out = self.run(&key.entry_name(), &[&y_lit, &c_lit])?;
            for (a, b) in yty.iter_mut().zip(&out[0]) {
                *a += b;
            }
            for (a, b) in cty_pad.iter_mut().zip(&out[1]) {
                *a += b;
            }
            for (a, b) in ctc.iter_mut().zip(&out[2]) {
                *a += b;
            }
        }
        meter.exit_block(block_bytes);
        yty.truncate(t);
        let mut cty_k = Matrix::zeros(k, t);
        for i in 0..k {
            for tt in 0..t {
                cty_k[(i, tt)] = cty_pad[i * tc + tt];
            }
        }
        let mut ctc_k = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                ctc_k[(i, j)] = ctc[i * kp + j];
            }
        }
        Ok(BaseStats { n, yty, cty: cty_k, ctc: ctc_k, r: householder_qr(c).r })
    }

    /// One shard's variant statistics through the shard-width-
    /// parameterized `compress_x` entry — one X-side pass for all `T`
    /// traits, `O(shard_m·N_p)` resident block memory.
    pub fn compress_shard(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<VariantBlockStats> {
        self.compress_x_dispatch(ys, c, x, j0, j1, PassKind::Scan)
    }

    /// SELECT candidate round through the `compress_x` entry family.
    pub fn compress_gathered(
        &self,
        ys: &Matrix,
        c: &Matrix,
        xs: &Matrix,
    ) -> anyhow::Result<VariantBlockStats> {
        self.compress_x_dispatch(ys, c, xs, 0, xs.cols, PassKind::Select)
    }

    fn compress_x_dispatch(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        j0: usize,
        j1: usize,
        pass: PassKind,
    ) -> anyhow::Result<VariantBlockStats> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n && x.rows == n, "row mismatch");
        anyhow::ensure!(j0 <= j1 && j1 <= x.cols, "bad column range {j0}..{j1}");
        let (k, t, w) = (c.cols, ys.cols, j1 - j0);
        let policy = self.exec.policy().clone();
        let key = policy.canon_key(KernelKind::CompressX, w, t);
        if w == 0 || self.entry(&key.entry_name())?.is_none() {
            return self.exec.compress_x(ys, c, x, j0, j1, pass);
        }
        let (kp, wc, tc) = (policy.k_pad, key.shard_w, key.n_traits);
        anyhow::ensure!(
            k <= kp,
            "K={k} exceeds artifact k_pad={kp}; re-run `make artifacts` with --k-pad ≥ {k}"
        );
        let meter = self.exec.meter();
        meter.record_pass(KernelKind::CompressX, pass);
        let nb = self.manifest.as_ref().map_or(n.max(1), |m| m.n_block);
        let n_blocks = n.div_ceil(nb).max(1);
        let block_bytes = 8 * (nb * (wc + tc + kp) + wc * tc + wc + kp * wc) as u64;
        meter.enter_block(block_bytes);

        let mut xty = vec![0.0; wc * tc];
        let mut xtx = vec![0.0; wc];
        let mut ctx = vec![0.0; kp * wc];
        let mut y_buf = vec![0.0f64; nb * tc];
        let mut c_buf = vec![0.0f64; nb * kp];
        let mut x_buf = vec![0.0f64; nb * wc];
        for bi in 0..n_blocks {
            let r0 = bi * nb;
            let r1 = (r0 + nb).min(n);
            pack_rows(ys, r0, r1, tc, &mut y_buf);
            pack_rows(c, r0, r1, kp, &mut c_buf);
            x_buf.fill(0.0);
            for i in 0..(r1 - r0) {
                x_buf[i * wc..i * wc + w].copy_from_slice(&x.row(r0 + i)[j0..j1]);
            }
            let y_lit = xla::Literal::vec1(&y_buf).reshape(&[nb as i64, tc as i64])?;
            let c_lit = xla::Literal::vec1(&c_buf).reshape(&[nb as i64, kp as i64])?;
            let x_lit = xla::Literal::vec1(&x_buf).reshape(&[nb as i64, wc as i64])?;
            let out = self.run(&key.entry_name(), &[&y_lit, &c_lit, &x_lit])?;
            for (a, b) in xty.iter_mut().zip(&out[0]) {
                *a += b;
            }
            for (a, b) in xtx.iter_mut().zip(&out[1]) {
                *a += b;
            }
            for (a, b) in ctx.iter_mut().zip(&out[2]) {
                *a += b;
            }
        }
        meter.exit_block(block_bytes);
        let mut xty_m = Matrix::zeros(w, t);
        for j in 0..w {
            xty_m.row_mut(j).copy_from_slice(&xty[j * tc..j * tc + t]);
        }
        xtx.truncate(w);
        let mut ctx_m = Matrix::zeros(k, w);
        for kk in 0..k {
            ctx_m.row_mut(kk).copy_from_slice(&ctx[kk * wc..kk * wc + w]);
        }
        Ok(VariantBlockStats { j0, xty: xty_m, xtx, ctx: ctx_m })
    }

    /// IRLS base entry (logistic scans): one weighted covariate-side
    /// pass per secure IRLS round. No lowered PJRT entry exists for the
    /// IRLS kernels — the logistic protocol requires **bit-identical**
    /// accumulation across compute modes, so both builds always serve
    /// this from the reference executor.
    pub fn compress_irls_base(
        &self,
        ys: &Matrix,
        c: &Matrix,
        beta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        self.exec.compress_irls_base(ys, c, beta)
    }

    /// IRLS weighted shard pass at the final β̂ (reference executor in
    /// both builds; see [`Self::compress_irls_base`]).
    pub fn compress_irls_shard(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
        beta: &[f64],
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<Vec<f64>> {
        self.exec.compress_irls_shard(ys, c, x, beta, j0, j1)
    }

    /// SELECT promote round through the gathered-columns entry.
    pub fn cross_products(
        &self,
        x: &Matrix,
        j: usize,
        xs: &Matrix,
    ) -> anyhow::Result<Vec<f64>> {
        let policy = self.exec.policy().clone();
        let key = policy.canon_key(KernelKind::SelectGather, xs.cols, 1);
        if self.entry(&key.entry_name())?.is_none() {
            return self.exec.select_gather(x, j, xs);
        }
        anyhow::ensure!(j < x.cols, "variant {j} out of range");
        anyhow::ensure!(x.rows == xs.rows, "row mismatch");
        let meter = self.exec.meter();
        meter.record_pass(KernelKind::SelectGather, PassKind::Select);
        let (n, h, hc) = (x.rows, xs.cols, key.shard_w);
        let nb = self.manifest.as_ref().map_or(n.max(1), |m| m.n_block);
        let n_blocks = n.div_ceil(nb).max(1);
        let block_bytes = 8 * (nb * hc + nb + hc) as u64;
        meter.enter_block(block_bytes);
        let mut v = vec![0.0; hc];
        let mut xj_buf = vec![0.0f64; nb];
        let mut xs_buf = vec![0.0f64; nb * hc];
        for bi in 0..n_blocks {
            let r0 = bi * nb;
            let r1 = (r0 + nb).min(n);
            xj_buf.fill(0.0);
            xs_buf.fill(0.0);
            for i in 0..(r1 - r0) {
                xj_buf[i] = x[(r0 + i, j)];
                xs_buf[i * hc..i * hc + h].copy_from_slice(xs.row(r0 + i));
            }
            let xj_lit = xla::Literal::vec1(&xj_buf);
            let xs_lit = xla::Literal::vec1(&xs_buf).reshape(&[nb as i64, hc as i64])?;
            let out = self.run(&key.entry_name(), &[&xj_lit, &xs_lit])?;
            for (a, b) in v.iter_mut().zip(&out[0]) {
                *a += b;
            }
        }
        meter.exit_block(block_bytes);
        v.truncate(h);
        Ok(v)
    }

    /// Whole-block compress: the base entry plus one full-width shard
    /// entry (single-shot callers / benches).
    pub fn compress_party(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
    ) -> anyhow::Result<CompressedParty> {
        let base = self.compress_base(ys, c)?;
        let vb = self.compress_shard(ys, c, x, 0, x.cols)?;
        Ok(CompressedParty {
            n: base.n,
            yty: base.yty,
            cty: base.cty,
            ctc: base.ctc,
            r: base.r,
            xty: vb.xty,
            xtx: vb.xtx,
            ctx: vb.ctx,
        })
    }

    /// Lemma 3.1 epilogue on aggregates through the `scan_stats`
    /// artifact (legacy fixed-shape entry), with p-values attached on
    /// the Rust side.
    #[allow(clippy::too_many_arguments)]
    pub fn scan_stats(
        &self,
        n: usize,
        k: usize,
        yty: f64,
        xty: &[f64],
        xtx: &[f64],
        qty: &[f64],
        qtx: &Matrix,
    ) -> anyhow::Result<AssocResult> {
        let m = xty.len();
        anyhow::ensure!(xtx.len() == m && qtx.cols == m && qtx.rows == k && qty.len() == k);
        // the legacy fixed-shape entry goes through the same lazy
        // compile-and-cache path as the suite entries
        if self.entry("scan_stats")?.is_none() {
            return self.exec_scan_stats(n, k, yty, xty, xtx, qty, qtx);
        }
        let manifest = self.manifest.as_ref().expect("entry without manifest");
        let (mb, kp) = (manifest.m_block, manifest.k_pad);
        anyhow::ensure!(k <= kp, "K={k} exceeds artifact k_pad={kp}");
        let m_blocks = m.div_ceil(mb).max(1);

        let mut qty_p = vec![0.0; kp];
        qty_p[..k].copy_from_slice(qty);
        let mut beta = vec![f64::NAN; m];
        let mut se = vec![f64::NAN; m];
        let mut t = vec![f64::NAN; m];
        let df = n as f64 - k as f64 - 1.0;

        let mut xty_buf = vec![0.0f64; mb];
        let mut xtx_buf = vec![0.0f64; mb];
        let mut qtx_buf = vec![0.0f64; kp * mb];
        for bj in 0..m_blocks {
            let c0 = bj * mb;
            let c1 = (c0 + mb).min(m);
            let cols = c1 - c0;
            xty_buf.fill(0.0);
            xty_buf[..cols].copy_from_slice(&xty[c0..c1]);
            xtx_buf.fill(0.0);
            xtx_buf[..cols].copy_from_slice(&xtx[c0..c1]);
            qtx_buf.fill(0.0);
            for kk in 0..k {
                let src = &qtx.row(kk)[c0..c1];
                qtx_buf[kk * mb..kk * mb + cols].copy_from_slice(src);
            }
            let args = [
                xla::Literal::scalar(n as f64),
                xla::Literal::scalar(k as f64),
                xla::Literal::scalar(yty),
                xla::Literal::vec1(&xty_buf),
                xla::Literal::vec1(&xtx_buf),
                xla::Literal::vec1(&qty_p),
                xla::Literal::vec1(&qtx_buf).reshape(&[kp as i64, mb as i64])?,
            ];
            let arg_refs: Vec<&xla::Literal> = args.iter().collect();
            let out = self.run("scan_stats", &arg_refs)?;
            for j in 0..cols {
                beta[c0 + j] = out[0][j];
                se[c0 + j] = out[1][j];
                t[c0 + j] = out[2][j];
            }
        }
        let p: Vec<f64> = t
            .iter()
            .map(|&tv| if tv.is_finite() { t_two_sided_p(tv, df) } else { f64::NAN })
            .collect();
        Ok(AssocResult { beta, se, t, p, df })
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_scan_stats(
        &self,
        n: usize,
        k: usize,
        yty: f64,
        xty: &[f64],
        xtx: &[f64],
        qty: &[f64],
        qtx: &Matrix,
    ) -> anyhow::Result<AssocResult> {
        Ok(crate::stats::scan_stats_from_projected_parts(n, k, yty, xty, xtx, qty, qtx))
    }
}

/// Pack rows `[r0, r1)` of `a` into `buf` (`nb × cols` row-major,
/// zero-padded on both axes).
fn pack_rows(a: &Matrix, r0: usize, r1: usize, cols: usize, buf: &mut [f64]) {
    buf.fill(0.0);
    for i in 0..(r1 - r0) {
        let src = a.row(r0 + i);
        buf[i * cols..i * cols + src.len()].copy_from_slice(src);
    }
}
