//! The artifact execution engine: compile-once, execute-many wrappers
//! over the PJRT CPU client.
//!
//! SELECT-phase note: the stepwise rounds touch only `O(H)` gathered
//! shortlist columns (and `O(H)` cross-products per promotion), so the
//! party serves them from the pure-Rust kernels in both compute
//! backends — there is no whole-`M` pass left to lower. A gathered-
//! columns artifact entry is tracked in ROADMAP next to per-shard
//! artifact lowering, for deployments where `N_p·H` is itself large.

use super::manifest::Manifest;
use crate::linalg::{cholesky_upper, Matrix};
use crate::scan::CompressedParty;
use crate::stats::{t_two_sided_p, AssocResult};
use std::collections::BTreeMap;
use std::path::Path;

/// Compiled artifact set. `!Send` by construction (PJRT raw pointers);
/// create one per party thread.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load `<dir>/manifest.json`, compile every entry on the CPU client.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for name in manifest.entries.keys() {
            let path = manifest.entry_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path {}", path.display()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(name.clone(), exe);
        }
        Ok(Engine { manifest, client, executables })
    }

    /// Number of compiled entry points.
    pub fn entry_count(&self) -> usize {
        self.executables.len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry `{name}` not compiled"))
    }

    /// Execute an entry returning the decomposed output tuple as f64 vecs.
    /// Takes borrowed literals so callers can reuse block buffers across
    /// calls without re-allocating.
    fn run(&self, name: &str, args: &[&xla::Literal]) -> anyhow::Result<Vec<Vec<f64>>> {
        let exe = self.exe(name)?;
        let result = exe.execute::<&xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.into_iter().map(|p| Ok(p.to_vec::<f64>()?)).collect()
    }

    /// Compress one party's data through the AOT artifacts. `ys` is the
    /// `N × T` trait matrix; produces the same trait-major
    /// `CompressedParty` as the pure-Rust path (verified by integration
    /// tests to ~1e-12).
    ///
    /// The artifact entries are single-trait, so trait columns are fed
    /// through `compress_yc`/`compress_x` one at a time; the shared
    /// genotype statistics (`X·X`, `CᵀX`, `CᵀC`) are taken from trait 0
    /// only. A trait-batched `compress_xy` entry would amortize the `X`
    /// passes (tracked in ROADMAP next to per-shard artifact lowering).
    pub fn compress_party(
        &self,
        ys: &Matrix,
        c: &Matrix,
        x: &Matrix,
    ) -> anyhow::Result<CompressedParty> {
        let n = ys.rows;
        anyhow::ensure!(c.rows == n && x.rows == n, "row mismatch");
        anyhow::ensure!(ys.cols >= 1, "need at least one trait column");
        let k = c.cols;
        let m = x.cols;
        let t_count = ys.cols;
        let (nb, mb, kp) = (self.manifest.n_block, self.manifest.m_block, self.manifest.k_pad);
        anyhow::ensure!(
            k <= kp,
            "K={k} exceeds artifact k_pad={kp}; re-run `make artifacts` with --k-pad ≥ {k}"
        );

        let n_blocks = n.div_ceil(nb).max(1);
        let m_blocks = m.div_ceil(mb).max(1);

        let mut yty = vec![0.0; t_count];
        let mut cty_pad = vec![0.0; kp * t_count]; // kp rows × T, row-major
        let mut ctc = vec![0.0; kp * kp];
        let mut xty = Matrix::zeros(m, t_count);
        let mut xtx = vec![0.0; m];
        let mut ctx = Matrix::zeros(k, m);

        // Reusable padded buffers.
        let mut y_buf = vec![0.0f64; nb];
        let mut c_buf = vec![0.0f64; nb * kp];
        let mut x_buf = vec![0.0f64; nb * mb];

        for bi in 0..n_blocks {
            let r0 = bi * nb;
            let r1 = (r0 + nb).min(n);
            let rows = r1 - r0;
            // pack C with zero padding
            c_buf.fill(0.0);
            for i in 0..rows {
                let src = c.row(r0 + i);
                c_buf[i * kp..i * kp + k].copy_from_slice(src);
            }
            // build the y/C literals once per sample block — reshape
            // allocates a fresh literal, so it must stay out of the
            // variant loop (EXPERIMENTS.md §Perf iteration 3)
            let c_lit = xla::Literal::vec1(&c_buf).reshape(&[nb as i64, kp as i64])?;
            let mut y_lits = Vec::with_capacity(t_count);
            for tt in 0..t_count {
                y_buf.fill(0.0);
                for i in 0..rows {
                    y_buf[i] = ys[(r0 + i, tt)];
                }
                y_lits.push(xla::Literal::vec1(&y_buf));
            }

            // covariate-side statistics once per sample block per trait
            for (tt, y_lit) in y_lits.iter().enumerate() {
                let out = self.run("compress_yc", &[y_lit, &c_lit])?;
                yty[tt] += out[0][0];
                for i in 0..kp {
                    cty_pad[i * t_count + tt] += out[1][i];
                }
                if tt == 0 {
                    for i in 0..kp * kp {
                        ctc[i] += out[2][i];
                    }
                }
            }

            // variant blocks
            for bj in 0..m_blocks {
                let c0 = bj * mb;
                let c1 = (c0 + mb).min(m);
                let cols = c1 - c0;
                x_buf.fill(0.0);
                for i in 0..rows {
                    let src = &x.row(r0 + i)[c0..c1];
                    x_buf[i * mb..i * mb + cols].copy_from_slice(src);
                }
                let x_lit = xla::Literal::vec1(&x_buf).reshape(&[nb as i64, mb as i64])?;
                for (tt, y_lit) in y_lits.iter().enumerate() {
                    let out = self.run("compress_x", &[y_lit, &c_lit, &x_lit])?;
                    // out: xty (mb), xtx (mb), ctx (kp × mb)
                    for j in 0..cols {
                        xty[(c0 + j, tt)] += out[0][j];
                    }
                    if tt == 0 {
                        for j in 0..cols {
                            xtx[c0 + j] += out[1][j];
                        }
                        for kk in 0..k {
                            let row = ctx.row_mut(kk);
                            for j in 0..cols {
                                row[c0 + j] += out[2][kk * mb + j];
                            }
                        }
                    }
                }
            }
        }

        // Slice covariate padding away.
        let mut cty_k = Matrix::zeros(k, t_count);
        for i in 0..k {
            for tt in 0..t_count {
                cty_k[(i, tt)] = cty_pad[i * t_count + tt];
            }
        }
        let mut ctc_k = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                ctc_k[(i, j)] = ctc[i * kp + j];
            }
        }
        // R_p from the Gram matrix (same positive-diagonal factor as QR).
        let r = cholesky_upper(&ctc_k)?;

        Ok(CompressedParty { n, yty, cty: cty_k, ctc: ctc_k, r, xty, xtx, ctx })
    }

    /// Lemma 3.1 epilogue on aggregates through the artifact, with
    /// p-values attached on the Rust side. `qty`/`qtx` are the projected
    /// statistics (K-dim); all M-sized inputs are blocked and padded.
    pub fn scan_stats(
        &self,
        n: usize,
        k: usize,
        yty: f64,
        xty: &[f64],
        xtx: &[f64],
        qty: &[f64],
        qtx: &Matrix,
    ) -> anyhow::Result<AssocResult> {
        let m = xty.len();
        anyhow::ensure!(xtx.len() == m && qtx.cols == m && qtx.rows == k && qty.len() == k);
        let (mb, kp) = (self.manifest.m_block, self.manifest.k_pad);
        anyhow::ensure!(k <= kp, "K={k} exceeds artifact k_pad={kp}");
        let m_blocks = m.div_ceil(mb).max(1);

        // K-padded projected stats (zero rows contribute nothing).
        let mut qty_p = vec![0.0; kp];
        qty_p[..k].copy_from_slice(qty);

        let mut beta = vec![f64::NAN; m];
        let mut se = vec![f64::NAN; m];
        let mut t = vec![f64::NAN; m];
        let df = n as f64 - k as f64 - 1.0;

        let mut xty_buf = vec![0.0f64; mb];
        let mut xtx_buf = vec![0.0f64; mb];
        let mut qtx_buf = vec![0.0f64; kp * mb];

        for bj in 0..m_blocks {
            let c0 = bj * mb;
            let c1 = (c0 + mb).min(m);
            let cols = c1 - c0;
            xty_buf.fill(0.0);
            xty_buf[..cols].copy_from_slice(&xty[c0..c1]);
            xtx_buf.fill(0.0);
            xtx_buf[..cols].copy_from_slice(&xtx[c0..c1]);
            qtx_buf.fill(0.0);
            for kk in 0..k {
                let src = &qtx.row(kk)[c0..c1];
                qtx_buf[kk * mb..kk * mb + cols].copy_from_slice(src);
            }
            let args = [
                xla::Literal::scalar(n as f64),
                xla::Literal::scalar(k as f64),
                xla::Literal::scalar(yty),
                xla::Literal::vec1(&xty_buf),
                xla::Literal::vec1(&xtx_buf),
                xla::Literal::vec1(&qty_p),
                xla::Literal::vec1(&qtx_buf).reshape(&[kp as i64, mb as i64])?,
            ];
            let arg_refs: Vec<&xla::Literal> = args.iter().collect();
            let out = self.run("scan_stats", &arg_refs)?;
            for j in 0..cols {
                beta[c0 + j] = out[0][j];
                se[c0 + j] = out[1][j];
                t[c0 + j] = out[2][j];
            }
        }
        let p: Vec<f64> = t
            .iter()
            .map(|&tv| if tv.is_finite() { t_two_sided_p(tv, df) } else { f64::NAN })
            .collect();
        Ok(AssocResult { beta, se, t, p, df })
    }
}
