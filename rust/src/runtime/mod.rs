//! PJRT runtime: loads the AOT artifacts and serves the compress /
//! scan-stats hot path from Rust.
//!
//! `make artifacts` (Python, build-time only) writes
//! `artifacts/{compress_x,compress_yc,scan_stats}.hlo.txt` plus
//! `manifest.json` with the block geometry. This module loads the HLO
//! *text* (`HloModuleProto::from_text_file` — the id-renumbering parser;
//! serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1),
//! compiles each entry once on the CPU PJRT client, and exposes typed
//! wrappers that handle the padding/slicing contract:
//!
//! - sample blocks of `n_block` rows; tail blocks are zero-padded (exact:
//!   every statistic is a sum of per-sample products),
//! - covariates zero-padded to `k_pad` columns; the padded rows/cols of
//!   `CᵀX`/`CᵀC` are sliced away before factorization,
//! - variant blocks of `m_block` columns; padded lanes produce NaN in
//!   `scan_stats` and are sliced away.
//!
//! The wrappers are `!Send` (PJRT pointers) — each party thread owns its
//! own [`Engine`], mirroring the one-process-per-party deployment.
//!
//! ## Feature gating
//!
//! The real engine needs the `xla` native bindings, which cannot be
//! vendored. It compiles only with `--features xla-runtime` (after adding
//! the `xla` crate to `rust/Cargo.toml` by hand). Without the feature a
//! stub [`Engine`] with the same API is compiled whose `load` always
//! errors — callers already treat a failed load as "artifacts
//! unavailable, use the pure-Rust compute path", so the whole pipeline
//! (including the sharded scan) works in either build.

mod manifest;

#[cfg(feature = "xla-runtime")]
mod engine;
#[cfg(not(feature = "xla-runtime"))]
#[path = "engine_stub.rs"]
mod engine;

pub use engine::Engine;
pub use manifest::Manifest;
